//! Recursive doubling all-gather (and, by mirroring, recursive halving
//! reduce-scatter) — the hypercube baseline [Thakur et al. 2005]. Works only
//! on power-of-two rank counts, which the paper calls out as a significant
//! constraint for AI workloads.
//!
//! At step `d`, rank `i` exchanges its entire aligned block of `2^d` chunks
//! with partner `i XOR 2^d`; like classic Bruck, the last step moves half of
//! the total data across the largest distance.

use crate::core::{Collective, Error, Result};
use crate::sched::program::{Op, Program};

/// Recursive-doubling all-gather. `n` must be a power of two.
pub fn allgather(n: usize) -> Program {
    try_allgather(n).expect("recursive doubling requires power-of-two nranks")
}

/// Fallible variant used by the generation front-end.
pub fn try_allgather(n: usize) -> Result<Program> {
    if !n.is_power_of_two() {
        return Err(Error::Unsupported(format!(
            "recursive doubling requires a power-of-two rank count, got {n}"
        )));
    }
    let mut p = Program::new(n, Collective::AllGather, "recursive");
    if n <= 1 {
        return Ok(p);
    }
    let k = n.trailing_zeros();
    for d in 0..k {
        let blk = 1usize << d;
        for i in 0..n {
            let partner = i ^ blk;
            // Block of chunks currently held: the 2^d-aligned block around i.
            let base = (i / blk) * blk;
            let send: Vec<usize> = (base..base + blk).collect();
            let pbase = (partner / blk) * blk;
            let recv: Vec<usize> = (pbase..pbase + blk).collect();
            p.push(i, Op::send(partner, send, d as usize));
            p.push(i, Op::recv(partner, recv, false, d as usize));
        }
    }
    Ok(p)
}

/// Recursive-halving reduce-scatter: the mirror of recursive doubling.
pub fn reduce_scatter(n: usize) -> Result<Program> {
    Ok(try_allgather(n)?.mirror())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;

    #[test]
    fn correct_pow2() {
        for k in 0..6 {
            verify_program(&allgather(1 << k)).unwrap();
            verify_program(&reduce_scatter(1 << k).unwrap()).unwrap();
        }
    }

    #[test]
    fn rejects_non_pow2() {
        assert!(try_allgather(6).is_err());
        assert!(try_allgather(7).is_err());
    }

    #[test]
    fn log_steps_and_doubling_payload() {
        let p = allgather(16);
        assert_eq!(p.steps, 4);
        let sizes: Vec<usize> = p
            .rounds()
            .values()
            .map(|ms| ms[0].chunks.len())
            .collect();
        assert_eq!(sizes, vec![1, 2, 4, 8]);
    }

    /// The last step moves half the data to the most distant partner — the
    /// pathology the paper describes for static-routed fabrics.
    #[test]
    fn last_step_is_far_and_fat() {
        let p = allgather(16);
        let rounds = p.rounds();
        let last = rounds.values().last().unwrap();
        for m in last {
            assert_eq!(m.chunks.len(), 8);
            assert_eq!(m.src ^ m.dst, 8);
        }
    }
}
