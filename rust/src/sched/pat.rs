//! PAT — Parallel Aggregated Trees (the paper's contribution).
//!
//! PAT starts from the dimension-reversed Bruck schedule
//! ([`crate::sched::bruck::allgather_far_first`]) and bounds the number of
//! chunks aggregated into any single transfer by the *aggregation factor*
//! `a` (in NCCL terms: how many chunks fit in the pre-mapped intermediate
//! buffer).
//!
//! * `a ≥ ceil((n-1)/2)` — the buffer holds the largest dimension round:
//!   identical to reversed-dimension Bruck, fully aggregated,
//!   `ceil(log2 n)` steps (Fig. 7).
//! * smaller `a` — the schedule becomes a fully-aggregated logarithmic
//!   *top* (dimensions above the `A = 2^⌊log2 a⌋` subtree roots) followed
//!   by the `A` *parallel trees* executed linearly (Figs. 5–9): the
//!   canonical subtree's edges are walked **depth-first, farthest child
//!   first** ("the algorithm starts by sending data far, then
//!   progressively getting closer to the root", Fig. 10), in lockstep
//!   across the `A` subtrees — each round aggregates one chunk per
//!   parallel tree into a single transfer.
//! * `a = 1` — a single tree executed fully linearly: `n-1` steps, each a
//!   full-buffer transfer at ring-like bandwidth (Fig. 10).
//!
//! The depth-first order is what delivers the paper's buffer guarantee
//! ("we will always be able to use intermediate buffers as we will have
//! emptied them before we need to communicate on that same dimension to
//! process data for another rank"): the mirrored reduce-scatter then keeps
//! only O(a + log n) live accumulators, versus Θ(n) for a naive
//! dimension-major order — measured and asserted in the tests, swept in
//! the occupancy bench, and exposed as [`LinearOrder::DimMajor`] for the
//! ablation study (paper P7).
//!
//! Reduce-scatter is the time-and-direction mirror (Fig. 11), obtained via
//! [`Program::mirror`]: nearest dimensions first, reversed tree, reduce on
//! receive, parallel trees before the logarithmic bottom.
//!
//! For non-power-of-two rank counts the lockstep rounds may be partially
//! empty (truncated subtrees); the schedule stays correct and
//! buffer-bounded but can use up to `n-1` steps where perfect packing
//! would use [`crate::core::pat_step_count`]. Power-of-two counts achieve
//! the closed form exactly.

use crate::core::{ceil_log2, Collective, Rank};
use crate::sched::program::{Op, Program};
use crate::sched::tree::FarFirstTree;

/// Phase classification of each PAT step (for Fig. 6-style analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepPhase {
    /// Fully-aggregated step above the parallel-tree roots (the
    /// logarithmic top of the tree).
    Logarithmic,
    /// A lockstep round of the linear phase executed within the parallel
    /// trees.
    Linear,
}

/// Sub-round ordering of the linear phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinearOrder {
    /// The paper's schedule: depth-first (farthest child first) within each
    /// parallel tree — bounded intermediate buffers.
    DepthFirst,
    /// Ablation: dimension-major, farthest offsets first. Same step count
    /// on powers of two, but the mirrored reduce-scatter needs Θ(n)
    /// accumulators (this is why PAT is *not* just "split Bruck rounds").
    DimMajor,
}

/// A PAT schedule round: all transfers cross dimension `dim`; `offsets`
/// are the tree-edge source offsets (≤ aggregation-factor many), i.e. rank
/// `i` sends the chunks rooted at `i - o` for each `o` to rank `i + 2^dim`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PatRound {
    pub dim: u32,
    pub offsets: Vec<usize>,
    pub phase: StepPhase,
}

/// Clamp a requested aggregation factor to the useful range for `n` ranks.
/// The largest useful aggregation is `ceil((n-1)/2)` (the size of the
/// final, distance-1 dimension round).
pub fn clamp_aggregation(n: usize, a: usize) -> usize {
    if n <= 2 {
        return 1;
    }
    let max_useful = (n - 1).div_ceil(2);
    a.clamp(1, max_useful)
}

/// The ordered PAT rounds for `n` ranks with aggregation `a`, paper
/// (depth-first) order.
pub fn rounds(n: usize, a: usize) -> Vec<PatRound> {
    rounds_with(n, a, LinearOrder::DepthFirst)
}

/// The ordered PAT rounds with an explicit linear-phase order.
pub fn rounds_with(n: usize, a: usize, order: LinearOrder) -> Vec<PatRound> {
    let t = FarFirstTree::new(n);
    let Some(dmax) = t.dmax() else {
        return Vec::new();
    };
    let a_req = clamp_aggregation(n, a);
    let full = (n - 1).div_ceil(2);
    let mut out = Vec::new();

    if a_req >= full {
        // Buffer fits every dimension round: exact dimension-reversed
        // Bruck, one round per dimension.
        for d in (0..=dmax).rev() {
            let offsets: Vec<usize> = t.edges_at_dim(d).into_iter().map(|e| e.from).collect();
            if offsets.is_empty() {
                continue;
            }
            let phase = if offsets.len() < a_req {
                StepPhase::Logarithmic
            } else {
                StepPhase::Linear
            };
            out.push(PatRound { dim: d, offsets, phase });
        }
        return out;
    }

    // A parallel trees (power of two), each spanning `span` offsets.
    let a_pow = prev_pow2(a_req);
    let span = (1usize << ceil_log2(n)) / a_pow;
    let top_dim = span.trailing_zeros(); // log2(span)

    // Logarithmic top: dimensions above the subtree roots, one round each.
    for d in (top_dim..=dmax).rev() {
        let offsets: Vec<usize> = t.edges_at_dim(d).into_iter().map(|e| e.from).collect();
        if !offsets.is_empty() {
            out.push(PatRound { dim: d, offsets, phase: StepPhase::Logarithmic });
        }
    }

    // Linear phase within the parallel trees.
    let roots: Vec<usize> = (0..n).step_by(span).collect();
    match order {
        LinearOrder::DepthFirst => {
            // Canonical subtree of `span` offsets, edges in pre-order DFS,
            // farthest child first, executed in lockstep across subtrees.
            let canon = FarFirstTree::new(span);
            let mut edges = Vec::with_capacity(span.saturating_sub(1));
            dfs_edges(&canon, 0, &mut edges);
            for (o_from, d) in edges {
                let hop = 1usize << d;
                let offsets: Vec<usize> = roots
                    .iter()
                    .map(|r| r + o_from)
                    .filter(|&o| o + hop < n)
                    .collect();
                if !offsets.is_empty() {
                    out.push(PatRound { dim: d, offsets, phase: StepPhase::Linear });
                }
            }
        }
        LinearOrder::DimMajor => {
            // Ablation: split each dimension round into blocks of a_pow,
            // farthest offsets first.
            for d in (0..top_dim).rev() {
                let mut offsets: Vec<usize> =
                    t.edges_at_dim(d).into_iter().map(|e| e.from).collect();
                offsets.reverse();
                for block in offsets.chunks(a_pow) {
                    out.push(PatRound {
                        dim: d,
                        offsets: block.to_vec(),
                        phase: StepPhase::Linear,
                    });
                }
            }
        }
    }
    out
}

/// Pre-order DFS over the canonical subtree, farthest child first,
/// emitting `(source offset, dim)` edges.
fn dfs_edges(t: &FarFirstTree, o: usize, out: &mut Vec<(usize, u32)>) {
    for c in t.children(o) {
        out.push((o, t.edge_dim(c)));
        dfs_edges(t, c, out);
    }
}

fn prev_pow2(x: usize) -> usize {
    debug_assert!(x >= 1);
    1usize << (usize::BITS - 1 - x.leading_zeros())
}

/// PAT all-gather program for `n` ranks with aggregation factor `a`.
pub fn allgather(n: usize, a: usize) -> Program {
    allgather_with(n, a, LinearOrder::DepthFirst)
}

/// PAT all-gather with an explicit linear-phase order (ablation).
pub fn allgather_with(n: usize, a: usize, order: LinearOrder) -> Program {
    let a_c = clamp_aggregation(n, a);
    let name = match order {
        LinearOrder::DepthFirst => format!("pat(a={a_c})"),
        LinearOrder::DimMajor => format!("pat_dimmajor(a={a_c})"),
    };
    let mut p = Program::new(n, Collective::AllGather, name);
    if n <= 1 {
        return p;
    }
    for (step, round) in rounds_with(n, a_c, order).iter().enumerate() {
        let hop = 1usize << round.dim;
        for i in 0..n {
            let dst: Rank = (i + hop) % n;
            let src: Rank = (i + n - hop) % n;
            let send: Vec<usize> = round.offsets.iter().map(|&o| (i + n - o) % n).collect();
            let recv: Vec<usize> = round.offsets.iter().map(|&o| (src + n - o) % n).collect();
            p.push(i, Op::send(dst, send, step));
            p.push(i, Op::recv(src, recv, false, step));
        }
    }
    p
}

/// PAT reduce-scatter: the mirror of PAT all-gather (paper Fig. 11).
pub fn reduce_scatter(n: usize, a: usize) -> Program {
    allgather(n, a).mirror()
}

/// PAT reduce-scatter with an explicit linear-phase order (ablation).
pub fn reduce_scatter_with(n: usize, a: usize, order: LinearOrder) -> Program {
    allgather_with(n, a, order).mirror()
}

/// Count the logarithmic vs linear steps of a PAT schedule (Fig. 6: "1 step
/// at the top, 3 steps within the tree" for n=8, a=2).
pub fn phase_counts(n: usize, a: usize) -> (usize, usize) {
    let mut log = 0;
    let mut lin = 0;
    for r in rounds(n, a) {
        match r.phase {
            StepPhase::Logarithmic => log += 1,
            StepPhase::Linear => lin += 1,
        }
    }
    (log, lin)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::pat_step_count;
    use crate::sched::bruck;
    use crate::sched::verify::verify_program;

    /// PAT with unconstrained aggregation IS dimension-reversed Bruck, for
    /// every rank count (the buffer fits whole dimension rounds).
    #[test]
    fn pat_full_agg_equals_reversed_bruck() {
        for n in 2..26 {
            let pat = allgather(n, usize::MAX);
            let mut bruck = bruck::allgather_far_first(n);
            bruck.algorithm = pat.algorithm.clone();
            assert_eq!(pat, bruck, "n={n}");
        }
    }

    #[test]
    fn correct_all_n_and_aggregations() {
        for n in 1..26 {
            for a in [1usize, 2, 3, 4, 8, usize::MAX] {
                verify_program(&allgather(n, a)).unwrap();
                verify_program(&reduce_scatter(n, a)).unwrap();
                verify_program(&allgather_with(n, a, LinearOrder::DimMajor)).unwrap();
                verify_program(&reduce_scatter_with(n, a, LinearOrder::DimMajor)).unwrap();
            }
        }
    }

    /// Step counts match the closed form on powers of two, and the paper's
    /// figures.
    #[test]
    fn step_counts_pow2() {
        for k in 1..7usize {
            let n = 1 << k;
            for a in [1usize, 2, 4, 8, 16] {
                let p = allgather(n, a);
                assert_eq!(
                    p.steps,
                    pat_step_count(n, clamp_aggregation(n, a).min(prev_pow2_pub(n, a))),
                    "n={n} a={a}"
                );
            }
        }
        // Paper figures.
        assert_eq!(allgather(8, 2).steps, 4); // Figs 5-6
        assert_eq!(allgather(8, 1).steps, 7); // Fig 10
        assert_eq!(allgather(16, 8).steps, 4); // Fig 7
        assert_eq!(allgather(16, 4).steps, 5); // Fig 8
        assert_eq!(allgather(16, 2).steps, 8); // Fig 9
    }

    fn prev_pow2_pub(n: usize, a: usize) -> usize {
        let c = clamp_aggregation(n, a);
        if c >= (n - 1).div_ceil(2) {
            c
        } else {
            super::prev_pow2(c)
        }
    }

    /// Non-power-of-two counts: between the ideal closed form and n-1
    /// steps, always correct (Fig. 4 territory).
    #[test]
    fn step_counts_non_pow2_bounded() {
        for n in [3usize, 5, 6, 7, 9, 11, 13, 17, 23, 25, 31, 33] {
            for a in [1usize, 2, 4, 8] {
                let p = allgather(n, a);
                let ideal = pat_step_count(n, clamp_aggregation(n, a));
                assert!(p.steps >= ideal.min(n - 1), "n={n} a={a}");
                assert!(p.steps <= n - 1 + ceil_log2(n) as usize, "n={n} a={a} steps={}", p.steps);
            }
        }
    }

    /// Fig. 6: n=8, a=2 has one logarithmic step at the top and three
    /// linear steps within the two parallel trees.
    #[test]
    fn fig6_phase_split() {
        assert_eq!(phase_counts(8, 2), (1, 3));
        // Fig. 7 (n=16, 8 trees): 3 top steps + 1 within-tree step.
        assert_eq!(phase_counts(16, 8), (3, 1));
        // Fig. 9 (n=16, 2 trees): 1 top step + 7 steps within each
        // 8-node parallel tree.
        assert_eq!(phase_counts(16, 2), (1, 7));
        // Fig. 10 (fully linear): no logarithmic top at all.
        assert_eq!(phase_counts(8, 1), (0, 7));
    }

    /// No transfer ever aggregates more than `a` chunks.
    #[test]
    fn aggregation_bounded() {
        for n in 2..26 {
            for a in 1..8 {
                for order in [LinearOrder::DepthFirst, LinearOrder::DimMajor] {
                    let p = allgather_with(n, a, order);
                    assert!(p.stats().max_aggregation <= a, "n={n} a={a} {order:?}");
                }
            }
        }
    }

    /// THE paper claim (P3): mirrored PAT reduce-scatter runs in
    /// `a · log2(n/a)` accumulators with the depth-first order (each of the
    /// `a` parallel trees holds one accumulator per level of its DFS path),
    /// but Θ(n) with the dimension-major order — the ordering is what buys
    /// the paper's "logarithmic amount of internal buffers".
    #[test]
    fn rs_accumulators_logarithmic_dfs_linear_dimmajor() {
        for n in [8usize, 16, 32, 64, 128] {
            for a in [1usize, 2, 4] {
                let occ_dfs = verify_program(&reduce_scatter(n, a)).unwrap();
                let bound = a * (ceil_log2(n) - crate::core::floor_log2(a)) as usize;
                assert!(
                    occ_dfs.peak_slots <= bound,
                    "dfs n={n} a={a}: peak {} > {bound}",
                    occ_dfs.peak_slots
                );
            }
            // dim-major ablation blows up linearly
            let occ_dm =
                verify_program(&reduce_scatter_with(n, 2, LinearOrder::DimMajor)).unwrap();
            assert!(
                occ_dm.peak_slots >= n / 2 - 1,
                "dim-major n={n}: peak {} unexpectedly small",
                occ_dm.peak_slots
            );
        }
    }

    /// A=1 degenerates to a fully linear single-tree schedule (Fig. 10):
    /// n-1 steps of exactly one chunk, for every n.
    #[test]
    fn fully_linear() {
        for n in 2..24 {
            let p = allgather(n, 1);
            assert_eq!(p.steps, n - 1, "n={n}");
            assert_eq!(p.stats().max_aggregation, 1);
        }
    }

    /// Fig. 10 order: the first transfer of the fully linear schedule is
    /// the farthest (root sends to its farthest child), then the schedule
    /// progressively closes in.
    #[test]
    fn fully_linear_far_first() {
        let rs = rounds(8, 1);
        assert_eq!(rs[0].dim, 2, "first transfer crosses the far dimension");
        assert_eq!(rs[0].offsets, vec![0]);
        // last round is the root's nearest child
        let last = rs.last().unwrap();
        assert_eq!(last.dim, 0);
        assert_eq!(last.offsets, vec![0]);
    }

    /// Mirror structure: PAT RS is PAT AG reversed (per-rank op lists flip).
    #[test]
    fn rs_is_exact_mirror() {
        let ag = allgather(12, 2);
        let rs = reduce_scatter(12, 2);
        for r in 0..12 {
            assert_eq!(ag.ranks[r].len(), rs.ranks[r].len());
            for (a, b) in ag.ranks[r].iter().zip(rs.ranks[r].iter().rev()) {
                match (a, b) {
                    (Op::Send { peer: pa, chunks: ca, .. }, Op::Recv { peer: pb, chunks: cb, reduce, .. }) => {
                        assert_eq!(pa, pb);
                        assert_eq!(ca, cb);
                        assert!(*reduce);
                    }
                    (Op::Recv { peer: pa, chunks: ca, .. }, Op::Send { peer: pb, chunks: cb, .. }) => {
                        assert_eq!(pa, pb);
                        assert_eq!(ca, cb);
                    }
                    other => panic!("mirror mismatch: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn clamping() {
        assert_eq!(clamp_aggregation(2, 100), 1);
        assert_eq!(clamp_aggregation(8, 100), 4);
        assert_eq!(clamp_aggregation(16, usize::MAX), 8);
        assert_eq!(clamp_aggregation(7, 100), 3);
        assert_eq!(clamp_aggregation(9, 100), 4);
    }

    /// Total transfers always cover each root's tree exactly: n-1 chunk
    /// transfers per root across the whole schedule.
    #[test]
    fn chunk_transfer_totals() {
        for n in 2..20 {
            for a in [1usize, 2, 3, usize::MAX] {
                let p = allgather(n, a);
                assert_eq!(p.stats().chunk_transfers, n * (n - 1), "n={n} a={a}");
            }
        }
    }
}
