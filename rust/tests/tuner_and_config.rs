//! Tuner sanity (DESIGN invariant 6), the hierarchical-prediction
//! calibration, and config/CLI plumbing.

use patcol::coordinator::config::{parse_bytes, ConfigMap};
use patcol::coordinator::tuner::{
    ALLREDUCE_CALIBRATION_TOLERANCE, CHANNEL_CALIBRATION_TOLERANCE, HIER_CALIBRATION_TOLERANCE,
};
use patcol::coordinator::{CommConfig, Communicator, Tuner};
use patcol::core::{Algorithm, Collective, PhaseAlg, Placement};
use patcol::obs::calib::{self, CalibRecord};
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};

/// Invariant 6: on a grid of (ranks, sizes), the tuner's pick simulates
/// within 25% of the best fixed candidate on the ideal fabric. (The tuner
/// uses a closed-form model, the reference is the event simulator, so we
/// allow model error but no gross misprediction.)
#[test]
fn tuner_never_grossly_wrong() {
    let tuner = Tuner::default();
    let cost = CostModel::ib_hdr();
    for &n in &[8usize, 32, 128] {
        let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
        for &size in &[256usize, 16 << 10, 1 << 20] {
            let sim_t = |alg: Algorithm| {
                let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
                simulate(&prog, &topo, &cost, size).unwrap().total_time
            };
            let candidates = [
                Algorithm::Ring,
                Algorithm::Pat { aggregation: usize::MAX },
                Algorithm::Pat { aggregation: 8 },
                Algorithm::Pat { aggregation: 1 },
            ];
            let best = candidates
                .iter()
                .map(|&a| sim_t(a))
                .fold(f64::INFINITY, f64::min);
            let picked = tuner.choose(n, size, 1 << 30, Collective::AllGather).algorithm;
            let picked_t = sim_t(picked);
            assert!(
                picked_t <= best * 1.25,
                "n={n} size={size}: picked {picked} at {picked_t}, best {best}"
            );
        }
    }
}

/// Tuner calibration (ROADMAP follow-up): `predict_hier` tracks the event
/// simulator on a tapered three-level fabric within the documented
/// constant [`HIER_CALIBRATION_TOLERANCE`] (both directions), across
/// aggregations and the latency→bandwidth size band. The fabric: 64 ranks
/// as 8-rank nodes = 8-rank leaves, 2 pods × 4 leaves, core tier tapered
/// ×0.25; the tuner's `inter_bw` is set to the core-tapered uplink the
/// closed form folds all contention into.
#[test]
fn predict_hier_tracks_simulator_on_tapered_fabric() {
    let n = 64usize;
    let k = 8usize;
    let nic = CostModel::ib_hdr_nic_bw();
    let topo = Topology::three_level(n, k, 4, 4, 2, nic, 1.0, 0.25).unwrap();
    let pl = Placement::uniform(n, k).unwrap();
    topo.check_placement(&pl).unwrap();
    let cost = CostModel::ib_hdr();
    let tuner = Tuner { inter_bw: Some(nic * 0.25), ..Tuner::default() };
    // The sweep doubles as a calibration drift run: every point is
    // appended to a JSONL history exactly as the CLI's `--calib-history`
    // flag records live runs, then folded through
    // `obs::calib::drift_summary` — the workflow that watches the
    // tolerance constant against model drift.
    let hist = std::env::temp_dir().join(format!(
        "patcol_hier_calib_drift_{}.jsonl",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&hist);
    for &a in &[2usize, usize::MAX] {
        for &chunk in &[4usize << 10, 64 << 10, 256 << 10] {
            let prog = sched::generate_placed(
                Algorithm::HierPat { aggregation: a },
                Collective::AllGather,
                &pl,
            )
            .unwrap();
            let sim_t = simulate(&prog, &topo, &cost, chunk).unwrap().total_time;
            let pred = tuner.predict_hier(&pl, a, chunk);
            let ratio = pred / sim_t;
            assert!(
                (1.0 / HIER_CALIBRATION_TOLERANCE..=HIER_CALIBRATION_TOLERANCE)
                    .contains(&ratio),
                "a={a} chunk={chunk}: predicted {pred:.6}s vs simulated {sim_t:.6}s \
                 (ratio {ratio:.2} outside ×/÷{HIER_CALIBRATION_TOLERANCE})"
            );
            let alg = if a == usize::MAX {
                "hier_pat:max".to_string()
            } else {
                format!("hier_pat:{a}")
            };
            calib::append(
                &hist,
                &CalibRecord {
                    collective: "allgather".into(),
                    alg,
                    nranks: n,
                    bytes: chunk,
                    channels: 1,
                    predicted_us: pred * 1e6,
                    measured_us: sim_t * 1e6,
                },
            )
            .unwrap();
        }
    }
    // Drift summary over the fresh history: every swept point present,
    // and every per-key worst residual inside what the tolerance constant
    // promises (ratio ∈ ×/÷T ⇒ |residual| ≤ (T−1)·100%).
    let drift = calib::drift_summary(&calib::load(&hist));
    assert_eq!(drift.len(), 6, "one drift key per (aggregation, size): {drift:?}");
    let limit_pct = (HIER_CALIBRATION_TOLERANCE - 1.0) * 100.0;
    for (key, d) in &drift {
        assert_eq!(d.n, 1, "{key}: single run per point in this sweep");
        assert!(
            d.max_abs_residual_pct <= limit_pct,
            "{key}: residual {:.1}% beyond the documented ±{limit_pct:.0}%",
            d.max_abs_residual_pct
        );
    }
    let _ = std::fs::remove_file(&hist);
}

/// Tuner calibration (the satellite to the hierarchy rework):
/// `predict_allreduce` tracks the event simulator on a tapered leaf-spine
/// fabric within the documented constant
/// [`ALLREDUCE_CALIBRATION_TOLERANCE`] (both directions), across the
/// latency→bandwidth band and pipeline segment counts. The fabric: 64
/// ranks on 8-rank leaves, 4 spines tapered ×0.25 — aggregate leaf uplink
/// equals one NIC, which is what the tuner's `inter_bw` is set to, so the
/// closed form's shared-uplink `flat_rate` matches the fabric the
/// simulator contends on.
#[test]
fn predict_allreduce_tracks_simulator_on_tapered_leaf_spine() {
    let n = 64usize;
    let k = 8usize;
    let nic = CostModel::ib_hdr_nic_bw();
    let topo = Topology::leaf_spine(n, k, 4, nic, 0.25).unwrap();
    let pl = Placement::uniform(n, k).unwrap();
    topo.check_placement(&pl).unwrap();
    let cost = CostModel::ib_hdr();
    // 4 uplinks × 0.25·nic = exactly one NIC of aggregate leaf uplink.
    let tuner = Tuner { inter_bw: Some(nic), ..Tuner::default() };
    let ph = PhaseAlg::Pat { aggregation: usize::MAX };
    for &bytes in &[4usize << 10, 64 << 10, 1 << 20] {
        for &segments in &[1usize, 2, 4] {
            let prog = sched::generate_placed(
                Algorithm::Compose { rs: ph, ag: ph, segments },
                Collective::AllReduce,
                &pl,
            )
            .unwrap();
            let seg_bytes = (bytes / segments).max(1);
            let sim_t = simulate(&prog, &topo, &cost, seg_bytes).unwrap().total_time;
            let pred = tuner.predict_allreduce(ph, ph, segments, n, seg_bytes, Some(&pl));
            let ratio = pred / sim_t;
            assert!(
                (1.0 / ALLREDUCE_CALIBRATION_TOLERANCE..=ALLREDUCE_CALIBRATION_TOLERANCE)
                    .contains(&ratio),
                "bytes={bytes} segments={segments}: predicted {pred:.6}s vs simulated \
                 {sim_t:.6}s (ratio {ratio:.2} outside ×/÷{ALLREDUCE_CALIBRATION_TOLERANCE})"
            );
        }
    }
}

/// Tuner calibration (the open ROADMAP item): `predict_channels` tracks
/// the event simulator on a multi-rail leaf-spine fabric within the
/// documented constant [`CHANNEL_CALIBRATION_TOLERANCE`] (both
/// directions), across the latency→bandwidth band and channel counts.
/// The fabric: 64 ranks on 8-rank leaves with 4 untapered spines; the
/// tuner's `parallel_links` is set to the spine count — the rails the
/// closed form lets extra channels recruit. The residual gaps the
/// constant absorbs (serial channel tax at small sizes, un-modeled ECMP
/// collision variance at large) are documented on the constant itself.
#[test]
fn predict_channels_tracks_simulator_on_multirail_fabric() {
    let n = 64usize;
    let spines = 4usize;
    let nic = CostModel::ib_hdr_nic_bw();
    let topo = Topology::leaf_spine(n, 8, spines, nic, 1.0).unwrap();
    let cost = CostModel::ib_hdr();
    let tuner = Tuner { parallel_links: spines, ..Tuner::default() };
    let a = usize::MAX; // fully-aggregated PAT, the multi-channel workhorse
    let base = sched::generate(Algorithm::Pat { aggregation: a }, Collective::AllGather, n)
        .unwrap();
    for &chunk in &[4usize << 10, 64 << 10, 1 << 20] {
        for &c in &[1usize, 2, 4] {
            let split = sched::channel::split(&base, c).unwrap();
            let sim_t = simulate(&split, &topo, &cost, chunk / c).unwrap().total_time;
            let pred = tuner.predict_channels(n, a, chunk, c);
            let ratio = pred / sim_t;
            assert!(
                (1.0 / CHANNEL_CALIBRATION_TOLERANCE..=CHANNEL_CALIBRATION_TOLERANCE)
                    .contains(&ratio),
                "chunk={chunk} channels={c}: predicted {pred:.6}s vs simulated \
                 {sim_t:.6}s (ratio {ratio:.2} outside ×/÷{CHANNEL_CALIBRATION_TOLERANCE})"
            );
        }
    }
}

/// The tuner respects the buffer budget end-to-end through the
/// communicator: with 2 slots, the resolved PAT aggregation is 1 for RS on
/// 32 ranks (law: a·log2(n/a) ≤ slots).
#[test]
fn buffer_budget_respected_via_communicator() {
    let comm = Communicator::new(CommConfig {
        nranks: 32,
        buffer_slots: Some(2),
        ..Default::default()
    })
    .unwrap();
    match comm.resolve(Collective::ReduceScatter, 64) {
        Algorithm::Pat { aggregation } => assert_eq!(aggregation, 1),
        Algorithm::Ring => {} // also buffer-safe
        other => panic!("unexpected {other}"),
    }
}

#[test]
fn config_file_to_communicator() {
    let cfg = ConfigMap::parse(
        "nranks = 6\nalgorithm = pat:2\nbuffer_slots = 16\ndatapath = scalar\n",
    )
    .unwrap();
    let cc = cfg.to_comm_config().unwrap();
    let comm = Communicator::new(cc).unwrap();
    assert_eq!(comm.nranks(), 6);
    let inputs: Vec<Vec<f32>> = (0..6).map(|r| vec![r as f32; 10]).collect();
    let (_, rep) = comm.all_gather_report(&inputs).unwrap();
    assert_eq!(rep.algorithm, Algorithm::Pat { aggregation: 2 });
}

#[test]
fn size_strings() {
    assert_eq!(parse_bytes("512").unwrap(), 512);
    assert_eq!(parse_bytes("8MiB").unwrap(), 8 << 20);
}

/// CLI binary smoke: selftest + explain + tune + sweep run clean.
#[test]
fn cli_binary_smoke() {
    let bin = env!("CARGO_BIN_EXE_patcol");
    for argv in [
        vec!["selftest", "--max-ranks", "9"],
        vec!["explain", "--ranks", "8", "--agg", "2"],
        vec!["tune", "--ranks", "64", "--size", "4KiB", "--buffer-slots", "16"],
        vec!["sweep", "--ranks", "16", "--sizes", "1KiB,64KiB"],
        vec![
            "simulate", "--ranks", "32", "--size", "64KiB", "--alg", "ring",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8",
        ],
        vec!["run", "--ranks", "4", "--size", "4KiB", "--alg", "pat:2",
             "--collective", "rs"],
        vec!["explain", "--ranks", "13", "--alg", "hier_pat:2",
             "--ranks-per-node", "4"],
        vec![
            "simulate", "--ranks", "32", "--size", "64KiB", "--alg", "hier_pat",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8",
            "--ranks-per-node", "8",
        ],
        vec!["run", "--ranks", "13", "--size", "4KiB", "--alg", "hier_pat:2",
             "--placement", "4,4,5", "--collective", "rs"],
        vec!["tune", "--ranks", "64", "--size", "1MiB", "--buffer-slots", "1024",
             "--ranks-per-node", "8", "--inter-gbps", "25"],
        vec!["run", "--ranks", "6", "--size", "4KiB", "--alg", "pat:2+ring:2"],
        vec!["explain", "--ranks", "8", "--alg", "pat+pat:2"],
        vec![
            "simulate", "--ranks", "32", "--size", "16KiB", "--alg", "pat+ring:4",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8", "--intra-gbps", "200",
            "--ranks-per-node", "8",
        ],
        vec!["tune", "--ranks", "64", "--size", "64KiB", "--buffer-slots", "256",
             "--collective", "ar"],
        vec!["run", "--ranks", "5", "--size", "2KiB", "--collective", "ar"],
        vec!["explain", "--ranks", "8", "--alg", "pat*4"],
        vec!["run", "--ranks", "4", "--size", "4KiB", "--alg", "pat:2",
             "--channels", "2", "--collective", "rs"],
        vec![
            "simulate", "--ranks", "32", "--size", "256KiB", "--alg", "pat*4",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8", "--taper", "0.5",
        ],
        vec!["tune", "--ranks", "64", "--size", "4MiB", "--buffer-slots", "1024",
             "--parallel-links", "4"],
        vec!["run", "--ranks", "5", "--size", "8KiB", "--collective", "ar",
             "--buckets", "4"],
        vec!["run", "--ranks", "4", "--size", "16KiB", "--collective", "ar",
             "--alg", "pat:2", "--bucket-bytes", "4KiB"],
        vec!["tune", "--ranks", "64", "--size", "4MiB", "--buffer-slots", "256",
             "--collective", "ar"],
        // multi-leader striping: L inter-node flows per node
        vec!["run", "--ranks", "16", "--size", "4KiB", "--alg", "hier_pat",
             "--ranks-per-node", "4", "--leaders-per-node", "2"],
        vec!["explain", "--ranks", "16", "--alg", "hier_pat:2",
             "--ranks-per-node", "4", "--leaders-per-node", "4"],
        // three-level placement grammar: <k>x<m> and explicit pods
        vec!["run", "--ranks", "32", "--size", "4KiB", "--alg", "hier_pat",
             "--placement", "4x4", "--collective", "rs"],
        vec!["explain", "--ranks", "17", "--alg", "hier_pat:2",
             "--placement", "4,4;4,5"],
        vec![
            "simulate", "--ranks", "32", "--size", "64KiB", "--alg", "hier_pat",
            "--topo", "three_level", "--ranks-per-leaf", "4",
            "--leaves-per-pod", "4", "--placement", "4x4",
            "--leaders-per-node", "2",
        ],
        vec!["tune", "--ranks", "64", "--size", "1MiB", "--buffer-slots", "1024",
             "--ranks-per-node", "8", "--leaders-per-node", "2",
             "--inter-gbps", "100"],
    ] {
        let out = std::process::Command::new(bin)
            .args(&argv)
            .output()
            .expect("spawn patcol");
        assert!(
            out.status.success(),
            "patcol {argv:?}: {}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }
}
