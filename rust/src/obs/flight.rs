//! Lock-free flight recorder for the threaded transport.
//!
//! Each rank thread owns one `FlightRecorder` — no locks, no atomics:
//! exclusivity comes from ownership, and the recordings are merged into
//! one [`Trace`] when the threads join. All recorders for a run share a
//! single `Instant` origin so their timestamps are directly comparable.
//!
//! Overhead discipline: when tracing is off the recorder is constructed
//! [`FlightRecorder::disabled`] and every `record`/`pool` call is a
//! single inlined branch on a bool — no `Instant::now()`, no allocation
//! (the hot loops read [`FlightRecorder::enabled`] before computing
//! timestamps). The event store is a bounded ring (default
//! [`DEFAULT_FLIGHT_CAPACITY`]); on overflow the oldest event is dropped
//! and counted, while the [`Counters`] keep exact totals regardless —
//! exactly the behavior wanted from a crash/watchdog flight recorder:
//! bounded memory, freshest history, lossless aggregates.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::core::Rank;
use crate::obs::trace::{Counters, Event, EventKind, Trace};

/// Ring capacity (events) used by the transport when tracing is enabled.
pub const DEFAULT_FLIGHT_CAPACITY: usize = 1 << 16;

/// Per-thread bounded event recorder (see module docs).
#[derive(Debug)]
pub struct FlightRecorder {
    enabled: bool,
    origin: Instant,
    capacity: usize,
    ring: VecDeque<Event>,
    dropped: u64,
    counters: BTreeMap<(Rank, usize), Counters>,
    /// Arena bytes per pool slot — when nonzero, every pool sample also
    /// emits an [`EventKind::Arena`] sample (schema v3) so occupancy is a
    /// byte curve, not just a slot count.
    arena_slot_bytes: usize,
    /// Static arena bytes (wire regions) under the pool curve.
    arena_base_bytes: usize,
}

impl FlightRecorder {
    /// A recorder that drops everything — what every rank thread gets
    /// when `TransportOptions::trace` is off.
    pub fn disabled() -> FlightRecorder {
        FlightRecorder {
            enabled: false,
            origin: Instant::now(),
            capacity: 0,
            ring: VecDeque::new(),
            dropped: 0,
            counters: BTreeMap::new(),
            arena_slot_bytes: 0,
            arena_base_bytes: 0,
        }
    }

    /// An enabled recorder stamping times relative to `origin` (pass the
    /// same origin to every thread of a run).
    pub fn new(origin: Instant, capacity: usize) -> FlightRecorder {
        FlightRecorder {
            enabled: true,
            origin,
            capacity: capacity.max(1),
            ring: VecDeque::with_capacity(capacity.max(1).min(1024)),
            dropped: 0,
            counters: BTreeMap::new(),
            arena_slot_bytes: 0,
            arena_base_bytes: 0,
        }
    }

    /// Teach the recorder the arena geometry — `slot_bytes` per pool slot
    /// over `base_bytes` of static wire regions — so pool samples derive
    /// the arena-occupancy byte curve ([`EventKind::Arena`], schema v3).
    pub fn set_arena_scale(&mut self, slot_bytes: usize, base_bytes: usize) {
        self.arena_slot_bytes = slot_bytes;
        self.arena_base_bytes = base_bytes;
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Seconds since the shared origin. Only call on the enabled path —
    /// guard with [`FlightRecorder::enabled`] to keep `Instant::now()`
    /// off the disabled hot path.
    #[inline]
    pub fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// `now()` when enabled, `0.0` (no clock read) when disabled — for
    /// call sites that want a timestamp unconditionally.
    #[inline]
    pub fn now_or_zero(&self) -> f64 {
        if self.enabled {
            self.now()
        } else {
            0.0
        }
    }

    #[inline]
    pub fn record(&mut self, ev: Event) {
        if !self.enabled {
            return;
        }
        self.counters
            .entry((ev.rank, ev.channel))
            .or_default()
            .absorb(&ev);
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    /// Record a buffer-pool occupancy sample (`live` slots) at `now`.
    #[inline]
    pub fn pool(&mut self, rank: Rank, channel: usize, step: usize, live: usize) {
        if !self.enabled {
            return;
        }
        let t = self.now();
        self.record(Event::span(EventKind::Pool, rank, channel, step, t, t).with_value(live));
        if self.arena_slot_bytes > 0 {
            let bytes = self.arena_base_bytes + live * self.arena_slot_bytes;
            self.record(
                Event::span(EventKind::Arena, rank, channel, step, t, t).with_value(bytes),
            );
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Human-readable dump of the last `n` events — what the watchdog
    /// appends to its timeout report so a deadlock arrives pre-blamed.
    pub fn render_tail(&self, n: usize) -> String {
        let mut out = String::new();
        let skip = self.ring.len().saturating_sub(n);
        for ev in self.ring.iter().skip(skip) {
            let peer = ev
                .peer
                .map(|p| format!(" peer={p}"))
                .unwrap_or_default();
            out.push_str(&format!(
                "  [{:>12.6}s] {:<6} rank={} ch={} step={}{}{}{}\n",
                ev.t_start,
                ev.kind.name(),
                ev.rank,
                ev.channel,
                ev.step,
                peer,
                if ev.bytes > 0 { format!(" bytes={}", ev.bytes) } else { String::new() },
                if ev.kind == EventKind::Pool {
                    format!(" live={}", ev.value)
                } else {
                    String::new()
                },
            ));
        }
        out
    }

    /// Consume into a sorted [`Trace`] fragment (one thread's view).
    pub fn finish(self) -> Trace {
        let mut t = Trace {
            events: self.ring.into_iter().collect(),
            counters: self.counters,
            dropped: self.dropped,
        };
        t.sort();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut fr = FlightRecorder::disabled();
        assert!(!fr.enabled());
        fr.record(Event::span(EventKind::SendOp, 0, 0, 0, 0.0, 1.0));
        fr.pool(0, 0, 0, 7);
        assert!(fr.is_empty());
        let t = fr.finish();
        assert!(t.events.is_empty());
        assert!(t.counters.is_empty());
    }

    #[test]
    fn ring_drops_oldest_but_counters_stay_exact() {
        let mut fr = FlightRecorder::new(Instant::now(), 4);
        for i in 0..10 {
            fr.record(
                Event::span(EventKind::SendOp, 0, 0, i, i as f64, i as f64 + 1.0).with_bytes(8),
            );
        }
        assert_eq!(fr.len(), 4);
        assert_eq!(fr.dropped(), 6);
        let t = fr.finish();
        assert_eq!(t.events.len(), 4);
        assert_eq!(t.events[0].step, 6, "oldest events were dropped");
        assert_eq!(t.dropped, 6);
        // aggregates never drop
        let c = t.counters_for(0, 0);
        assert_eq!(c.msgs_sent, 10);
        assert_eq!(c.bytes_sent, 80);
    }

    #[test]
    fn tail_renders_events() {
        let mut fr = FlightRecorder::new(Instant::now(), 16);
        fr.record(
            Event::span(EventKind::RecvOp, 3, 1, 2, 0.5, 0.75)
                .with_peer(7)
                .with_bytes(64),
        );
        let tail = fr.render_tail(8);
        assert!(tail.contains("recv"));
        assert!(tail.contains("rank=3"));
        assert!(tail.contains("ch=1"));
        assert!(tail.contains("peer=7"));
    }
}
