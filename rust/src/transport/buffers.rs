//! Bounded intermediate-buffer pool with occupancy accounting.
//!
//! PAT exists because intermediate buffers are scarce: NCCL pre-maps a
//! fixed-size staging region per peer, and the aggregation factor is
//! exactly "how many chunks fit". The pool hands out fixed-size slots
//! (one chunk each), fails fast if a schedule exceeds its capacity, and
//! records the high-water mark — the quantity the paper claims stays
//! logarithmic in rank count and independent of operation size.

use crate::core::{Error, Rank, Result};
use crate::obs::FlightRecorder;

/// A pool of `capacity` chunk-sized slots (`None` = unbounded, measuring
/// only).
#[derive(Debug)]
pub struct BufferPool {
    slot_elems: usize,
    capacity: Option<usize>,
    free: Vec<Vec<f32>>,
    live: usize,
    peak: usize,
    allocated: usize,
}

impl BufferPool {
    pub fn new(slot_elems: usize, capacity: Option<usize>) -> BufferPool {
        BufferPool {
            slot_elems,
            capacity,
            free: Vec::new(),
            live: 0,
            peak: 0,
            allocated: 0,
        }
    }

    /// Acquire a zeroed slot. Errors if the configured capacity would be
    /// exceeded — a PAT schedule that violates its own aggregation bound is
    /// a bug, not a condition to absorb.
    pub fn acquire(&mut self) -> Result<Vec<f32>> {
        if let Some(cap) = self.capacity {
            if self.live >= cap {
                return Err(Error::Transport(format!(
                    "buffer pool exhausted: {} live slots of capacity {cap}",
                    self.live
                )));
            }
        }
        self.live += 1;
        self.peak = self.peak.max(self.live);
        match self.free.pop() {
            Some(mut v) => {
                v.fill(0.0);
                Ok(v)
            }
            None => {
                self.allocated += 1;
                Ok(vec![0.0; self.slot_elems])
            }
        }
    }

    /// Return a slot to the pool.
    pub fn release(&mut self, slot: Vec<f32>) {
        debug_assert_eq!(slot.len(), self.slot_elems);
        self.live -= 1;
        self.free.push(slot);
    }

    /// Current live slots.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of simultaneously-live slots.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Distinct vectors ever allocated (allocation pressure metric for the
    /// perf pass — steady-state should reuse, not allocate).
    pub fn total_allocated(&self) -> usize {
        self.allocated
    }

    /// Accounting-only reservation: enforce and track occupancy without
    /// handing out storage. Used by the all-gather send path, where the
    /// wire message itself is the staging storage — copying into a
    /// separate slot would only model the same bytes twice (perf pass:
    /// −1 full payload copy per transfer; see EXPERIMENTS.md §Perf).
    pub fn reserve(&mut self, slots: usize) -> Result<()> {
        if let Some(cap) = self.capacity {
            if self.live + slots > cap {
                return Err(Error::Transport(format!(
                    "buffer pool exhausted: {} live + {slots} requested of capacity {cap}",
                    self.live
                )));
            }
        }
        self.live += slots;
        self.peak = self.peak.max(self.live);
        Ok(())
    }

    /// Release an accounting-only reservation.
    pub fn unreserve(&mut self, slots: usize) {
        debug_assert!(self.live >= slots);
        self.live -= slots;
    }

    // Traced variants: same transitions, plus a pool-occupancy sample into
    // the flight recorder (a no-op branch when tracing is off). The sample
    // carries the op coordinates so occupancy is attributable to the
    // (rank, channel, step) that moved it.

    /// [`BufferPool::acquire`] + occupancy sample.
    pub fn acquire_traced(
        &mut self,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<Vec<f32>> {
        let slot = self.acquire()?;
        fr.pool(rank, channel, step, self.live);
        Ok(slot)
    }

    /// [`BufferPool::release`] + occupancy sample.
    pub fn release_traced(
        &mut self,
        slot: Vec<f32>,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) {
        self.release(slot);
        fr.pool(rank, channel, step, self.live);
    }

    /// [`BufferPool::reserve`] + occupancy sample.
    pub fn reserve_traced(
        &mut self,
        slots: usize,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        self.reserve(slots)?;
        fr.pool(rank, channel, step, self.live);
        Ok(())
    }

    /// [`BufferPool::unreserve`] + occupancy sample.
    pub fn unreserve_traced(
        &mut self,
        slots: usize,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) {
        self.unreserve(slots);
        fr.pool(rank, channel, step, self.live);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_and_reuses() {
        let mut p = BufferPool::new(8, Some(2));
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert_eq!(p.live(), 2);
        assert!(p.acquire().is_err());
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(p.peak(), 2);
        // slot reused, not newly allocated
        assert_eq!(p.total_allocated(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.live(), 0);
    }

    #[test]
    fn acquired_slots_are_zeroed() {
        let mut p = BufferPool::new(4, None);
        let mut a = p.acquire().unwrap();
        a.fill(7.0);
        p.release(a);
        let b = p.acquire().unwrap();
        assert!(b.iter().all(|&x| x == 0.0));
        p.release(b);
    }

    #[test]
    fn unbounded_never_errors() {
        let mut p = BufferPool::new(1, None);
        let slots: Vec<_> = (0..100).map(|_| p.acquire().unwrap()).collect();
        assert_eq!(p.peak(), 100);
        for s in slots {
            p.release(s);
        }
    }
}
