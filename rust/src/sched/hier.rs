//! Hierarchical (two-level, topology-aware) PAT over a rank
//! [`Placement`] — the production-scale extension the paper's "communicate
//! close dimensions first" construction points at, and what NCCL itself
//! does across NVLink domains: keep the chatty traffic inside a node, run
//! the latency-optimal algorithm only between nodes.
//!
//! An all-gather program has three phases, in disjoint step ranges so the
//! rounds render cleanly:
//!
//! 1. **Intra-node gather** — within each node, a near-first binomial tree
//!    over the co-located ranks funnels every rank's chunk to the node
//!    *leader* (each edge forwards its whole subtree's chunks, so a node of
//!    `k` ranks needs `k-1` intra-node messages). All traffic stays under
//!    one switch.
//! 2. **Inter-node PAT** — the leaders run the flat PAT schedule over
//!    *nodes*: the program for `nnodes` virtual ranks
//!    ([`pat::rounds`]) is expanded by substituting each virtual rank with
//!    its leader and each virtual chunk with that node's chunk set. The
//!    aggregation factor therefore bounds how many *node chunk sets* one
//!    transfer carries. Uneven node sizes just produce uneven chunk lists.
//! 3. **Intra-node fan-out** — the same tree, root-down: each edge carries
//!    everything the receiving subtree does not already hold (all chunks
//!    minus the child's own subtree), so every rank ends with all `n`
//!    chunks.
//!
//! Correctness of phase 2 follows from the flat PAT invariant by
//! isomorphism: after phase 1 the leader of node `m` holds exactly node
//! `m`'s chunks, which is the image of "flat rank `m` holds chunk `m`";
//! every subsequent message is the image of a flat PAT message.
//!
//! Reduce-scatter is the time-and-direction mirror ([`Program::mirror`]):
//! intra-node scatter of partial sums, inter-node PAT reduce among leaders,
//! intra-node fan-in — so [`crate::sched::verify::verify_program`] covers it
//! with no hierarchical-specific executor.
//!
//! Buffer note: unlike flat PAT, the leaders relay everything for their
//! node — up to `n - 1` staged chunks in the all-gather, and up to `n`
//! live accumulators in the mirrored reduce-scatter (between the fan-in
//! and inter-node phases the leader holds a partial sum for every chunk).
//! The hierarchy trades leader buffer space for fabric locality; the tuner
//! only offers `HierPat` when the buffer budget covers that (see
//! [`crate::coordinator::tuner::Tuner::choose_placed`]).

use std::collections::HashSet;

use crate::core::{ChunkId, Collective, Placement};
use crate::sched::pat;
use crate::sched::program::{Op, Program};
use crate::sched::tree::NearFirstTree;

/// Intra-node tree edges as `(parent, child)` local offsets in pre-order
/// (every edge appears after the edge that delivers to its parent) — the
/// fan-out execution order.
fn preorder_edges(k: usize) -> Vec<(usize, usize)> {
    fn visit(t: &NearFirstTree, o: usize, out: &mut Vec<(usize, usize)>) {
        for c in t.children(o) {
            out.push((o, c));
            visit(t, c, out);
        }
    }
    let t = NearFirstTree::new(k);
    let mut out = Vec::new();
    visit(&t, 0, &mut out);
    out
}

/// Intra-node tree edges as `(child, parent)` local offsets in post-order
/// (every edge appears after all edges inside the child's subtree) — the
/// gather execution order.
fn postorder_edges(k: usize) -> Vec<(usize, usize)> {
    fn visit(t: &NearFirstTree, o: usize, out: &mut Vec<(usize, usize)>) {
        for c in t.children(o) {
            visit(t, c, out);
            out.push((c, o));
        }
    }
    let t = NearFirstTree::new(k);
    let mut out = Vec::new();
    visit(&t, 0, &mut out);
    out
}

/// Local offsets in the subtree rooted at `o`, ascending.
fn subtree_offsets(t: &NearFirstTree, o: usize) -> Vec<usize> {
    let mut out = vec![o];
    let mut i = 0;
    while i < out.len() {
        let cur = out[i];
        out.extend(t.children(cur));
        i += 1;
    }
    out.sort_unstable();
    out
}

/// Step counts of the three phases `(intra_gather, inter_pat, fan_out)` for
/// this placement and aggregation (all-gather orientation; the mirrored
/// reduce-scatter reverses them).
pub fn phase_spans(pl: &Placement, a: usize) -> (usize, usize, usize) {
    let nnodes = pl.nnodes();
    let intra = pl.max_node_size().saturating_sub(1);
    let inter = if nnodes > 1 {
        pat::rounds(nnodes, pat::clamp_aggregation(nnodes, a)).len()
    } else {
        0
    };
    (intra, inter, intra)
}

/// Hierarchical PAT all-gather over `pl` with inter-node aggregation `a`.
pub fn allgather(pl: &Placement, a: usize) -> Program {
    let n = pl.nranks();
    let nnodes = pl.nnodes();
    let a_c = if nnodes > 1 {
        pat::clamp_aggregation(nnodes, a)
    } else {
        1
    };
    let name = format!("hier_pat(a={a_c},nodes={nnodes})");
    let mut p = Program::new(n, Collective::AllGather, name);
    if n <= 1 {
        return p;
    }
    let (s1, s2, _) = phase_spans(pl, a);

    // Phase 1: intra-node gather to the leader. Edge (child -> parent)
    // carries the child's whole subtree of chunks; post-order guarantees
    // the child received its own subtree first.
    for node in 0..nnodes {
        let local = pl.ranks_of(node);
        let k = local.len();
        if k <= 1 {
            continue;
        }
        let t = NearFirstTree::new(k);
        for (step, &(c, par)) in postorder_edges(k).iter().enumerate() {
            let chunks: Vec<ChunkId> =
                subtree_offsets(&t, c).iter().map(|&o| local[o]).collect();
            p.push(local[c], Op::send(local[par], chunks.clone(), step));
            p.push(local[par], Op::recv(local[c], chunks, false, step));
        }
    }

    // Phase 2: flat PAT over nodes, executed by the leaders. Virtual chunk
    // `m` expands to node m's rank list.
    if nnodes > 1 {
        for (j, round) in pat::rounds(nnodes, a_c).iter().enumerate() {
            let step = s1 + j;
            let hop = 1usize << round.dim;
            for i in 0..nnodes {
                let dst = (i + hop) % nnodes;
                let src = (i + nnodes - hop) % nnodes;
                let send: Vec<ChunkId> = round
                    .offsets
                    .iter()
                    .flat_map(|&o| pl.ranks_of((i + nnodes - o) % nnodes).iter().copied())
                    .collect();
                let recv: Vec<ChunkId> = round
                    .offsets
                    .iter()
                    .flat_map(|&o| pl.ranks_of((src + nnodes - o) % nnodes).iter().copied())
                    .collect();
                p.push(pl.leader(i), Op::send(pl.leader(dst), send, step));
                p.push(pl.leader(i), Op::recv(pl.leader(src), recv, false, step));
            }
        }
    }

    // Phase 3: intra-node fan-out. Edge (parent -> child) carries every
    // chunk outside the child's subtree; pre-order guarantees the parent
    // received its fan-out payload (or, for the leader, finished phase 2)
    // first.
    for node in 0..nnodes {
        let local = pl.ranks_of(node);
        let k = local.len();
        if k <= 1 {
            continue;
        }
        let t = NearFirstTree::new(k);
        for (idx, &(par, c)) in preorder_edges(k).iter().enumerate() {
            let step = s1 + s2 + idx;
            let sub: HashSet<ChunkId> =
                subtree_offsets(&t, c).iter().map(|&o| local[o]).collect();
            let chunks: Vec<ChunkId> = (0..n).filter(|x| !sub.contains(x)).collect();
            p.push(local[par], Op::send(local[c], chunks.clone(), step));
            p.push(local[c], Op::recv(local[par], chunks, false, step));
        }
    }
    p
}

/// Hierarchical PAT reduce-scatter: the mirror of the all-gather (fan-in,
/// inter-node PAT reduce, intra-node scatter).
pub fn reduce_scatter(pl: &Placement, a: usize) -> Program {
    allgather(pl, a).mirror()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;

    #[test]
    fn correct_across_sizes_and_aggregations() {
        for &n in &[2usize, 3, 5, 8, 12, 13, 16, 17, 24] {
            for &k in &[1usize, 2, 3, 4, 5, 8] {
                let pl = Placement::uniform(n, k.min(n)).unwrap();
                for &a in &[1usize, 2, 4, usize::MAX] {
                    let ag = allgather(&pl, a);
                    verify_program(&ag)
                        .unwrap_or_else(|e| panic!("ag n={n} k={k} a={a}: {e}"));
                    let rs = reduce_scatter(&pl, a);
                    verify_program(&rs)
                        .unwrap_or_else(|e| panic!("rs n={n} k={k} a={a}: {e}"));
                }
            }
        }
    }

    #[test]
    fn explicit_uneven_nodes() {
        let pl = Placement::from_node_sizes(&[4, 1, 5, 3]).unwrap();
        for &a in &[1usize, 2, usize::MAX] {
            verify_program(&allgather(&pl, a)).unwrap();
            verify_program(&reduce_scatter(&pl, a)).unwrap();
        }
    }

    /// With singleton nodes the hierarchy degenerates to flat PAT: same
    /// per-rank op lists (only the program name differs).
    #[test]
    fn singleton_placement_equals_flat_pat() {
        for n in [2usize, 5, 8, 13, 16] {
            for a in [1usize, 2, usize::MAX] {
                let pl = Placement::singletons(n).unwrap();
                let hier = allgather(&pl, a);
                let flat = pat::allgather(n, a);
                assert_eq!(hier.ranks, flat.ranks, "n={n} a={a}");
                assert_eq!(hier.steps, flat.steps, "n={n} a={a}");
            }
        }
    }

    /// A single node degenerates to a pure intra-node tree (no inter phase).
    #[test]
    fn single_node_is_tree_only() {
        let pl = Placement::uniform(6, 6).unwrap();
        let p = allgather(&pl, usize::MAX);
        verify_program(&p).unwrap();
        let (s1, s2, s3) = phase_spans(&pl, usize::MAX);
        assert_eq!((s1, s2, s3), (5, 0, 5));
        assert_eq!(p.steps, s1 + s2 + s3);
        // every message stays inside the node by construction
        for m in p.messages() {
            assert_eq!(pl.node_of(m.src), pl.node_of(m.dst));
        }
    }

    /// Only leaders speak across nodes, and non-leader traffic stays local.
    #[test]
    fn cross_node_messages_are_leader_to_leader() {
        let pl = Placement::uniform(13, 4).unwrap();
        let p = allgather(&pl, 2);
        for m in p.messages() {
            if pl.node_of(m.src) != pl.node_of(m.dst) {
                assert!(pl.is_leader(m.src), "src {} not a leader", m.src);
                assert!(pl.is_leader(m.dst), "dst {} not a leader", m.dst);
            }
        }
    }

    /// Every valid all-gather delivers each foreign chunk exactly once:
    /// chunk transfers total n(n-1), same as the flat generators.
    #[test]
    fn chunk_transfer_totals() {
        for (n, k) in [(8usize, 4usize), (13, 4), (16, 5), (9, 2)] {
            let pl = Placement::uniform(n, k).unwrap();
            let p = allgather(&pl, 2);
            assert_eq!(p.stats().chunk_transfers, n * (n - 1), "n={n} k={k}");
        }
    }

    /// Inter-node messages carry at most `a` node chunk sets.
    #[test]
    fn inter_node_aggregation_bounded() {
        let pl = Placement::uniform(32, 4).unwrap();
        for a in [1usize, 2, 4] {
            let p = allgather(&pl, a);
            let max_sets = p
                .messages()
                .iter()
                .filter(|m| pl.node_of(m.src) != pl.node_of(m.dst))
                .map(|m| {
                    let nodes: HashSet<usize> =
                        m.chunks.iter().map(|&c| pl.node_of(c)).collect();
                    nodes.len()
                })
                .max()
                .unwrap_or(0);
            assert!(max_sets <= a, "a={a}: {max_sets} node sets in one message");
        }
    }

    /// Leader staging is bounded by n-1 chunks for AG (its own chunk is
    /// never staged) and n accumulators for RS (between fan-in and the
    /// inter-node phase the leader holds a partial sum for every chunk) —
    /// the hierarchy's buffer trade-off.
    #[test]
    fn occupancy_bounded() {
        for (n, k) in [(13usize, 4usize), (16, 8), (24, 5)] {
            let pl = Placement::uniform(n, k).unwrap();
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let (p, bound) = match coll {
                    Collective::AllGather => (allgather(&pl, 2), n - 1),
                    _ => (reduce_scatter(&pl, 2), n),
                };
                let occ = verify_program(&p).unwrap();
                assert!(
                    occ.peak_slots <= bound,
                    "{coll} n={n} k={k}: peak {} > {bound}",
                    occ.peak_slots
                );
            }
        }
    }

    #[test]
    fn phase_spans_cover_program() {
        let pl = Placement::uniform(13, 4).unwrap();
        let (s1, s2, s3) = phase_spans(&pl, 2);
        assert_eq!(s1, 3);
        assert_eq!(s3, 3);
        assert!(s2 >= 1);
        let p = allgather(&pl, 2);
        assert_eq!(p.steps, s1 + s2 + s3);
        let rs = reduce_scatter(&pl, 2);
        assert_eq!(rs.steps, p.steps);
    }
}
