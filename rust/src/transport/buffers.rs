//! Bounded intermediate-buffer pool with occupancy accounting, carved
//! from the transport [`Arena`](crate::transport::arena::Arena).
//!
//! PAT exists because intermediate buffers are scarce: NCCL pre-maps a
//! fixed-size staging region per peer, and the aggregation factor is
//! exactly "how many chunks fit". The pool hands out fixed-size slots
//! (one chunk each), fails fast if a schedule exceeds its capacity, and
//! records the high-water mark — the quantity the paper claims stays
//! logarithmic in rank count and independent of operation size.
//!
//! ## Offset math
//!
//! The arena holds two kinds of regions, both addressed by `(offset,
//! len)` descriptors; the engine computes the layout once per run.
//!
//! **Equal chunk grids** (primitive, composed, and channel-split
//! programs): the striped payload places chunk `c` of a `nchunks`-chunk
//! space at element offset
//!
//! ```text
//! off(c) = (c mod n) · L  +  (c div n) · sub        (within a payload)
//! ```
//!
//! where `n` is the rank count, `L = payload / n` the per-slot length,
//! and `sub = L / stripes` the per-stripe sublength — i.e. stripe `k`
//! of rank slot `r` for chunk `c = k·n + r`. This is the same layout
//! every program shape shares because ownership is `c mod n`
//! everywhere.
//!
//! **Sized chunk grids** (bucketed programs, where bucket payloads
//! differ): chunk `c` lives at the prefix sum of the per-chunk element
//! grid, `off(c) = Σ_{i<c} elems[i]`, and slots are sized
//! `max(elems)`.
//!
//! **Pool slots**: a pool backed by an arena region at base `B` with
//! `S` slots of `slot_elems` elements each places slot `i` at
//! `B + i · slot_elems`. Slot storage is reused through a free list of
//! offsets — acquire/release moves descriptors, never bytes — so the
//! steady-state path performs zero heap allocations; if a run
//! legitimately needs more live slots than the arena region holds
//! (unbounded pools measuring occupancy), the pool falls back to heap
//! vectors and counts each one in [`BufferPool::total_allocated`].

use std::sync::Arc;

use crate::core::{Error, Rank, Result};
use crate::obs::FlightRecorder;
use crate::transport::arena::Arena;

/// Annotate a pool-exhaustion error with the (rank, channel, step) that
/// hit it, so the failure site is blameable from the error text alone
/// (the adversary harness parses these coordinates back out).
fn blame_pool(e: Error, rank: Rank, channel: usize, step: usize) -> Error {
    match e {
        Error::Transport(m) => {
            Error::Transport(format!("{m} (rank {rank}, channel {channel}, step {step})"))
        }
        other => other,
    }
}

/// One staging/accumulator slot: an arena region descriptor, or a heap
/// vector when the arena region is exhausted. Carries its own `Arc` to
/// the arena so access never borrows the pool.
#[derive(Debug)]
pub enum Slot {
    Arena { arena: Arc<Arena>, off: usize, len: usize },
    Heap(Vec<f32>),
}

impl Slot {
    /// Mutable view of the slot's storage.
    pub fn data(&mut self) -> &mut [f32] {
        match self {
            // SAFETY: the pool hands out disjoint arena regions and the
            // slot holds exclusive access until released (module docs).
            Slot::Arena { arena, off, len } => unsafe { arena.slice_mut(*off, *len) },
            Slot::Heap(v) => v,
        }
    }

    /// Shared view of the slot's storage.
    pub fn as_slice(&self) -> &[f32] {
        match self {
            // SAFETY: as in `data` — the region is exclusively leased.
            Slot::Arena { arena, off, len } => unsafe { arena.slice(*off, *len) },
            Slot::Heap(v) => v,
        }
    }
}

/// A pool of `capacity` chunk-sized slots (`None` = unbounded, measuring
/// only), backed by an arena region when one is configured.
#[derive(Debug)]
pub struct BufferPool {
    slot_elems: usize,
    capacity: Option<usize>,
    /// Arena backing: `(arena, base_offset, slot_count)`.
    storage: Option<(Arc<Arena>, usize, usize)>,
    /// Next never-carved arena slot index.
    next: usize,
    /// Released arena slot offsets, ready for reuse.
    free_offs: Vec<usize>,
    /// Released heap slots, ready for reuse.
    free_heap: Vec<Vec<f32>>,
    live: usize,
    peak: usize,
    allocated: usize,
}

impl BufferPool {
    /// Heap-only pool (no arena region).
    pub fn new(slot_elems: usize, capacity: Option<usize>) -> BufferPool {
        BufferPool {
            slot_elems,
            capacity,
            storage: None,
            next: 0,
            free_offs: Vec::new(),
            free_heap: Vec::new(),
            live: 0,
            peak: 0,
            allocated: 0,
        }
    }

    /// Pool over the arena region `[base, base + slots · slot_elems)`.
    pub fn with_arena(
        slot_elems: usize,
        capacity: Option<usize>,
        arena: Arc<Arena>,
        base: usize,
        slots: usize,
    ) -> BufferPool {
        debug_assert!(base + slots * slot_elems <= arena.elems());
        BufferPool {
            slot_elems,
            capacity,
            storage: Some((arena, base, slots)),
            next: 0,
            free_offs: Vec::new(),
            free_heap: Vec::new(),
            live: 0,
            peak: 0,
            allocated: 0,
        }
    }

    /// Acquire a zeroed slot. Errors if the configured capacity would be
    /// exceeded — a PAT schedule that violates its own aggregation bound is
    /// a bug, not a condition to absorb.
    pub fn acquire(&mut self) -> Result<Slot> {
        if let Some(cap) = self.capacity {
            if self.live >= cap {
                return Err(Error::Transport(format!(
                    "buffer pool exhausted: {} live slots of capacity {cap}",
                    self.live
                )));
            }
        }
        self.live += 1;
        self.peak = self.peak.max(self.live);
        if let Some((arena, base, slots)) = &self.storage {
            let off = match self.free_offs.pop() {
                Some(off) => Some(off),
                None if self.next < *slots => {
                    let off = *base + self.next * self.slot_elems;
                    self.next += 1;
                    Some(off)
                }
                None => None,
            };
            if let Some(off) = off {
                let mut slot =
                    Slot::Arena { arena: arena.clone(), off, len: self.slot_elems };
                slot.data().fill(0.0);
                return Ok(slot);
            }
        }
        match self.free_heap.pop() {
            Some(mut v) => {
                v.fill(0.0);
                Ok(Slot::Heap(v))
            }
            None => {
                self.allocated += 1;
                Ok(Slot::Heap(vec![0.0; self.slot_elems]))
            }
        }
    }

    /// Return a slot to the pool.
    pub fn release(&mut self, slot: Slot) {
        self.live -= 1;
        match slot {
            Slot::Arena { off, len, .. } => {
                debug_assert_eq!(len, self.slot_elems);
                self.free_offs.push(off);
            }
            Slot::Heap(v) => {
                debug_assert_eq!(v.len(), self.slot_elems);
                self.free_heap.push(v);
            }
        }
    }

    /// Current live slots.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of simultaneously-live slots.
    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Heap vectors ever allocated — the allocation-pressure metric the
    /// perf pass gates on. Zero on the steady-state arena path; nonzero
    /// only when the pool outgrew its arena region (or has none).
    pub fn total_allocated(&self) -> usize {
        self.allocated
    }

    /// Accounting-only reservation: enforce and track occupancy without
    /// handing out storage. Used by the all-gather send path, where the
    /// wire message itself is the staging storage — copying into a
    /// separate slot would only model the same bytes twice (perf pass:
    /// −1 full payload copy per transfer; see EXPERIMENTS.md §Perf).
    pub fn reserve(&mut self, slots: usize) -> Result<()> {
        if let Some(cap) = self.capacity {
            if self.live + slots > cap {
                return Err(Error::Transport(format!(
                    "buffer pool exhausted: {} live + {slots} requested of capacity {cap}",
                    self.live
                )));
            }
        }
        self.live += slots;
        self.peak = self.peak.max(self.live);
        Ok(())
    }

    /// Release an accounting-only reservation.
    pub fn unreserve(&mut self, slots: usize) {
        debug_assert!(self.live >= slots);
        self.live -= slots;
    }

    // Traced variants: same transitions, plus a pool-occupancy sample into
    // the flight recorder (a no-op branch when tracing is off). The sample
    // carries the op coordinates so occupancy is attributable to the
    // (rank, channel, step) that moved it.

    /// [`BufferPool::acquire`] + occupancy sample. Exhaustion errors are
    /// annotated with the blamed (rank, channel, step) so adversarial
    /// episode reports can name the failure site.
    pub fn acquire_traced(
        &mut self,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<Slot> {
        let slot = self.acquire().map_err(|e| blame_pool(e, rank, channel, step))?;
        fr.pool(rank, channel, step, self.live);
        Ok(slot)
    }

    /// [`BufferPool::release`] + occupancy sample.
    pub fn release_traced(
        &mut self,
        slot: Slot,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) {
        self.release(slot);
        fr.pool(rank, channel, step, self.live);
    }

    /// [`BufferPool::reserve`] + occupancy sample. Exhaustion errors are
    /// annotated with the blamed (rank, channel, step), as in
    /// [`BufferPool::acquire_traced`].
    pub fn reserve_traced(
        &mut self,
        slots: usize,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        self.reserve(slots).map_err(|e| blame_pool(e, rank, channel, step))?;
        fr.pool(rank, channel, step, self.live);
        Ok(())
    }

    /// [`BufferPool::unreserve`] + occupancy sample.
    pub fn unreserve_traced(
        &mut self,
        slots: usize,
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) {
        self.unreserve(slots);
        fr.pool(rank, channel, step, self.live);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_and_reuses() {
        let mut p = BufferPool::new(8, Some(2));
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        assert_eq!(p.live(), 2);
        assert!(p.acquire().is_err());
        p.release(a);
        let c = p.acquire().unwrap();
        assert_eq!(p.peak(), 2);
        // slot reused, not newly allocated
        assert_eq!(p.total_allocated(), 2);
        p.release(b);
        p.release(c);
        assert_eq!(p.live(), 0);
    }

    #[test]
    fn acquired_slots_are_zeroed() {
        let mut p = BufferPool::new(4, None);
        let mut a = p.acquire().unwrap();
        a.data().fill(7.0);
        p.release(a);
        let b = p.acquire().unwrap();
        assert!(b.as_slice().iter().all(|&x| x == 0.0));
        p.release(b);
    }

    #[test]
    fn unbounded_never_errors() {
        let mut p = BufferPool::new(1, None);
        let slots: Vec<_> = (0..100).map(|_| p.acquire().unwrap()).collect();
        assert_eq!(p.peak(), 100);
        for s in slots {
            p.release(s);
        }
    }

    #[test]
    fn arena_backed_pool_is_allocation_free() {
        let arena = Arc::new(Arena::new(64).unwrap());
        // region [16, 16 + 3·8): 3 slots of 8 elems
        let mut p = BufferPool::with_arena(8, Some(4), arena.clone(), 16, 3);
        let mut a = p.acquire().unwrap();
        a.data().fill(5.0);
        let b = p.acquire().unwrap();
        let c = p.acquire().unwrap();
        assert!(matches!(a, Slot::Arena { .. }));
        assert!(matches!(c, Slot::Arena { .. }));
        // the 4th live slot exceeds the 3-slot region: heap fallback
        let d = p.acquire().unwrap();
        assert!(matches!(d, Slot::Heap(_)));
        assert_eq!(p.total_allocated(), 1);
        assert_eq!(p.peak(), 4);
        p.release(a);
        // reused arena slot comes back zeroed
        let e = p.acquire().unwrap();
        assert!(matches!(e, Slot::Arena { .. }));
        assert!(e.as_slice().iter().all(|&x| x == 0.0));
        assert_eq!(p.total_allocated(), 1);
        p.release(b);
        p.release(c);
        p.release(d);
        p.release(e);
        assert_eq!(p.live(), 0);
    }

    #[test]
    fn arena_slots_are_disjoint() {
        let arena = Arc::new(Arena::new(32).unwrap());
        let mut p = BufferPool::with_arena(4, None, arena, 0, 8);
        let mut offs = Vec::new();
        let slots: Vec<_> = (0..8).map(|_| p.acquire().unwrap()).collect();
        for s in &slots {
            match s {
                Slot::Arena { off, len, .. } => {
                    assert_eq!(*len, 4);
                    offs.push(*off);
                }
                Slot::Heap(_) => panic!("expected arena slots"),
            }
        }
        offs.sort_unstable();
        offs.dedup();
        assert_eq!(offs.len(), 8, "slot offsets overlap");
        for s in slots {
            p.release(s);
        }
    }
}
