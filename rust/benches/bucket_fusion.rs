//! Bucketed multi-collective fusion: fused vs sequential gradient-bucket
//! all-reduce on the 256-rank tapered three-level fat-tree.
//!
//! The question the `sched/bucket` subsystem answers: once a training
//! step's gradient is a stream of B back-to-back all-reduces, what does
//! fusing them into ONE program buy over running them one after another?
//! The sequential baseline runs each bucket's composed RS∘AG program to
//! completion before starting the next (B independent simulations, times
//! summed — no cross-operation overlap by construction). The fused
//! program staggers bucket `i+1`'s reduce-scatter into bucket `i`'s
//! all-gather and gives every bucket its own channel (own ECMP flows), so
//! the inter-operation latency chains hide behind each other and
//! concurrent buckets spread over parallel spines/cores. The sweep also
//! measures the ramp shape (first bucket half the steady size — the
//! pipeline fills sooner), and records the per-bucket wall-clock windows
//! (`SimReport::channel_spans` → `bucket::bucket_windows`) at the
//! headline point so the overlap itself is machine-readable, not just its
//! effect.
//!
//! `--smoke` runs a minimal configuration (CI bench-rot guard).

use patcol::coordinator::tuner::bucket_sizes;
use patcol::report::Report;
use patcol::sched::bucket::{self, BucketLayout};
use patcol::sched::pat;
use patcol::sim::{simulate, simulate_sized, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = if smoke { 64usize } else { 256usize };
    let topo =
        Topology::three_level(n, 8, 4, 4, 2, CostModel::ib_hdr_nic_bw(), 1.0, 0.25).unwrap();
    let cost = CostModel::ib_hdr();

    let rsp = pat::reduce_scatter(n, usize::MAX);
    let agp = pat::allgather(n, usize::MAX);

    // Total gradient bytes per rank for the whole batch.
    let totals: &[usize] = if smoke {
        &[64 << 10]
    } else {
        &[16 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20]
    };
    let bucket_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };

    let mut report = Report::new("bucket_fusion");
    report.param("nranks", Json::num(n as f64));
    report.param("topology", Json::str(topo.name.clone()));
    report.param("smoke", Json::Bool(smoke));

    // Sequential baseline: one composed RS∘AG program run to completion
    // per bucket. The program is loop-invariant and its simulated time
    // depends only on the per-chunk size, so both are memoized across the
    // sweep (equal-shape rows are nb identical simulations otherwise).
    let single = bucket::fuse(&bucket::uniform(&rsp, &agp, 1, 1)).unwrap();
    let mut seq_cache: Vec<(usize, f64)> = Vec::new();
    let mut seq_time = |cb: usize| -> f64 {
        if let Some(&(_, t)) = seq_cache.iter().find(|&&(c, _)| c == cb) {
            return t;
        }
        let t = simulate(&single, &topo, &cost, cb).unwrap().total_time;
        seq_cache.push((cb, t));
        t
    };
    // The fused program and its layout depend only on the bucket count —
    // build each once, outside the totals × shape sweep.
    let fused_by_nb: Vec<(usize, patcol::sched::Program, BucketLayout)> = bucket_counts
        .iter()
        .map(|&nb| {
            let buckets = bucket::uniform(&rsp, &agp, nb, 1);
            let layout = BucketLayout::of(&buckets);
            (nb, bucket::fuse(&buckets).unwrap(), layout)
        })
        .collect();

    println!(
        "\nbucketed all-reduce: fused one-program vs sequential per-bucket on {}:",
        topo.name
    );
    let mut t = Table::new(["total/rank", "buckets", "shape", "fused", "sequential", "speedup"]);
    for &total in totals {
        for (nb, fused, layout) in &fused_by_nb {
            let nb = *nb;
            for ramp in [false, true] {
                if nb == 1 && ramp {
                    continue;
                }
                let sizes = bucket_sizes(total, nb, ramp);
                // Per-bucket per-chunk bytes (each bucket has n chunks).
                let per_chunk: Vec<usize> =
                    sizes.iter().map(|&b| (b / n).max(1)).collect();
                let chunk_bytes = layout.chunk_elems(&per_chunk);
                let t_fused = simulate_sized(fused, &topo, &cost, &chunk_bytes)
                    .unwrap()
                    .total_time;
                let t_seq: f64 = per_chunk.iter().map(|&cb| seq_time(cb)).sum();
                t.row([
                    fmt_bytes(total),
                    format!("{nb}"),
                    (if ramp { "ramp" } else { "equal" }).to_string(),
                    fmt_time_s(t_fused),
                    fmt_time_s(t_seq),
                    format!("{:.2}x", t_seq / t_fused),
                ]);
                report.rows.push(Json::obj(vec![
                    ("total_bytes", Json::num(total as f64)),
                    ("buckets", Json::num(nb as f64)),
                    ("ramp", Json::Bool(ramp)),
                    ("fused_time", Json::num(t_fused)),
                    ("sequential_time", Json::num(t_seq)),
                    ("speedup", Json::num(t_seq / t_fused)),
                ]));
            }
        }
    }
    print!("{}", t.render());

    // Headline (the acceptance row): at 64 KiB/rank split into 4 equal
    // buckets, the fused program beats the sequential chain — the
    // cross-operation pipeline hides 3 of the 4 per-bucket latency chains
    // and the per-bucket channels spread over distinct spines/cores. The
    // margin is large (the sequential chain pays 4 full RS∘AG latency
    // chains back to back), so the assert holds at the smoke scale too.
    let total = 64 << 10;
    let nb = 4usize;
    let sizes = bucket_sizes(total, nb, false);
    let per_chunk: Vec<usize> = sizes.iter().map(|&b| (b / n).max(1)).collect();
    let (_, fused, layout) = fused_by_nb.iter().find(|&&(b, ..)| b == nb).unwrap();
    let rep = simulate_sized(fused, &topo, &cost, &layout.chunk_elems(&per_chunk)).unwrap();
    let t_fused = rep.total_time;
    let t_seq: f64 = per_chunk.iter().map(|&cb| seq_time(cb)).sum();
    println!(
        "\nfused bkt4 vs sequential x4 at {} per rank: {} vs {} ({:.2}x)",
        fmt_bytes(total),
        fmt_time_s(t_fused),
        fmt_time_s(t_seq),
        t_seq / t_fused
    );
    report.param("headline_speedup", Json::num(t_seq / t_fused));

    // The measured inter-bucket overlap at the headline point: bucket
    // i+1's window starts before bucket i's ends.
    let windows = bucket::bucket_windows(layout, &rep.channel_spans);
    let mut overlapped = 0usize;
    let rows: Vec<Json> = windows
        .iter()
        .map(|w| {
            Json::obj(vec![
                ("bucket", Json::num(w.bucket as f64)),
                ("t_start", Json::num(w.t_start)),
                ("t_end", Json::num(w.t_end)),
            ])
        })
        .collect();
    for w in windows.windows(2) {
        if w[1].t_start < w[0].t_end {
            overlapped += 1;
        }
    }
    report.param("headline_bucket_windows", Json::Arr(rows));
    println!(
        "bucket windows overlapping at the headline point: {overlapped}/{}",
        windows.len().saturating_sub(1)
    );
    assert_eq!(
        overlapped,
        windows.len().saturating_sub(1),
        "every adjacent bucket pair must overlap in the fused schedule"
    );
    assert!(
        t_fused < t_seq,
        "bucket fusion must pay at {} per rank: {t_fused} !< {t_seq}",
        fmt_bytes(total)
    );
    report.save().unwrap();
}
