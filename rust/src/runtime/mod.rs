//! PJRT runtime bridge: loads the AOT-compiled HLO-text artifacts produced
//! by `python/compile/aot.py` (L2 JAX graphs calling L1 Pallas kernels) and
//! executes them from the rust hot path. Python is never on this path —
//! artifacts are built once by `make artifacts`.
//!
//! Interchange format is HLO **text**: jax ≥ 0.5 serializes HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids and round-trips cleanly.

pub mod client;
pub mod artifacts;
pub mod service;

pub use artifacts::{ArtifactKind, ArtifactMeta, Registry};
pub use client::{Executable, PjrtContext};
pub use service::{default_reduce_shards, PjrtHandle, PjrtService};
