//! Network topologies. Each topology maps `nranks` endpoints onto a set of
//! directed links and yields, per (src, dst, flow-hash), the ordered link
//! path a message traverses.
//!
//! Links are directed and identified by dense `LinkId`s; each has its own
//! bandwidth so tapered tiers (the paper's "higher levels of the fabric
//! being tapered") are expressible directly.

use crate::core::{Error, Placement, Rank, Result};
use crate::sim::routing::flow_hash;

pub type LinkId = usize;

/// A directed link with a fixed bandwidth (bytes/second).
#[derive(Debug, Clone)]
pub struct Link {
    pub bandwidth: f64,
    /// Human-readable role, e.g. "nic_tx", "leaf_up", "spine_down".
    pub kind: LinkKind,
    /// Tier of the fabric this link belongs to (0 = NIC/leaf edge).
    pub level: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    NicTx,
    NicRx,
    Up,
    Down,
    Global,
    /// NVLink-class intra-node egress (see [`Topology::with_intra_node`]).
    IntraTx,
    /// NVLink-class intra-node ingress.
    IntraRx,
}

/// A topology instance: links plus routing.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nranks: usize,
    pub links: Vec<Link>,
    pub name: String,
    kind: Kind,
    /// NVLink-class intra-node tier (None = intra-node traffic rides the
    /// NIC links, the pre-`intra_gbps` behaviour).
    intra: Option<IntraNode>,
}

/// Modelled intra-node (NVLink-domain) links: contiguous nodes of
/// `ranks_per_node`, one Tx and one Rx link per rank starting at link id
/// `base`.
#[derive(Debug, Clone)]
struct IntraNode {
    ranks_per_node: usize,
    base: usize,
}

#[derive(Debug, Clone)]
enum Kind {
    /// Non-blocking crossbar: every message crosses src NIC-tx and dst
    /// NIC-rx only. The ideal α-β fabric.
    Flat,
    /// Two-level CLOS: `leaves` leaf switches × `ranks_per_leaf` ranks;
    /// every leaf connects to each of `spines` spine switches. Static ECMP
    /// picks the spine by flow hash.
    LeafSpine {
        ranks_per_leaf: usize,
        leaves: usize,
        spines: usize,
    },
    /// Three-level CLOS: pods of leaves with pod-local spines, cores above.
    /// Models the tapered top tier of large training fabrics.
    ThreeLevel {
        ranks_per_leaf: usize,
        leaves_per_pod: usize,
        pods: usize,
        spines_per_pod: usize,
        cores: usize,
    },
    /// Dragonfly-lite: fully-connected groups, one global link per group
    /// pair (heavily tapered by construction).
    Dragonfly { ranks_per_group: usize, groups: usize },
}

impl Topology {
    /// Ideal non-blocking fabric (pure α-β behaviour, no contention beyond
    /// the endpoints).
    pub fn flat(nranks: usize, nic_bw: f64) -> Topology {
        let mut links = Vec::with_capacity(2 * nranks);
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicTx, level: 0 });
        }
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicRx, level: 0 });
        }
        Topology {
            nranks,
            links,
            name: format!("flat({nranks})"),
            kind: Kind::Flat,
            intra: None,
        }
    }

    /// Two-level leaf-spine fat-tree. `taper` scales the per-spine uplink
    /// bandwidth: `taper = 1.0` is full bisection when
    /// `spines == ranks_per_leaf`; smaller means an oversubscribed fabric.
    pub fn leaf_spine(
        nranks: usize,
        ranks_per_leaf: usize,
        spines: usize,
        nic_bw: f64,
        taper: f64,
    ) -> Result<Topology> {
        if ranks_per_leaf == 0 || nranks % ranks_per_leaf != 0 {
            return Err(Error::Topology(format!(
                "nranks={nranks} not divisible by ranks_per_leaf={ranks_per_leaf}"
            )));
        }
        if spines == 0 {
            // A zero-spine fabric would panic in route() (modulo by zero).
            return Err(Error::Topology("leaf_spine needs at least one spine".into()));
        }
        let leaves = nranks / ranks_per_leaf;
        let up_bw = nic_bw * taper;
        let mut links = Vec::new();
        // [0, n): nic tx; [n, 2n): nic rx
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicTx, level: 0 });
        }
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicRx, level: 0 });
        }
        // per (leaf, spine): up then down
        for _leaf in 0..leaves {
            for _s in 0..spines {
                links.push(Link { bandwidth: up_bw, kind: LinkKind::Up, level: 1 });
                links.push(Link { bandwidth: up_bw, kind: LinkKind::Down, level: 1 });
            }
        }
        Ok(Topology {
            nranks,
            links,
            name: format!("leaf_spine({nranks},g={ranks_per_leaf},s={spines},t={taper})"),
            kind: Kind::LeafSpine { ranks_per_leaf, leaves, spines },
            intra: None,
        })
    }

    /// Three-level fat-tree: `pods` × `leaves_per_pod` × `ranks_per_leaf`
    /// ranks. `pod_taper` scales leaf→spine links, `core_taper` scales
    /// spine→core links (the paper's tapered top tier).
    pub fn three_level(
        nranks: usize,
        ranks_per_leaf: usize,
        leaves_per_pod: usize,
        spines_per_pod: usize,
        cores: usize,
        nic_bw: f64,
        pod_taper: f64,
        core_taper: f64,
    ) -> Result<Topology> {
        let pod_size = ranks_per_leaf * leaves_per_pod;
        if pod_size == 0 || nranks % pod_size != 0 {
            return Err(Error::Topology(format!(
                "nranks={nranks} not divisible by pod size {pod_size}"
            )));
        }
        if spines_per_pod == 0 || cores == 0 {
            // Zero spines/cores would panic in route() (modulo by zero).
            return Err(Error::Topology(
                "three_level needs at least one spine per pod and one core".into(),
            ));
        }
        let pods = nranks / pod_size;
        let leaves = pods * leaves_per_pod;
        let mut links = Vec::new();
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicTx, level: 0 });
        }
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicRx, level: 0 });
        }
        // per (leaf, spine-in-pod): up, down — level 1
        let spine_bw = nic_bw * pod_taper;
        for _leaf in 0..leaves {
            for _s in 0..spines_per_pod {
                links.push(Link { bandwidth: spine_bw, kind: LinkKind::Up, level: 1 });
                links.push(Link { bandwidth: spine_bw, kind: LinkKind::Down, level: 1 });
            }
        }
        // per (pod, spine, core): up, down — level 2
        let core_bw = nic_bw * core_taper;
        for _pod in 0..pods {
            for _s in 0..spines_per_pod {
                for _c in 0..cores {
                    links.push(Link { bandwidth: core_bw, kind: LinkKind::Up, level: 2 });
                    links.push(Link { bandwidth: core_bw, kind: LinkKind::Down, level: 2 });
                }
            }
        }
        Ok(Topology {
            nranks,
            links,
            name: format!(
                "three_level({nranks},g={ranks_per_leaf},lp={leaves_per_pod},sp={spines_per_pod},c={cores})"
            ),
            kind: Kind::ThreeLevel {
                ranks_per_leaf,
                leaves_per_pod,
                pods,
                spines_per_pod,
                cores,
            },
            intra: None,
        })
    }

    /// Dragonfly-lite: `groups` groups of `ranks_per_group`; intra-group is
    /// non-blocking, each group pair shares a single global link per
    /// direction at `global_bw`.
    pub fn dragonfly(
        nranks: usize,
        ranks_per_group: usize,
        nic_bw: f64,
        global_bw: f64,
    ) -> Result<Topology> {
        if ranks_per_group == 0 || nranks % ranks_per_group != 0 {
            return Err(Error::Topology(format!(
                "nranks={nranks} not divisible by ranks_per_group={ranks_per_group}"
            )));
        }
        let groups = nranks / ranks_per_group;
        let mut links = Vec::new();
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicTx, level: 0 });
        }
        for _ in 0..nranks {
            links.push(Link { bandwidth: nic_bw, kind: LinkKind::NicRx, level: 0 });
        }
        // one directed global link per ordered group pair (g1 != g2)
        for _ in 0..groups * groups {
            links.push(Link { bandwidth: global_bw, kind: LinkKind::Global, level: 1 });
        }
        Ok(Topology {
            nranks,
            links,
            name: format!("dragonfly({nranks},g={ranks_per_group})"),
            kind: Kind::Dragonfly { ranks_per_group, groups },
            intra: None,
        })
    }

    /// Model NVLink-class intra-node links distinct from the leaf NICs
    /// (the `intra_gbps` knob): ranks are grouped into contiguous nodes of
    /// `ranks_per_node`, and every same-node message rides a dedicated
    /// per-rank intra Tx/Rx link pair at `intra_bw` bytes/s instead of the
    /// NIC links — so hierarchical and composed schedules stop paying NIC
    /// serialization for local traffic.
    ///
    /// Nodes must sit inside one leaf switch (distance level 0): a node
    /// straddling a leaf would teleport fabric traffic onto the NVLink
    /// tier, so that is rejected with [`Error::Topology`].
    pub fn with_intra_node(mut self, ranks_per_node: usize, intra_bw: f64) -> Result<Topology> {
        if ranks_per_node == 0 {
            return Err(Error::Topology("ranks_per_node must be >= 1".into()));
        }
        if !(intra_bw.is_finite() && intra_bw > 0.0) {
            return Err(Error::Topology("intra-node bandwidth must be > 0".into()));
        }
        if self.intra.is_some() {
            return Err(Error::Topology(format!(
                "{} already has intra-node links",
                self.name
            )));
        }
        // Contiguous nodes; check each node's first and last rank share a
        // leaf (leaves are contiguous, so the whole node does).
        let mut lo = 0usize;
        while lo < self.nranks {
            let hi = (lo + ranks_per_node - 1).min(self.nranks - 1);
            if self.distance_level(lo, hi) != 0 {
                return Err(Error::Topology(format!(
                    "intra-node group [{lo}, {hi}] straddles a leaf of {}",
                    self.name
                )));
            }
            lo += ranks_per_node;
        }
        let base = self.links.len();
        for _ in 0..self.nranks {
            self.links.push(Link { bandwidth: intra_bw, kind: LinkKind::IntraTx, level: 0 });
        }
        for _ in 0..self.nranks {
            self.links.push(Link { bandwidth: intra_bw, kind: LinkKind::IntraRx, level: 0 });
        }
        self.name = format!("{}+intra(k={ranks_per_node})", self.name);
        self.intra = Some(IntraNode { ranks_per_node, base });
        Ok(self)
    }

    #[inline]
    fn nic_tx(&self, r: Rank) -> LinkId {
        r
    }
    #[inline]
    fn nic_rx(&self, r: Rank) -> LinkId {
        self.nranks + r
    }

    /// The ordered link path for a message `src → dst`. `flow` feeds the
    /// static ECMP hash (constant per (src,dst) pair in NCCL-like fabrics —
    /// callers pass 0 extra entropy for fully static routing).
    pub fn route(&self, src: Rank, dst: Rank, flow: u64) -> Vec<LinkId> {
        debug_assert!(src < self.nranks && dst < self.nranks);
        if src == dst {
            return vec![];
        }
        if let Some(intra) = &self.intra {
            if src / intra.ranks_per_node == dst / intra.ranks_per_node {
                return vec![intra.base + src, intra.base + self.nranks + dst];
            }
        }
        match &self.kind {
            Kind::Flat => vec![self.nic_tx(src), self.nic_rx(dst)],
            Kind::LeafSpine { ranks_per_leaf, leaves: _, spines } => {
                let ls = src / ranks_per_leaf;
                let ld = dst / ranks_per_leaf;
                if ls == ld {
                    return vec![self.nic_tx(src), self.nic_rx(dst)];
                }
                let s = (flow_hash(src as u64, dst as u64, flow) % *spines as u64) as usize;
                let base = 2 * self.nranks;
                let up = base + 2 * (ls * spines + s);
                let down = base + 2 * (ld * spines + s) + 1;
                vec![self.nic_tx(src), up, down, self.nic_rx(dst)]
            }
            Kind::ThreeLevel {
                ranks_per_leaf,
                leaves_per_pod,
                pods,
                spines_per_pod,
                cores,
            } => {
                let pod_size = ranks_per_leaf * leaves_per_pod;
                let (ps, pd) = (src / pod_size, dst / pod_size);
                let (ls, ld) = (src / ranks_per_leaf, dst / ranks_per_leaf);
                if ls == ld {
                    return vec![self.nic_tx(src), self.nic_rx(dst)];
                }
                let leaves = pods * leaves_per_pod;
                let spine_base = 2 * self.nranks;
                let core_base = spine_base + 2 * leaves * spines_per_pod;
                let s = (flow_hash(src as u64, dst as u64, flow) % *spines_per_pod as u64) as usize;
                if ps == pd {
                    // up to a pod spine, back down
                    let up = spine_base + 2 * (ls * spines_per_pod + s);
                    let down = spine_base + 2 * (ld * spines_per_pod + s) + 1;
                    return vec![self.nic_tx(src), up, down, self.nic_rx(dst)];
                }
                // cross-pod: leaf->spine, spine->core, core->spine', spine'->leaf'
                let c = (flow_hash(dst as u64, src as u64, flow ^ 0x9E37) % *cores as u64) as usize;
                let up1 = spine_base + 2 * (ls * spines_per_pod + s);
                let up2 = core_base + 2 * ((ps * spines_per_pod + s) * cores + c);
                let down2 = core_base + 2 * ((pd * spines_per_pod + s) * cores + c) + 1;
                let down1 = spine_base + 2 * (ld * spines_per_pod + s) + 1;
                vec![self.nic_tx(src), up1, up2, down2, down1, self.nic_rx(dst)]
            }
            Kind::Dragonfly { ranks_per_group, groups } => {
                let gs = src / ranks_per_group;
                let gd = dst / ranks_per_group;
                if gs == gd {
                    return vec![self.nic_tx(src), self.nic_rx(dst)];
                }
                let g = 2 * self.nranks + gs * groups + gd;
                vec![self.nic_tx(src), g, self.nic_rx(dst)]
            }
        }
    }

    /// Number of switch hops a message crosses (for α_hop): `route.len()`
    /// is the number of links; hops = links - 1 crossings of switching
    /// elements plus endpoint NICs. We use links-1 as the "switch traversal"
    /// count.
    pub fn hops(&self, src: Rank, dst: Rank) -> usize {
        if src == dst {
            0
        } else {
            self.route(src, dst, 0).len() - 1
        }
    }

    /// Topological distance classes for traffic accounting: the highest
    /// fabric level a (src,dst) message must cross (0 = same leaf /
    /// NIC-only, 1 = one switch tier, 2 = top tier).
    pub fn distance_level(&self, src: Rank, dst: Rank) -> usize {
        if src == dst {
            return 0;
        }
        match &self.kind {
            Kind::Flat => 0,
            Kind::LeafSpine { ranks_per_leaf, .. } => {
                if src / ranks_per_leaf == dst / ranks_per_leaf {
                    0
                } else {
                    1
                }
            }
            Kind::ThreeLevel { ranks_per_leaf, leaves_per_pod, .. } => {
                let pod = ranks_per_leaf * leaves_per_pod;
                if src / ranks_per_leaf == dst / ranks_per_leaf {
                    0
                } else if src / pod == dst / pod {
                    1
                } else {
                    2
                }
            }
            Kind::Dragonfly { ranks_per_group, .. } => {
                if src / ranks_per_group == dst / ranks_per_group {
                    0
                } else {
                    1
                }
            }
        }
    }

    /// Highest distance level present in this topology.
    pub fn max_level(&self) -> usize {
        match &self.kind {
            Kind::Flat => 0,
            Kind::LeafSpine { .. } | Kind::Dragonfly { .. } => 1,
            Kind::ThreeLevel { .. } => 2,
        }
    }

    /// Check that a [`Placement`] is compatible with this topology: the
    /// rank counts match and every node's ranks sit under a single leaf
    /// switch (distance level 0), so a hierarchical schedule's intra-node
    /// phases never touch the fabric. A node straddling a leaf boundary —
    /// e.g. a node size that does not divide the leaf radix — is rejected
    /// with [`Error::Topology`] instead of silently (or panickingly)
    /// misrouting. For three-level placements the same containment is
    /// checked one tier up: every placement pod must sit inside one
    /// fabric pod (distance level ≤ 1), so the three-level schedule's
    /// intra-pod rounds never cross the core tier it thinks it is
    /// avoiding.
    pub fn check_placement(&self, placement: &Placement) -> Result<()> {
        if placement.nranks() != self.nranks {
            return Err(Error::Topology(format!(
                "placement covers {} ranks, topology {} has {}",
                placement.nranks(),
                self.name,
                self.nranks
            )));
        }
        for node in 0..placement.nnodes() {
            let ranks = placement.ranks_of(node);
            let first = ranks[0];
            for &r in &ranks[1..] {
                if self.distance_level(first, r) != 0 {
                    return Err(Error::Topology(format!(
                        "placement node {node} (size {}) straddles a leaf of {}: \
                         ranks {first} and {r} are {} fabric level(s) apart \
                         (node size must divide the leaf radix)",
                        ranks.len(),
                        self.name,
                        self.distance_level(first, r)
                    )));
                }
            }
        }
        if placement.is_three_level() {
            for pod in 0..placement.npods() {
                let nodes = placement.pod_nodes(pod);
                let first = placement.ranks_of(nodes[0])[0];
                for &m in nodes {
                    for &r in placement.ranks_of(m) {
                        if self.distance_level(first, r) > 1 {
                            return Err(Error::Topology(format!(
                                "placement pod {pod} ({} nodes) straddles a pod of {}: \
                                 ranks {first} and {r} are {} fabric levels apart \
                                 (pod node-groups must divide the fabric pod)",
                                nodes.len(),
                                self.name,
                                self.distance_level(first, r)
                            )));
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_routes() {
        let t = Topology::flat(4, 10e9);
        assert_eq!(t.route(0, 3, 0), vec![0, 4 + 3]);
        assert_eq!(t.route(2, 2, 0), Vec::<usize>::new());
        assert_eq!(t.hops(0, 3), 1);
    }

    #[test]
    fn leaf_spine_local_vs_remote() {
        let t = Topology::leaf_spine(8, 4, 2, 10e9, 1.0).unwrap();
        // same leaf: 2 links
        assert_eq!(t.route(0, 3, 0).len(), 2);
        assert_eq!(t.distance_level(0, 3), 0);
        // cross leaf: 4 links
        assert_eq!(t.route(0, 7, 0).len(), 4);
        assert_eq!(t.distance_level(0, 7), 1);
    }

    #[test]
    fn leaf_spine_static_routing_is_deterministic() {
        let t = Topology::leaf_spine(16, 4, 4, 10e9, 1.0).unwrap();
        let p1 = t.route(1, 9, 0);
        let p2 = t.route(1, 9, 0);
        assert_eq!(p1, p2);
    }

    #[test]
    fn three_level_distances() {
        // 2 pods x 2 leaves x 4 ranks = 16
        let t = Topology::three_level(16, 4, 2, 2, 2, 10e9, 1.0, 0.5).unwrap();
        assert_eq!(t.distance_level(0, 3), 0); // same leaf
        assert_eq!(t.distance_level(0, 5), 1); // same pod, cross leaf
        assert_eq!(t.distance_level(0, 12), 2); // cross pod
        assert_eq!(t.route(0, 3, 0).len(), 2);
        assert_eq!(t.route(0, 5, 0).len(), 4);
        assert_eq!(t.route(0, 12, 0).len(), 6);
        assert_eq!(t.max_level(), 2);
    }

    #[test]
    fn three_level_core_links_tapered() {
        let t = Topology::three_level(16, 4, 2, 2, 2, 10e9, 1.0, 0.25).unwrap();
        let path = t.route(0, 12, 0);
        // third link is the spine->core uplink at core_taper bandwidth
        let core_link = &t.links[path[2]];
        assert_eq!(core_link.level, 2);
        assert!((core_link.bandwidth - 2.5e9).abs() < 1.0);
    }

    #[test]
    fn routes_are_valid_link_ids() {
        let topos = vec![
            Topology::flat(12, 1e9),
            Topology::leaf_spine(12, 3, 2, 1e9, 0.5).unwrap(),
            Topology::three_level(24, 2, 3, 2, 2, 1e9, 1.0, 0.5).unwrap(),
            Topology::dragonfly(12, 4, 1e9, 0.5e9).unwrap(),
        ];
        for t in &topos {
            for s in 0..t.nranks {
                for d in 0..t.nranks {
                    for l in t.route(s, d, 0) {
                        assert!(l < t.links.len(), "{} route {s}->{d}", t.name);
                    }
                }
            }
        }
    }

    #[test]
    fn dragonfly_global_links_shared() {
        let t = Topology::dragonfly(8, 4, 10e9, 5e9).unwrap();
        // both cross-group flows share the single g0->g1 global link
        let p1 = t.route(0, 4, 0);
        let p2 = t.route(1, 5, 0);
        assert_eq!(p1[1], p2[1]);
    }

    #[test]
    fn divisibility_checked() {
        assert!(Topology::leaf_spine(10, 4, 2, 1e9, 1.0).is_err());
        assert!(Topology::dragonfly(10, 4, 1e9, 1e9).is_err());
    }

    /// Constructor misuse that used to reach a panic path (modulo-by-zero
    /// in route()) is now a clean Error::Topology.
    #[test]
    fn degenerate_params_rejected_with_topology_error() {
        let err = Topology::leaf_spine(8, 4, 0, 1e9, 1.0).unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        let err = Topology::three_level(16, 4, 2, 0, 2, 1e9, 1.0, 1.0).unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        let err = Topology::three_level(16, 4, 2, 2, 0, 1e9, 1.0, 1.0).unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        let err = Topology::leaf_spine(10, 4, 2, 1e9, 1.0).unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
    }

    #[test]
    fn intra_node_links_route_local_traffic() {
        let t = Topology::leaf_spine(16, 4, 2, 25e9, 1.0)
            .unwrap()
            .with_intra_node(4, 200e9)
            .unwrap();
        // same node: two intra links at NVLink bandwidth
        let path = t.route(0, 3, 0);
        assert_eq!(path.len(), 2);
        for &l in &path {
            assert!(matches!(
                t.links[l].kind,
                LinkKind::IntraTx | LinkKind::IntraRx
            ));
            assert!((t.links[l].bandwidth - 200e9).abs() < 1.0);
        }
        // distance accounting unchanged: same leaf is still level 0
        assert_eq!(t.distance_level(0, 3), 0);
        // cross-node traffic still rides the NICs and the fabric
        let cross = t.route(0, 7, 0);
        assert_eq!(t.links[cross[0]].kind, LinkKind::NicTx);
        assert_eq!(cross.len(), 4);
        // link ids all valid
        for s in 0..t.nranks {
            for d in 0..t.nranks {
                for l in t.route(s, d, 0) {
                    assert!(l < t.links.len());
                }
            }
        }
    }

    #[test]
    fn intra_node_misuse_rejected() {
        // nodes of 5 straddle 4-rank leaves
        let err = Topology::leaf_spine(16, 4, 2, 25e9, 1.0)
            .unwrap()
            .with_intra_node(5, 200e9)
            .unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        assert!(err.to_string().contains("straddles"), "{err}");
        let t = Topology::flat(8, 25e9);
        assert!(t.clone().with_intra_node(0, 200e9).is_err());
        assert!(t.clone().with_intra_node(4, 0.0).is_err());
        // double application rejected
        let once = t.with_intra_node(4, 200e9).unwrap();
        assert!(once.with_intra_node(4, 200e9).is_err());
    }

    #[test]
    fn placement_compatibility() {
        let t = Topology::leaf_spine(16, 4, 2, 1e9, 1.0).unwrap();
        // nodes of 4 align with the 4-rank leaves
        t.check_placement(&Placement::uniform(16, 4).unwrap()).unwrap();
        // nodes of 2 also fit (two nodes per leaf)
        t.check_placement(&Placement::uniform(16, 2).unwrap()).unwrap();
        // nodes of 5 straddle leaf boundaries
        let err = t
            .check_placement(&Placement::uniform(16, 5).unwrap())
            .unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        assert!(err.to_string().contains("straddles"), "{err}");
        // rank-count mismatch
        let err = t
            .check_placement(&Placement::uniform(8, 4).unwrap())
            .unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        // the flat crossbar accepts anything (everything is level 0)
        Topology::flat(16, 1e9)
            .check_placement(&Placement::uniform(16, 5).unwrap())
            .unwrap();
    }

    /// Pod containment: a three-level placement is accepted only when
    /// every placement pod sits inside one fabric pod.
    #[test]
    fn placement_pod_compatibility() {
        // 2 pods × 4 leaves × 4 ranks = 32
        let t = Topology::three_level(32, 4, 4, 2, 2, 1e9, 1.0, 0.5).unwrap();
        // 4-node pods align with the 16-rank fabric pods
        t.check_placement(&Placement::parse("4x4", 32).unwrap()).unwrap();
        // two-level placements are untouched by the pod check
        t.check_placement(&Placement::uniform(32, 4).unwrap()).unwrap();
        // 3-node pods straddle the fabric-pod boundary (pod 1 holds
        // ranks 12..24, which span the core tier at rank 16)
        let err = t
            .check_placement(&Placement::parse("4x3", 32).unwrap())
            .unwrap_err();
        assert!(matches!(err, Error::Topology(_)), "{err}");
        assert!(err.to_string().contains("straddles a pod"), "{err}");
    }
}
