"""AOT pipeline: lower the L2 graphs (which call the L1 Pallas kernels) to
HLO **text** artifacts that the rust runtime loads via the `xla` crate.

HLO text — NOT serialized protos — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under --out-dir (default ../artifacts):

* reduce_f32_<n>.hlo.txt        pairwise Pallas reduction, size classes
* reduce<k>_f32_<n>.hlo.txt     fused k-way Pallas reduction
* scale_add_f32_<n>.hlo.txt     optimizer shard update (Pallas)
* train_step.hlo.txt            transformer loss+grads (value_and_grad)
* init_params.f32               initial flat parameter vector (raw LE f32)
* manifest.json                 registry consumed by rust/src/runtime

Usage: cd python && python -m compile.aot [--out-dir DIR] [--quick]
"""

import argparse
import json
import os
import struct

import jax
from jax._src.lib import xla_client as xc

from compile import model


REDUCE_SIZES = (1024, 16384, 262144)
REDUCE_K = 4
REDUCE_K_SIZES = (16384,)
SCALE_ADD_SIZES = (4096, 65536)
NRANKS_DEFAULT = 8  # zero_train's default world size


def to_hlo_text(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, name: str, fn, specs, entry: dict, manifest: list) -> None:
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(fn, specs)
    with open(path, "w") as f:
        f.write(text)
    manifest.append({"name": name, "file": f"{name}.hlo.txt", **entry})
    print(f"  wrote {path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--quick",
        action="store_true",
        help="small reduce kernels only (fast CI smoke)",
    )
    ap.add_argument("--nranks", type=int, default=NRANKS_DEFAULT,
                    help="world size the train-step shard artifacts target")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest: list = []

    reduce_sizes = REDUCE_SIZES[:1] if args.quick else REDUCE_SIZES
    for n in reduce_sizes:
        fn, specs = model.reduce2_graph(n)
        emit(args.out_dir, f"reduce_f32_{n}", fn, specs,
             {"kind": "reduce", "n": n, "k": 2}, manifest)

    if not args.quick:
        for n in REDUCE_K_SIZES:
            fn, specs = model.reduce_k_graph(n, REDUCE_K)
            emit(args.out_dir, f"reduce{REDUCE_K}_f32_{n}", fn, specs,
                 {"kind": "reduce_k", "n": n, "k": REDUCE_K}, manifest)

        for n in SCALE_ADD_SIZES:
            fn, specs = model.scale_add_graph(n)
            emit(args.out_dir, f"scale_add_f32_{n}", fn, specs,
                 {"kind": "scale_add", "n": n, "k": 2}, manifest)

        # Transformer train step + initial parameters.
        cfg = model.ModelConfig()
        fn, specs, nparams, flat0 = model.train_step_graph(cfg)
        emit(
            args.out_dir, "train_step", fn, specs,
            {
                "kind": "train_step",
                "n": nparams,
                "k": 2,
                "extra": {
                    "batch": cfg.batch,
                    "seq": cfg.seq,
                    "vocab": cfg.vocab,
                    "d_model": cfg.d_model,
                    "n_layers": cfg.n_layers,
                    "params": nparams,
                },
            },
            manifest,
        )
        # Shard-sized scale_add for the default world size (padded shard).
        shard = -(-nparams // args.nranks)
        shard = -(-shard // 128) * 128  # lane-align
        fn, specs = model.scale_add_graph(shard)
        emit(args.out_dir, f"scale_add_f32_{shard}", fn, specs,
             {"kind": "scale_add", "n": shard, "k": 2}, manifest)

        raw = bytes()
        import numpy as np

        raw = np.asarray(flat0, dtype="<f4").tobytes()
        with open(os.path.join(args.out_dir, "init_params.f32"), "wb") as f:
            f.write(raw)
        print(f"  wrote init_params.f32 ({len(raw)} bytes, {nparams} params)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest}, f, indent=2, sort_keys=True)
    print(f"  wrote manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
