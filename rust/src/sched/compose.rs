//! Collective composition: fuse a reduce-scatter program with an all-gather
//! program into one all-reduce [`Program`], with *segment pipelining*.
//!
//! NCCL builds all-reduce as reduce-scatter followed by all-gather — the
//! workload PAT's two primitives exist to serve. Run sequentially, the
//! composition executes both phases back to back. This module pipelines
//! the composition the way production collectives do, by *segmenting*:
//! the payload splits into `S` equal segments, each an independent
//! all-reduce over its own chunk space, staggered so that segment `i`'s
//! all-gather shares its step range with segment `i+1`'s reduce-scatter.
//!
//! Segments are **channels**: segment `s`'s ops are emitted on channel `s`
//! ([`Op::channel`]), using the shared merge machinery of
//! [`crate::sched::channel`] — the composer is a user of the IR's channel
//! dimension, not a chunk-id convention downstream layers re-infer. Two
//! execution models consume the fused program:
//!
//! * the reference executor runs each rank as ONE in-order stream (the
//!   merged op order below) — correctness and the fused staging-slot
//!   bound are checked there;
//! * the simulator and the threaded transport run each segment as its own
//!   NCCL-style channel (independent per-rank stream + per-channel
//!   connection), so segments genuinely overlap in time while contending
//!   for the same links.
//!
//! Where it pays: at latency-to-mid payload sizes the overlapping
//! channels fill each other's link idle gaps — and, since segments are
//! channels with their own statically-hashed flows, spread over distinct
//! spines/cores — so `pat+pat:4` beats the sequential `pat+pat:1` on the
//! 256-rank tapered fat-tree (~10% at 64 KiB/rank under the
//! channel-salted router). At bandwidth-bound sizes both phases saturate
//! the same tapered core links and the advantage shrinks toward the pure
//! path-spreading gain — `benches/allreduce_compose.rs` records the
//! whole sweep and the tuner sweeps segment counts against it.
//!
//! ## The IR-to-IR transform
//!
//! [`fuse`] takes *any* reduce-scatter program and *any* all-gather
//! program over the same rank count (mixed generator pairs are fine:
//! `pat+ring`, `hier_pat+pat`, …) and emits one [`Collective::AllReduce`]
//! program:
//!
//! * **Chunk renaming** — segment `s` of the payload uses chunk ids
//!   `s·n + c`; chunk `s·n + c` is owned by rank `c` (owner = id mod n),
//!   so the segments' chunk spaces are disjoint and the verifier /
//!   transport can execute all segments through one shared state machine.
//! * **Step staggering** — segment `s`'s reduce-scatter occupies global
//!   steps `[s·R, s·R + R)` and its all-gather `[(s+1)·R, (s+1)·R + A)`
//!   (`R`/`A` = phase step counts), so segment `s`'s all-gather shares its
//!   step range with segment `s+1`'s reduce-scatter — that is the overlap.
//! * **FIFO-safe interleaving** — each rank's composed op list is the
//!   [`crate::sched::channel::merge_rank_streams`] merge of its 2·S
//!   per-phase streams ordered by `(global step, segment, phase)`,
//!   preserving original in-stream order. Because every rank merges by the
//!   same key and a message's send and recv carry the same step in the
//!   source programs, the k-th send `s → d` still faces the k-th recv at
//!   `d` from `s`: per-connection FIFO survives composition.
//! * **Mirror reuse** — reduce-scatter phase programs come from
//!   [`Program::mirror`] exactly as for the standalone collective; the
//!   composer never re-derives a schedule, it only renames and interleaves.
//!
//! Receives keep their phase semantics through the `reduce` flag:
//! reducing receives accumulate partial sums until a chunk's owner holds
//! the complete reduction, plain receives install the rebroadcast final
//! value (see `sched::verify::verify_program` for the reference executor
//! and `transport::run_allreduce` for the real-byte engine).
//!
//! The same stagger generalizes across *operations*:
//! [`crate::sched::bucket`] fuses a batch of independent all-reduces
//! (gradient buckets, sizes may differ) by treating each bucket the way
//! this module treats a segment — uniform single-segment buckets produce
//! exactly this module's output.

use crate::core::{ChunkId, Collective, Error, Placement, Result};
use crate::sched::channel;
use crate::sched::program::Program;

/// Which half of the composition a step/message belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    ReduceScatter,
    AllGather,
}

impl Phase {
    pub fn as_str(&self) -> &'static str {
        match self {
            Phase::ReduceScatter => "reduce-scatter",
            Phase::AllGather => "all-gather",
        }
    }
}

/// The step grid of a composed program: where each segment's two phases
/// sit, and how they overlap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Layout {
    pub nranks: usize,
    pub segments: usize,
    /// Step count of one segment's reduce-scatter phase (the stagger).
    pub rs_steps: usize,
    /// Step count of one segment's all-gather phase.
    pub ag_steps: usize,
}

impl Layout {
    /// Layout of [`fuse`]`(rs, ag, segments)` without building the fused
    /// program.
    pub fn of(rs: &Program, ag: &Program, segments: usize) -> Layout {
        Layout {
            nranks: rs.nranks,
            segments,
            rs_steps: rs.steps,
            ag_steps: ag.steps,
        }
    }

    /// Total logical steps of the fused program.
    pub fn total_steps(&self) -> usize {
        if self.segments == 0 {
            return 0;
        }
        self.segments * self.rs_steps + self.ag_steps
    }

    /// Global step range `[start, end)` of `segment`'s phase.
    pub fn span(&self, segment: usize, phase: Phase) -> (usize, usize) {
        debug_assert!(segment < self.segments);
        let base = segment * self.rs_steps;
        match phase {
            Phase::ReduceScatter => (base, base + self.rs_steps),
            Phase::AllGather => (base + self.rs_steps, base + self.rs_steps + self.ag_steps),
        }
    }

    /// Classify a message of the fused program by its step and first chunk
    /// id: `(segment, phase)`. The step alone is ambiguous (overlap is the
    /// point), the chunk id pins the segment, and the step then pins the
    /// phase.
    pub fn classify(&self, step: usize, chunk: ChunkId) -> (usize, Phase) {
        let segment = (chunk / self.nranks.max(1)).min(self.segments.saturating_sub(1));
        let (_, rs_end) = self.span(segment, Phase::ReduceScatter);
        if step < rs_end {
            (segment, Phase::ReduceScatter)
        } else {
            (segment, Phase::AllGather)
        }
    }
}

/// The wall-clock window one (segment, phase) occupied in a simulation —
/// built from the simulator's per-step spans so phase overlap is directly
/// visible (segment `i`'s all-gather window intersecting segment `i+1`'s
/// reduce-scatter window).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseWindow {
    pub segment: usize,
    pub phase: Phase,
    /// Global step range `[start, end)`.
    pub steps: (usize, usize),
    /// Earliest link-serialization start of any message in the phase.
    pub t_start: f64,
    /// Latest arrival of any message in the phase.
    pub t_end: f64,
}

/// Aggregate the simulator's per-step `(start, end)` spans (see
/// `sim::SimReport::step_spans`) into per-(segment, phase) windows. Steps
/// with no messages (the simulator's `(+inf, -inf)` sentinel) are skipped;
/// phases with no traffic at all are omitted.
pub fn phase_windows(layout: &Layout, step_spans: &[(f64, f64)]) -> Vec<PhaseWindow> {
    let mut out = Vec::new();
    for segment in 0..layout.segments {
        for phase in [Phase::ReduceScatter, Phase::AllGather] {
            let (lo, hi) = layout.span(segment, phase);
            let mut t_start = f64::INFINITY;
            let mut t_end = f64::NEG_INFINITY;
            for step in lo..hi.min(step_spans.len()) {
                let (s, e) = step_spans[step];
                if s.is_finite() {
                    t_start = t_start.min(s);
                    t_end = t_end.max(e);
                }
            }
            if t_start.is_finite() {
                out.push(PhaseWindow { segment, phase, steps: (lo, hi), t_start, t_end });
            }
        }
    }
    out
}

/// Fuse a reduce-scatter program and an all-gather program over the same
/// rank count into one pipelined all-reduce program with `segments`
/// payload segments (see the module docs for the construction).
pub fn fuse(rs: &Program, ag: &Program, segments: usize) -> Result<Program> {
    if rs.collective != Collective::ReduceScatter {
        return Err(Error::Schedule(format!(
            "compose: reduce-scatter phase is a {} program",
            rs.collective
        )));
    }
    if ag.collective != Collective::AllGather {
        return Err(Error::Schedule(format!(
            "compose: all-gather phase is a {} program",
            ag.collective
        )));
    }
    if rs.nranks != ag.nranks {
        return Err(Error::Schedule(format!(
            "compose: phase rank counts differ ({} vs {})",
            rs.nranks, ag.nranks
        )));
    }
    if segments == 0 {
        return Err(Error::Schedule("compose: segments must be >= 1".into()));
    }
    if rs.channels > 1 || ag.channels > 1 {
        // The segment chunk renaming assumes the phases' n-chunk space;
        // split the *fused* program instead (channels compose that way).
        return Err(Error::Schedule(
            "compose: phase programs must be single-channel (apply \
             channel::split to the fused program)"
                .into(),
        ));
    }
    let n = rs.nranks;
    let layout = Layout::of(rs, ag, segments);
    let name = format!("{}+{}:{segments}", rs.algorithm, ag.algorithm);
    let mut out = Program::new(n, Collective::AllReduce, name);

    // Per rank: merge the 2·segments phase streams by (global step,
    // stream index = segment·2 + phase), preserving in-stream order — a
    // segment's RS stream sits before its AG stream so they order
    // correctly if they ever share a step. Segment `seg` IS channel `seg`
    // of the fused program.
    for rank in 0..n {
        let mut streams: Vec<channel::Stream<'_>> = Vec::with_capacity(2 * segments);
        for seg in 0..segments {
            let (rs_lo, _) = layout.span(seg, Phase::ReduceScatter);
            let (ag_lo, _) = layout.span(seg, Phase::AllGather);
            streams.push(channel::Stream {
                ops: &rs.ranks[rank],
                step_base: rs_lo,
                chunk_base: seg * n,
                channel_base: seg,
            });
            streams.push(channel::Stream {
                ops: &ag.ranks[rank],
                step_base: ag_lo,
                chunk_base: seg * n,
                channel_base: seg,
            });
        }
        channel::merge_rank_streams(&mut out, rank, &streams);
    }
    Ok(out)
}

/// Convenience front-end: build the two phase programs for an algorithm
/// pair over `nranks` (hierarchical phases use `placement`, or contiguous
/// default-sized nodes when absent) and fuse them.
pub fn allreduce(
    rs: crate::core::PhaseAlg,
    ag: crate::core::PhaseAlg,
    segments: usize,
    nranks: usize,
    placement: Option<&Placement>,
) -> Result<Program> {
    let build = |alg: crate::core::Algorithm, coll: Collective| match placement {
        Some(pl) => crate::sched::generate_placed(alg, coll, pl),
        None => crate::sched::generate(alg, coll, nranks),
    };
    let rsp = build(rs.to_algorithm(), Collective::ReduceScatter)?;
    let agp = build(ag.to_algorithm(), Collective::AllGather)?;
    fuse(&rsp, &agp, segments)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::PhaseAlg;
    use crate::sched::program::Op;
    use crate::sched::verify::verify_program;
    use crate::sched::{pat, ring};

    #[test]
    fn rejects_bad_inputs() {
        let ag = pat::allgather(8, 2);
        let rs = pat::reduce_scatter(8, 2);
        // wrong collectives in either slot
        assert!(fuse(&ag, &ag, 1).is_err());
        assert!(fuse(&rs, &rs, 1).is_err());
        // rank mismatch
        assert!(fuse(&pat::reduce_scatter(4, 2), &ag, 1).is_err());
        // zero segments
        assert!(fuse(&rs, &ag, 0).is_err());
        // multi-channel phases: split the fused program instead
        let split_rs = crate::sched::channel::split(&rs, 2).unwrap();
        assert!(fuse(&split_rs, &ag, 1).is_err());
    }

    #[test]
    fn layout_spans_overlap_between_adjacent_segments() {
        let rs = pat::reduce_scatter(8, 2);
        let ag = ring::allgather(8);
        let l = Layout::of(&rs, &ag, 3);
        assert_eq!(l.total_steps(), 3 * rs.steps + ag.steps);
        let (a0, a1) = l.span(0, Phase::AllGather);
        let (r0, r1) = l.span(1, Phase::ReduceScatter);
        // segment 0's all-gather shares its step range with segment 1's
        // reduce-scatter — the pipelining overlap.
        assert_eq!(a0, r0);
        assert!(a0 < r1 && r0 < a1);
        let p = fuse(&rs, &ag, 3).unwrap();
        assert_eq!(p.steps, l.total_steps());
    }

    #[test]
    fn fused_program_verifies_and_remaps_chunks() {
        let n = 8;
        let rs = pat::reduce_scatter(n, 2);
        let ag = ring::allgather(n);
        let p = fuse(&rs, &ag, 2).unwrap();
        assert_eq!(p.collective, Collective::AllReduce);
        assert_eq!(p.chunk_space(), 2 * n);
        verify_program(&p).unwrap();
        // chunk transfers: both phases move n(n-1) chunks per segment
        assert_eq!(p.stats().chunk_transfers, 2 * 2 * n * (n - 1));
        // segments are first-class channels: every op runs on the channel
        // of its segment (chunk ids `seg·n + c`)
        assert_eq!(p.channels, 2);
        for ops in &p.ranks {
            for op in ops {
                let seg = op.chunks().first().map(|&c| c / n).unwrap_or(0);
                assert_eq!(op.channel(), seg);
            }
        }
    }

    #[test]
    fn single_segment_is_sequential_composition() {
        let n = 6;
        let rs = pat::reduce_scatter(n, 2);
        let ag = pat::allgather(n, 2);
        let p = fuse(&rs, &ag, 1).unwrap();
        verify_program(&p).unwrap();
        // every rank's op list is its RS ops then its AG ops
        for r in 0..n {
            assert_eq!(p.ranks[r].len(), rs.ranks[r].len() + ag.ranks[r].len());
            for (i, op) in p.ranks[r].iter().enumerate() {
                let reduce_phase = i < rs.ranks[r].len();
                if let Op::Recv { reduce, .. } = op {
                    assert_eq!(*reduce, reduce_phase, "rank {r} op {i}");
                }
            }
        }
    }

    #[test]
    fn mixed_pairs_verify() {
        for n in [2usize, 3, 7, 12, 16] {
            for (rs, ag) in [
                (PhaseAlg::Pat { aggregation: usize::MAX }, PhaseAlg::Ring),
                (PhaseAlg::Ring, PhaseAlg::Pat { aggregation: 2 }),
                (PhaseAlg::BruckFarFirst, PhaseAlg::BruckNearFirst),
                (
                    PhaseAlg::HierPat { aggregation: 2 },
                    PhaseAlg::Pat { aggregation: 2 },
                ),
            ] {
                for segments in [1usize, 2, 4] {
                    let p = allreduce(rs, ag, segments, n, None).unwrap();
                    verify_program(&p).unwrap_or_else(|e| {
                        panic!("{}+{} n={n} s={segments}: {e}", rs.spec(), ag.spec())
                    });
                }
            }
        }
    }

    #[test]
    fn degenerate_single_rank() {
        let p = allreduce(
            PhaseAlg::Pat { aggregation: 1 },
            PhaseAlg::Pat { aggregation: 1 },
            4,
            1,
            None,
        )
        .unwrap();
        assert_eq!(p.total_ops(), 0);
        verify_program(&p).unwrap();
    }

    #[test]
    fn classify_disambiguates_overlapping_steps() {
        let rs = pat::reduce_scatter(8, 2);
        let ag = pat::allgather(8, 2);
        let l = Layout::of(&rs, &ag, 2);
        let overlap_step = rs.steps; // first step of seg0 AG and seg1 RS
        assert_eq!(l.classify(overlap_step, 0), (0, Phase::AllGather));
        assert_eq!(l.classify(overlap_step, 8), (1, Phase::ReduceScatter));
    }
}
