//! The unified event schema and the recorders that fill it.
//!
//! One flat [`Event`] struct covers every executor (the discrete-event
//! simulator and the threaded transport): a `kind` discriminant, the
//! (rank, channel, step) coordinates every event carries, optional
//! message fields (peer, chunk count, first chunk id, bytes), and a
//! `[t_start, t_end]` window in seconds from the run origin. Executors
//! that cannot produce a given kind simply never emit it — the *schema*
//! is identical either way, which is what lets one exporter and one
//! counter set serve both.

use std::collections::BTreeMap;

use crate::core::{ChunkId, Rank};

/// Version of the event schema (also stamped into exported Chrome
/// traces). Bumped whenever a field is added; see the stability guarantee
/// in [`crate::obs`].
///
/// v3 (additive over v2): the [`EventKind::Arena`] counter kind — arena
/// occupancy in bytes as a timeline curve rather than only a join-time
/// counter. v2 traces remain loadable: consumers that predate the kind
/// skip it, and [`crate::obs::chrome::import_chrome_trace`] tolerates
/// documents missing it.
///
/// v4 (additive over v3): the [`EventKind::Adversary`] span kind —
/// schedule-exploration provenance from [`crate::adversary`] (episode
/// outcomes on channel 0, shrink trials on channel 1, both on a
/// synthetic per-index timeline). Older traces remain loadable as
/// before.
pub const SCHEMA_VERSION: u32 = 4;

/// What an [`Event`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EventKind {
    /// A `Send` op occupying its (rank, channel) stream: pack + post.
    SendOp,
    /// A `Recv` op occupying its stream: match + unpack (+ reduce).
    RecvOp,
    /// A message in flight: serialization start → arrival (simulator) or
    /// post → FIFO match (transport). `rank` is the *source*, `peer` the
    /// destination.
    Wire,
    /// A channel blocked on an unmatched receive. In the transport this
    /// is time the whole rank thread spent parked, attributed to each
    /// channel that was blocked during the park.
    Stall,
    /// One reduction-kernel invocation on the receive datapath.
    Reduce,
    /// Buffer-pool occupancy sample: `value` = live slots after a
    /// transition (counter event, `t_start == t_end`).
    Pool,
    /// Arena occupancy sample: `value` = bytes of arena footprint in use
    /// (pool slots + wire regions) at the sample instant (counter event,
    /// `t_start == t_end`). Schema v3; transport-only.
    Arena,
    /// Schedule-exploration provenance from [`crate::adversary`] (schema
    /// v4). Emitted on a synthetic per-index timeline (seconds = episode
    /// or trial index, not wall time): channel 0 events are episode
    /// outcomes (`step` = episode index, `value` = deviations applied,
    /// `bytes` = 1 on a failing episode, 0 on a clean one); channel 1
    /// events are shrink trials (`step` = trial index, `value` =
    /// surviving deviations, `bytes` = 1 when the trial reproduced the
    /// blame).
    Adversary,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::SendOp => "send",
            EventKind::RecvOp => "recv",
            EventKind::Wire => "wire",
            EventKind::Stall => "stall",
            EventKind::Reduce => "reduce",
            EventKind::Pool => "pool",
            EventKind::Arena => "arena",
            EventKind::Adversary => "adversary",
        }
    }
}

/// One timeline event. Fields that do not apply to a kind hold their
/// neutral value (`None` / `0`); see [`EventKind`] for which apply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    /// Emitting rank (for [`EventKind::Wire`]: the source rank).
    pub rank: Rank,
    pub channel: usize,
    pub step: usize,
    /// Message peer (Wire: destination; SendOp/RecvOp/Stall: remote rank).
    pub peer: Option<Rank>,
    /// Chunks aggregated into the message (0 for non-message events).
    pub chunks: usize,
    /// First chunk id of the message — what pins a composed event to its
    /// pipeline segment / bucket (see [`crate::sched::compose::Layout`]).
    pub chunk0: Option<ChunkId>,
    /// Payload bytes (message and reduce events).
    pub bytes: usize,
    /// Kind-specific magnitude (Pool: live slots after the transition).
    pub value: usize,
    /// Seconds from the run origin.
    pub t_start: f64,
    /// Seconds from the run origin (`== t_start` for counter samples).
    pub t_end: f64,
}

impl Event {
    /// A bare span of `kind` at (rank, channel, step) — message fields
    /// default to empty; chain the `with_*` builders below.
    pub fn span(
        kind: EventKind,
        rank: Rank,
        channel: usize,
        step: usize,
        t_start: f64,
        t_end: f64,
    ) -> Event {
        Event {
            kind,
            rank,
            channel,
            step,
            peer: None,
            chunks: 0,
            chunk0: None,
            bytes: 0,
            value: 0,
            t_start,
            t_end,
        }
    }

    pub fn with_peer(mut self, peer: Rank) -> Event {
        self.peer = Some(peer);
        self
    }

    /// Attach message payload facts: chunk count, first chunk id, bytes.
    pub fn with_msg(mut self, chunks: &[ChunkId], bytes: usize) -> Event {
        self.chunks = chunks.len();
        self.chunk0 = chunks.first().copied();
        self.bytes = bytes;
        self
    }

    pub fn with_bytes(mut self, bytes: usize) -> Event {
        self.bytes = bytes;
        self
    }

    pub fn with_value(mut self, value: usize) -> Event {
        self.value = value;
        self
    }

    pub fn duration(&self) -> f64 {
        (self.t_end - self.t_start).max(0.0)
    }
}

/// Per-(rank, channel) aggregate counters, maintained incrementally as
/// events are recorded — cheap to read even when the event ring has
/// wrapped (the counters never drop).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Counters {
    pub bytes_sent: usize,
    pub bytes_recv: usize,
    pub msgs_sent: usize,
    pub msgs_recv: usize,
    /// Total seconds this channel sat blocked on unmatched receives.
    pub stall_seconds: f64,
    /// Total seconds spent in reduction-kernel invocations.
    pub reduce_seconds: f64,
    pub reduce_calls: usize,
    /// Peak buffer-pool occupancy observed while this channel was active.
    pub pool_peak: usize,
    /// Arena high-water mark in bytes: the largest footprint (pool slots +
    /// wire regions) this rank actually touched. Set at thread join by the
    /// transport (schema v2); 0 for executors without an arena.
    pub arena_hw_bytes: usize,
    /// Heap allocations on the steady-state datapath (pool slots that
    /// fell back to the heap). Set at thread join by the transport
    /// (schema v2); the zero-alloc gate asserts this stays 0 on a warm
    /// arena cache.
    pub allocs: usize,
}

impl Counters {
    /// Fold one event into the counter set.
    pub fn absorb(&mut self, ev: &Event) {
        match ev.kind {
            EventKind::SendOp => {
                self.msgs_sent += 1;
                self.bytes_sent += ev.bytes;
            }
            EventKind::RecvOp => {
                self.msgs_recv += 1;
                self.bytes_recv += ev.bytes;
            }
            EventKind::Stall => self.stall_seconds += ev.duration(),
            EventKind::Reduce => {
                self.reduce_calls += 1;
                self.reduce_seconds += ev.duration();
            }
            EventKind::Pool => self.pool_peak = self.pool_peak.max(ev.value),
            EventKind::Arena => {
                self.arena_hw_bytes = self.arena_hw_bytes.max(ev.value)
            }
            // Harness provenance, not traffic: nothing to count.
            EventKind::Wire | EventKind::Adversary => {}
        }
    }

    /// Element-wise sum (for run totals).
    pub fn merge(&mut self, other: &Counters) {
        self.bytes_sent += other.bytes_sent;
        self.bytes_recv += other.bytes_recv;
        self.msgs_sent += other.msgs_sent;
        self.msgs_recv += other.msgs_recv;
        self.stall_seconds += other.stall_seconds;
        self.reduce_seconds += other.reduce_seconds;
        self.reduce_calls += other.reduce_calls;
        self.pool_peak = self.pool_peak.max(other.pool_peak);
        self.arena_hw_bytes = self.arena_hw_bytes.max(other.arena_hw_bytes);
        self.allocs += other.allocs;
    }
}

/// A finished recording: the merged event timeline plus the per-(rank,
/// channel) counters — the thing [`crate::transport::TransportReport`]
/// carries and the Chrome exporter consumes.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    /// Events sorted by `t_start` (after [`Trace::sort`] / merge).
    pub events: Vec<Event>,
    pub counters: BTreeMap<(Rank, usize), Counters>,
    /// Events lost to flight-recorder ring wrap (0 for unbounded
    /// recorders; counters above are *not* affected by drops).
    pub dropped: u64,
}

impl Trace {
    /// Merge another trace (e.g. one rank thread's flight recording) into
    /// this one. Call [`Trace::sort`] once after the last absorb.
    pub fn absorb(&mut self, other: Trace) {
        self.events.extend(other.events);
        for (k, c) in other.counters {
            self.counters.entry(k).or_default().merge(&c);
        }
        self.dropped += other.dropped;
    }

    pub fn sort(&mut self) {
        self.events
            .sort_by(|a, b| a.t_start.total_cmp(&b.t_start));
    }

    pub fn counters_for(&self, rank: Rank, channel: usize) -> Counters {
        self.counters.get(&(rank, channel)).copied().unwrap_or_default()
    }

    /// Sum of every (rank, channel) counter set.
    pub fn totals(&self) -> Counters {
        let mut t = Counters::default();
        for c in self.counters.values() {
            t.merge(c);
        }
        t
    }

    /// Derived view: wall-clock window of each logical step over the
    /// trace's [`EventKind::Wire`] events — `(earliest start, latest
    /// end)`, `(+inf, -inf)` sentinel for silent steps. For a simulator
    /// trace this reproduces `SimReport::step_spans` exactly.
    pub fn step_spans(&self, steps: usize) -> Vec<(f64, f64)> {
        let mut spans = vec![(f64::INFINITY, f64::NEG_INFINITY); steps];
        for ev in self.events.iter().filter(|e| e.kind == EventKind::Wire) {
            if let Some(s) = spans.get_mut(ev.step) {
                s.0 = s.0.min(ev.t_start);
                s.1 = s.1.max(ev.t_end);
            }
        }
        spans
    }

    /// Derived view: wall-clock window of each channel's wire traffic
    /// (see [`Trace::step_spans`]); reproduces `SimReport::channel_spans`
    /// for simulator traces.
    pub fn channel_spans(&self, channels: usize) -> Vec<(f64, f64)> {
        let mut spans = vec![(f64::INFINITY, f64::NEG_INFINITY); channels];
        for ev in self.events.iter().filter(|e| e.kind == EventKind::Wire) {
            if let Some(s) = spans.get_mut(ev.channel) {
                s.0 = s.0.min(ev.t_start);
                s.1 = s.1.max(ev.t_end);
            }
        }
        spans
    }
}

/// Unbounded recorder — what the simulator writes into (the discrete
/// event loop is single-threaded, so no ring or thread-locality games
/// are needed; the transport uses [`crate::obs::FlightRecorder`]).
#[derive(Debug, Default)]
pub struct TraceRecorder {
    events: Vec<Event>,
    counters: BTreeMap<(Rank, usize), Counters>,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    pub fn record(&mut self, ev: Event) {
        self.counters
            .entry((ev.rank, ev.channel))
            .or_default()
            .absorb(&ev);
        self.events.push(ev);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Consume into a sorted [`Trace`].
    pub fn finish(self) -> Trace {
        let mut t = Trace { events: self.events, counters: self.counters, dropped: 0 };
        t.sort();
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wire(rank: Rank, channel: usize, step: usize, t0: f64, t1: f64) -> Event {
        Event::span(EventKind::Wire, rank, channel, step, t0, t1)
            .with_peer(rank + 1)
            .with_msg(&[3, 7], 128)
    }

    #[test]
    fn counters_accumulate_per_kind() {
        let mut rec = TraceRecorder::new();
        rec.record(Event::span(EventKind::SendOp, 0, 0, 0, 0.0, 1.0).with_bytes(100));
        rec.record(Event::span(EventKind::SendOp, 0, 0, 1, 1.0, 2.0).with_bytes(50));
        rec.record(Event::span(EventKind::RecvOp, 0, 0, 1, 2.0, 3.0).with_bytes(70));
        rec.record(Event::span(EventKind::Stall, 0, 0, 1, 3.0, 3.5));
        rec.record(Event::span(EventKind::Reduce, 0, 0, 1, 3.5, 4.0).with_bytes(70));
        rec.record(Event::span(EventKind::Pool, 0, 0, 1, 4.0, 4.0).with_value(3));
        rec.record(Event::span(EventKind::Pool, 0, 0, 2, 4.5, 4.5).with_value(2));
        // a second channel keeps its own row
        rec.record(Event::span(EventKind::SendOp, 0, 1, 0, 0.0, 1.0).with_bytes(9));
        let trace = rec.finish();
        let c = trace.counters_for(0, 0);
        assert_eq!(c.msgs_sent, 2);
        assert_eq!(c.bytes_sent, 150);
        assert_eq!(c.msgs_recv, 1);
        assert_eq!(c.bytes_recv, 70);
        assert!((c.stall_seconds - 0.5).abs() < 1e-12);
        assert_eq!(c.reduce_calls, 1);
        assert!((c.reduce_seconds - 0.5).abs() < 1e-12);
        assert_eq!(c.pool_peak, 3);
        assert_eq!(trace.counters_for(0, 1).bytes_sent, 9);
        assert_eq!(trace.totals().bytes_sent, 159);
    }

    #[test]
    fn derived_spans_cover_wire_events_only() {
        let mut rec = TraceRecorder::new();
        rec.record(wire(0, 0, 0, 1.0, 2.0));
        rec.record(wire(1, 0, 0, 0.5, 1.5));
        rec.record(wire(0, 1, 2, 3.0, 4.0));
        // non-wire events must not disturb the spans
        rec.record(Event::span(EventKind::Stall, 0, 0, 0, 0.0, 9.0));
        let trace = rec.finish();
        let steps = trace.step_spans(3);
        assert_eq!(steps[0], (0.5, 2.0));
        assert!(!steps[1].0.is_finite(), "silent step keeps the sentinel");
        assert_eq!(steps[2], (3.0, 4.0));
        let chans = trace.channel_spans(2);
        assert_eq!(chans[0], (0.5, 2.0));
        assert_eq!(chans[1], (3.0, 4.0));
    }

    #[test]
    fn absorb_merges_and_sorts() {
        let mut a = TraceRecorder::new();
        a.record(wire(0, 0, 0, 2.0, 3.0));
        let mut b = TraceRecorder::new();
        b.record(wire(1, 0, 0, 1.0, 2.0));
        let mut t = a.finish();
        t.absorb(b.finish());
        t.sort();
        assert_eq!(t.events.len(), 2);
        assert!(t.events[0].t_start <= t.events[1].t_start);
        assert_eq!(t.counters.len(), 2);
    }
}
