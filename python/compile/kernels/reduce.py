"""L1 Pallas kernels for the reduce-scatter datapath.

The paper's reduce-scatter reduces every received chunk into an
accumulation buffer ("each time we receive data, we also reduce it with the
current accumulation buffer"); in NCCL this is the GPU reduction kernel on
the datapath. Here it is written as a Pallas kernel, tiled for TPU:

* 1-D operands are viewed as ``(rows, 128)`` with rows padded to a multiple
  of 8 — the VPU's (8, 128) native tile.
* ``BlockSpec`` streams ``(BLOCK_ROWS, 128)`` tiles HBM→VMEM; the kernel is
  elementwise, so VMEM residency is ``(k_inputs + 1) * BLOCK_ROWS * 128 * 4``
  bytes — for the default block of 256 rows and the 2-input kernel, 384 KiB,
  leaving ample VMEM for double buffering.
* The op is memory-bound (1 FLOP per 12 bytes moved for k=2); the roofline
  is HBM bandwidth, and the k-way variant amortizes the accumulator
  traffic: k-way moves ``(k+1)·n`` elements versus ``3n·(k-1)`` for a chain
  of pairwise adds.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so kernels run in interpret mode and lower to plain HLO —
numerically identical, structurally the same schedule (see DESIGN.md
§Hardware-Adaptation-TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per VMEM block; (BLOCK_ROWS, 128) f32 = 128 KiB per operand tile.
BLOCK_ROWS = 256
LANES = 128
SUBLANES = 8


def padded_2d(n: int) -> tuple[int, int]:
    """View length-``n`` data as (rows, 128) with rows a multiple of 8."""
    rows = -(-n // LANES)  # ceil
    rows = -(-rows // SUBLANES) * SUBLANES
    return rows, LANES


def _add2_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _addk_kernel(*refs):
    # refs = (acc, x0, .., x{k-1}, out)
    out = refs[-1]
    acc = refs[0][...]
    for x in refs[1:-1]:
        acc = acc + x[...]
    out[...] = acc


def _tiles(rows: int) -> tuple[int, int]:
    block = min(BLOCK_ROWS, rows)
    # rows is a multiple of 8; keep the block a divisor of rows so the grid
    # is exact (no partial tiles to mask).
    while rows % block != 0:
        block -= SUBLANES
    return block, rows // block


@functools.partial(jax.jit, static_argnames=())
def reduce2(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise ``a + b`` over equal-length 1-D f32 arrays via Pallas."""
    (n,) = a.shape
    rows, lanes = padded_2d(n)
    pad = rows * lanes - n
    a2 = jnp.pad(a, (0, pad)).reshape(rows, lanes)
    b2 = jnp.pad(b, (0, pad)).reshape(rows, lanes)
    block, grid = _tiles(rows)
    out = pl.pallas_call(
        _add2_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), a.dtype),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
            pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        interpret=True,
    )(a2, b2)
    return out.reshape(-1)[:n]


def reduce_k(acc: jax.Array, *xs: jax.Array) -> jax.Array:
    """Fused ``acc + Σ xs`` (k-way reduction) via one Pallas kernel.

    One kernel launch folds ``len(xs)`` received chunks into the
    accumulator — the batched linear-phase optimization (EXPERIMENTS.md
    §Perf).
    """
    (n,) = acc.shape
    rows, lanes = padded_2d(n)
    pad = rows * lanes - n
    ops = [jnp.pad(v, (0, pad)).reshape(rows, lanes) for v in (acc, *xs)]
    block, grid = _tiles(rows)
    spec = pl.BlockSpec((block, lanes), lambda i: (i, 0))
    out = pl.pallas_call(
        _addk_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), acc.dtype),
        grid=(grid,),
        in_specs=[spec] * (1 + len(xs)),
        out_specs=spec,
        interpret=True,
    )(*ops)
    return out.reshape(-1)[:n]
