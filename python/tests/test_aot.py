"""AOT pipeline checks: HLO text artifacts + manifest (quick mode)."""

import json
import os
import subprocess
import sys

import pytest


def test_quick_aot_emits_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    arts = manifest["artifacts"]
    assert len(arts) >= 1
    for a in arts:
        path = out / a["file"]
        assert path.exists(), a
        text = path.read_text()
        assert text.startswith("HloModule"), a["file"]
        # interpret-mode pallas must lower to plain HLO: no Mosaic
        # custom-calls the CPU PJRT client cannot run.
        assert "tpu_custom_call" not in text
        assert a["kind"] in {"reduce", "reduce_k", "scale_add", "train_step"}


def test_hlo_text_parses_back():
    from compile import model
    from compile.aot import to_hlo_text
    from jax._src.lib import xla_client as xc

    fn, specs = model.reduce2_graph(128)
    text = to_hlo_text(fn, specs)
    # round-trip through the HLO text parser the rust side uses
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
