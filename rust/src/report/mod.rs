//! Machine-readable bench/experiment results: every bench writes a JSON
//! document under `bench_results/` (plus the human table on stdout), so
//! EXPERIMENTS.md entries are regenerable and diffable.

use std::path::{Path, PathBuf};

use crate::core::Result;
use crate::util::json::Json;

/// A named result set: free-form parameters plus a list of row objects.
#[derive(Debug, Clone)]
pub struct Report {
    pub name: String,
    pub params: Vec<(String, Json)>,
    pub rows: Vec<Json>,
}

impl Report {
    pub fn new(name: impl Into<String>) -> Report {
        Report { name: name.into(), params: Vec::new(), rows: Vec::new() }
    }

    pub fn param(&mut self, key: &str, value: Json) -> &mut Self {
        self.params.push((key.to_string(), value));
        self
    }

    pub fn row(&mut self, row: Vec<(&str, Json)>) -> &mut Self {
        self.rows.push(Json::obj(row));
        self
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            // Stamped so downstream consumers of archived bench JSON can
            // tell which observability/event vocabulary produced it (the
            // append-only guarantee in [`crate::obs`]).
            ("schema_version", Json::num(crate::obs::SCHEMA_VERSION)),
            ("name", Json::str(self.name.clone())),
            (
                "params",
                Json::Obj(
                    self.params
                        .iter()
                        .map(|(k, v)| (k.clone(), v.clone()))
                        .collect(),
                ),
            ),
            ("rows", Json::Arr(self.rows.clone())),
        ])
    }

    /// Output directory: `$PATCOL_BENCH_DIR` or `bench_results/`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PATCOL_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("bench_results"))
    }

    /// Write `<dir>/<name>.json`; creates the directory.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.json", self.name));
        std::fs::write(&path, self.to_json().to_pretty())?;
        Ok(path)
    }

    /// Write to the default directory and announce on stdout. When
    /// [`crate::obs::baseline::BASELINE_ENV`] is set, the report is also
    /// stamped into the baseline trajectory document it names — how the
    /// CI bench-baseline job builds `BENCH_8.json` without any per-bench
    /// code.
    pub fn save(&self) -> Result<()> {
        let path = self.write(&Self::default_dir())?;
        println!("[report] wrote {}", path.display());
        if let Ok(baseline) = std::env::var(crate::obs::baseline::BASELINE_ENV) {
            let bpath = Path::new(&baseline);
            crate::obs::baseline::stamp(bpath, &self.name, &self.to_json())?;
            println!("[report] stamped {} into {}", self.name, bpath.display());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn roundtrip_on_disk() {
        let mut r = Report::new("unit_test_report");
        r.param("nranks", Json::num(8.0));
        r.row(vec![("alg", Json::str("ring")), ("t", Json::num(1.5))]);
        r.row(vec![("alg", Json::str("pat")), ("t", Json::num(0.5))]);
        let dir = std::env::temp_dir().join("patcol_report_test");
        let path = r.write(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let back = json::parse(&text).unwrap();
        assert_eq!(back.get("name").unwrap().as_str(), Some("unit_test_report"));
        assert_eq!(
            back.get("schema_version").unwrap().as_usize(),
            Some(crate::obs::SCHEMA_VERSION as usize)
        );
        assert_eq!(back.get("rows").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            back.get("params").unwrap().get("nranks").unwrap().as_usize(),
            Some(8)
        );
    }
}
