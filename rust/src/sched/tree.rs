//! Truncated binomial trees over rank offsets `[0, n)` — the building block
//! of Bruck-style all-gather schedules (paper Figs. 2, 4, 6–10).
//!
//! All trees are expressed over *offsets*: the broadcast tree for rank `r`'s
//! chunk spans offsets `o = (rank - r) mod n`. Shifting by the root rank
//! turns tree edges into concrete (src, dst) rank pairs.
//!
//! Two dimension orders appear in the paper:
//!
//! * **Near-first** (classic Bruck, Fig. 1): data for the root reaches
//!   offset `o` by adding set bits of `o` from lowest to highest, so
//!   `parent(o) = o - 2^msb(o)`. Executing dims 0,1,2,… transfers 1,2,4,…
//!   chunks — the *last* step moves half the data the *farthest*.
//! * **Far-first** (dimension-reversed Bruck, Fig. 3; the PAT tree): bits
//!   are added highest-to-lowest, so `parent(o) = o & (o-1)` (clear lowest
//!   set bit). Executing dims …,2,1,0 sends 1,2,4,… chunks at *decreasing*
//!   distance — long-haul transfers stay small, which is the property PAT
//!   inherits.
//!
//! Both constructions are valid for any `n` (truncated trees, Fig. 4):
//! every offset `< n` is reachable because each prefix of its bit
//! decomposition is `≤ o < n`.

use crate::core::floor_log2;

/// Edge `(from, to)` between offsets, crossing dimension `dim`
/// (`to = from + 2^dim`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    pub dim: u32,
}

/// The far-first (dimension-reversed) truncated binomial tree — the PAT
/// broadcast tree.
#[derive(Debug, Clone)]
pub struct FarFirstTree {
    pub n: usize,
}

impl FarFirstTree {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        FarFirstTree { n }
    }

    /// Highest dimension with any edge: `floor(log2(n-1))`. `None` if n == 1.
    pub fn dmax(&self) -> Option<u32> {
        if self.n <= 1 {
            None
        } else {
            Some(floor_log2(self.n - 1))
        }
    }

    /// Parent of offset `o` (`o > 0`): clear the lowest set bit.
    pub fn parent(&self, o: usize) -> usize {
        assert!(o > 0 && o < self.n);
        o & (o - 1)
    }

    /// The dimension of the edge from `parent(o)` to `o`: the lowest set bit.
    pub fn edge_dim(&self, o: usize) -> u32 {
        assert!(o > 0);
        o.trailing_zeros()
    }

    /// Children of offset `o`, ordered far-to-near (descending dim):
    /// `o + 2^d` for `d < lsb(o)` (all dims for the root `o = 0`), bounded
    /// by `n`.
    pub fn children(&self, o: usize) -> Vec<usize> {
        let top = if o == 0 {
            match self.dmax() {
                Some(d) => d as i64,
                None => return vec![],
            }
        } else {
            o.trailing_zeros() as i64 - 1
        };
        let mut out = Vec::new();
        for d in (0..=top).rev() {
            let c = o + (1usize << d);
            if c < self.n {
                out.push(c);
            }
        }
        out
    }

    /// All edges crossing dimension `d`: sources are the multiples of
    /// `2^(d+1)` with `o + 2^d < n`. Returned in ascending source order.
    pub fn edges_at_dim(&self, d: u32) -> Vec<Edge> {
        let stride = 1usize << (d + 1);
        let hop = 1usize << d;
        let mut out = Vec::new();
        let mut o = 0usize;
        while o + hop < self.n {
            out.push(Edge { from: o, to: o + hop, dim: d });
            o += stride;
        }
        out
    }

    /// All edges, far dimension first (the PAT / reversed-Bruck execution
    /// order), sources ascending within a dimension.
    pub fn edges_far_first(&self) -> Vec<Edge> {
        let mut out = Vec::new();
        if let Some(dmax) = self.dmax() {
            for d in (0..=dmax).rev() {
                out.extend(self.edges_at_dim(d));
            }
        }
        out
    }

    /// Depth of offset `o` in the tree (= number of set bits: each bit is
    /// one hop from the root).
    pub fn depth(&self, o: usize) -> u32 {
        o.count_ones()
    }
}

/// The near-first (classic Bruck) truncated binomial tree.
#[derive(Debug, Clone)]
pub struct NearFirstTree {
    pub n: usize,
}

impl NearFirstTree {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        NearFirstTree { n }
    }

    pub fn dmax(&self) -> Option<u32> {
        if self.n <= 1 {
            None
        } else {
            Some(floor_log2(self.n - 1))
        }
    }

    /// Parent of offset `o`: clear the highest set bit.
    pub fn parent(&self, o: usize) -> usize {
        assert!(o > 0 && o < self.n);
        o - (1usize << floor_log2(o))
    }

    pub fn edge_dim(&self, o: usize) -> u32 {
        assert!(o > 0);
        floor_log2(o)
    }

    /// Children of `o`: `o + 2^d` for `d > msb(o)` (any dim for the root),
    /// bounded by `n`. Ordered near-to-far (ascending dim).
    pub fn children(&self, o: usize) -> Vec<usize> {
        let lo = if o == 0 { 0 } else { floor_log2(o) + 1 };
        let mut out = Vec::new();
        if let Some(dmax) = self.dmax() {
            for d in lo..=dmax {
                let c = o + (1usize << d);
                if c < self.n {
                    out.push(c);
                }
            }
        }
        out
    }

    /// All edges crossing dimension `d`: sources are offsets `o < 2^d` with
    /// `o + 2^d < n` — i.e. `min(2^d, n - 2^d)` edges, the classic Bruck
    /// transfer count.
    pub fn edges_at_dim(&self, d: u32) -> Vec<Edge> {
        let hop = 1usize << d;
        let count = hop.min(self.n.saturating_sub(hop));
        (0..count)
            .map(|o| Edge { from: o, to: o + hop, dim: d })
            .collect()
    }

    /// All edges, near dimension first (classic Bruck execution order).
    pub fn edges_near_first(&self) -> Vec<Edge> {
        let mut out = Vec::new();
        if let Some(dmax) = self.dmax() {
            for d in 0..=dmax {
                out.extend(self.edges_at_dim(d));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    /// Every offset in [1, n) must be reachable from 0 through parent links.
    /// Walking *up* a far-first tree clears the lowest set bit each hop, so
    /// edge dims strictly increase toward the root (equivalently: they
    /// strictly decrease along the root→leaf path, the far-first property).
    #[test]
    fn far_first_tree_spans_any_n() {
        for n in 1..130 {
            let t = FarFirstTree::new(n);
            for o in 1..n {
                let mut cur = o;
                let mut last_dim: i64 = -1;
                while cur != 0 {
                    let d = t.edge_dim(cur) as i64;
                    assert!(d > last_dim, "dims must increase walking up (n={n}, o={o})");
                    last_dim = d;
                    cur = t.parent(cur);
                }
            }
        }
    }

    /// Near-first mirror: walking up clears the highest set bit each hop,
    /// so edge dims strictly decrease toward the root.
    #[test]
    fn near_first_tree_spans_any_n() {
        for n in 1..130 {
            let t = NearFirstTree::new(n);
            for o in 1..n {
                let mut cur = o;
                let mut last_dim = u32::MAX;
                while cur != 0 {
                    let d = t.edge_dim(cur);
                    assert!(d < last_dim, "dims must decrease walking up (n={n}, o={o})");
                    last_dim = d;
                    cur = t.parent(cur);
                }
            }
        }
    }

    /// The union of edges_at_dim over all dims is exactly n-1 edges, one per
    /// non-root offset, and matches parent().
    #[test]
    fn edges_form_the_tree() {
        for n in 2..100 {
            let t = FarFirstTree::new(n);
            let edges = t.edges_far_first();
            assert_eq!(edges.len(), n - 1, "n={n}");
            let mut seen = HashSet::new();
            for e in &edges {
                assert_eq!(t.parent(e.to), e.from);
                assert_eq!(t.edge_dim(e.to), e.dim);
                assert!(seen.insert(e.to), "offset {} reached twice (n={n})", e.to);
            }
            let nt = NearFirstTree::new(n);
            let edges = nt.edges_near_first();
            assert_eq!(edges.len(), n - 1, "near n={n}");
            for e in &edges {
                assert_eq!(nt.parent(e.to), e.from);
            }
        }
    }

    /// children() is consistent with parent().
    #[test]
    fn children_parent_consistent() {
        for n in [1usize, 2, 3, 7, 8, 16, 23, 64, 100] {
            let t = FarFirstTree::new(n);
            for o in 0..n {
                for c in t.children(o) {
                    assert_eq!(t.parent(c), o, "far n={n} o={o} c={c}");
                }
            }
            let nt = NearFirstTree::new(n);
            for o in 0..n {
                for c in nt.children(o) {
                    assert_eq!(nt.parent(c), o, "near n={n} o={o} c={c}");
                }
            }
        }
    }

    /// Paper Fig. 3 (reversed-dim Bruck, 8 ranks): dims executed 2,1,0 send
    /// 1, 2, 4 chunks respectively.
    #[test]
    fn far_first_dim_transfer_counts_8() {
        let t = FarFirstTree::new(8);
        assert_eq!(t.edges_at_dim(2).len(), 1);
        assert_eq!(t.edges_at_dim(1).len(), 2);
        assert_eq!(t.edges_at_dim(0).len(), 4);
    }

    /// Paper Fig. 1 (classic Bruck, 8 ranks): dims executed 0,1,2 send
    /// 1, 2, 4 chunks.
    #[test]
    fn near_first_dim_transfer_counts_8() {
        let t = NearFirstTree::new(8);
        assert_eq!(t.edges_at_dim(0).len(), 1);
        assert_eq!(t.edges_at_dim(1).len(), 2);
        assert_eq!(t.edges_at_dim(2).len(), 4);
    }

    /// Paper Fig. 4 (7 ranks): per-dim chunk counts for the truncated tree.
    #[test]
    fn truncated_7_counts() {
        let t = FarFirstTree::new(7);
        // far-first: dim 2 -> 1 edge (0->4), dim 1 -> 2 (0->2, 4->6),
        // dim 0 -> 3 (0->1, 2->3, 4->5); total 6 = n-1.
        assert_eq!(t.edges_at_dim(2).len(), 1);
        assert_eq!(t.edges_at_dim(1).len(), 2);
        assert_eq!(t.edges_at_dim(0).len(), 3);
        let nt = NearFirstTree::new(7);
        assert_eq!(nt.edges_at_dim(0).len(), 1);
        assert_eq!(nt.edges_at_dim(1).len(), 2);
        assert_eq!(nt.edges_at_dim(2).len(), 3);
    }

    #[test]
    fn depth_is_popcount() {
        let t = FarFirstTree::new(16);
        assert_eq!(t.depth(0), 0);
        assert_eq!(t.depth(7), 3);
        assert_eq!(t.depth(8), 1);
    }

    #[test]
    fn single_rank_has_no_edges() {
        assert!(FarFirstTree::new(1).edges_far_first().is_empty());
        assert!(NearFirstTree::new(1).edges_near_first().is_empty());
        assert_eq!(FarFirstTree::new(1).dmax(), None);
    }
}
