//! Threaded execution of schedule programs with real data movement.
//!
//! One OS thread per rank; each rank owns an mpsc receiver and cloned
//! senders to every peer. Messages are tagged with their **channel** and
//! FIFO order is per (src, channel) connection — the rank thread runs a
//! cooperative scheduler over its per-channel op streams (NCCL's
//! per-channel proxy progress, collapsed onto one thread): each pass
//! first posts **every ready send across all channels in one batched
//! sweep** (one scheduler wakeup drains the whole send frontier, the
//! way NCCL's proxy posts all ready work per progress call), then
//! drives each channel as far as it can; a blocking `Recv` only stalls
//! its own channel, and when no channel can progress the thread parks
//! on the shared receiver with a watchdog timeout so schedule bugs fail
//! loudly instead of hanging the suite. Single-channel programs
//! reproduce the classic one-stream-per-rank execution exactly.
//!
//! **Zero-copy arena datapath**: every run computes a static layout over
//! one page-aligned [`Arena`] — a staging/accumulator slot region per
//! rank followed by one single-use wire region per `Send` op — and the
//! wires carry plain `(offset, len)` descriptors instead of owned
//! vectors. Senders pack (or fuse-reduce) directly into their wire
//! region; receivers read payloads straight out of the arena; the mpsc
//! descriptor handoff provides the happens-before edge. With a
//! [`TransportOptions::arena`] cache configured (the
//! [`crate::coordinator::Communicator`] does this), steady-state
//! operations perform **zero heap allocations**: the arena is leased
//! from the cache, and the [`BufferPool`] carves slots from it.
//!
//! All-gather writes into a full receive buffer per rank; in *staged*
//! mode (the NCCL case PAT is designed for — user buffers are not
//! directly sendable/receivable, so every transfer goes through
//! pre-mapped staging), each message's chunks transit bounded staging
//! slots from the [`BufferPool`] around the send, enforcing the PAT
//! aggregation bound: a schedule aggregating more chunks per transfer
//! than the buffer holds fails loudly. Reduce-scatter keeps *persistent*
//! per-chunk accumulators in pool slots — the stronger constraint the
//! paper says the algorithm was originally designed around — and folds
//! incoming data through the configured [`DataPath`] (scalar loop or the
//! AOT Pallas kernel via the sharded PJRT service).
//!
//! Channel-split programs ([`crate::sched::channel::split`]) stripe the
//! payload: a program whose chunk space is `C × nranks` moves `1/C`-sized
//! sub-chunks, chunk `k·n + r` being stripe `k` of rank `r`'s
//! contribution. The run functions below derive `C` from the program's
//! chunk space, so the same entry points execute single- and
//! multi-channel programs (inputs must split evenly into `C` stripes; the
//! [`crate::coordinator::Communicator`] pads odd lengths).

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::core::{ChunkId, Collective, Error, Rank, Result};
use crate::obs::{Event, EventKind, FlightRecorder, Trace, DEFAULT_FLIGHT_CAPACITY};
use crate::sched::program::{Op, Program};
use crate::transport::arena::{Arena, ArenaCache, ArenaLease};
use crate::transport::buffers::BufferPool;
use crate::transport::datapath::DataPath;
use crate::transport::delivery::{self, Decision, DeliveryFactory, DeliveryPolicy, Verdict};

/// Engine configuration.
#[derive(Clone)]
pub struct TransportOptions {
    pub datapath: DataPath,
    /// Staging/accumulator slot capacity per rank. `None` measures without
    /// enforcing. PAT schedules with aggregation `a` are expected to run
    /// within `a` slots (claim P3, verified in tests). Channels progress
    /// independently and share the rank's pool, so the sound capacity for
    /// a multi-channel program is the *sum* of its per-channel peaks
    /// (C × the single-channel bound for a C-way split), not the merged
    /// reference-executor measurement.
    pub slot_capacity: Option<usize>,
    /// All-gather: physically route forwarded chunks through staging slots
    /// (models un-registerable user buffers) instead of sending straight
    /// from the receive buffer.
    pub staged: bool,
    /// Structurally verify the program before running (cheap; disable for
    /// large-scale benches).
    pub validate: bool,
    /// Watchdog for blocking receives.
    pub recv_timeout: Duration,
    /// Record the unified [`crate::obs`] event timeline: each rank thread
    /// keeps a lock-free [`FlightRecorder`] ring, merged into
    /// [`TransportReport::trace`] at join. When off (the default) every
    /// recording call is a single inlined branch — no clock reads.
    pub trace: bool,
    /// Arena cache backing the run's wire regions and pool slots. `None`
    /// (the default) allocates a private one-shot arena per run; a shared
    /// [`ArenaCache`] (one per communicator) makes repeated operations of
    /// the same footprint allocation-free —
    /// [`TransportReport::arena_allocs`] is 0 on the warm path.
    pub arena: Option<ArenaCache>,
    /// Adversarial delivery hook: builds one
    /// [`crate::transport::delivery::DeliveryPolicy`] per rank thread,
    /// interposed at every connection-FIFO match (see
    /// [`crate::transport::delivery`] and [`crate::adversary`]). `None`
    /// (the default) keeps the eager fast path — the policy branch is
    /// never taken.
    pub delivery: Option<DeliveryFactory>,
}

impl Default for TransportOptions {
    fn default() -> Self {
        TransportOptions {
            datapath: DataPath::Scalar,
            slot_capacity: None,
            staged: true,
            validate: true,
            recv_timeout: Duration::from_secs(30),
            trace: false,
            arena: None,
            delivery: None,
        }
    }
}

/// Execution metrics.
#[derive(Debug, Clone, Default)]
pub struct TransportReport {
    /// Peak staging slots (AG) or accumulator slots (RS) on any rank.
    pub peak_slots: usize,
    /// Per-rank peak staging/accumulator slots (index = rank; `max` is
    /// [`TransportReport::peak_slots`]). This is what attributes a
    /// hierarchical schedule's footprint: the stripe leaders' rows are
    /// the pipelined fan-out's staging cost, asserted against
    /// [`crate::sched::hier::staging_bound`] by the correctness matrix
    /// and the bench baseline gate.
    pub peak_slots_by_rank: Vec<usize>,
    /// Total payload bytes moved between ranks.
    pub bytes_moved: usize,
    /// Total messages.
    pub messages: usize,
    /// Wall-clock duration of the collective.
    pub wall: Duration,
    /// Heap-allocated slot vectors (allocation pressure). Zero on the
    /// arena path — the perf gate the steady state is held to.
    pub slots_allocated: usize,
    /// Preallocated arena footprint in bytes for this run.
    pub arena_bytes: usize,
    /// Arena high-water mark: the largest per-rank footprint actually
    /// touched — peak pool slots plus that rank's wire regions, in bytes.
    pub arena_hw_bytes: usize,
    /// Arenas allocated by this run: 1 when the cache was cold (or no
    /// cache was configured), 0 on the warm steady-state path.
    pub arena_allocs: usize,
    /// The unified event timeline (merged across rank threads, sorted by
    /// start time), present when [`TransportOptions::trace`] was set.
    pub trace: Option<Trace>,
}

/// A wire message is a **descriptor**: the payload already sits in the
/// shared arena, written there by the sender before the descriptor is
/// posted (the mpsc send/recv pair is the happens-before edge).
struct WireMsg {
    src: Rank,
    /// The connection this message rides: FIFO holds per (src, channel).
    channel: usize,
    /// Post time (seconds from the run origin; 0.0 when tracing is off).
    /// Travels with the message so the receiver can record the wire span
    /// post → FIFO match against the shared clock.
    t_sent: f64,
    /// Payload region in the arena.
    off: usize,
    len: usize,
}

/// Per-rank endpoint hiding the single-receiver / per-connection-FIFO
/// plumbing. Only `(offset, len)` descriptors cross the channels — no
/// payload bytes, no buffer-return protocol (every wire region is
/// single-use within a run, so there is nothing to recycle and no
/// recycling loop to starve).
struct Endpoint {
    rank: Rank,
    senders: Vec<Sender<WireMsg>>,
    receiver: Receiver<WireMsg>,
    /// Arrived-but-unclaimed messages per (src, channel) — the per-channel
    /// connection FIFOs, each entry `(t_sent, (off, len))`.
    pending: HashMap<(Rank, usize), VecDeque<(f64, (usize, usize))>>,
    /// Messages ever stashed into `pending`. The channel scheduler uses
    /// this to notice arrivals drained mid-pass for an already-checked
    /// channel (it must re-poll instead of blocking on the receiver).
    stashed: u64,
    timeout: Duration,
}

impl Endpoint {
    fn send(&self, dst: Rank, chan: usize, off: usize, len: usize, t_sent: f64) -> Result<()> {
        self.senders[dst]
            .send(WireMsg { src: self.rank, channel: chan, t_sent, off, len })
            .map_err(|_| Error::Transport(format!("rank {dst} hung up")))
    }

    fn stash(&mut self, msg: WireMsg) {
        self.stashed += 1;
        self.pending
            .entry((msg.src, msg.channel))
            .or_default()
            .push_back((msg.t_sent, (msg.off, msg.len)));
    }

    /// Non-blocking: drain everything that has arrived into the
    /// per-connection FIFOs.
    fn drain(&mut self) {
        while let Ok(msg) = self.receiver.try_recv() {
            self.stash(msg);
        }
    }

    /// Remove and return entry `idx` of the (src, chan) connection FIFO.
    /// `idx > 0` reorders messages within the connection — only the
    /// delivery layer may do that, and only with the FIFO-ordering
    /// sentinel armed ([`delivery::fifo_reorder_allowed`]).
    fn take_at(&mut self, src: Rank, chan: usize, idx: usize) -> Option<(f64, (usize, usize))> {
        self.pending.get_mut(&(src, chan)).and_then(|q| q.remove(idx))
    }

    /// Queued-but-unclaimed messages on the (src, chan) connection FIFO.
    fn fifo_depth(&self, src: Rank, chan: usize) -> usize {
        self.pending.get(&(src, chan)).map_or(0, |q| q.len())
    }

    /// Block until at least one new message arrives (stashed into the
    /// per-connection FIFOs). The watchdog timeout turns a deadlocked
    /// schedule into an error instead of a hang.
    fn wait_any(&mut self) -> Result<()> {
        let msg = self.receiver.recv_timeout(self.timeout).map_err(|_| {
            Error::Transport(format!(
                "rank {} timed out with every channel blocked on a receive \
                 (deadlocked or unmatched schedule?)",
                self.rank
            ))
        })?;
        self.stash(msg);
        Ok(())
    }

    /// Bounded grace wait used by the delivery layer's bounded-hold rule:
    /// give in-flight traffic one short interval to land (deepening the
    /// FIFOs, which is what a holding policy is waiting for) before the
    /// engine force-releases a held connection. Returns whether anything
    /// arrived.
    fn wait_brief(&mut self) -> bool {
        match self.receiver.recv_timeout(Duration::from_millis(1)) {
            Ok(msg) => {
                self.stash(msg);
                true
            }
            Err(_) => false,
        }
    }
}

fn make_endpoints(n: usize, timeout: Duration) -> Vec<Endpoint> {
    let mut senders = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, receiver)| Endpoint {
            rank,
            senders: senders.clone(),
            receiver,
            pending: HashMap::new(),
            stashed: 0,
            timeout,
        })
        .collect()
}

/// Outcome of polling one connection through the delivery layer.
enum Polled {
    /// A descriptor was matched (at the FIFO index the policy chose).
    Data((f64, (usize, usize))),
    /// Nothing deliverable: FIFO empty, or a firm (park-eligible) hold.
    Blocked,
    /// The policy soft-held an arrived message — park is forbidden, the
    /// bounded-hold rule applies.
    Held,
}

/// Poll the (src, chan) connection, routing the match through the
/// delivery policy when one is installed. Maintains the deterministic
/// virtual-time clocks (`matched` = per-connection match counts, `vtime`
/// = rank-total match count) that name decision points stably for the
/// adversary's shrinker. `force` implements the bounded-hold rule: the
/// policy is not consulted, the head is delivered, and the policy is
/// notified with `forced = true`.
#[allow(clippy::too_many_arguments)]
fn recv_decide(
    ep: &mut Endpoint,
    src: Rank,
    chan: usize,
    policy: &mut Option<Box<dyn DeliveryPolicy>>,
    matched: &mut HashMap<(Rank, usize), u64>,
    vtime: &mut u64,
    force: bool,
) -> Polled {
    ep.drain();
    let depth = ep.fifo_depth(src, chan);
    if depth == 0 {
        return Polled::Blocked;
    }
    let Some(pol) = policy.as_mut() else {
        // Eager fast path: no policy, no clocks.
        return match ep.take_at(src, chan, 0) {
            Some(d) => Polled::Data(d),
            None => Polled::Blocked,
        };
    };
    let nth = matched.entry((src, chan)).or_insert(0);
    let d = Decision { rank: ep.rank, src, channel: chan, depth, nth: *nth, vtime: *vtime };
    let (want, forced) = if force {
        (0, true)
    } else {
        match pol.decide(d) {
            Verdict::Deliver(i) => (i, false),
            Verdict::Hold => return Polled::Held,
            Verdict::HoldFirm => return Polled::Blocked,
        }
    };
    // The FIFO-ordering guard: only the connection head may be matched.
    // Disabled by the adversary's mutation sentinel, under which a policy
    // really can reorder messages within one connection.
    let idx = if delivery::fifo_reorder_allowed() { want.min(depth - 1) } else { 0 };
    pol.delivered(d, idx, forced);
    *nth += 1;
    *vtime += 1;
    match ep.take_at(src, chan, idx) {
        Some(data) => Polled::Data(data),
        None => Polled::Blocked,
    }
}

/// Drive a rank's per-channel op streams to completion (the cooperative
/// per-channel scheduler, see the module docs). `exec` performs one op,
/// identified by its **global index** in the rank's op list (the arena
/// layout is indexed the same way): for receives the matched
/// `(t_sent, (off, len))` descriptor is passed in; for sends it is `None`
/// and `exec` posts the message itself via the endpoint. Each pass opens
/// with a batched send sweep — every channel's ready sends post in one
/// wakeup before any receive is polled. `fr` is the rank's flight
/// recorder: park intervals become per-channel stall events, and a
/// watchdog timeout dumps its tail into the error (with the delivery
/// policy's perturbation log attached when one is installed).
///
/// `policy` is the rank's adversarial delivery controller (see
/// [`delivery`]); matches route through [`recv_decide`], and a pass that
/// only soft-held traffic triggers the bounded-hold rule instead of a
/// park — exploration policies therefore cannot deadlock a live
/// schedule.
fn drive_channels<F>(
    ep: &mut Endpoint,
    ops: &[Op],
    channels: usize,
    fr: &mut FlightRecorder,
    mut policy: Option<Box<dyn DeliveryPolicy>>,
    mut exec: F,
) -> Result<()>
where
    F: FnMut(
        &mut Endpoint,
        usize,
        &Op,
        Option<(f64, (usize, usize))>,
        &mut FlightRecorder,
    ) -> Result<()>,
{
    let nchan = channels.max(1);
    let mut streams: Vec<Vec<(usize, &Op)>> = vec![Vec::new(); nchan];
    for (i, op) in ops.iter().enumerate() {
        streams[op.channel()].push((i, op));
    }
    let mut pc = vec![0usize; nchan];
    let mut remaining = ops.len();
    let mut matched: HashMap<(Rank, usize), u64> = HashMap::new();
    let mut vtime = 0u64;
    let mut force = false;
    while remaining > 0 {
        let seen = ep.stashed;
        let mut progressed = false;
        let mut held = false;
        // Batched dispatch: post every ready send across every channel
        // before polling a single receive — one wakeup drains the whole
        // send frontier, so peers' receives match sooner.
        for (k, stream) in streams.iter().enumerate() {
            while pc[k] < stream.len() {
                let (idx, op) = stream[pc[k]];
                if !matches!(op, Op::Send { .. }) {
                    break;
                }
                exec(ep, idx, op, None, fr)?;
                pc[k] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        for (k, stream) in streams.iter().enumerate() {
            while pc[k] < stream.len() {
                let (idx, op) = stream[pc[k]];
                let data = match op {
                    Op::Send { .. } => None,
                    Op::Recv { peer, .. } => {
                        match recv_decide(ep, *peer, k, &mut policy, &mut matched, &mut vtime, force)
                        {
                            Polled::Data(d) => Some(d),
                            // This channel blocks; the others keep progressing.
                            Polled::Blocked => break,
                            Polled::Held => {
                                held = true;
                                break;
                            }
                        }
                    }
                };
                exec(ep, idx, op, data, fr)?;
                pc[k] += 1;
                remaining -= 1;
                progressed = true;
            }
        }
        if progressed {
            force = false;
        }
        // Block only if the pass neither retired an op nor drained a new
        // arrival: a message stashed mid-pass may belong to a channel
        // checked earlier in the pass, so re-poll before parking.
        if remaining > 0 && !progressed && ep.stashed == seen {
            if held {
                // Bounded-hold rule: every blocked channel is blocked on a
                // policy hold, not a missing message. Grant one short
                // grace wait for in-flight traffic to deepen the FIFOs;
                // if nothing lands, force-release held heads next pass.
                if !ep.wait_brief() {
                    force = true;
                }
                continue;
            }
            let t_park = fr.now_or_zero();
            if ep.wait_any().is_err() {
                return Err(blame_timeout(ep, &streams, &pc, fr, policy.as_deref()));
            }
            if fr.enabled() {
                // The whole rank thread was parked; every channel whose
                // head is an unmatched Recv was stalled for the interval.
                let t_wake = fr.now();
                for (k, stream) in streams.iter().enumerate() {
                    if pc[k] >= stream.len() {
                        continue;
                    }
                    if let Op::Recv { peer, step, .. } = stream[pc[k]].1 {
                        fr.record(
                            Event::span(EventKind::Stall, ep.rank, k, *step, t_park, t_wake)
                                .with_peer(*peer),
                        );
                    }
                }
            }
        }
    }
    Ok(())
}

/// Build the watchdog's blamed stall report: which (rank, channel, step)
/// is blocked on which peer, how deep each pending connection FIFO is,
/// the delivery policy's perturbation log when a policy is installed,
/// and — when tracing — the flight recorder's tail. Works with tracing
/// off; the per-channel blame needs no recorded history.
fn blame_timeout(
    ep: &Endpoint,
    streams: &[Vec<(usize, &Op)>],
    pc: &[usize],
    fr: &FlightRecorder,
    policy: Option<&dyn DeliveryPolicy>,
) -> Error {
    let mut msg = format!(
        "rank {} timed out with every channel blocked on a receive \
         (deadlocked or unmatched schedule?)",
        ep.rank
    );
    for (k, stream) in streams.iter().enumerate() {
        if pc[k] >= stream.len() {
            continue;
        }
        if let Op::Recv { peer, chunks, step, .. } = stream[pc[k]].1 {
            msg.push_str(&format!(
                "\n  channel {k}: op {}/{} blocked on recv from rank {peer} at step {step} \
                 ({} chunks; {} message(s) queued on that connection)",
                pc[k],
                stream.len(),
                chunks.len(),
                ep.fifo_depth(*peer, k)
            ));
        }
    }
    if let Some(pol) = policy {
        let log = pol.perturbation_log();
        if !log.is_empty() {
            msg.push_str("\ndelivery-policy perturbation log:\n");
            msg.push_str(&log);
        }
    }
    if fr.enabled() && !fr.is_empty() {
        msg.push_str("\nflight recorder tail:\n");
        msg.push_str(&fr.render_tail(16));
    }
    Error::Transport(msg)
}

/// The channel-striped chunk grid of a program over per-rank payloads of
/// `elems` elements: `stripes` sub-chunks of `sub` elements each. Chunk
/// `c` is stripe `c / nranks` of rank `c % nranks`'s payload.
fn stripe_grid(p: &Program, elems: usize, what: &str) -> Result<(usize, usize)> {
    let n = p.nranks.max(1);
    let nchunks = p.chunk_space();
    if nchunks % n != 0 {
        return Err(Error::Transport(format!(
            "{what}: chunk space {nchunks} is not a multiple of nranks {n}"
        )));
    }
    let stripes = (nchunks / n).max(1);
    if elems % stripes != 0 {
        return Err(Error::Transport(format!(
            "{what}: payload of {elems} elements does not split into {stripes} \
             channel stripes (pad to a multiple, as the Communicator does)"
        )));
    }
    Ok((stripes, elems / stripes))
}

/// The static arena layout of one run: per rank, a pool-slot region
/// (sized to the schedule's distinct reduce-receive chunks, clamped to
/// the slot capacity) followed by one dedicated wire region per `Send`
/// op. Because regions are disjoint by construction and each wire region
/// backs exactly one message, descriptors can be handed across threads
/// with no further coordination.
struct ArenaPlan {
    /// Per-rank pool region: `(base_offset, slot_count)`.
    pool: Vec<(usize, usize)>,
    /// Per-rank, per-op wire region offset (`usize::MAX` on receives —
    /// the descriptor arrives on the wire).
    send_off: Vec<Vec<usize>>,
    /// Per-rank total wire elements (the rank's send footprint).
    wire: Vec<usize>,
    /// Total arena elements.
    total: usize,
}

/// Compute the [`ArenaPlan`] for a program. `msg_elems` sizes a send's
/// wire region from its chunk list; `slot_recv` says which receives
/// consume a persistent pool slot (reduce-receives — their distinct
/// chunk count bounds the rank's simultaneously-live accumulators, so
/// carving exactly that many slots guarantees the pool never falls back
/// to the heap, and clamping to `cap` stays sufficient because the pool
/// errors out at `cap` live slots anyway).
fn plan_arena(
    p: &Program,
    slot_elems: usize,
    cap: Option<usize>,
    msg_elems: impl Fn(&[ChunkId]) -> usize,
    slot_recv: impl Fn(&Op) -> bool,
) -> ArenaPlan {
    let mut pool = Vec::with_capacity(p.ranks.len());
    let mut send_off = Vec::with_capacity(p.ranks.len());
    let mut wire = Vec::with_capacity(p.ranks.len());
    let mut cursor = 0usize;
    for ops in &p.ranks {
        let mut distinct: HashSet<ChunkId> = HashSet::new();
        for op in ops {
            if slot_recv(op) {
                if let Op::Recv { chunks, .. } = op {
                    distinct.extend(chunks.iter().copied());
                }
            }
        }
        let slots = cap.map_or(distinct.len(), |c| distinct.len().min(c));
        pool.push((cursor, slots));
        cursor += slots * slot_elems;
        let base = cursor;
        let mut offs = Vec::with_capacity(ops.len());
        for op in ops {
            match op {
                Op::Send { chunks, .. } => {
                    offs.push(cursor);
                    cursor += msg_elems(chunks);
                }
                Op::Recv { .. } => offs.push(usize::MAX),
            }
        }
        send_off.push(offs);
        wire.push(cursor - base);
    }
    ArenaPlan { pool, send_off, wire, total: cursor }
}

/// Lease the run's arena: from the configured cache (warm steady state
/// reuses the allocation) or a private one-shot arena.
fn lease_arena(opts: &TransportOptions, elems: usize) -> Result<ArenaLease> {
    match &opts.arena {
        Some(cache) => cache.checkout(elems),
        None => ArenaLease::private(Arena::new(elems)?),
    }
}

/// Run an all-gather program. `inputs[r]` is rank r's contribution
/// (uniform length); returns each rank's gathered buffer of `n × len`
/// elements (rank `s`'s contribution at offset `s × len`). Multi-channel
/// programs stripe each contribution across their channels; `len` must be
/// divisible by the channel count.
pub fn run_allgather(
    p: &Program,
    inputs: &[Vec<f32>],
    opts: &TransportOptions,
) -> Result<(Vec<Vec<f32>>, TransportReport)> {
    let chunk = inputs.first().map(|v| v.len()).unwrap_or(0);
    let mut outputs: Vec<Vec<f32>> = vec![vec![0f32; p.nranks * chunk]; p.nranks];
    let rep = run_allgather_into(p, inputs, &mut outputs, opts)?;
    Ok((outputs, rep))
}

/// Like [`run_allgather`], writing into caller-provided receive buffers
/// (each `n × len` elements) — the NCCL calling convention, and the hot
/// path for repeated collectives: no per-call output allocation or zeroing
/// (perf pass, EXPERIMENTS.md §Perf).
pub fn run_allgather_into(
    p: &Program,
    inputs: &[Vec<f32>],
    outputs: &mut [Vec<f32>],
    opts: &TransportOptions,
) -> Result<TransportReport> {
    if p.collective != Collective::AllGather {
        return Err(Error::Transport(format!(
            "run_allgather on a {} program",
            p.collective
        )));
    }
    let n = p.nranks;
    if inputs.len() != n {
        return Err(Error::Transport(format!(
            "expected {n} inputs, got {}",
            inputs.len()
        )));
    }
    let len = inputs.first().map(|v| v.len()).unwrap_or(0);
    if inputs.iter().any(|v| v.len() != len) {
        return Err(Error::Transport("ragged input chunk sizes".into()));
    }
    if outputs.len() != n || outputs.iter().any(|o| o.len() != n * len) {
        return Err(Error::Transport(format!(
            "outputs must be {n} buffers of {} elements",
            n * len
        )));
    }
    let (_, sub) = stripe_grid(p, len, "run_allgather")?;
    if opts.validate {
        crate::sched::verify::verify_program(p)?;
    }
    // All-gather never acquires persistent slots (staging is
    // accounting-only around the send; the wire region is the storage).
    let plan = plan_arena(p, sub, opts.slot_capacity, |chunks| chunks.len() * sub, |_| false);
    let lease = lease_arena(opts, plan.total)?;
    let arena = lease.arena().clone();
    let endpoints = make_endpoints(n, opts.recv_timeout);
    let report = Mutex::new(TransportReport {
        arena_bytes: arena.bytes(),
        arena_allocs: if lease.fresh() { 1 } else { 0 },
        ..Default::default()
    });
    let start = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(n);
        for (r, (ep, out_slot)) in endpoints
            .into_iter()
            .zip(outputs.iter_mut())
            .enumerate()
        {
            let p = &p;
            let inputs = &inputs;
            let report = &report;
            let opts = &*opts;
            let plan = &plan;
            let arena = &arena;
            handles.push(s.spawn(move || -> Result<()> {
                let mut ep = ep;
                let mut fr = if opts.trace {
                    FlightRecorder::new(start, DEFAULT_FLIGHT_CAPACITY)
                } else {
                    FlightRecorder::disabled()
                };
                let recvbuf: &mut [f32] = out_slot;
                recvbuf[r * len..(r + 1) * len].copy_from_slice(&inputs[r]);
                // Chunk `c` = stripe `c / n` of rank `c % n`'s slot.
                let off = |c: ChunkId| (c % n) * len + (c / n) * sub;
                let (pool_base, pool_slots) = plan.pool[r];
                let mut pool = BufferPool::with_arena(
                    sub,
                    opts.slot_capacity,
                    arena.clone(),
                    pool_base,
                    pool_slots,
                );
                fr.set_arena_scale(sub * 4, plan.wire[r] * 4);
                let send_off = &plan.send_off[r];
                let mut local_bytes = 0usize;
                let mut local_msgs = 0usize;

                let policy = opts.delivery.as_ref().map(|f| f(r));
                drive_channels(&mut ep, &p.ranks[r], p.channels, &mut fr, policy, |ep, idx, op, data, fr| {
                    match op {
                        Op::Send { peer, chunks, channel, step } => {
                            let t0 = fr.now_or_zero();
                            // Pack through staging: one slot per sub-chunk of
                            // the message is live until the send is posted,
                            // enforcing that a transfer never aggregates more
                            // than the buffer budget. The wire region itself
                            // is the staging storage (reserve() is
                            // accounting-only), so packing costs exactly one
                            // copy of the payload.
                            if opts.staged {
                                pool.reserve_traced(chunks.len(), fr, r, *channel, *step)?;
                            }
                            let woff = send_off[idx];
                            let wlen = chunks.len() * sub;
                            // SAFETY: this wire region is dedicated to this
                            // op by the plan; nobody else touches it until
                            // the descriptor is posted below.
                            let msg = unsafe { arena.slice_mut(woff, wlen) };
                            for (i, &c) in chunks.iter().enumerate() {
                                let o = off(c);
                                msg[i * sub..(i + 1) * sub]
                                    .copy_from_slice(&recvbuf[o..o + sub]);
                            }
                            let bytes = wlen * 4;
                            local_bytes += bytes;
                            local_msgs += 1;
                            ep.send(*peer, *channel, woff, wlen, t0)?;
                            if opts.staged {
                                pool.unreserve_traced(chunks.len(), fr, r, *channel, *step);
                            }
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::SendOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                        Op::Recv { peer, chunks, channel, step, .. } => {
                            let (t_sent, (doff, dlen)) =
                                data.expect("recv scheduled without payload");
                            if dlen != chunks.len() * sub {
                                return Err(Error::Transport(format!(
                                    "rank {r}: message from {peer} has {} elems, want {}",
                                    dlen,
                                    chunks.len() * sub
                                )));
                            }
                            // SAFETY: the sender finished writing this
                            // single-use wire region before posting the
                            // descriptor (mpsc happens-before).
                            let data = unsafe { arena.slice(doff, dlen) };
                            let bytes = dlen * 4;
                            let t0 = fr.now_or_zero();
                            if fr.enabled() {
                                // Wire span: peer's post time → FIFO match,
                                // recorded by the receiving side against the
                                // shared run origin.
                                fr.record(
                                    Event::span(EventKind::Wire, *peer, *channel, *step, t_sent, t0)
                                        .with_peer(r)
                                        .with_msg(chunks, bytes),
                                );
                            }
                            for (i, &c) in chunks.iter().enumerate() {
                                let seg = &data[i * sub..(i + 1) * sub];
                                let o = off(c);
                                recvbuf[o..o + sub].copy_from_slice(seg);
                            }
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::RecvOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                    }
                    Ok(())
                })?;
                let hw = (pool.peak() * sub + plan.wire[r]) * 4;
                let mut rep = report.lock().unwrap();
                rep.peak_slots = rep.peak_slots.max(pool.peak());
                if rep.peak_slots_by_rank.len() < n {
                    rep.peak_slots_by_rank.resize(n, 0);
                }
                rep.peak_slots_by_rank[r] = pool.peak();
                rep.bytes_moved += local_bytes;
                rep.messages += local_msgs;
                rep.slots_allocated += pool.total_allocated();
                rep.arena_hw_bytes = rep.arena_hw_bytes.max(hw);
                if opts.trace {
                    let mut t = fr.finish();
                    let c = t.counters.entry((r, 0)).or_default();
                    c.arena_hw_bytes = c.arena_hw_bytes.max(hw);
                    c.allocs += pool.total_allocated();
                    rep.trace.get_or_insert_with(Trace::default).absorb(t);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| Error::Transport("rank thread panicked".into()))??;
        }
        Ok(())
    })?;

    let mut rep = report.into_inner().unwrap();
    rep.wall = start.elapsed();
    if let Some(t) = rep.trace.as_mut() {
        t.sort();
    }
    drop(lease);
    Ok(rep)
}

/// Run a reduce-scatter program. `inputs[r]` holds rank r's contribution to
/// all `n` output slots (`n × L` elements); returns each rank's reduced own
/// slot (`L` elements). Multi-channel programs stripe each slot across
/// their channels; `L` must be divisible by the channel count.
pub fn run_reduce_scatter(
    p: &Program,
    inputs: &[Vec<f32>],
    opts: &TransportOptions,
) -> Result<(Vec<Vec<f32>>, TransportReport)> {
    if p.collective != Collective::ReduceScatter {
        return Err(Error::Transport(format!(
            "run_reduce_scatter on a {} program",
            p.collective
        )));
    }
    let n = p.nranks;
    if inputs.len() != n {
        return Err(Error::Transport(format!(
            "expected {n} inputs, got {}",
            inputs.len()
        )));
    }
    if n == 0 {
        return Ok((vec![], TransportReport::default()));
    }
    let total = inputs[0].len();
    if total % n != 0 || inputs.iter().any(|v| v.len() != total) {
        return Err(Error::Transport(format!(
            "reduce-scatter inputs must be uniform and divisible by nranks={n}"
        )));
    }
    let l = total / n;
    let (stripes, sub) = stripe_grid(p, l, "run_reduce_scatter")?;
    if opts.validate {
        crate::sched::verify::verify_program(p)?;
    }
    // Every RS receive folds into a persistent accumulator slot.
    let plan = plan_arena(
        p,
        sub,
        opts.slot_capacity,
        |chunks| chunks.len() * sub,
        |op| matches!(op, Op::Recv { .. }),
    );
    let lease = lease_arena(opts, plan.total)?;
    let arena = lease.arena().clone();
    let endpoints = make_endpoints(n, opts.recv_timeout);
    let report = Mutex::new(TransportReport {
        arena_bytes: arena.bytes(),
        arena_allocs: if lease.fresh() { 1 } else { 0 },
        ..Default::default()
    });
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); n];
    let start = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(n);
        for (r, (ep, out_slot)) in endpoints
            .into_iter()
            .zip(outputs.iter_mut())
            .enumerate()
        {
            let p = &p;
            let inputs = &inputs;
            let report = &report;
            let opts = &*opts;
            let plan = &plan;
            let arena = &arena;
            handles.push(s.spawn(move || -> Result<()> {
                let mut ep = ep;
                let mut fr = if opts.trace {
                    FlightRecorder::new(start, DEFAULT_FLIGHT_CAPACITY)
                } else {
                    FlightRecorder::disabled()
                };
                // Chunk `c` = stripe `c / n` of output slot `c % n`.
                let off = |c: ChunkId| (c % n) * l + (c / n) * sub;
                let own = |c: ChunkId| &inputs[r][off(c)..off(c) + sub];
                let (pool_base, pool_slots) = plan.pool[r];
                let mut pool = BufferPool::with_arena(
                    sub,
                    opts.slot_capacity,
                    arena.clone(),
                    pool_base,
                    pool_slots,
                );
                fr.set_arena_scale(sub * 4, plan.wire[r] * 4);
                let send_off = &plan.send_off[r];
                let mut acc: HashMap<ChunkId, crate::transport::buffers::Slot> = HashMap::new();
                let mut local_bytes = 0usize;
                let mut local_msgs = 0usize;

                let policy = opts.delivery.as_ref().map(|f| f(r));
                // Sentinels only bite adversarial runs: an armed sentinel in
                // another test of this process must not corrupt concurrent
                // eager-delivery runs.
                let adversarial = policy.is_some();
                drive_channels(&mut ep, &p.ranks[r], p.channels, &mut fr, policy, |ep, idx, op, data, fr| {
                    match op {
                        Op::Send { peer, chunks, channel, step } => {
                            let t0 = fr.now_or_zero();
                            let woff = send_off[idx];
                            let wlen = chunks.len() * sub;
                            // SAFETY: dedicated single-use wire region
                            // (disjoint from every pool slot by the plan).
                            let msg = unsafe { arena.slice_mut(woff, wlen) };
                            for (i, &c) in chunks.iter().enumerate() {
                                let dst = &mut msg[i * sub..(i + 1) * sub];
                                match acc.remove(&c) {
                                    Some(slot) => {
                                        // fused accumulator + own contribution
                                        // straight into the wire region
                                        opts.datapath.add_into_traced(
                                            dst, slot.as_slice(), own(c), fr, r, *channel, *step,
                                        )?;
                                        // Mutation sentinel B (test/adversary
                                        // builds only): dropping the consumed
                                        // accumulator without releasing it
                                        // leaks its pool slot — the adversary
                                        // explorer must catch the resulting
                                        // exhaustion.
                                        if adversarial && delivery::slot_release_skipped() {
                                            drop(slot);
                                        } else {
                                            pool.release_traced(slot, fr, r, *channel, *step);
                                        }
                                    }
                                    None => dst.copy_from_slice(own(c)),
                                }
                            }
                            let bytes = wlen * 4;
                            local_bytes += bytes;
                            local_msgs += 1;
                            ep.send(*peer, *channel, woff, wlen, t0)?;
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::SendOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                        Op::Recv { peer, chunks, channel, step, .. } => {
                            let (t_sent, (doff, dlen)) =
                                data.expect("recv scheduled without payload");
                            if dlen != chunks.len() * sub {
                                return Err(Error::Transport(format!(
                                    "rank {r}: message from {peer} has {} elems, want {}",
                                    dlen,
                                    chunks.len() * sub
                                )));
                            }
                            // SAFETY: single-use region, written before the
                            // descriptor was posted (mpsc happens-before).
                            let data = unsafe { arena.slice(doff, dlen) };
                            let bytes = dlen * 4;
                            let t0 = fr.now_or_zero();
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::Wire, *peer, *channel, *step, t_sent, t0)
                                        .with_peer(r)
                                        .with_msg(chunks, bytes),
                                );
                            }
                            for (i, &c) in chunks.iter().enumerate() {
                                let seg = &data[i * sub..(i + 1) * sub];
                                match acc.get_mut(&c) {
                                    Some(slot) => opts.datapath.reduce_into_traced(
                                        slot.data(), seg, fr, r, *channel, *step,
                                    )?,
                                    None => {
                                        let mut slot =
                                            pool.acquire_traced(fr, r, *channel, *step)?;
                                        slot.data().copy_from_slice(seg);
                                        acc.insert(c, slot);
                                    }
                                }
                            }
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::RecvOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                    }
                    Ok(())
                })?;
                // Output: own contribution plus whatever accumulated, one
                // stripe per channel.
                let mut out = vec![0f32; l];
                for k in 0..stripes {
                    let c = k * n + r;
                    let dst = &mut out[k * sub..(k + 1) * sub];
                    dst.copy_from_slice(own(c));
                    if let Some(slot) = acc.remove(&c) {
                        opts.datapath.reduce_into(dst, slot.as_slice())?;
                        pool.release(slot);
                    }
                }
                if !acc.is_empty() {
                    return Err(Error::Transport(format!(
                        "rank {r}: stale accumulators for chunks {:?}",
                        acc.keys().collect::<Vec<_>>()
                    )));
                }
                *out_slot = out;
                let hw = (pool.peak() * sub + plan.wire[r]) * 4;
                let mut rep = report.lock().unwrap();
                rep.peak_slots = rep.peak_slots.max(pool.peak());
                if rep.peak_slots_by_rank.len() < n {
                    rep.peak_slots_by_rank.resize(n, 0);
                }
                rep.peak_slots_by_rank[r] = pool.peak();
                rep.bytes_moved += local_bytes;
                rep.messages += local_msgs;
                rep.slots_allocated += pool.total_allocated();
                rep.arena_hw_bytes = rep.arena_hw_bytes.max(hw);
                if opts.trace {
                    let mut t = fr.finish();
                    let c = t.counters.entry((r, 0)).or_default();
                    c.arena_hw_bytes = c.arena_hw_bytes.max(hw);
                    c.allocs += pool.total_allocated();
                    rep.trace.get_or_insert_with(Trace::default).absorb(t);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| Error::Transport("rank thread panicked".into()))??;
        }
        Ok(())
    })?;

    let mut rep = report.into_inner().unwrap();
    rep.wall = start.elapsed();
    if let Some(t) = rep.trace.as_mut() {
        t.sort();
    }
    drop(lease);
    Ok((outputs, rep))
}

/// Run an all-reduce program (an RS∘AG composition from
/// [`crate::sched::compose`], possibly channel-split). `inputs[r]` holds
/// rank r's contribution to every chunk of the composed chunk space
/// (`chunk_space × chunk` elements, segments/stripes concatenated); every
/// output is the full element-wise sum across ranks of the same length.
///
/// Execution per rank follows the composition semantics: reducing receives
/// fold into pool-backed accumulators (the reduce-scatter phase);
/// a send of a non-finalized chunk pays the rank's own contribution plus
/// any accumulator (the owner's first such send completes the reduction
/// and starts the rebroadcast); plain receives install final values in the
/// output buffer; sends of finalized chunks relay from the output through
/// transient staging reservations. One [`BufferPool`] per rank covers both
/// phases and all channels, so `slot_capacity` bounds the *combined*
/// accumulator + staging footprint — the fused program's staging-slot
/// bound.
pub fn run_allreduce(
    p: &Program,
    inputs: &[Vec<f32>],
    opts: &TransportOptions,
) -> Result<(Vec<Vec<f32>>, TransportReport)> {
    if p.collective != Collective::AllReduce {
        return Err(Error::Transport(format!(
            "run_allreduce on a {} program",
            p.collective
        )));
    }
    if p.nranks == 0 {
        return Ok((vec![], TransportReport::default()));
    }
    let nchunks = p.chunk_space();
    let total = inputs.first().map(Vec::len).unwrap_or(0);
    if total % nchunks != 0 || inputs.iter().any(|v| v.len() != total) {
        return Err(Error::Transport(format!(
            "all-reduce inputs must be uniform and divisible by the chunk space {nchunks}"
        )));
    }
    run_allreduce_batch(p, &vec![total / nchunks; nchunks], inputs, opts)
}

/// Run a (possibly bucketed, see [`crate::sched::bucket`]) all-reduce
/// program over a *per-chunk element grid*: `chunk_elems[c]` is the
/// element count of chunk id `c`, so buckets of different sizes execute
/// through one program — bucket `b`'s chunks all carry `b`'s per-chunk
/// share, and the grid for a uniform program is constant (which is
/// exactly what [`run_allreduce`] passes). `inputs[r]` concatenates rank
/// r's contribution to every chunk in chunk-id order (`Σ chunk_elems`
/// elements); every output is the full element-wise sum of the same
/// length.
///
/// One [`BufferPool`] per rank — slots sized to the largest chunk —
/// covers both phases, every channel, and every bucket, so
/// `slot_capacity` bounds the *combined* accumulator + staging footprint:
/// the fused staging-slot bound is genuinely shared across buckets rather
/// than provisioned per operation. The arena plan sizes wire regions per
/// send from the same grid, so unequal buckets ride the zero-copy path
/// too.
pub fn run_allreduce_batch(
    p: &Program,
    chunk_elems: &[usize],
    inputs: &[Vec<f32>],
    opts: &TransportOptions,
) -> Result<(Vec<Vec<f32>>, TransportReport)> {
    if p.collective != Collective::AllReduce {
        return Err(Error::Transport(format!(
            "run_allreduce_batch on a {} program",
            p.collective
        )));
    }
    let n = p.nranks;
    if inputs.len() != n {
        return Err(Error::Transport(format!(
            "expected {n} inputs, got {}",
            inputs.len()
        )));
    }
    if n == 0 {
        return Ok((vec![], TransportReport::default()));
    }
    let nchunks = chunk_elems.len();
    if nchunks < p.chunk_space() {
        return Err(Error::Transport(format!(
            "chunk grid covers {nchunks} chunks, program uses {}",
            p.chunk_space()
        )));
    }
    // Prefix offsets of the chunk grid: chunk c occupies
    // `[off[c], off[c] + chunk_elems[c])` of every rank's buffer.
    let mut off = Vec::with_capacity(nchunks);
    let mut total = 0usize;
    for &e in chunk_elems {
        off.push(total);
        total += e;
    }
    if inputs.iter().any(|v| v.len() != total) {
        return Err(Error::Transport(format!(
            "all-reduce batch inputs must have exactly {total} elements (the chunk grid)"
        )));
    }
    let slot_elems = chunk_elems.iter().copied().max().unwrap_or(0);
    if opts.validate {
        crate::sched::verify::verify_program(p)?;
    }
    // Only reduce-receives hold persistent accumulator slots; plain
    // receives install straight into the output buffer.
    let plan = plan_arena(
        p,
        slot_elems,
        opts.slot_capacity,
        |chunks| chunks.iter().map(|&c| chunk_elems[c]).sum(),
        |op| matches!(op, Op::Recv { reduce: true, .. }),
    );
    let lease = lease_arena(opts, plan.total)?;
    let arena = lease.arena().clone();
    let endpoints = make_endpoints(n, opts.recv_timeout);
    let report = Mutex::new(TransportReport {
        arena_bytes: arena.bytes(),
        arena_allocs: if lease.fresh() { 1 } else { 0 },
        ..Default::default()
    });
    let mut outputs: Vec<Vec<f32>> = vec![Vec::new(); n];
    let start = Instant::now();

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(n);
        for (r, (ep, out_slot)) in endpoints
            .into_iter()
            .zip(outputs.iter_mut())
            .enumerate()
        {
            let p = &p;
            let inputs = &inputs;
            let report = &report;
            let opts = &*opts;
            let off = &off;
            let plan = &plan;
            let arena = &arena;
            handles.push(s.spawn(move || -> Result<()> {
                let mut ep = ep;
                let mut fr = if opts.trace {
                    FlightRecorder::new(start, DEFAULT_FLIGHT_CAPACITY)
                } else {
                    FlightRecorder::disabled()
                };
                let own = |c: ChunkId| &inputs[r][off[c]..off[c] + chunk_elems[c]];
                let mut out = vec![0f32; total];
                let (pool_base, pool_slots) = plan.pool[r];
                let mut pool = BufferPool::with_arena(
                    slot_elems,
                    opts.slot_capacity,
                    arena.clone(),
                    pool_base,
                    pool_slots,
                );
                fr.set_arena_scale(slot_elems * 4, plan.wire[r] * 4);
                let send_off = &plan.send_off[r];
                let mut acc: HashMap<ChunkId, crate::transport::buffers::Slot> = HashMap::new();
                let mut finalized = vec![false; nchunks];
                let mut local_bytes = 0usize;
                let mut local_msgs = 0usize;

                let policy = opts.delivery.as_ref().map(|f| f(r));
                drive_channels(&mut ep, &p.ranks[r], p.channels, &mut fr, policy, |ep, idx, op, data, fr| {
                    match op {
                        Op::Send { peer, chunks, channel, step } => {
                            let t0 = fr.now_or_zero();
                            // Finalized chunks relay through staging (the
                            // all-gather-style forward path); non-finalized
                            // chunks are reduce-scatter contribute-sends
                            // consuming their accumulator.
                            let mut reserved = 0usize;
                            if opts.staged {
                                reserved =
                                    chunks.iter().filter(|&&c| finalized[c]).count();
                                pool.reserve_traced(reserved, fr, r, *channel, *step)?;
                            }
                            let woff = send_off[idx];
                            let wlen: usize =
                                chunks.iter().map(|&c| chunk_elems[c]).sum();
                            // SAFETY: dedicated single-use wire region
                            // (disjoint from every pool slot by the plan).
                            let msg = unsafe { arena.slice_mut(woff, wlen) };
                            let mut pos = 0usize;
                            for &c in chunks {
                                let len = chunk_elems[c];
                                let dst = &mut msg[pos..pos + len];
                                pos += len;
                                if finalized[c] {
                                    dst.copy_from_slice(&out[off[c]..off[c] + len]);
                                } else if c % n == r {
                                    // Owner: fold accumulator + own
                                    // contribution, keep the final locally,
                                    // and broadcast it.
                                    match acc.remove(&c) {
                                        Some(slot) => {
                                            opts.datapath.add_into_traced(
                                                dst, &slot.as_slice()[..len], own(c),
                                                fr, r, *channel, *step,
                                            )?;
                                            pool.release_traced(slot, fr, r, *channel, *step);
                                        }
                                        None => dst.copy_from_slice(own(c)),
                                    }
                                    out[off[c]..off[c] + len].copy_from_slice(dst);
                                    finalized[c] = true;
                                } else {
                                    match acc.remove(&c) {
                                        Some(slot) => {
                                            opts.datapath.add_into_traced(
                                                dst, &slot.as_slice()[..len], own(c),
                                                fr, r, *channel, *step,
                                            )?;
                                            pool.release_traced(slot, fr, r, *channel, *step);
                                        }
                                        None => dst.copy_from_slice(own(c)),
                                    }
                                }
                            }
                            let bytes = wlen * 4;
                            local_bytes += bytes;
                            local_msgs += 1;
                            ep.send(*peer, *channel, woff, wlen, t0)?;
                            if opts.staged {
                                pool.unreserve_traced(reserved, fr, r, *channel, *step);
                            }
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::SendOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                        Op::Recv { peer, chunks, reduce, channel, step } => {
                            let (t_sent, (doff, dlen)) =
                                data.expect("recv scheduled without payload");
                            let want: usize = chunks.iter().map(|&c| chunk_elems[c]).sum();
                            if dlen != want {
                                return Err(Error::Transport(format!(
                                    "rank {r}: message from {peer} has {} elems, want {want}",
                                    dlen
                                )));
                            }
                            // SAFETY: single-use region, written before the
                            // descriptor was posted (mpsc happens-before).
                            let data = unsafe { arena.slice(doff, dlen) };
                            let bytes = dlen * 4;
                            let t0 = fr.now_or_zero();
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::Wire, *peer, *channel, *step, t_sent, t0)
                                        .with_peer(r)
                                        .with_msg(chunks, bytes),
                                );
                            }
                            let mut pos = 0usize;
                            for &c in chunks {
                                let len = chunk_elems[c];
                                let seg = &data[pos..pos + len];
                                pos += len;
                                if *reduce {
                                    match acc.get_mut(&c) {
                                        Some(slot) => opts.datapath.reduce_into_traced(
                                            &mut slot.data()[..len], seg, fr, r, *channel, *step,
                                        )?,
                                        None => {
                                            let mut slot =
                                                pool.acquire_traced(fr, r, *channel, *step)?;
                                            slot.data()[..len].copy_from_slice(seg);
                                            acc.insert(c, slot);
                                        }
                                    }
                                } else {
                                    out[off[c]..off[c] + len].copy_from_slice(seg);
                                    finalized[c] = true;
                                }
                            }
                            if fr.enabled() {
                                fr.record(
                                    Event::span(EventKind::RecvOp, r, *channel, *step, t0, fr.now())
                                        .with_peer(*peer)
                                        .with_msg(chunks, bytes),
                                );
                            }
                        }
                    }
                    Ok(())
                })?;
                // Owned chunks that were never broadcast (single-rank
                // degenerate programs) finalize locally.
                for c in 0..nchunks {
                    if !finalized[c] {
                        if c % n != r {
                            return Err(Error::Transport(format!(
                                "rank {r}: no final value for chunk {c}"
                            )));
                        }
                        let len = chunk_elems[c];
                        out[off[c]..off[c] + len].copy_from_slice(own(c));
                        if let Some(slot) = acc.remove(&c) {
                            opts.datapath.reduce_into(
                                &mut out[off[c]..off[c] + len],
                                &slot.as_slice()[..len],
                            )?;
                            pool.release(slot);
                        }
                    }
                }
                if !acc.is_empty() {
                    return Err(Error::Transport(format!(
                        "rank {r}: stale accumulators for chunks {:?}",
                        acc.keys().collect::<Vec<_>>()
                    )));
                }
                *out_slot = out;
                let hw = (pool.peak() * slot_elems + plan.wire[r]) * 4;
                let mut rep = report.lock().unwrap();
                rep.peak_slots = rep.peak_slots.max(pool.peak());
                if rep.peak_slots_by_rank.len() < n {
                    rep.peak_slots_by_rank.resize(n, 0);
                }
                rep.peak_slots_by_rank[r] = pool.peak();
                rep.bytes_moved += local_bytes;
                rep.messages += local_msgs;
                rep.slots_allocated += pool.total_allocated();
                rep.arena_hw_bytes = rep.arena_hw_bytes.max(hw);
                if opts.trace {
                    let mut t = fr.finish();
                    let c = t.counters.entry((r, 0)).or_default();
                    c.arena_hw_bytes = c.arena_hw_bytes.max(hw);
                    c.allocs += pool.total_allocated();
                    rep.trace.get_or_insert_with(Trace::default).absorb(t);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().map_err(|_| Error::Transport("rank thread panicked".into()))??;
        }
        Ok(())
    })?;

    let mut rep = report.into_inner().unwrap();
    rep.wall = start.elapsed();
    if let Some(t) = rep.trace.as_mut() {
        t.sort();
    }
    drop(lease);
    Ok((outputs, rep))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{channel as chan, pat, ring};
    use crate::util::Rng;

    fn ag_inputs(n: usize, chunk: usize, seed: u64) -> Vec<Vec<f32>> {
        // integer-valued so f32 sums are exact
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..chunk).map(|_| rng.below(1000) as f32).collect())
            .collect()
    }

    fn rs_inputs(n: usize, chunk: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..n * chunk).map(|_| rng.below(1000) as f32).collect())
            .collect()
    }

    #[test]
    fn allgather_matches_reference() {
        for n in [2usize, 3, 7, 8] {
            let inputs = ag_inputs(n, 16, n as u64);
            let mut want = Vec::new();
            for inp in &inputs {
                want.extend_from_slice(inp);
            }
            for a in [1usize, 2, usize::MAX] {
                let p = pat::allgather(n, a);
                let (outs, _) = run_allgather(&p, &inputs, &TransportOptions::default()).unwrap();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &want, "n={n} a={a} rank={r}");
                }
            }
        }
    }

    #[test]
    fn reduce_scatter_matches_reference() {
        for n in [2usize, 3, 7, 8] {
            let chunk = 16;
            let inputs = rs_inputs(n, chunk, 7 + n as u64);
            for a in [1usize, 2, usize::MAX] {
                let p = pat::reduce_scatter(n, a);
                let (outs, _) =
                    run_reduce_scatter(&p, &inputs, &TransportOptions::default()).unwrap();
                for r in 0..n {
                    let want: Vec<f32> = (0..chunk)
                        .map(|i| (0..n).map(|src| inputs[src][r * chunk + i]).sum())
                        .collect();
                    assert_eq!(outs[r], want, "n={n} a={a} rank={r}");
                }
            }
        }
    }

    /// Channel-split all-gather and reduce-scatter produce the same results
    /// as single-channel: striping is invisible in the output.
    #[test]
    fn channel_split_matches_reference() {
        for n in [2usize, 5, 8] {
            let chunk = 24; // divisible by 1, 2, 3, 4
            let inputs = ag_inputs(n, chunk, 100 + n as u64);
            let mut want = Vec::new();
            for inp in &inputs {
                want.extend_from_slice(inp);
            }
            for c in [2usize, 3, 4] {
                let p = chan::split(&pat::allgather(n, 2), c).unwrap();
                let (outs, rep) =
                    run_allgather(&p, &inputs, &TransportOptions::default()).unwrap();
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &want, "ag n={n} c={c} rank={r}");
                }
                assert_eq!(rep.bytes_moved, (n - 1) * n * chunk * 4, "ag n={n} c={c}");

                let prs = chan::split(&pat::reduce_scatter(n, 2), c).unwrap();
                let rsi = rs_inputs(n, chunk, 200 + n as u64);
                let (outs, _) =
                    run_reduce_scatter(&prs, &rsi, &TransportOptions::default()).unwrap();
                for r in 0..n {
                    let want: Vec<f32> = (0..chunk)
                        .map(|i| (0..n).map(|src| rsi[src][r * chunk + i]).sum())
                        .collect();
                    assert_eq!(outs[r], want, "rs n={n} c={c} rank={r}");
                }
            }
        }
    }

    /// A payload that does not divide into the channel stripes is a loud
    /// error (the Communicator pads before reaching the transport).
    #[test]
    fn indivisible_stripe_rejected() {
        let p = chan::split(&ring::allgather(4), 4).unwrap();
        let inputs = ag_inputs(4, 6, 1); // 6 % 4 != 0
        let err = run_allgather(&p, &inputs, &TransportOptions::default()).unwrap_err();
        assert!(err.to_string().contains("stripe"), "{err}");
    }

    /// The PAT transfer-staging bound: an aggregation-a all-gather schedule
    /// never needs more than a send-staging slots (enforced, not measured).
    #[test]
    fn pat_respects_slot_capacity() {
        let n = 16;
        for a in [1usize, 2, 4] {
            let p = pat::allgather(n, a);
            let opts = TransportOptions {
                slot_capacity: Some(a),
                ..Default::default()
            };
            let inputs = ag_inputs(n, 8, a as u64);
            let (_, rep) = run_allgather(&p, &inputs, &opts).unwrap();
            assert!(rep.peak_slots <= a, "a={a} peak={}", rep.peak_slots);
        }
    }

    /// A C-channel split runs within C× the single-channel staging bound
    /// (each stripe is an independent copy of the schedule, sharing the
    /// rank's physical pool), enforced.
    #[test]
    fn channel_split_respects_scaled_slot_capacity() {
        let n = 16;
        let a = 2;
        for c in [2usize, 4] {
            let p = chan::split(&pat::allgather(n, a), c).unwrap();
            let opts = TransportOptions {
                slot_capacity: Some(a * c),
                ..Default::default()
            };
            let inputs = ag_inputs(n, 8, c as u64);
            let (_, rep) = run_allgather(&p, &inputs, &opts).unwrap();
            assert!(rep.peak_slots <= a * c, "c={c} peak={}", rep.peak_slots);
        }
    }

    /// The RS accumulator bound (the paper's "logarithmic amount of
    /// internal buffers"): peak live accumulators stays within
    /// a · log2(n/a), independent of chunk size.
    #[test]
    fn rs_accumulators_logarithmic() {
        let n = 16usize;
        for a in [1usize, 2, 4] {
            let bound = a * (crate::core::ceil_log2(n) - crate::core::floor_log2(a)) as usize;
            for chunk in [4usize, 64] {
                let prs = pat::reduce_scatter(n, a);
                let rs_in = rs_inputs(n, chunk, a as u64);
                let opts_rs = TransportOptions {
                    slot_capacity: Some(bound),
                    ..Default::default()
                };
                let (_, rep) = run_reduce_scatter(&prs, &rs_in, &opts_rs).unwrap();
                assert!(
                    rep.peak_slots <= bound,
                    "rs a={a} chunk={chunk} peak={}",
                    rep.peak_slots
                );
            }
        }
    }

    #[test]
    fn ring_transport_works() {
        let n = 6;
        let inputs = ag_inputs(n, 32, 3);
        let (outs, rep) = run_allgather(&ring::allgather(n), &inputs, &Default::default()).unwrap();
        assert_eq!(rep.messages, n * (n - 1));
        let mut want = Vec::new();
        for inp in &inputs {
            want.extend_from_slice(inp);
        }
        assert_eq!(outs[0], want);
    }

    #[test]
    fn wrong_collective_rejected() {
        let p = ring::allgather(4);
        let inputs = rs_inputs(4, 4, 1);
        assert!(run_reduce_scatter(&p, &inputs, &Default::default()).is_err());
        assert!(run_allreduce(&p, &inputs, &Default::default()).is_err());
    }

    #[test]
    fn allreduce_matches_reference() {
        for n in [2usize, 3, 7, 8] {
            for segments in [1usize, 2, 4] {
                let rs = pat::reduce_scatter(n, 2);
                let ag = pat::allgather(n, 2);
                let p = crate::sched::compose::fuse(&rs, &ag, segments).unwrap();
                let nchunks = p.chunk_space();
                let chunk = 8;
                let mut rng = Rng::new(n as u64 * 7 + segments as u64);
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..nchunks * chunk).map(|_| rng.below(500) as f32).collect())
                    .collect();
                let (outs, rep) =
                    run_allreduce(&p, &inputs, &TransportOptions::default()).unwrap();
                for (r, out) in outs.iter().enumerate() {
                    for i in 0..nchunks * chunk {
                        let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                        assert_eq!(out[i], want, "n={n} s={segments} rank={r} idx={i}");
                    }
                }
                assert!(rep.messages > 0 || n == 1);
            }
        }
    }

    /// A channel-split all-reduce (split applied on top of the composition)
    /// still sums exactly.
    #[test]
    fn allreduce_channel_split_matches_reference() {
        let n = 6;
        let rs = pat::reduce_scatter(n, 2);
        let ag = ring::allgather(n);
        let fused = crate::sched::compose::fuse(&rs, &ag, 2).unwrap();
        let p = chan::split(&fused, 2).unwrap();
        assert_eq!(p.channels, 4);
        let nchunks = p.chunk_space();
        let chunk = 4;
        let mut rng = Rng::new(77);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..nchunks * chunk).map(|_| rng.below(500) as f32).collect())
            .collect();
        let (outs, _) = run_allreduce(&p, &inputs, &TransportOptions::default()).unwrap();
        for (r, out) in outs.iter().enumerate() {
            for i in 0..nchunks * chunk {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "rank={r} idx={i}");
            }
        }
    }

    /// The fused staging bound: segment channels progress independently in
    /// this engine, so the sound capacity is the per-segment peak (the
    /// single-segment composition, measured by the reference executor) ×
    /// segments — every channel simultaneously at its own worst point —
    /// plus one in-flight message's aggregation. Enforced, not measured.
    #[test]
    fn allreduce_respects_fused_slot_bound() {
        let n = 16usize;
        for segments in [1usize, 2, 4] {
            let rs = pat::reduce_scatter(n, 2);
            let ag = pat::allgather(n, 2);
            let p = crate::sched::compose::fuse(&rs, &ag, segments).unwrap();
            let per_segment = {
                let one = crate::sched::compose::fuse(&rs, &ag, 1).unwrap();
                crate::sched::verify::verify_program(&one).unwrap().peak_slots
            };
            let cap = segments * per_segment + p.stats().max_aggregation + 1;
            crate::sched::verify::verify_program(&p).unwrap();
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                ..Default::default()
            };
            let nchunks = p.chunk_space();
            let inputs: Vec<Vec<f32>> =
                (0..n).map(|r| vec![r as f32; nchunks * 4]).collect();
            let (_, rep) = run_allreduce(&p, &inputs, &opts).unwrap();
            assert!(
                rep.peak_slots <= cap,
                "segments={segments}: peak {} > cap {cap}",
                rep.peak_slots
            );
        }
    }

    /// A bucketed all-reduce with *unequal* bucket sizes sums exactly:
    /// the per-chunk element grid routes each bucket's differently-sized
    /// chunks through one shared state machine and one shared pool.
    #[test]
    fn allreduce_batch_unequal_buckets_match_reference() {
        use crate::sched::bucket;
        for n in [2usize, 3, 7, 8] {
            let rs = pat::reduce_scatter(n, 2);
            let ag = pat::allgather(n, 2);
            let buckets = bucket::uniform(&rs, &ag, 3, 1);
            let p = bucket::fuse(&buckets).unwrap();
            let layout = bucket::BucketLayout::of(&buckets);
            // ramp-shaped: small first bucket, growing tail
            let chunk_elems = layout.chunk_elems(&[2, 4, 8]);
            let total: usize = chunk_elems.iter().sum();
            let mut rng = Rng::new(n as u64 * 13);
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..total).map(|_| rng.below(500) as f32).collect())
                .collect();
            let (outs, rep) =
                run_allreduce_batch(&p, &chunk_elems, &inputs, &TransportOptions::default())
                    .unwrap();
            for (r, out) in outs.iter().enumerate() {
                for i in 0..total {
                    let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                    assert_eq!(out[i], want, "n={n} rank={r} idx={i}");
                }
            }
            assert!(rep.messages > 0);
            // a grid that does not match the inputs is a loud error
            assert!(run_allreduce_batch(
                &p,
                &layout.chunk_elems(&[2, 4, 9]),
                &inputs,
                &TransportOptions::default()
            )
            .is_err());
        }
    }

    /// The fused bucketed staging bound is shared across buckets: B
    /// single-segment buckets run within B × the single-composition peak
    /// plus one in-flight message's aggregation, enforced.
    #[test]
    fn allreduce_batch_respects_shared_slot_bound() {
        use crate::sched::bucket;
        let n = 16usize;
        let rs = pat::reduce_scatter(n, 2);
        let ag = pat::allgather(n, 2);
        let per_single = {
            let one = crate::sched::compose::fuse(&rs, &ag, 1).unwrap();
            crate::sched::verify::verify_program(&one).unwrap().peak_slots
        };
        for nb in [1usize, 2, 4] {
            let buckets = bucket::uniform(&rs, &ag, nb, 1);
            let p = bucket::fuse(&buckets).unwrap();
            let layout = bucket::BucketLayout::of(&buckets);
            let cap = nb * per_single + p.stats().max_aggregation + 1;
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                ..Default::default()
            };
            let chunk_elems = layout.chunk_elems(&vec![4; nb]);
            let total: usize = chunk_elems.iter().sum();
            let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; total]).collect();
            let (_, rep) = run_allreduce_batch(&p, &chunk_elems, &inputs, &opts).unwrap();
            assert!(
                rep.peak_slots <= cap,
                "nb={nb}: peak {} > cap {cap}",
                rep.peak_slots
            );
        }
    }

    /// Tracing on: the merged trace accounts for every message on both
    /// sides, records pool occupancy (RS accumulators), and its per-rank
    /// pool-peak counters match the report's enforced peak.
    #[test]
    fn traced_run_accounts_for_every_message() {
        use crate::obs::EventKind;
        let n = 16;
        let p = pat::reduce_scatter(n, 2);
        let inputs = rs_inputs(n, 8, 5);
        let opts = TransportOptions { trace: true, ..Default::default() };
        let (_, rep) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
        let trace = rep.trace.as_ref().expect("trace requested");
        let totals = trace.totals();
        assert_eq!(totals.msgs_sent, rep.messages);
        assert_eq!(totals.msgs_recv, rep.messages);
        assert_eq!(totals.bytes_sent, rep.bytes_moved);
        assert_eq!(totals.bytes_recv, rep.bytes_moved);
        let wires = trace.events.iter().filter(|e| e.kind == EventKind::Wire).count();
        assert_eq!(wires, rep.messages);
        assert!(totals.reduce_calls > 0, "RS must invoke the reduce kernel");
        assert!(totals.pool_peak > 0, "RS must sample accumulator occupancy");
        assert_eq!(totals.pool_peak, rep.peak_slots, "counter peak == enforced peak");
        // events are globally sorted and windows are sane
        for w in trace.events.windows(2) {
            assert!(w[0].t_start <= w[1].t_start);
        }
        for ev in &trace.events {
            assert!(ev.t_end >= ev.t_start, "{ev:?}");
        }
    }

    /// Tracing off: the report carries no trace and runs stay correct
    /// (the disabled recorder is a pure pass-through).
    #[test]
    fn untraced_run_has_no_trace() {
        let n = 8;
        let inputs = ag_inputs(n, 16, 2);
        let (_, rep) =
            run_allgather(&pat::allgather(n, 2), &inputs, &TransportOptions::default()).unwrap();
        assert!(rep.trace.is_none());
    }

    /// Satellite: the watchdog names the blocked (rank, channel, step),
    /// the peer, and the pending FIFO depth — with tracing off.
    #[test]
    fn watchdog_blames_blocked_channel() {
        let mut p = Program::new(2, Collective::AllGather, "broken");
        p.push(0, Op::recv(1, vec![1], false, 3));
        p.push(0, Op::send(1, vec![0], 3));
        p.push(1, Op::recv(0, vec![0], false, 3));
        let opts = TransportOptions {
            validate: false,
            recv_timeout: Duration::from_millis(100),
            ..Default::default()
        };
        let inputs = vec![vec![1.0f32], vec![2.0f32]];
        let err = run_allgather(&p, &inputs, &opts).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(err.contains("channel 0"), "{err}");
        assert!(err.contains("step 3"), "{err}");
        assert!(err.contains("blocked on recv from rank"), "{err}");
        assert!(err.contains("queued on that connection"), "{err}");
    }

    /// Satellite: when a run deadlocks under an adversarial delivery
    /// policy, the watchdog's stall report carries the policy's
    /// perturbation log — the blamed rank's schedule *and* what the
    /// adversary did to it arrive in one error.
    #[test]
    fn watchdog_attaches_perturbation_log() {
        let mut p = Program::new(2, Collective::AllGather, "broken");
        p.push(0, Op::recv(1, vec![1], false, 3));
        p.push(0, Op::send(1, vec![0], 3));
        p.push(1, Op::recv(0, vec![0], false, 3));
        let spec = crate::adversary::PolicySpec::parse("delay:7").unwrap();
        let opts = TransportOptions {
            validate: false,
            recv_timeout: Duration::from_millis(100),
            delivery: Some(spec.transport_factory()),
            ..Default::default()
        };
        let inputs = vec![vec![1.0f32], vec![2.0f32]];
        let err = run_allgather(&p, &inputs, &opts).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        assert!(err.contains("delivery-policy perturbation log"), "{err}");
        assert!(err.contains("policy=delay"), "{err}");
    }

    #[test]
    fn capacity_violation_detected() {
        // Unconstrained bruck far-first on 16 ranks needs >2 staging slots;
        // capping at 1 must error.
        let p = crate::sched::bruck::allgather_far_first(16);
        let inputs = ag_inputs(16, 4, 9);
        let opts = TransportOptions {
            slot_capacity: Some(1),
            ..Default::default()
        };
        let err = run_allgather(&p, &inputs, &opts).unwrap_err();
        assert!(err.to_string().contains("exhausted"), "{err}");
    }

    /// Tentpole: a shared [`ArenaCache`] makes the second run of the same
    /// collective allocation-free — the arena is reused (`arena_allocs ==
    /// 0`), no pool slot falls back to the heap (`slots_allocated == 0`),
    /// the high-water mark fits inside the preallocated footprint, and
    /// results stay exact.
    #[test]
    fn arena_cache_reuse_reports_zero_allocs() {
        let n = 8;
        let chunk = 16;
        let p = pat::reduce_scatter(n, 2);
        let inputs = rs_inputs(n, chunk, 42);
        let opts = TransportOptions {
            arena: Some(ArenaCache::new()),
            ..Default::default()
        };
        let (_, rep1) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
        assert_eq!(rep1.arena_allocs, 1, "cold cache allocates exactly once");
        assert!(rep1.arena_bytes > 0);
        let (outs, rep2) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
        assert_eq!(rep2.arena_allocs, 0, "warm cache must not allocate an arena");
        assert_eq!(rep2.slots_allocated, 0, "steady state must not heap-allocate slots");
        assert!(rep2.arena_hw_bytes > 0);
        assert!(
            rep2.arena_hw_bytes <= rep2.arena_bytes,
            "hw {} > footprint {}",
            rep2.arena_hw_bytes,
            rep2.arena_bytes
        );
        for r in 0..n {
            let want: Vec<f32> = (0..chunk)
                .map(|i| (0..n).map(|src| inputs[src][r * chunk + i]).sum())
                .collect();
            assert_eq!(outs[r], want, "rank={r}");
        }
    }
}
