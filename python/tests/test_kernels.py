"""L1 kernel correctness: Pallas vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; parametrized cases pin the size
classes that ship as artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import reduce as kred
from compile.kernels import update as kupd
from compile.kernels import ref


def rand(n, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(n, dtype=np.float32) * scale)


@pytest.mark.parametrize("n", [1, 7, 128, 1024, 1025, 4096, 16384])
def test_reduce2_matches_ref_sizes(n):
    a, b = rand(n, 1), rand(n, 2)
    got = kred.reduce2(a, b)
    np.testing.assert_allclose(got, ref.ref_reduce2(a, b), rtol=1e-6)
    assert got.shape == (n,)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_reduce2_hypothesis(n, seed, scale):
    a, b = rand(n, seed, scale), rand(n, seed + 1, scale)
    np.testing.assert_allclose(
        kred.reduce2(a, b), ref.ref_reduce2(a, b), rtol=1e-6, atol=1e-6 * scale
    )


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_reduce_k_hypothesis(n, k, seed):
    acc = rand(n, seed)
    xs = [rand(n, seed + i + 1) for i in range(k)]
    got = kred.reduce_k(acc, *xs)
    want = ref.ref_reduce_k(acc, *xs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [4096, 65536])
def test_reduce_k_equals_chain_of_reduce2(n):
    acc = rand(n, 3)
    xs = [rand(n, 10 + i) for i in range(4)]
    fused = kred.reduce_k(acc, *xs)
    chained = acc
    for x in xs:
        chained = kred.reduce2(chained, x)
    np.testing.assert_allclose(fused, chained, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=4000),
    seed=st.integers(min_value=0, max_value=1000),
    lr=st.sampled_from([0.0, 1e-4, 0.1, 1.0]),
)
def test_scale_add_hypothesis(n, seed, lr):
    p, g = rand(n, seed), rand(n, seed + 7)
    lrv = jnp.asarray([lr], dtype=jnp.float32)
    np.testing.assert_allclose(
        kupd.scale_add(p, g, lrv), ref.ref_scale_add(p, g, lrv), rtol=1e-6, atol=1e-7
    )


def test_padding_is_not_leaked():
    # Non-multiple-of-lane sizes must not read/write padding.
    n = 130
    a = jnp.ones((n,), jnp.float32)
    b = jnp.full((n,), 2.0, jnp.float32)
    out = kred.reduce2(a, b)
    assert out.shape == (n,)
    np.testing.assert_array_equal(np.asarray(out), np.full(n, 3.0, np.float32))


def test_tiles_divide_rows():
    for n in [1, 8, 127, 128, 1024, 100_000]:
        rows, lanes = kred.padded_2d(n)
        assert rows % 8 == 0 and lanes == 128
        block, grid = kred._tiles(rows)
        assert block * grid == rows


def test_kernels_lower_to_hlo_text():
    """The artifact path works end-to-end for a pallas-calling graph."""
    from compile import model
    from compile.aot import to_hlo_text

    fn, specs = model.reduce2_graph(256)
    text = to_hlo_text(fn, specs)
    assert "HloModule" in text
    assert len(text) > 100
