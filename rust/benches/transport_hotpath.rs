//! HOT — wall-clock performance of the real transport hot path (the part
//! the perf pass optimizes; EXPERIMENTS.md §Perf records before/after).
//!
//! Measures, with the in-repo harness (criterion is unavailable offline):
//! * the scalar reduction kernel's memory bandwidth,
//! * end-to-end all-gather / reduce-scatter wall time and effective
//!   algorithm bandwidth across sizes on 8 threaded ranks,
//! * allocation pressure (pool slots allocated per op),
//! * the reduction-service request ABI: the pre-arena owned round trip
//!   (clone both operands in, copy the reply back) vs the slice-descriptor
//!   path, across shard counts — the regression gate `--smoke` asserts a
//!   ≥ 2× floor on,
//! * the arena send path (2-rank all-gather, one wire descriptor), and
//! * the zero-allocation steady state on a warm arena cache.

use patcol::bench::{bench, black_box, BenchOpts};
use patcol::report::Report;
use patcol::runtime::PjrtService;
use patcol::sched::{pat, ring};
use patcol::transport::datapath::scalar_add;
use patcol::transport::{
    run_allgather, run_allgather_into, run_reduce_scatter, ArenaCache, TransportOptions,
};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};
use patcol::util::Rng;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new("transport_hotpath");
    let opts = if smoke { patcol::bench::quick() } else { BenchOpts::default() };

    // --- scalar reduce kernel roofline ------------------------------------
    println!("\nscalar reduction kernel (acc += x):");
    let kernel_sizes: &[usize] = if smoke {
        &[4 << 10]
    } else {
        &[4 << 10, 256 << 10, 4 << 20]
    };
    for &n in kernel_sizes {
        let elems = n / 4;
        let mut acc = vec![1.0f32; elems];
        let x = vec![2.0f32; elems];
        let m = bench(&format!("scalar_add {}", fmt_bytes(n)), &opts, || {
            scalar_add(black_box(&mut acc), black_box(&x));
        });
        // 2 reads + 1 write per element
        let bytes = 3.0 * n as f64;
        println!(
            "  {}  ({}/s)",
            m.line(),
            fmt_bytes((bytes / m.per_iter()) as usize)
        );
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("scalar_add")),
            ("bytes", Json::num(n as f64)),
            ("per_iter_s", Json::num(m.per_iter())),
            ("gbps", Json::num(bytes / m.per_iter() / 1e9)),
        ]));
    }

    // --- end-to-end transport --------------------------------------------
    let n = 8usize;
    let topts = TransportOptions {
        validate: false,
        ..Default::default()
    };
    println!("\nthreaded transport, {n} ranks (wall time per collective):");
    let mut table = Table::new(["op", "size/rank", "alg", "wall p50", "algbw", "allocs"]);
    let chunk_sweep: &[usize] = if smoke {
        &[16 << 10]
    } else {
        &[16 << 10, 256 << 10, 4 << 20]
    };
    for &chunk_bytes in chunk_sweep {
        let chunk = chunk_bytes / 4;
        let mut rng = Rng::new(1);

        // all-gather
        let ag_in: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0f32; chunk];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        for (name, prog) in [
            ("pat(a=2)", pat::allgather(n, 2)),
            ("ring", ring::allgather(n)),
        ] {
            let mut outputs: Vec<Vec<f32>> = vec![vec![0f32; n * chunk]; n];
            let m = bench(&format!("ag {name} {}", fmt_bytes(chunk_bytes)), &opts, || {
                run_allgather_into(
                    black_box(&prog),
                    black_box(&ag_in),
                    black_box(&mut outputs),
                    &topts,
                )
                .unwrap();
            });
            let payload = ((n - 1) * chunk * 4) as f64;
            let (_, rep) = run_allgather(&prog, &ag_in, &topts).unwrap();
            table.row([
                "all-gather".into(),
                fmt_bytes(chunk_bytes),
                name.to_string(),
                fmt_time_s(m.per_iter()),
                format!("{}/s", fmt_bytes((payload / m.per_iter()) as usize)),
                format!("{}", rep.slots_allocated),
            ]);
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("allgather")),
                ("alg", Json::str(name)),
                ("chunk_bytes", Json::num(chunk_bytes as f64)),
                ("wall_s", Json::num(m.per_iter())),
                ("algbw_gbps", Json::num(payload / m.per_iter() / 1e9)),
                ("allocs", Json::num(rep.slots_allocated as f64)),
            ]));
        }

        // reduce-scatter
        let rs_in: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0f32; n * chunk];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        for (name, prog) in [
            ("pat(a=2)", pat::reduce_scatter(n, 2)),
            ("ring", ring::reduce_scatter(n)),
        ] {
            let m = bench(&format!("rs {name} {}", fmt_bytes(chunk_bytes)), &opts, || {
                let out = run_reduce_scatter(black_box(&prog), black_box(&rs_in), &topts).unwrap();
                black_box(out);
            });
            let payload = ((n - 1) * chunk * 4) as f64;
            let (_, rep) = run_reduce_scatter(&prog, &rs_in, &topts).unwrap();
            table.row([
                "reduce-scatter".into(),
                fmt_bytes(chunk_bytes),
                name.to_string(),
                fmt_time_s(m.per_iter()),
                format!("{}/s", fmt_bytes((payload / m.per_iter()) as usize)),
                format!("{}", rep.slots_allocated),
            ]);
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("reduce_scatter")),
                ("alg", Json::str(name)),
                ("chunk_bytes", Json::num(chunk_bytes as f64)),
                ("wall_s", Json::num(m.per_iter())),
                ("algbw_gbps", Json::num(payload / m.per_iter() / 1e9)),
                ("allocs", Json::num(rep.slots_allocated as f64)),
            ]));
        }
    }
    print!("{}", table.render());

    // --- observability overhead ------------------------------------------
    // Same op with the flight recorder off vs on. Off must stay at the
    // baseline (the disabled path is one predicted branch, no clock
    // reads); on pays for timestamps + ring pushes and is reported so the
    // cost of always-on tracing is a measured number, not a guess.
    println!("\ntracing overhead (pat(a=2) reduce-scatter, {n} ranks):");
    {
        let chunk_bytes: usize = if smoke { 16 << 10 } else { 256 << 10 };
        let chunk = chunk_bytes / 4;
        let mut rng = Rng::new(3);
        let rs_in: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0f32; n * chunk];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        let prog = pat::reduce_scatter(n, 2);
        let traced_opts = TransportOptions {
            validate: false,
            trace: true,
            ..Default::default()
        };
        let off = bench(&format!("rs untraced {}", fmt_bytes(chunk_bytes)), &opts, || {
            let out = run_reduce_scatter(black_box(&prog), black_box(&rs_in), &topts).unwrap();
            black_box(out);
        });
        let on = bench(&format!("rs traced {}", fmt_bytes(chunk_bytes)), &opts, || {
            let out =
                run_reduce_scatter(black_box(&prog), black_box(&rs_in), &traced_opts).unwrap();
            black_box(out);
        });
        let ratio = on.per_iter() / off.per_iter().max(1e-12);
        println!(
            "  off {}  on {}  ({ratio:.2}x)",
            fmt_time_s(off.per_iter()),
            fmt_time_s(on.per_iter()),
        );
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("trace_overhead")),
            ("chunk_bytes", Json::num(chunk_bytes as f64)),
            ("wall_off_s", Json::num(off.per_iter())),
            ("wall_on_s", Json::num(on.per_iter())),
            ("ratio", Json::num(ratio)),
        ]));
    }

    // --- reduction-service ABI: owned round trip vs slice descriptors ----
    // The scalar-backend service exercises the exact same request routing,
    // thread hops, and reply plumbing as the PJRT backend, so the ABI
    // delta measured here is the datapath's, not the kernel's. The owned
    // baseline replays the pre-arena hot path: clone both operands into
    // the request, receive an owned reply, copy it back into the
    // accumulator (~3 extra passes over each operand). The slice path
    // sends pointer+len descriptors and reduces in place.
    println!("\nreduction-service ABI (1 MiB operands, scalar-backend shards):");
    let (owned_gbps, slice2_gbps) = {
        let elems = (1usize << 20) / 4;
        let mut rng = Rng::new(5);
        let mut acc = vec![0f32; elems];
        rng.fill_f32(&mut acc);
        let mut x = vec![0f32; elems];
        rng.fill_f32(&mut x);
        // 2 operand reads + 1 accumulator write per logical reduce
        let bytes = 3.0 * (elems * 4) as f64;

        let owned = {
            let (_svc, h) = PjrtService::spawn_scalar(1).unwrap();
            let m = bench("reduce owned abi, 1 shard", &opts, || {
                let a = black_box(&acc).to_vec();
                let b = black_box(&x).to_vec();
                let out = h.reduce_owned(a, b).unwrap();
                acc.copy_from_slice(black_box(&out));
            });
            let gbps = bytes / m.per_iter() / 1e9;
            println!("  owned @1 shard  {}  ({gbps:.2} GB/s)", m.line());
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("reduce_path")),
                ("abi", Json::str("owned")),
                ("shards", Json::num(1.0)),
                ("bytes", Json::num((elems * 4) as f64)),
                ("wall_s", Json::num(m.per_iter())),
                ("gbps", Json::num(gbps)),
            ]));
            gbps
        };
        let mut slice2 = 0.0f64;
        for shards in [2usize, 4] {
            let (_svc, h) = PjrtService::spawn_scalar(shards).unwrap();
            let mut rank = 0usize;
            let m = bench(&format!("reduce slice abi, {shards} shards"), &opts, || {
                rank = rank.wrapping_add(1);
                h.reduce_into_routed(rank, 0, black_box(&mut acc), black_box(&x))
                    .unwrap();
            });
            let gbps = bytes / m.per_iter() / 1e9;
            println!("  slice @{shards} shards {}  ({gbps:.2} GB/s)", m.line());
            report.rows.push(Json::obj(vec![
                ("kind", Json::str("reduce_path")),
                ("abi", Json::str("slice")),
                ("shards", Json::num(shards as f64)),
                ("bytes", Json::num((elems * 4) as f64)),
                ("wall_s", Json::num(m.per_iter())),
                ("gbps", Json::num(gbps)),
            ]));
            if shards == 2 {
                slice2 = gbps;
            }
        }
        (owned, slice2)
    };

    // --- arena send path (2-rank all-gather, one wire descriptor) ---------
    println!("\narena send path (2 ranks, single-chunk all-gather):");
    {
        let chunk_bytes: usize = if smoke { 1 << 20 } else { 4 << 20 };
        let chunk = chunk_bytes / 4;
        let mut rng = Rng::new(9);
        let ag_in: Vec<Vec<f32>> = (0..2)
            .map(|_| {
                let mut v = vec![0f32; chunk];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        let prog = ring::allgather(2);
        let aopts = TransportOptions {
            validate: false,
            arena: Some(ArenaCache::new()),
            ..Default::default()
        };
        let mut outputs: Vec<Vec<f32>> = vec![vec![0f32; 2 * chunk]; 2];
        // warm the cache so the measured loop is the steady state
        run_allgather_into(&prog, &ag_in, &mut outputs, &aopts).unwrap();
        let m = bench(&format!("send path {}", fmt_bytes(chunk_bytes)), &opts, || {
            run_allgather_into(
                black_box(&prog),
                black_box(&ag_in),
                black_box(&mut outputs),
                &aopts,
            )
            .unwrap();
        });
        let payload = chunk_bytes as f64; // (n-1) chunks per rank at n=2
        let gbps = payload / m.per_iter() / 1e9;
        println!("  {}  ({gbps:.2} GB/s)", m.line());
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("send_path")),
            ("chunk_bytes", Json::num(chunk_bytes as f64)),
            ("wall_s", Json::num(m.per_iter())),
            ("gbps", Json::num(gbps)),
        ]));
    }

    // --- zero-allocation steady state -------------------------------------
    // One warm cache, two runs: the first populates the arena, the second
    // must allocate nothing at all (no fresh arena, no heap-fallback pool
    // slots) — the same invariant tests/observability.rs gates on.
    println!("\nsteady-state allocations (pat(a=2) reduce-scatter, warm arena):");
    let steady_allocs = {
        let chunk = (16usize << 10) / 4;
        let mut rng = Rng::new(13);
        let rs_in: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v = vec![0f32; n * chunk];
                rng.fill_f32(&mut v);
                v
            })
            .collect();
        let prog = pat::reduce_scatter(n, 2);
        let aopts = TransportOptions {
            validate: false,
            arena: Some(ArenaCache::new()),
            ..Default::default()
        };
        let (_, rep1) = run_reduce_scatter(&prog, &rs_in, &aopts).unwrap();
        let (_, rep2) = run_reduce_scatter(&prog, &rs_in, &aopts).unwrap();
        println!(
            "  run 1: arenas {} pool-heap {}   run 2: arenas {} pool-heap {}",
            rep1.arena_allocs, rep1.slots_allocated, rep2.arena_allocs, rep2.slots_allocated
        );
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("steady_state")),
            ("arena_allocs_cold", Json::num(rep1.arena_allocs as f64)),
            ("arena_allocs_warm", Json::num(rep2.arena_allocs as f64)),
            ("pool_heap_warm", Json::num(rep2.slots_allocated as f64)),
            ("arena_hw_bytes", Json::num(rep2.arena_hw_bytes as f64)),
        ]));
        rep2.arena_allocs + rep2.slots_allocated
    };

    if smoke {
        assert!(
            slice2_gbps >= 2.0 * owned_gbps,
            "reduce-path floor: slice@2 {slice2_gbps:.2} GB/s < 2x owned@1 {owned_gbps:.2} GB/s"
        );
        assert_eq!(steady_allocs, 0, "steady state allocated on the warm path");
        println!(
            "\nsmoke OK: reduce path {:.1}x owned baseline, steady-state allocs 0",
            slice2_gbps / owned_gbps.max(1e-12)
        );
    }

    report.save().unwrap();
}
