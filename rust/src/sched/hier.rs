//! Hierarchical (topology-aware) PAT over a rank [`Placement`] — the
//! production-scale extension the paper's "communicate close dimensions
//! first" construction points at, and what NCCL itself does across NVLink
//! domains: keep the chatty traffic inside a node, run the latency-optimal
//! algorithm only between nodes. Three coordinated optimisations keep the
//! construction fast at scale:
//!
//! * **Multi-leader striping.** The inter-node phase is striped across the
//!   first `L = Placement::effective_leaders()` ranks of every node. Stripe
//!   `ℓ` owns the local chunks at offsets `≡ ℓ (mod L)` and runs a complete
//!   hierarchical schedule of its own on channel `ℓ` (its own ECMP salt);
//!   per-rank op lists are the FIFO-safe
//!   [`channel::merge_rank_streams`] merge of the `L` stripe streams. One
//!   leader NIC per node becomes `L` parallel flows at bandwidth-bound
//!   sizes.
//! * **Pipelined fan-out.** Instead of one bulk intra-node fan-out after
//!   the whole inter-node phase, each inter-node round `j` is immediately
//!   followed by a *wave*: an intra-node broadcast tree carrying exactly
//!   round `j`'s arrivals. Wave `j` overlaps round `j+1` on the fabric, and
//!   a leader stages only a round's payload (O(a · kmax/L) chunks) plus
//!   relayed sets — sublinear in `n`, in place of the old Θ(n) leader
//!   staging ([`staging_bound`] is the law the tuner budgets against).
//! * **Three-level recursion.** A [`Placement`] with pods
//!   (leaf/pod/fabric) recurses: intra-node gather, intra-pod PAT over the
//!   pod's nodes (each round waved into the nodes), then inter-pod PAT over
//!   pod leaders, each round distributed by a *pod wave* (leader-to-leader
//!   tree across the pod's nodes) followed by node waves.
//!
//! An all-gather stripe runs, on a shared step grid:
//!
//! 1. **Intra-node gather** — within each node, a near-first binomial tree
//!    over the stripe's member ranks funnels the stripe's chunks to its
//!    stripe leader.
//! 2. **Local broadcast (wave 0)** — the node's own stripe chunks reach
//!    every co-located rank (each edge carries what the receiver does not
//!    already hold from the gather).
//! 3. **Inter-node PAT + waves** — the stripe leaders run flat PAT over
//!    *nodes* (or recurse over pods): the program for `nnodes` virtual
//!    ranks ([`pat::rounds`]) is expanded by substituting each virtual rank
//!    with its stripe leader and each virtual chunk with that node's stripe
//!    chunk set; each round's arrivals are waved into the node on the next
//!    steps. The aggregation factor bounds how many *node chunk sets* one
//!    transfer carries; uneven node sizes just produce uneven chunk lists.
//!
//! Correctness of the inter phase follows from the flat PAT invariant by
//! isomorphism: after the gather, the stripe leader of node `m` holds
//! exactly node `m`'s stripe chunks — the image of "flat rank `m` holds
//! chunk `m`" — and every subsequent message is the image of a flat PAT
//! message; waves deliver each PAT arrival exactly once to the rest of the
//! node (and, for pod waves, to the rest of the pod's leaders).
//!
//! Reduce-scatter is the time-and-direction mirror ([`Program::mirror`]):
//! per-round intra-node reduction waves feeding the inter-node PAT reduce,
//! then an intra-node scatter — so
//! [`crate::sched::verify::verify_program`] covers it with no
//! hierarchical-specific executor.
//!
//! The phase structure is a list ([`phase_list`]), not a fixed triple:
//! two-level programs have three phases, three-level programs four.

use std::collections::HashSet;

use crate::core::{ceil_log2, ChunkId, Collective, Placement, Rank};
use crate::sched::channel::{self, Stream};
use crate::sched::pat;
use crate::sched::program::{Op, Program};
use crate::sched::tree::NearFirstTree;

/// Intra-node tree edges as `(parent, child)` indices in pre-order (every
/// edge appears after the edge that delivers to its parent) — the fan-out
/// execution order.
fn preorder_edges(k: usize) -> Vec<(usize, usize)> {
    fn visit(t: &NearFirstTree, o: usize, out: &mut Vec<(usize, usize)>) {
        for c in t.children(o) {
            out.push((o, c));
            visit(t, c, out);
        }
    }
    let t = NearFirstTree::new(k);
    let mut out = Vec::new();
    visit(&t, 0, &mut out);
    out
}

/// Intra-node tree edges as `(child, parent)` indices in post-order (every
/// edge appears after all edges inside the child's subtree) — the gather
/// execution order.
fn postorder_edges(k: usize) -> Vec<(usize, usize)> {
    fn visit(t: &NearFirstTree, o: usize, out: &mut Vec<(usize, usize)>) {
        for c in t.children(o) {
            visit(t, c, out);
            out.push((c, o));
        }
    }
    let t = NearFirstTree::new(k);
    let mut out = Vec::new();
    visit(&t, 0, &mut out);
    out
}

/// Indices in the subtree rooted at `o`, ascending.
fn subtree_offsets(t: &NearFirstTree, o: usize) -> Vec<usize> {
    let mut out = vec![o];
    let mut i = 0;
    while i < out.len() {
        let cur = out[i];
        out.extend(t.children(cur));
        i += 1;
    }
    out.sort_unstable();
    out
}

/// One named phase of a hierarchical program (all-gather orientation; the
/// mirrored reduce-scatter reverses the list).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HierPhase {
    /// Stable slug: `intra_gather`, `intra_bcast`, `inter_pipeline`,
    /// `pod_pipeline` or `fabric_pipeline`.
    pub name: &'static str,
    /// Step count of the phase's span.
    pub steps: usize,
}

/// Per-stripe step-grid constants, shared by construction and
/// [`phase_list`]. All stripes use the same grid (derived from the global
/// maxima) so their streams merge on aligned step keys.
struct Grid {
    /// Intra-node gather span: `ceil(kmax / L) - 1`.
    g: usize,
    /// Node-wave span: `kmax - 1`.
    w: usize,
    /// Pod-wave span (three-level): `max pod node count - 1`.
    pw: usize,
}

fn grid(pl: &Placement) -> Grid {
    let l = pl.effective_leaders();
    let kmax = pl.max_node_size();
    let pw = if pl.is_three_level() {
        (0..pl.npods()).map(|q| pl.pod_nodes(q).len()).max().unwrap_or(1) - 1
    } else {
        0
    };
    Grid { g: kmax.div_ceil(l).saturating_sub(1), w: kmax.saturating_sub(1), pw }
}

/// Max intra-pod PAT round count across pods (three-level phase 2a).
fn pod_rounds_max(pl: &Placement, a: usize) -> usize {
    (0..pl.npods())
        .map(|q| {
            let m = pl.pod_nodes(q).len();
            if m > 1 { pat::rounds(m, pat::clamp_aggregation(m, a)).len() } else { 0 }
        })
        .max()
        .unwrap_or(0)
}

/// The phase list of a hierarchical program for this placement and
/// aggregation (all-gather orientation; the mirror reverses it). Phase
/// step counts sum to the program's step count for regular placements
/// (every stripe grid slot occupied); uneven pods can leave the tail of a
/// span empty, so the sum is an upper bound in general.
pub fn phase_list(pl: &Placement, a: usize) -> Vec<HierPhase> {
    let gr = grid(pl);
    let mut phases = vec![
        HierPhase { name: "intra_gather", steps: gr.g },
        HierPhase { name: "intra_bcast", steps: gr.w },
    ];
    if pl.is_three_level() {
        let rp = pod_rounds_max(pl, a);
        if rp > 0 {
            phases.push(HierPhase { name: "pod_pipeline", steps: rp * (1 + gr.w) });
        }
        let np = pl.npods();
        if np > 1 {
            let r = pat::rounds(np, pat::clamp_aggregation(np, a)).len();
            phases.push(HierPhase {
                name: "fabric_pipeline",
                steps: r * (1 + gr.pw + gr.w),
            });
        }
    } else if pl.nnodes() > 1 {
        let nn = pl.nnodes();
        let r = pat::rounds(nn, pat::clamp_aggregation(nn, a)).len();
        phases.push(HierPhase { name: "inter_pipeline", steps: r * (1 + gr.w) });
    }
    phases
}

/// The leader staging-budget law: a conservative bound on the peak
/// buffer-slot occupancy of the pipelined hierarchical schedule (chunks
/// staged for forwarding in the all-gather; live accumulators in the
/// mirrored reduce-scatter). Per level the leader holds its own stripe set
/// plus at most one in-flight round payload (`a · set`) and the relayed
/// sets of later rounds (another `a · set` per remaining dimension), so
/// the bound is logarithmic in the node (and pod) count — *sublinear in
/// `n`*, unlike the old bulk fan-out's Θ(n). Capped at the trivial bound
/// (`n - 1` staged chunks / `n` accumulators), which full aggregation can
/// reach. The tuner gates `HierPat` on this law instead of `n`
/// ([`crate::coordinator::tuner::Tuner::choose_placed`]).
pub fn staging_bound(pl: &Placement, a: usize, coll: Collective) -> usize {
    let n = pl.nranks();
    if n <= 1 {
        return 1;
    }
    let trivial = match coll {
        Collective::ReduceScatter => n,
        _ => n.saturating_sub(1),
    };
    let nnodes = pl.nnodes();
    if nnodes <= 1 {
        return trivial;
    }
    let l = pl.effective_leaders();
    let kmax = pl.max_node_size();
    let s = kmax.div_ceil(l); // one node's stripe chunk set
    let analytic = if pl.is_three_level() && pl.npods() > 1 {
        let np = pl.npods();
        let mnodes = (0..np).map(|q| pl.pod_nodes(q).len()).max().unwrap();
        let pod_set =
            (0..np).map(|q| pl.pod_rank_count(q).div_ceil(l)).max().unwrap();
        let a2a = pat::clamp_aggregation(mnodes.max(2), a);
        let a2b = pat::clamp_aggregation(np, a);
        s + pod_set
            + a2a * s * (ceil_log2(mnodes.max(2)) as usize + 2)
            + a2b * pod_set * (ceil_log2(np) as usize + 2)
            + a2a.max(a2b) * kmax
            + 2
    } else {
        let ac = pat::clamp_aggregation(nnodes, a);
        s + ac * s * (ceil_log2(nnodes) as usize + 2) + ac * kmax + 2
    };
    // The mirrored reduce-scatter additionally holds the node's own stripe
    // as accumulators across the scatter.
    let analytic = match coll {
        Collective::ReduceScatter => analytic + s + kmax,
        _ => analytic,
    };
    analytic.min(trivial)
}

/// Per-node, per-stripe construction state.
struct NodeStripe {
    /// The stripe leader: the rank at local offset `stripe`.
    leader: Rank,
    /// The node's stripe chunk set (global chunk ids, ascending).
    chunks: Vec<ChunkId>,
    /// Wave-tree index → local offset, stripe leader first (index 0).
    wave_order: Vec<usize>,
}

/// Push one intra-node wave: a pre-order broadcast tree over all local
/// ranks (rooted at the stripe leader) where every edge carries the full
/// `payload` — round arrivals are fresh for every non-leader rank.
fn push_wave(p: &mut Program, local: &[Rank], ns: &NodeStripe, payload: &[ChunkId], base: usize) {
    let k = local.len();
    if k <= 1 || payload.is_empty() {
        return;
    }
    for (idx, &(pi, ci)) in preorder_edges(k).iter().enumerate() {
        let src = local[ns.wave_order[pi]];
        let dst = local[ns.wave_order[ci]];
        p.push(src, Op::send(dst, payload.to_vec(), base + idx));
        p.push(dst, Op::recv(src, payload.to_vec(), false, base + idx));
    }
}

/// Build stripe `st`'s complete sub-schedule (gather, local broadcast,
/// pipelined inter phases) on channel 0; the caller merges stripes onto
/// their channels.
fn stripe_program(pl: &Placement, a: usize, st: usize, l: usize) -> Program {
    let n = pl.nranks();
    let nnodes = pl.nnodes();
    let mut p = Program::new(n, Collective::AllGather, String::new());
    let gr = grid(pl);

    // Per-node stripe state + phase 1 (gather) and wave 0 (local
    // broadcast of the node's own stripe chunks).
    let mut ns: Vec<NodeStripe> = Vec::with_capacity(nnodes);
    for node in 0..nnodes {
        let local = pl.ranks_of(node);
        let k = local.len();
        let members: Vec<usize> = (st..k).step_by(l).collect();
        let chunks: Vec<ChunkId> = members.iter().map(|&o| local[o]).collect();
        let mut wave_order = vec![st];
        wave_order.extend((0..k).filter(|&o| o != st));
        // Gather: near-first tree over the stripe members, child subtrees
        // funneled to the stripe leader (member index 0 = offset `st`).
        let mt = NearFirstTree::new(members.len());
        // What each local offset holds after the gather (only stripe
        // members hold stripe chunks: their own gather subtree).
        let mut held: Vec<HashSet<ChunkId>> = vec![HashSet::new(); k];
        for (i, &o) in members.iter().enumerate() {
            held[o] = subtree_offsets(&mt, i).iter().map(|&j| local[members[j]]).collect();
        }
        for (step, &(ci, pi)) in postorder_edges(members.len()).iter().enumerate() {
            let sub: Vec<ChunkId> =
                subtree_offsets(&mt, ci).iter().map(|&j| local[members[j]]).collect();
            p.push(local[members[ci]], Op::send(local[members[pi]], sub.clone(), step));
            p.push(local[members[pi]], Op::recv(local[members[ci]], sub, false, step));
        }
        // Wave 0: the node's own stripe chunks to every co-located rank;
        // each edge carries what the receiver does not already hold.
        if k > 1 {
            for (idx, &(pi, ci)) in preorder_edges(k).iter().enumerate() {
                let off_c = wave_order[ci];
                let payload: Vec<ChunkId> =
                    chunks.iter().copied().filter(|c| !held[off_c].contains(c)).collect();
                if payload.is_empty() {
                    continue;
                }
                let src = local[wave_order[pi]];
                let dst = local[off_c];
                p.push(src, Op::send(dst, payload.clone(), gr.g + idx));
                p.push(dst, Op::recv(src, payload, false, gr.g + idx));
            }
        }
        ns.push(NodeStripe { leader: local[st], chunks, wave_order });
    }

    let base = gr.g + gr.w;
    if pl.is_three_level() {
        // Phase 2a: intra-pod PAT over each pod's nodes, every round waved
        // into the nodes on the next steps.
        let np = pl.npods();
        for pod in 0..np {
            let nodes = pl.pod_nodes(pod);
            let m = nodes.len();
            if m <= 1 {
                continue;
            }
            let ac = pat::clamp_aggregation(m, a);
            for (j, round) in pat::rounds(m, ac).iter().enumerate() {
                let step = base + j * (1 + gr.w);
                let hop = 1usize << round.dim;
                let mut recvs: Vec<Vec<ChunkId>> = Vec::with_capacity(m);
                for v in 0..m {
                    let srcv = (v + m - hop) % m;
                    let dstv = (v + hop) % m;
                    let send: Vec<ChunkId> = round
                        .offsets
                        .iter()
                        .flat_map(|&o| ns[nodes[(v + m - o) % m]].chunks.iter().copied())
                        .collect();
                    let recv: Vec<ChunkId> = round
                        .offsets
                        .iter()
                        .flat_map(|&o| ns[nodes[(srcv + m - o) % m]].chunks.iter().copied())
                        .collect();
                    p.push(ns[nodes[v]].leader, Op::send(ns[nodes[dstv]].leader, send, step));
                    p.push(
                        ns[nodes[v]].leader,
                        Op::recv(ns[nodes[srcv]].leader, recv.clone(), false, step),
                    );
                    recvs.push(recv);
                }
                for v in 0..m {
                    push_wave(&mut p, pl.ranks_of(nodes[v]), &ns[nodes[v]], &recvs[v], step + 1);
                }
            }
        }
        // Phase 2b: inter-pod PAT over the pod leaders (stripe leader of
        // each pod's first node); each round's arrivals ride a pod wave
        // (leader-to-leader tree across the pod's nodes) and then node
        // waves.
        if np > 1 {
            let base2b = base + pod_rounds_max(pl, a) * (1 + gr.w);
            let ac = pat::clamp_aggregation(np, a);
            let pod_chunks: Vec<Vec<ChunkId>> = (0..np)
                .map(|q| {
                    pl.pod_nodes(q).iter().flat_map(|&mm| ns[mm].chunks.iter().copied()).collect()
                })
                .collect();
            for (j, round) in pat::rounds(np, ac).iter().enumerate() {
                let step = base2b + j * (1 + gr.pw + gr.w);
                let hop = 1usize << round.dim;
                let mut recvs: Vec<Vec<ChunkId>> = Vec::with_capacity(np);
                for q in 0..np {
                    let srcq = (q + np - hop) % np;
                    let dstq = (q + np + hop) % np;
                    let send: Vec<ChunkId> = round
                        .offsets
                        .iter()
                        .flat_map(|&o| pod_chunks[(q + np - o) % np].iter().copied())
                        .collect();
                    let recv: Vec<ChunkId> = round
                        .offsets
                        .iter()
                        .flat_map(|&o| pod_chunks[(srcq + np - o) % np].iter().copied())
                        .collect();
                    let leader = |x: usize| ns[pl.pod_nodes(x)[0]].leader;
                    p.push(leader(q), Op::send(leader(dstq % np), send, step));
                    p.push(leader(q), Op::recv(leader(srcq), recv.clone(), false, step));
                    recvs.push(recv);
                }
                for q in 0..np {
                    if recvs[q].is_empty() {
                        continue;
                    }
                    let nodes = pl.pod_nodes(q);
                    if nodes.len() > 1 {
                        for (idx, &(pi, ci)) in preorder_edges(nodes.len()).iter().enumerate() {
                            let src = ns[nodes[pi]].leader;
                            let dst = ns[nodes[ci]].leader;
                            p.push(src, Op::send(dst, recvs[q].clone(), step + 1 + idx));
                            p.push(dst, Op::recv(src, recvs[q].clone(), false, step + 1 + idx));
                        }
                    }
                    for &mm in nodes {
                        push_wave(&mut p, pl.ranks_of(mm), &ns[mm], &recvs[q], step + 1 + gr.pw);
                    }
                }
            }
        }
    } else if nnodes > 1 {
        // Phase 2 (two-level): flat PAT over nodes, each round waved into
        // the nodes. Virtual chunk `m` expands to node m's stripe set.
        let ac = pat::clamp_aggregation(nnodes, a);
        for (j, round) in pat::rounds(nnodes, ac).iter().enumerate() {
            let step = base + j * (1 + gr.w);
            let hop = 1usize << round.dim;
            let mut recvs: Vec<Vec<ChunkId>> = Vec::with_capacity(nnodes);
            for i in 0..nnodes {
                let src = (i + nnodes - hop) % nnodes;
                let dst = (i + hop) % nnodes;
                let send: Vec<ChunkId> = round
                    .offsets
                    .iter()
                    .flat_map(|&o| ns[(i + nnodes - o) % nnodes].chunks.iter().copied())
                    .collect();
                let recv: Vec<ChunkId> = round
                    .offsets
                    .iter()
                    .flat_map(|&o| ns[(src + nnodes - o) % nnodes].chunks.iter().copied())
                    .collect();
                p.push(ns[i].leader, Op::send(ns[dst].leader, send, step));
                p.push(ns[i].leader, Op::recv(ns[src].leader, recv.clone(), false, step));
                recvs.push(recv);
            }
            for i in 0..nnodes {
                push_wave(&mut p, pl.ranks_of(i), &ns[i], &recvs[i], step + 1);
            }
        }
    }
    p
}

/// Hierarchical PAT all-gather over `pl` with per-level inter aggregation
/// `a`, striped across `pl.effective_leaders()` stripe leaders per node
/// (stripe `ℓ` rides channel `ℓ`).
pub fn allgather(pl: &Placement, a: usize) -> Program {
    let n = pl.nranks();
    let nnodes = pl.nnodes();
    let l = pl.effective_leaders();
    let a_top = if pl.is_three_level() && pl.npods() > 1 {
        pat::clamp_aggregation(pl.npods(), a)
    } else if nnodes > 1 {
        pat::clamp_aggregation(nnodes, a)
    } else {
        1
    };
    let mut name = format!("hier_pat(a={a_top},nodes={nnodes}");
    if pl.is_three_level() {
        name.push_str(&format!(",pods={}", pl.npods()));
    }
    if l > 1 {
        name.push_str(&format!(",leaders={l}"));
    }
    name.push(')');
    let mut p = Program::new(n, Collective::AllGather, name);
    if n <= 1 {
        return p;
    }
    let stripes: Vec<Program> = (0..l).map(|st| stripe_program(pl, a, st, l)).collect();
    for r in 0..n {
        let streams: Vec<Stream<'_>> = stripes
            .iter()
            .enumerate()
            .map(|(i, sp)| Stream {
                ops: &sp.ranks[r],
                step_base: 0,
                chunk_base: 0,
                channel_base: i,
            })
            .collect();
        channel::merge_rank_streams(&mut p, r, &streams);
    }
    p
}

/// Hierarchical PAT reduce-scatter: the mirror of the all-gather
/// (per-round intra-node reduction waves, inter PAT reduce, intra-node
/// scatter).
pub fn reduce_scatter(pl: &Placement, a: usize) -> Program {
    allgather(pl, a).mirror()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;

    #[test]
    fn correct_across_sizes_and_aggregations() {
        for &n in &[2usize, 3, 5, 8, 12, 13, 16, 17, 24] {
            for &k in &[1usize, 2, 3, 4, 5, 8] {
                let pl = Placement::uniform(n, k.min(n)).unwrap();
                for &a in &[1usize, 2, 4, usize::MAX] {
                    let ag = allgather(&pl, a);
                    verify_program(&ag)
                        .unwrap_or_else(|e| panic!("ag n={n} k={k} a={a}: {e}"));
                    let rs = reduce_scatter(&pl, a);
                    verify_program(&rs)
                        .unwrap_or_else(|e| panic!("rs n={n} k={k} a={a}: {e}"));
                }
            }
        }
    }

    #[test]
    fn correct_with_multiple_leaders() {
        for &n in &[8usize, 12, 16, 24, 32] {
            for &k in &[2usize, 4, 8] {
                if k > n {
                    continue;
                }
                for &l in &[2usize, 3, 4] {
                    let pl = Placement::uniform(n, k).unwrap().with_leaders(l).unwrap();
                    for &a in &[1usize, 2, usize::MAX] {
                        let ag = allgather(&pl, a);
                        verify_program(&ag)
                            .unwrap_or_else(|e| panic!("ag n={n} k={k} l={l} a={a}: {e}"));
                        assert_eq!(ag.channels, pl.effective_leaders(), "n={n} k={k} l={l}");
                        let rs = reduce_scatter(&pl, a);
                        verify_program(&rs)
                            .unwrap_or_else(|e| panic!("rs n={n} k={k} l={l} a={a}: {e}"));
                    }
                }
            }
        }
    }

    #[test]
    fn correct_three_level_uneven_pods() {
        // uneven nodes AND uneven pods, with and without extra leaders
        let cases: Vec<Placement> = vec![
            Placement::from_pod_sizes(&[vec![4, 4], vec![4, 4, 4], vec![2]]).unwrap(),
            Placement::from_pod_sizes(&[vec![3, 2], vec![4, 1]]).unwrap(),
            Placement::parse("4x2", 32).unwrap(),
            Placement::parse("4x2", 32).unwrap().with_leaders(2).unwrap(),
            Placement::parse("2,2;2,2;2,2", 12).unwrap().with_leaders(2).unwrap(),
        ];
        for pl in cases {
            for &a in &[1usize, 2, usize::MAX] {
                let ag = allgather(&pl, a);
                verify_program(&ag)
                    .unwrap_or_else(|e| panic!("ag {} a={a}: {e}", pl.describe()));
                let rs = reduce_scatter(&pl, a);
                verify_program(&rs)
                    .unwrap_or_else(|e| panic!("rs {} a={a}: {e}", pl.describe()));
            }
        }
    }

    #[test]
    fn explicit_uneven_nodes() {
        let pl = Placement::from_node_sizes(&[4, 1, 5, 3]).unwrap();
        for &a in &[1usize, 2, usize::MAX] {
            verify_program(&allgather(&pl, a)).unwrap();
            verify_program(&reduce_scatter(&pl, a)).unwrap();
        }
        // extra leaders clamp to the min node size (1) and stay correct
        let pl = pl.with_leaders(4).unwrap();
        assert_eq!(pl.effective_leaders(), 1);
        verify_program(&allgather(&pl, 2)).unwrap();
    }

    /// With singleton nodes the hierarchy degenerates to flat PAT: same
    /// per-rank op lists (only the program name differs).
    #[test]
    fn singleton_placement_equals_flat_pat() {
        for n in [2usize, 5, 8, 13, 16] {
            for a in [1usize, 2, usize::MAX] {
                let pl = Placement::singletons(n).unwrap();
                let hier = allgather(&pl, a);
                let flat = pat::allgather(n, a);
                assert_eq!(hier.ranks, flat.ranks, "n={n} a={a}");
                assert_eq!(hier.steps, flat.steps, "n={n} a={a}");
            }
        }
    }

    /// A single node degenerates to a pure intra-node tree (gather +
    /// local broadcast, no inter phase).
    #[test]
    fn single_node_is_tree_only() {
        let pl = Placement::uniform(6, 6).unwrap();
        let p = allgather(&pl, usize::MAX);
        verify_program(&p).unwrap();
        let phases = phase_list(&pl, usize::MAX);
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0], HierPhase { name: "intra_gather", steps: 5 });
        assert_eq!(phases[1], HierPhase { name: "intra_bcast", steps: 5 });
        assert_eq!(p.steps, 10);
        // every message stays inside the node by construction
        for m in p.messages() {
            assert_eq!(pl.node_of(m.src), pl.node_of(m.dst));
        }
    }

    /// Only stripe leaders speak across nodes, and non-leader traffic
    /// stays local; with one leader that means the node leaders.
    #[test]
    fn cross_node_messages_are_leader_to_leader() {
        let pl = Placement::uniform(13, 4).unwrap();
        let p = allgather(&pl, 2);
        for m in p.messages() {
            if pl.node_of(m.src) != pl.node_of(m.dst) {
                assert!(pl.is_leader(m.src), "src {} not a leader", m.src);
                assert!(pl.is_leader(m.dst), "dst {} not a leader", m.dst);
            }
        }
        let pl = Placement::uniform(16, 4).unwrap().with_leaders(2).unwrap();
        let p = allgather(&pl, 2);
        let mut by_channel: HashSet<usize> = HashSet::new();
        for m in p.messages() {
            if pl.node_of(m.src) != pl.node_of(m.dst) {
                assert!(pl.is_stripe_leader(m.src), "src {} not a stripe leader", m.src);
                assert!(pl.is_stripe_leader(m.dst), "dst {} not a stripe leader", m.dst);
                by_channel.insert(m.channel);
            }
        }
        // both stripes carry inter-node traffic on their own channel
        assert_eq!(by_channel.len(), 2, "{by_channel:?}");
    }

    /// Every valid all-gather delivers each foreign chunk exactly once:
    /// chunk transfers total n(n-1), same as the flat generators —
    /// including striped and three-level constructions.
    #[test]
    fn chunk_transfer_totals() {
        for (n, k) in [(8usize, 4usize), (13, 4), (16, 5), (9, 2)] {
            let pl = Placement::uniform(n, k).unwrap();
            let p = allgather(&pl, 2);
            assert_eq!(p.stats().chunk_transfers, n * (n - 1), "n={n} k={k}");
        }
        let pl = Placement::uniform(16, 4).unwrap().with_leaders(2).unwrap();
        assert_eq!(allgather(&pl, 2).stats().chunk_transfers, 16 * 15);
        let pl = Placement::parse("4x2", 32).unwrap().with_leaders(2).unwrap();
        assert_eq!(allgather(&pl, 2).stats().chunk_transfers, 32 * 31);
    }

    /// Inter-node PAT messages carry at most `a` node chunk sets.
    #[test]
    fn inter_node_aggregation_bounded() {
        let pl = Placement::uniform(32, 4).unwrap();
        for a in [1usize, 2, 4] {
            let p = allgather(&pl, a);
            let max_sets = p
                .messages()
                .iter()
                .filter(|m| pl.node_of(m.src) != pl.node_of(m.dst))
                .map(|m| {
                    let nodes: HashSet<usize> =
                        m.chunks.iter().map(|&c| pl.node_of(c)).collect();
                    nodes.len()
                })
                .max()
                .unwrap_or(0);
            assert!(max_sets <= a, "a={a}: {max_sets} node sets in one message");
        }
    }

    /// The pipelined fan-out keeps leader staging under the analytic
    /// [`staging_bound`] law, and that law is sublinear in `n`: growing
    /// the fabric 8x (fixed node size) must not grow the measured peak
    /// anywhere near 8x.
    #[test]
    fn occupancy_follows_staging_bound() {
        let mut peaks = Vec::new();
        for n in [16usize, 32, 64, 128] {
            let pl = Placement::uniform(n, 4).unwrap();
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let p = match coll {
                    Collective::AllGather => allgather(&pl, 2),
                    _ => reduce_scatter(&pl, 2),
                };
                let occ = verify_program(&p).unwrap();
                let bound = staging_bound(&pl, 2, coll);
                assert!(
                    occ.peak_slots <= bound,
                    "{coll} n={n}: peak {} > bound {bound}",
                    occ.peak_slots
                );
                if coll == Collective::AllGather {
                    peaks.push(occ.peak_slots);
                }
            }
        }
        // sublinear: 16 -> 128 ranks is 8x; the peak must grow far less
        let (first, last) = (peaks[0], peaks[3]);
        assert!(
            last < first * 4 && last < 128 / 2,
            "staging not sublinear: peaks {peaks:?}"
        );
    }

    /// Multi-leader striping also divides leader staging.
    #[test]
    fn striping_reduces_staging() {
        let pl1 = Placement::uniform(64, 8).unwrap();
        let pl4 = Placement::uniform(64, 8).unwrap().with_leaders(4).unwrap();
        let p1 = verify_program(&allgather(&pl1, 2)).unwrap().peak_slots;
        let p4 = verify_program(&allgather(&pl4, 2)).unwrap().peak_slots;
        assert!(p4 < p1, "L=4 peak {p4} not below L=1 peak {p1}");
        assert!(p4 <= staging_bound(&pl4, 2, Collective::AllGather));
    }

    #[test]
    fn phase_list_covers_program() {
        // two-level, uniform: spans are exact
        let pl = Placement::uniform(16, 4).unwrap();
        let phases = phase_list(&pl, 2);
        assert_eq!(phases[0].name, "intra_gather");
        assert_eq!(phases[0].steps, 3);
        assert_eq!(phases[1].name, "intra_bcast");
        assert_eq!(phases[1].steps, 3);
        assert_eq!(phases[2].name, "inter_pipeline");
        let total: usize = phases.iter().map(|ph| ph.steps).sum();
        let p = allgather(&pl, 2);
        assert_eq!(p.steps, total);
        let rs = reduce_scatter(&pl, 2);
        assert_eq!(rs.steps, p.steps);
        // three-level, uniform pods: spans are exact and the list has 4
        // entries
        let pl = Placement::parse("4x2", 32).unwrap();
        let phases = phase_list(&pl, 2);
        assert_eq!(phases.len(), 4);
        assert_eq!(phases[2].name, "pod_pipeline");
        assert_eq!(phases[3].name, "fabric_pipeline");
        let total: usize = phases.iter().map(|ph| ph.steps).sum();
        assert_eq!(allgather(&pl, 2).steps, total);
        // uneven pods: the sum is an upper bound
        let pl = Placement::from_pod_sizes(&[vec![4, 4], vec![2]]).unwrap();
        let total: usize = phase_list(&pl, 2).iter().map(|ph| ph.steps).sum();
        assert!(allgather(&pl, 2).steps <= total);
    }

    /// Cross-pod traffic is pod-leader to pod-leader only.
    #[test]
    fn cross_pod_messages_are_pod_leader_to_pod_leader() {
        let pl = Placement::parse("4x2", 32).unwrap();
        let pod_leaders: HashSet<Rank> =
            (0..pl.npods()).map(|q| pl.leader(pl.pod_nodes(q)[0])).collect();
        let p = allgather(&pl, 2);
        for m in p.messages() {
            let (ps, pd) =
                (pl.pod_of_node(pl.node_of(m.src)), pl.pod_of_node(pl.node_of(m.dst)));
            if ps != pd {
                assert!(pod_leaders.contains(&m.src), "src {} not a pod leader", m.src);
                assert!(pod_leaders.contains(&m.dst), "dst {} not a pod leader", m.dst);
            }
        }
    }
}
