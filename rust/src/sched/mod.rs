//! Schedule generation: the PAT algorithm and its baselines, all emitting a
//! common per-rank program IR ([`Program`]).
//!
//! One IR serves every consumer in the stack:
//! * [`verify`] — the reference executor (correctness, FIFO/deadlock checks,
//!   buffer-occupancy measurement),
//! * [`crate::transport`] — the threaded real-byte engine,
//! * [`crate::sim`] — the event-driven network simulator,
//! * the schedule explorer example (regenerates the paper's figures).
//!
//! Reduce-scatter programs are derived from all-gather programs by
//! [`Program::mirror`]: reverse time, flip send↔recv, reduce on receive.
//! This is exactly the paper's construction ("the reduce-scatter PAT
//! algorithm works the same way as all-gather, but with a reversed binomial
//! tree", communicating close dimensions first and executing the parallel
//! trees before the logarithmic part).

pub mod program;
pub mod tree;
pub mod ring;
pub mod bruck;
pub mod recursive;
pub mod pat;
pub mod verify;
pub mod explain;

pub use program::{Op, Program, ProgramStats};
pub use tree::{FarFirstTree, NearFirstTree};
pub use verify::{verify_program, OccupancyReport};

use crate::core::{Algorithm, Collective, Error, Result};

/// Generate a program for `algorithm` on `nranks`.
///
/// For reduce-scatter, every algorithm is the mirror of its all-gather
/// counterpart (recursive doubling mirrors to recursive halving).
pub fn generate(alg: Algorithm, coll: Collective, nranks: usize) -> Result<Program> {
    if nranks == 0 {
        return Err(Error::Schedule("nranks must be >= 1".into()));
    }
    if !alg.supports(nranks) {
        return Err(Error::Unsupported(format!(
            "{alg} does not support nranks={nranks} (power-of-two required)"
        )));
    }
    let ag = match alg {
        Algorithm::Ring => ring::allgather(nranks),
        Algorithm::BruckNearFirst => bruck::allgather_near_first(nranks),
        Algorithm::BruckFarFirst => bruck::allgather_far_first(nranks),
        Algorithm::Recursive => recursive::allgather(nranks),
        Algorithm::Pat { aggregation } => pat::allgather(nranks, aggregation),
        Algorithm::PatAuto => {
            return Err(Error::Schedule(
                "PatAuto must be resolved by the tuner before generation".into(),
            ))
        }
    };
    Ok(match coll {
        Collective::AllGather => ag,
        Collective::ReduceScatter => ag.mirror(),
    })
}
