//! Core types shared by every layer: ranks, chunks, collectives, algorithms,
//! element types and error handling.

pub mod error;
pub mod placement;

pub use error::{Error, Result};
pub use placement::Placement;

use std::fmt;

/// A rank id within a communicator, `0..nranks`.
pub type Rank = usize;

/// A chunk id. For all-gather, chunk `c` is the contribution of rank `c`
/// (and ends up in slot `c` of every receive buffer). For reduce-scatter,
/// chunk `c` is the slice of every rank's send buffer that reduces to rank
/// `c`'s output.
pub type ChunkId = usize;

/// The two collectives PAT implements (the paper's scope).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    /// Every rank contributes one chunk; every rank ends with all `n` chunks.
    AllGather,
    /// Every rank contributes `n` chunks; rank `r` ends with the element-wise
    /// sum over ranks of chunk `r`.
    ReduceScatter,
}

impl Collective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Collective::AllGather => "all_gather",
            Collective::ReduceScatter => "reduce_scatter",
        }
    }
}

impl fmt::Display for Collective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Algorithm selection for a collective operation.
///
/// `Ring` is NCCL's historical AG/RS algorithm (linear step count, full
/// bandwidth). `BruckNearFirst`/`BruckFarFirst` and `RecursiveDoubling` (AG) /
/// `RecursiveHalving` (RS) are the classic logarithmic baselines discussed in
/// the paper. `Pat` is the paper's contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Ring,
    /// Classic Bruck dimension order: nearest dimension first (paper Fig. 1).
    BruckNearFirst,
    /// Dimension-reversed Bruck: farthest dimension first (paper Fig. 3).
    BruckFarFirst,
    /// Recursive doubling (AG) / halving (RS); power-of-two ranks only.
    Recursive,
    /// Parallel Aggregated Trees with at most `aggregation` parallel trees
    /// (chunks aggregated per transfer). `aggregation` is clamped to a power
    /// of two in `[1, 2^(ceil(log2 n) - 1)]`.
    Pat { aggregation: usize },
    /// PAT with aggregation chosen from the intermediate-buffer budget and
    /// the operation size (what the tuner does in NCCL).
    PatAuto,
    /// Two-level hierarchical PAT over a rank [`Placement`]: an intra-node
    /// gather (near-first tree among co-located ranks), an inter-node PAT
    /// among per-node leaders with `aggregation` bounding how many *node*
    /// chunk sets one transfer carries, and an intra-node fan-out. The
    /// placement comes from the communicator/CLI configuration (see
    /// [`crate::sched::generate_placed`]); without one, contiguous nodes of
    /// 8 ranks are assumed.
    HierPat { aggregation: usize },
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::Ring => "ring".into(),
            Algorithm::BruckNearFirst => "bruck_near".into(),
            Algorithm::BruckFarFirst => "bruck_far".into(),
            Algorithm::Recursive => "recursive".into(),
            Algorithm::Pat { aggregation } if *aggregation >= usize::MAX / 2 => {
                "pat(full)".into()
            }
            Algorithm::Pat { aggregation } => format!("pat(a={aggregation})"),
            Algorithm::PatAuto => "pat_auto".into(),
            Algorithm::HierPat { aggregation } if *aggregation >= usize::MAX / 2 => {
                "hier_pat(full)".into()
            }
            Algorithm::HierPat { aggregation } => format!("hier_pat(a={aggregation})"),
        }
    }

    /// Parse a CLI/config spelling: `ring`, `bruck_near`, `bruck_far`,
    /// `recursive`, `pat`, `pat:<agg>`, `pat_auto`, `hier_pat`,
    /// `hier_pat:<agg>`.
    pub fn parse(s: &str) -> Result<Algorithm> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("pat:") {
            let a: usize = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad pat aggregation: {rest:?}")))?;
            if a == 0 {
                return Err(Error::Config("pat aggregation must be >= 1".into()));
            }
            return Ok(Algorithm::Pat { aggregation: a });
        }
        if let Some(rest) = s.strip_prefix("hier_pat:") {
            let a: usize = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad hier_pat aggregation: {rest:?}")))?;
            if a == 0 {
                return Err(Error::Config("hier_pat aggregation must be >= 1".into()));
            }
            return Ok(Algorithm::HierPat { aggregation: a });
        }
        match s {
            "ring" => Ok(Algorithm::Ring),
            "bruck_near" | "bruck" => Ok(Algorithm::BruckNearFirst),
            "bruck_far" => Ok(Algorithm::BruckFarFirst),
            "recursive" | "rd" | "rh" => Ok(Algorithm::Recursive),
            "pat" => Ok(Algorithm::Pat { aggregation: usize::MAX }),
            "pat_auto" => Ok(Algorithm::PatAuto),
            "hier_pat" | "hier" => Ok(Algorithm::HierPat { aggregation: usize::MAX }),
            other => Err(Error::Config(format!("unknown algorithm {other:?}"))),
        }
    }

    /// Does this algorithm support `nranks`?
    pub fn supports(&self, nranks: usize) -> bool {
        match self {
            Algorithm::Recursive => nranks.is_power_of_two(),
            _ => nranks >= 1,
        }
    }
}

impl fmt::Display for Algorithm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

/// Element types supported on the datapath. The wire format is always raw
/// little-endian bytes; reduction kernels exist for each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
}

impl DType {
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
        }
    }
}

/// Ceiling log2 for schedule dimensioning. `ceil_log2(1) == 0`.
pub fn ceil_log2(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}

/// Floor log2. `floor_log2(1) == 0`.
pub fn floor_log2(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - 1 - n.leading_zeros()
}

/// Ideal (perfectly packed) step count of the PAT schedule for `nranks`
/// with aggregation `a`: `Σ_d ceil(|O_d| / a)` where `|O_d|` counts offsets
/// `o ≡ 0 (mod 2^{d+1})` with `o + 2^d < nranks`.
///
/// The implemented schedule achieves this exactly for power-of-two rank
/// counts (and for `a = 1` / full aggregation on any count); for awkward
/// counts the lockstep depth-first linear phase may leave partially-empty
/// rounds and use up to `n-1` steps (see `sched::pat`).
pub fn pat_step_count(nranks: usize, a: usize) -> usize {
    debug_assert!(a >= 1);
    if nranks <= 1 {
        return 0;
    }
    let dmax = floor_log2(nranks - 1); // highest dim with any transfer
    let mut steps = 0usize;
    for d in 0..=dmax {
        let stride = 1usize << (d + 1);
        let span = nranks - (1usize << d); // o in [0, span), o % stride == 0
        let od = (span + stride - 1) / stride;
        steps += (od + a - 1) / a;
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_helpers() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(floor_log2(1), 0);
        assert_eq!(floor_log2(8), 3);
        assert_eq!(floor_log2(9), 3);
    }

    #[test]
    fn step_counts_match_paper_figures() {
        // N=8: full Bruck 3 steps; agg 2 -> 4 (Figs 5-6); agg 1 -> 7 (Fig 10).
        assert_eq!(pat_step_count(8, 4), 3);
        assert_eq!(pat_step_count(8, 2), 4);
        assert_eq!(pat_step_count(8, 1), 7);
        // N=16: 8 trees -> 4 (Fig 7); 4 trees -> 5 (Fig 8); 2 trees -> 8 (Fig 9).
        assert_eq!(pat_step_count(16, 8), 4);
        assert_eq!(pat_step_count(16, 4), 5);
        assert_eq!(pat_step_count(16, 2), 8);
        assert_eq!(pat_step_count(16, 1), 15);
    }

    #[test]
    fn step_count_fully_linear_is_nminus1() {
        for n in 2..70 {
            assert_eq!(pat_step_count(n, 1), n - 1, "n={n}");
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        assert_eq!(Algorithm::parse("ring").unwrap(), Algorithm::Ring);
        assert_eq!(Algorithm::parse("pat:4").unwrap(), Algorithm::Pat { aggregation: 4 });
        assert_eq!(Algorithm::parse("bruck_far").unwrap(), Algorithm::BruckFarFirst);
        assert_eq!(
            Algorithm::parse("hier_pat:2").unwrap(),
            Algorithm::HierPat { aggregation: 2 }
        );
        assert_eq!(
            Algorithm::parse("hier_pat").unwrap(),
            Algorithm::HierPat { aggregation: usize::MAX }
        );
        assert_eq!(Algorithm::parse("hier_pat").unwrap().name(), "hier_pat(full)");
        assert_eq!(
            Algorithm::HierPat { aggregation: 2 }.name(),
            "hier_pat(a=2)"
        );
        assert!(Algorithm::parse("nope").is_err());
        assert!(Algorithm::parse("pat:0").is_err());
        assert!(Algorithm::parse("hier_pat:0").is_err());
    }

    #[test]
    fn recursive_requires_pow2() {
        assert!(Algorithm::Recursive.supports(8));
        assert!(!Algorithm::Recursive.supports(7));
        assert!(Algorithm::Pat { aggregation: 1 }.supports(7));
    }
}
