//! Calibration-drift history: predicted vs measured, append-only.
//!
//! The tuner's closed forms ([`crate::coordinator::tuner`]) predict a
//! wall time for every (collective, algorithm, size, channels) point;
//! the transport then measures one. The gap between the two is what the
//! `*_CALIBRATION_TOLERANCE` constants bound — but without a recorded
//! history those constants are folklore. This module turns every tuned
//! run into one [`CalibRecord`] appended to a JSON-lines file (set
//! `calib_history` in the coordinator config), so tolerance tightening
//! is driven by trend lines: load the file, fold it with
//! [`drift_summary`], and see per-(alg, size, channels) residuals over
//! time.
//!
//! The history is **append-only JSONL** — one self-contained JSON
//! object per line, never rewritten — so concurrent runs can append
//! without coordination and partial lines from a crash corrupt at most
//! themselves (loading skips unparsable lines).

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::core::Result;
use crate::util::json::{self, Json};

/// One tuned run's prediction vs measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibRecord {
    /// Collective name (`allgather`, `reduce_scatter`, `allreduce`, ...).
    pub collective: String,
    /// Resolved algorithm label (e.g. `pat(a=4)`, `ring`).
    pub alg: String,
    pub nranks: usize,
    /// Total payload bytes per rank.
    pub bytes: usize,
    pub channels: usize,
    /// Tuner model prediction, microseconds.
    pub predicted_us: f64,
    /// Transport wall time, microseconds.
    pub measured_us: f64,
}

impl CalibRecord {
    /// Signed residual in percent: positive when the run was slower
    /// than predicted.
    pub fn residual_pct(&self) -> f64 {
        if self.predicted_us > 0.0 {
            100.0 * (self.measured_us - self.predicted_us) / self.predicted_us
        } else {
            0.0
        }
    }

    /// Grouping key for drift trend lines.
    pub fn key(&self) -> String {
        format!(
            "{}/{}/n{}/b{}/c{}",
            self.collective, self.alg, self.nranks, self.bytes, self.channels
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("collective", Json::str(self.collective.clone())),
            ("alg", Json::str(self.alg.clone())),
            ("nranks", Json::num(self.nranks as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("channels", Json::num(self.channels as f64)),
            ("predicted_us", Json::num(self.predicted_us)),
            ("measured_us", Json::num(self.measured_us)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<CalibRecord> {
        Some(CalibRecord {
            collective: j.get("collective")?.as_str()?.to_string(),
            alg: j.get("alg")?.as_str()?.to_string(),
            nranks: j.get("nranks")?.as_usize()?,
            bytes: j.get("bytes")?.as_usize()?,
            channels: j.get("channels")?.as_usize()?,
            predicted_us: j.get("predicted_us")?.as_f64()?,
            measured_us: j.get("measured_us")?.as_f64()?,
        })
    }
}

/// Append one record to the JSONL history at `path` (created, with its
/// parent directories, on first use).
pub fn append(path: &Path, rec: &CalibRecord) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    writeln!(f, "{}", rec.to_json().to_string())?;
    Ok(())
}

/// Load every parsable record from the JSONL history at `path`.
/// Unparsable lines (crash-truncated tails, foreign content) are
/// skipped, not fatal; a missing file is an empty history.
pub fn load(path: &Path) -> Vec<CalibRecord> {
    let Ok(text) = std::fs::read_to_string(path) else {
        return Vec::new();
    };
    text.lines()
        .filter_map(|l| json::parse(l).ok())
        .filter_map(|j| CalibRecord::from_json(&j))
        .collect()
}

/// Aggregate drift per [`CalibRecord::key`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Drift {
    /// Number of runs recorded at this point.
    pub n: usize,
    /// Mean signed residual, percent.
    pub mean_residual_pct: f64,
    /// Largest absolute residual, percent — the figure a tolerance
    /// constant must stay above.
    pub max_abs_residual_pct: f64,
}

/// Fold records into per-key drift trends.
pub fn drift_summary(records: &[CalibRecord]) -> BTreeMap<String, Drift> {
    let mut out: BTreeMap<String, Drift> = BTreeMap::new();
    for r in records {
        let d = out.entry(r.key()).or_default();
        let res = r.residual_pct();
        d.mean_residual_pct = (d.mean_residual_pct * d.n as f64 + res) / (d.n + 1) as f64;
        d.max_abs_residual_pct = d.max_abs_residual_pct.max(res.abs());
        d.n += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("patcol_calib_{}_{name}", std::process::id()))
    }

    fn rec(predicted: f64, measured: f64) -> CalibRecord {
        CalibRecord {
            collective: "allreduce".into(),
            alg: "pat(a=4)".into(),
            nranks: 16,
            bytes: 1 << 20,
            channels: 2,
            predicted_us: predicted,
            measured_us: measured,
        }
    }

    #[test]
    fn append_then_load_roundtrips() {
        let path = tmp("roundtrip.jsonl");
        let _ = std::fs::remove_file(&path);
        append(&path, &rec(100.0, 110.0)).unwrap();
        append(&path, &rec(100.0, 95.0)).unwrap();
        let got = load(&path);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], rec(100.0, 110.0));
        assert!((got[0].residual_pct() - 10.0).abs() < 1e-12);
        assert!((got[1].residual_pct() + 5.0).abs() < 1e-12);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_skips_garbage_lines_and_missing_files() {
        let path = tmp("garbage.jsonl");
        let _ = std::fs::remove_file(&path);
        assert!(load(&path).is_empty(), "missing file is an empty history");
        append(&path, &rec(50.0, 60.0)).unwrap();
        {
            let mut f =
                std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{\"collective\": \"trunca").unwrap();
        }
        append(&path, &rec(50.0, 40.0)).unwrap();
        assert_eq!(load(&path).len(), 2, "truncated line skipped, rest kept");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn drift_summary_tracks_mean_and_worst_case() {
        let records = vec![rec(100.0, 110.0), rec(100.0, 90.0), rec(100.0, 130.0)];
        let summary = drift_summary(&records);
        assert_eq!(summary.len(), 1);
        let d = summary["allreduce/pat(a=4)/n16/b1048576/c2"];
        assert_eq!(d.n, 3);
        // residuals: +10, -10, +30 → mean +10, worst |30|
        assert!((d.mean_residual_pct - 10.0).abs() < 1e-9);
        assert!((d.max_abs_residual_pct - 30.0).abs() < 1e-9);
    }
}
