//! P1 at scale — latency vs rank count at a fixed small message: Ring is
//! linear in n, PAT is logarithmic. A linear fit on (n, t) vs
//! (log2 n, t) classifies each measured curve.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::stats::linfit;
use patcol::util::table::{fmt_time_s, Table};

fn main() {
    // 64 B per rank: fully latency-dominated — the regime the paper's
    // "logarithmic number of network transfers for small size operations"
    // claim targets. (At larger sizes the β·n·S serialization term is
    // inherently linear for all-gather — every rank must receive (n-1)
    // chunks — so only the α part can be logarithmic.)
    let smoke = std::env::args().any(|a| a == "--smoke");
    let chunk = 64usize;
    let cost = CostModel::ib_hdr();
    let ranks: Vec<usize> = if smoke {
        vec![8, 16, 32, 64]
    } else {
        vec![8, 16, 32, 64, 128, 256, 512, 1024, 2048]
    };
    let algs = [
        Algorithm::Ring,
        Algorithm::Pat { aggregation: usize::MAX },
        Algorithm::Pat { aggregation: 8 },
    ];

    let mut report = Report::new("scaling_vs_ranks");
    report.param("chunk_bytes", Json::num(chunk as f64));
    report.param("collective", Json::str("all_gather"));

    let header: Vec<String> = std::iter::once("ranks".to_string())
        .chain(algs.iter().map(|a| a.name()))
        .collect();
    let mut table = Table::new(header);
    let mut curves: Vec<Vec<f64>> = vec![Vec::new(); algs.len()];

    for &n in &ranks {
        let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
        let mut row = vec![format!("{n}")];
        let mut jrow = vec![("ranks", Json::num(n as f64))];
        let names: Vec<String> = algs.iter().map(|a| a.name()).collect();
        for (i, alg) in algs.iter().enumerate() {
            let prog = sched::generate(*alg, Collective::AllGather, n).unwrap();
            let t = simulate(&prog, &topo, &cost, chunk).unwrap().total_time;
            curves[i].push(t);
            row.push(fmt_time_s(t));
            jrow.push((names[i].as_str(), Json::num(t)));
        }
        table.row(row);
        report.rows.push(Json::obj(jrow));
    }

    println!("\nall-gather latency vs ranks at 64 B/rank (flat fabric):");
    print!("{}", table.render());

    // Classify curve shapes: R² of t vs n (linear) against t vs log2 n,
    // over the α-dominated range (n ≤ 256). Beyond that the per-chunk
    // local cost γ·(n-1) takes over — exactly the paper's §Performance
    // caveat: "the number of chunks of data we need to manipulate
    // separately is linear … there is always a scale at which the linear
    // part will become predominant over the logarithmic part."
    // Structural classification: with the local per-chunk cost γ zeroed
    // (the limit the paper's "further optimization of the linear part"
    // aims at), PAT's curve is pure α·log2(n) while ring stays α·(n-1).
    let mut gamma0 = cost;
    gamma0.gamma_chunk = 0.0;
    gamma0.gamma_byte = 0.0;
    let ns: Vec<f64> = ranks.iter().map(|&n| n as f64).collect();
    let logns: Vec<f64> = ranks.iter().map(|&n| (n as f64).log2()).collect();
    println!("\nstructural classification (γ = 0, R² of linear fit):");
    for alg in &algs {
        let curve: Vec<f64> = ranks
            .iter()
            .map(|&n| {
                let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
                let prog = sched::generate(*alg, Collective::AllGather, n).unwrap();
                simulate(&prog, &topo, &gamma0, chunk).unwrap().total_time
            })
            .collect();
        let (_, _, r2_lin) = linfit(&ns, &curve);
        let (_, _, r2_log) = linfit(&logns, &curve);
        let shape = if r2_lin > r2_log { "LINEAR" } else { "LOG" };
        println!(
            "  {:<14} R²(t~n)={:.4}  R²(t~log n)={:.4}  -> {}",
            alg.name(),
            r2_lin,
            r2_log,
            shape
        );
        report.param(&format!("r2_linear_{}", alg.name()), Json::num(r2_lin));
        report.param(&format!("r2_log_{}", alg.name()), Json::num(r2_log));
    }

    // The paper's caveat, demonstrated: with the local linear part made
    // free (γ = 0), PAT's full curve is pure α·log; with the measured γ it
    // eventually bends linear. Report the large-n growth factor both ways.
    let mut ideal_cost = cost;
    ideal_cost.gamma_chunk = 0.0;
    ideal_cost.gamma_byte = 0.0;
    let t_big = |cost: &patcol::sim::CostModel, n: usize| {
        let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
        let prog = sched::generate(
            Algorithm::Pat { aggregation: usize::MAX },
            Collective::AllGather,
            n,
        )
        .unwrap();
        simulate(&prog, &topo, cost, chunk).unwrap().total_time
    };
    let hi = if smoke { 256usize } else { 2048 };
    let g_real = t_big(&cost, hi) / t_big(&cost, 64);
    let g_ideal = t_big(&ideal_cost, hi) / t_big(&ideal_cost, 64);
    println!(
        "\npat(full) growth 64→{hi} ranks: {:.1}x measured vs {:.1}x with free linear part \
         (ideal log growth = {:.1}x)",
        g_real,
        g_ideal,
        ((hi as f64).log2() + 1.0) / (64f64.log2() + 1.0)
    );
    report.param("growth_real", Json::num(g_real));
    report.param("growth_gamma0", Json::num(g_ideal));
    report.save().unwrap();
}
