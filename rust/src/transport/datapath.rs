//! The receive-side reduction datapath.
//!
//! Reduce-scatter folds every incoming chunk into an accumulator — the
//! compute hot-spot the paper's NCCL implementation runs as a GPU kernel.
//! Two implementations:
//!
//! * [`DataPath::Scalar`] — a lane-chunked pure-rust kernel (fixed-width
//!   inner loops LLVM vectorizes reliably); the baseline and fallback.
//! * [`DataPath::Pjrt`] — the AOT-compiled Pallas reduce kernel executed
//!   through the sharded PJRT service ([`crate::runtime::PjrtHandle`];
//!   the `xla` crate's handles are not `Send`, so dedicated threads own
//!   the clients — the analog of kernels serializing on device streams).
//!   Requests route by `(rank, channel)` hash and pass slice
//!   descriptors, so the service reads each operand exactly once.
//!   Three-layer path: Pallas (L1) → jax graph (L2) → rust runtime (L3).

use crate::core::{Rank, Result};
use crate::obs::{Event, EventKind, FlightRecorder};
use crate::runtime::PjrtHandle;

/// Reduction backend used by the transport engine.
#[derive(Clone)]
pub enum DataPath {
    /// Pure-rust elementwise add.
    Scalar,
    /// AOT Pallas kernel via the sharded PJRT service.
    Pjrt(PjrtHandle),
}

impl DataPath {
    /// `acc[i] += x[i]` for all i (shard 0 on the PJRT path).
    pub fn reduce_into(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        self.reduce_into_at(0, 0, acc, x)
    }

    /// `acc[i] += x[i]`, routed to the `(rank, channel)` service shard on
    /// the PJRT path.
    pub fn reduce_into_at(
        &self,
        rank: Rank,
        channel: usize,
        acc: &mut [f32],
        x: &[f32],
    ) -> Result<()> {
        debug_assert_eq!(acc.len(), x.len());
        match self {
            DataPath::Scalar => {
                scalar_add(acc, x);
                Ok(())
            }
            DataPath::Pjrt(h) => h.reduce_into_routed(rank, channel, acc, x),
        }
    }

    /// `out[i] = a[i] + b[i]` — the 3-operand fused form over a
    /// preallocated destination (the arena send path): one read of each
    /// operand, one write of the destination, on both backends.
    pub fn add_into(&self, out: &mut [f32], a: &[f32], b: &[f32]) -> Result<()> {
        self.add_into_at(0, 0, out, a, b)
    }

    /// [`DataPath::add_into`], routed to the `(rank, channel)` service
    /// shard on the PJRT path.
    pub fn add_into_at(
        &self,
        rank: Rank,
        channel: usize,
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
    ) -> Result<()> {
        debug_assert_eq!(a.len(), b.len());
        debug_assert_eq!(out.len(), a.len());
        match self {
            DataPath::Scalar => {
                scalar_add_into(out, a, b);
                Ok(())
            }
            DataPath::Pjrt(h) => h.add_into_routed(rank, channel, out, a, b),
        }
    }

    /// Append `a + b` to `out` (3-operand fused form for growable
    /// destinations). On the PJRT path the suffix is resized once and the
    /// sum runs through the sharded slice ABI — one read of each operand,
    /// no service round trip over owned vectors.
    pub fn add_extend(&self, out: &mut Vec<f32>, a: &[f32], b: &[f32]) -> Result<()> {
        debug_assert_eq!(a.len(), b.len());
        match self {
            DataPath::Scalar => {
                out.extend(a.iter().zip(b.iter()).map(|(x, y)| x + y));
                Ok(())
            }
            DataPath::Pjrt(h) => {
                let base = out.len();
                out.resize(base + a.len(), 0.0);
                h.add_into_routed(0, 0, &mut out[base..], a, b)
            }
        }
    }

    /// [`DataPath::reduce_into_at`] wrapped in a reduce-kernel span when
    /// the flight recorder is enabled (single branch + no clock reads when
    /// disabled — the hot path stays untouched).
    #[allow(clippy::too_many_arguments)]
    pub fn reduce_into_traced(
        &self,
        acc: &mut [f32],
        x: &[f32],
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        if !fr.enabled() {
            return self.reduce_into_at(rank, channel, acc, x);
        }
        let t0 = fr.now();
        self.reduce_into_at(rank, channel, acc, x)?;
        let t1 = fr.now();
        fr.record(
            Event::span(EventKind::Reduce, rank, channel, step, t0, t1)
                .with_bytes(std::mem::size_of_val(x)),
        );
        Ok(())
    }

    /// [`DataPath::add_into_at`] wrapped in a reduce-kernel span (see
    /// [`DataPath::reduce_into_traced`]).
    #[allow(clippy::too_many_arguments)]
    pub fn add_into_traced(
        &self,
        out: &mut [f32],
        a: &[f32],
        b: &[f32],
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        if !fr.enabled() {
            return self.add_into_at(rank, channel, out, a, b);
        }
        let t0 = fr.now();
        self.add_into_at(rank, channel, out, a, b)?;
        let t1 = fr.now();
        fr.record(
            Event::span(EventKind::Reduce, rank, channel, step, t0, t1)
                .with_bytes(std::mem::size_of_val(b)),
        );
        Ok(())
    }

    /// [`DataPath::add_extend`] wrapped in a reduce-kernel span (see
    /// [`DataPath::reduce_into_traced`]).
    #[allow(clippy::too_many_arguments)]
    pub fn add_extend_traced(
        &self,
        out: &mut Vec<f32>,
        a: &[f32],
        b: &[f32],
        fr: &mut FlightRecorder,
        rank: Rank,
        channel: usize,
        step: usize,
    ) -> Result<()> {
        if !fr.enabled() {
            return self.add_extend(out, a, b);
        }
        let t0 = fr.now();
        self.add_extend(out, a, b)?;
        let t1 = fr.now();
        fr.record(
            Event::span(EventKind::Reduce, rank, channel, step, t0, t1)
                .with_bytes(std::mem::size_of_val(b)),
        );
        Ok(())
    }

    pub fn name(&self) -> &'static str {
        match self {
            DataPath::Scalar => "scalar",
            DataPath::Pjrt(_) => "pjrt",
        }
    }
}

/// Lane width of the scalar kernels. Fixed-width inner loops over
/// `chunks_exact` give LLVM a compile-time trip count, which vectorizes
/// reliably where a plain zip loop sometimes does not.
const LANES: usize = 8;

/// The scalar kernel, split out so benches can target it directly:
/// `acc[i] += x[i]` over fixed-width lanes plus a scalar remainder.
#[inline]
pub fn scalar_add(acc: &mut [f32], x: &[f32]) {
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (a, b) in (&mut ac).zip(&mut xc) {
        for i in 0..LANES {
            a[i] += b[i];
        }
    }
    for (a, b) in ac.into_remainder().iter_mut().zip(xc.remainder()) {
        *a += *b;
    }
}

/// 3-operand scalar kernel: `out[i] = a[i] + b[i]` over fixed-width
/// lanes plus a scalar remainder.
#[inline]
pub fn scalar_add_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((o, x), y) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for i in 0..LANES {
            o[i] = x[i] + y[i];
        }
    }
    for ((o, x), y) in oc
        .into_remainder()
        .iter_mut()
        .zip(ac.remainder())
        .zip(bc.remainder())
    {
        *o = *x + *y;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_adds() {
        let mut acc = vec![1.0, 2.0, 3.0];
        DataPath::Scalar.reduce_into(&mut acc, &[10.0, 20.0, 30.0]).unwrap();
        assert_eq!(acc, vec![11.0, 22.0, 33.0]);
    }

    /// Lengths straddling the lane width exercise both the lane loop and
    /// the remainder.
    #[test]
    fn lane_kernels_cover_remainders() {
        for len in [0usize, 1, 7, 8, 9, 19, 64, 65] {
            let mut acc: Vec<f32> = (0..len).map(|i| i as f32).collect();
            let x: Vec<f32> = (0..len).map(|i| 2.0 * i as f32).collect();
            scalar_add(&mut acc, &x);
            for (i, &v) in acc.iter().enumerate() {
                assert_eq!(v, 3.0 * i as f32, "len {len} idx {i}");
            }
            let mut out = vec![0.0f32; len];
            scalar_add_into(&mut out, &acc, &x);
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, 5.0 * i as f32, "len {len} idx {i}");
            }
        }
    }

    #[test]
    fn add_into_and_extend_match() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [10.0f32, 20.0, 30.0, 40.0, 50.0];
        let mut out = vec![0.0f32; 5];
        DataPath::Scalar.add_into(&mut out, &a, &b).unwrap();
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0, 55.0]);
        let mut grown = vec![7.0f32];
        DataPath::Scalar.add_extend(&mut grown, &a, &b).unwrap();
        assert_eq!(grown, vec![7.0, 11.0, 22.0, 33.0, 44.0, 55.0]);
    }

    #[test]
    fn names() {
        assert_eq!(DataPath::Scalar.name(), "scalar");
    }
}
