//! `patcol` — the command-line launcher.
//!
//! Subcommands:
//!
//! * `explain`  — print a schedule step-by-step + the PAT tree (regenerates
//!   the paper's figures as text).
//! * `run`      — execute a collective on the in-process transport with
//!   real bytes (optionally through the PJRT Pallas datapath).
//! * `simulate` — run a schedule through the network simulator at scale.
//! * `trace`    — run one op on the simulator and/or the transport with the
//!   observability layer on, write Chrome trace JSON for each executor, and
//!   print per-(rank, channel) counters plus the Träff lower-bound
//!   comparison.
//! * `analyze`  — read an exported Chrome trace back and report the
//!   critical path (wire/reduce/stall/wait decomposition), the stall
//!   taxonomy and occupancy percentiles, and the Träff optimality gap.
//! * `baseline` — compare a bench-baseline document (written by running
//!   the bench suite with `PATCOL_BASELINE` set) against the committed
//!   one; exits nonzero on regressions — the CI gate.
//! * `sweep`    — compare algorithms across sizes on the simulator.
//! * `tune`     — show the tuner's decision for a configuration.
//! * `selftest` — quick correctness matrix across algorithms and rank
//!   counts.
//! * `adversary` — schedule-exploration harness: run seeded adversarial
//!   delivery episodes against the threaded transport, shrink failures to
//!   minimal replayable traces, replay saved traces (`--replay`).

use patcol::cli::Args;
use patcol::coordinator::config::parse_bytes;
use patcol::coordinator::{CommConfig, Communicator, DataPathKind, Tuner};
use patcol::core::{AlgSpec, Algorithm, Collective, Placement, Result};
use patcol::sched::{self, explain, pat};
use patcol::sim::{self, CostModel, Topology};
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};
use patcol::util::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return;
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            print_usage();
            std::process::exit(2);
        }
    };
    let res = match args.command.as_str() {
        "explain" => cmd_explain(&args),
        "run" => cmd_run(&args),
        "simulate" => cmd_simulate(&args),
        "trace" => cmd_trace(&args),
        "analyze" => cmd_analyze(&args),
        "baseline" => cmd_baseline(&args),
        "sweep" => cmd_sweep(&args),
        "tune" => cmd_tune(&args),
        "selftest" => cmd_selftest(&args),
        "adversary" => cmd_adversary(&args),
        other => {
            eprintln!("unknown command {other:?}");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "patcol — PAT collective communication (all-gather / reduce-scatter)

USAGE: patcol <command> [--options]

COMMANDS
  explain   --ranks N [--agg A] [--alg ALG] [--collective ag|rs|ar] [--trees]
            [--channels C] [--placement SPEC | --ranks-per-node K]
            [--leaders-per-node L]
  run       --ranks N --size BYTES [--alg ALG] [--collective ag|rs|ar]
            [--channels C] [--buckets B | --bucket-bytes BYTES]
            [--datapath scalar|pjrt] [--reduce-shards N] [--buffer-slots S]
            [--trace PATH] [--placement SPEC | --ranks-per-node K]
            [--leaders-per-node L]
  simulate  --ranks N --size BYTES [--alg ALG] [--collective ag|rs|ar]
            [--channels C] [--topo flat|leaf_spine|three_level|dragonfly]
            [--taper F] [--intra-gbps G] [--placement SPEC | --ranks-per-node K]
            [--leaders-per-node L] [--trace PATH]
            [--jitter F] [--flaps N] [--flap-dur S] [--fault-seed S]
  trace     --ranks N --size BYTES [--alg ALG] [--collective ag|rs|ar]
            [--channels C] [--exec sim|transport|both] [--out STEM]
            [--topo ...] [--smoke]
  analyze   TRACE.json [--json] [--bytes BYTES] [--ranks N]
            [--collective ag|rs|ar]
  baseline  --current FILE [--committed FILE]
  sweep     --ranks N [--sizes LIST] [--collective ag|rs] [--topo ...]
  tune      --ranks N --size BYTES [--buffer-slots S] [--collective ag|rs|ar]
            [--placement SPEC | --ranks-per-node K] [--inter-gbps G]
            [--parallel-links L] [--leaders-per-node L]
  selftest  [--max-ranks N]
  adversary --ranks N [--alg ALG] [--collective ag|rs] [--channels C]
            [--elems E] [--episodes K] [--seed S]
            [--policy delay|reorder|pressure|dpor|mix[:SEED]]
            [--out TRACE.json] [--trace PATH] [--smoke]
            [--replay TRACE.json] [--sentinel fifo|slot]

ALG — the full grammar is alg[+alg][:<segments>][*<channels>]:
     ring | bruck_near | bruck_far | recursive | pat | pat:<agg> | pat_auto
     | hier_pat | hier_pat:<agg>   (two-level, placement-aware)
     | rs+ag[:<segments>]          (all-reduce composition, e.g. pat+ring:4)
     any spelling takes *<channels> (NCCL-style channel split: pat*4,
     pat+ring:2*4 = two pipeline segments, each striped over 4 channels)
SIZES: e.g. 1KiB,64KiB,1MiB (per-rank chunk size)
SPEC:  uniform:<k> | <k> | <k1>,<k2>,...  (node sizes; uneven allowed)
       | <k>x<m> (three-level: k ranks/node, pods of m nodes)
       | <sizes>;<sizes>;... (three-level: explicit pods of node sizes)
--leaders-per-node gives hierarchical algorithms L stripe leaders per
  node: each leader owns an interleaved chunk stripe and its own
  inter-node channel (L ECMP flows per node; clamped to the smallest
  node)
--channels splits the collective across C channels (--channels overrides *C)
--buckets B (or --bucket-bytes BYTES) splits an all-reduce payload into
  gradient buckets fused into one pipelined program (bucket i+1's RS
  overlaps bucket i's AG; one channel set per bucket, so --channels > 1
  cannot stack on top)
--reduce-shards sizes the PJRT reduction service (worker threads, each
  owning a client; requests route by (rank, channel) hash); default =
  min(cores, ranks)
--intra-gbps models NVLink-class intra-node links (with --ranks-per-node)
--parallel-links feeds the tuner's channel-count crossover (tune)
--trace PATH (run/simulate) writes the observability timeline as Chrome
  trace-event JSON (load in Perfetto / chrome://tracing); `trace` runs one
  op on both executors, writes STEM.sim.json / STEM.transport.json, and
  prints per-(rank, channel) counters + the Träff lower-bound comparison
  (--smoke: fixed 8-rank/4KiB run that re-parses its own output)
--calib-history PATH (run) appends one predicted-vs-measured record per
  collective to a JSONL drift history (see obs::calib)
`analyze` reads a trace either executor exported and prints the critical
  path with its send/wire/recv/reduce/stall/wait decomposition, per-step
  buckets, stall taxonomy, occupancy percentiles, and the Träff
  optimality gap (--bytes overrides the payload estimate; --json for
  machine-readable output)
`baseline` compares the bench document written under PATCOL_BASELINE
  against the committed one (default BENCH_8.json) and exits nonzero on
  any regression
`adversary` runs seeded episodes of the collective through the threaded
  transport under an adversarial delivery policy; the first
  deterministic failure is shrunk to a minimal replayable JSON trace
  (--out) and the command exits nonzero. --smoke runs a small fixed
  matrix (the CI job); --replay re-runs a saved trace and requires the
  recorded blame to reproduce bit-exactly; --sentinel arms a transport
  mutation (needs a build with --features adversary)
--jitter F / --flaps N (simulate) add deterministic fault axes on the
  fabric (seeded per-message serialization stretch in [0,F]; N link-down
  windows of --flap-dur seconds) and report the slowdown vs the clean
  run — the simulator-side schedule-robustness number"
    );
}

fn parse_collective(s: &str) -> Result<Collective> {
    match s {
        "ag" | "allgather" | "all_gather" => Ok(Collective::AllGather),
        "rs" | "reducescatter" | "reduce_scatter" => Ok(Collective::ReduceScatter),
        "ar" | "allreduce" | "all_reduce" => Ok(Collective::AllReduce),
        other => Err(patcol::core::Error::Config(format!(
            "unknown collective {other:?}"
        ))),
    }
}

fn collective(args: &Args) -> Result<Collective> {
    parse_collective(&args.str("collective", "ag"))
}

/// Collective for this invocation: a composed algorithm always runs as
/// all-reduce (the only collective it can generate); the `--collective`
/// flag is still parsed so typos keep failing loudly.
fn collective_for(args: &Args, alg: Option<Algorithm>) -> Result<Collective> {
    let coll = collective(args)?;
    match alg {
        Some(Algorithm::Compose { .. }) => Ok(Collective::AllReduce),
        _ => Ok(coll),
    }
}

/// `--alg` (the [`AlgSpec`] grammar, so a `*<channels>` suffix is
/// accepted) plus the `--channels` override. Returns the algorithm (None
/// when `--alg` is absent) and the pinned channel count (None = let the
/// tuner/default decide).
fn alg_channels(args: &Args) -> Result<(Option<Algorithm>, Option<usize>)> {
    let mut channels = None;
    let alg = match args.opt_str("alg") {
        Some(s) => {
            let (alg, pinned) = AlgSpec::parse_pinned(&s)?;
            if let Some(c) = pinned {
                channels = Some(c);
            }
            Some(alg)
        }
        None => None,
    };
    if let Some(c) = args.opt_str("channels") {
        let c: usize = c
            .parse()
            .map_err(|_| patcol::core::Error::Config(format!("--channels: bad integer {c:?}")))?;
        if c == 0 {
            return Err(patcol::core::Error::Config("--channels must be >= 1".into()));
        }
        channels = Some(c);
    }
    Ok((alg, channels))
}

/// `--leaders-per-node L`: stripe leaders per node for hierarchical
/// algorithms (None if absent; zero is rejected).
fn leaders_opt(args: &Args) -> Result<Option<usize>> {
    match args.opt_str("leaders-per-node") {
        None => Ok(None),
        Some(s) => {
            let l: usize = s.parse().map_err(|_| {
                patcol::core::Error::Config(format!("--leaders-per-node: bad integer {s:?}"))
            })?;
            if l == 0 {
                return Err(patcol::core::Error::Config(
                    "--leaders-per-node must be >= 1".into(),
                ));
            }
            Ok(Some(l))
        }
    }
}

/// Fold `--leaders-per-node` into a placement (idempotent — the
/// communicator applies the same count again on its own placement).
fn with_cli_leaders(pl: Placement, args: &Args) -> Result<Placement> {
    match leaders_opt(args)? {
        Some(l) => pl.with_leaders(l),
        None => Ok(pl),
    }
}

/// Placement from `--placement SPEC` or `--ranks-per-node K` (None if
/// neither is given), with `--leaders-per-node` applied.
fn placement_opt(args: &Args, nranks: usize) -> Result<Option<Placement>> {
    if let Some(spec) = args.opt_str("placement") {
        return Ok(Some(with_cli_leaders(Placement::parse(&spec, nranks)?, args)?));
    }
    let k = args.usize("ranks-per-node", 0)?;
    if k == 0 {
        return Ok(None);
    }
    Ok(Some(with_cli_leaders(Placement::uniform(nranks, k)?, args)?))
}

/// The placement a hierarchical algorithm runs on: the explicit one, or
/// contiguous default-sized nodes (both with `--leaders-per-node`
/// applied).
fn placement_or_default(args: &Args, nranks: usize) -> Result<Placement> {
    match placement_opt(args, nranks)? {
        Some(p) => Ok(p),
        None => with_cli_leaders(
            Placement::uniform(nranks, sched::DEFAULT_RANKS_PER_NODE)?,
            args,
        ),
    }
}

/// Generate `alg`, routing hierarchical algorithms through the
/// placement-aware front-end.
fn generate_for_cli(
    args: &Args,
    alg: Algorithm,
    coll: Collective,
    nranks: usize,
) -> Result<patcol::sched::Program> {
    if alg.uses_placement() {
        let pl = placement_or_default(args, nranks)?;
        sched::generate_placed(alg, coll, &pl)
    } else {
        sched::generate(alg, coll, nranks)
    }
}

fn topology(args: &Args, nranks: usize) -> Result<Topology> {
    let nic = CostModel::ib_hdr_nic_bw();
    let taper = args.f64("taper", 1.0)?;
    let mut topo = match args.str("topo", "flat").as_str() {
        "flat" => Topology::flat(nranks, nic),
        "leaf_spine" => {
            let g = args.usize("ranks-per-leaf", 8.min(nranks))?;
            let s = args.usize("spines", (g).max(1))?;
            Topology::leaf_spine(nranks, g, s, nic, taper)?
        }
        "three_level" => {
            let g = args.usize("ranks-per-leaf", 8.min(nranks))?;
            let lp = args.usize("leaves-per-pod", 4)?;
            let sp = args.usize("spines-per-pod", g)?;
            let c = args.usize("cores", sp)?;
            Topology::three_level(nranks, g, lp, sp, c, nic, 1.0, taper)?
        }
        "dragonfly" => {
            let g = args.usize("ranks-per-group", 8.min(nranks))?;
            Topology::dragonfly(nranks, g, nic, nic * taper)?
        }
        other => {
            return Err(patcol::core::Error::Config(format!(
                "unknown topology {other:?}"
            )))
        }
    };
    // NVLink-class intra-node links (`--intra-gbps`, sized by
    // --ranks-per-node): local traffic leaves the NIC links.
    let intra_gbps = args.f64("intra-gbps", 0.0)?;
    if intra_gbps > 0.0 {
        let k = args.usize("ranks-per-node", 8.min(nranks).max(1))?;
        topo = topo.with_intra_node(k, intra_gbps * 1e9)?;
    }
    Ok(topo)
}

fn cmd_explain(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 8)?;
    let agg = args.usize("agg", usize::MAX)?;
    let (alg_opt, channels) = alg_channels(args)?;
    let alg = alg_opt.unwrap_or(Algorithm::Pat { aggregation: agg });
    let channels = channels.unwrap_or(1);
    let coll = collective_for(args, Some(alg))?;
    // `base` keeps the single-channel view for the phase tables; `prog`
    // is what executes (split across channels when requested) and what
    // the step table — with its channel column — renders.
    let base = generate_for_cli(args, alg, coll, n)?;
    let prog = sched::channel::split(&base, channels)?;
    println!("{}", explain::render_steps(&prog));
    if let Algorithm::Pat { .. } = alg {
        println!("{}", explain::render_pat_tree(n, agg));
    }
    if let Algorithm::HierPat { aggregation } = alg {
        // The hierarchical phase table describes a single-phase program;
        // for all-reduce the compose view below covers both phases.
        if coll != Collective::AllReduce {
            let pl = placement_or_default(args, n)?;
            println!("{}", explain::render_hier_phases(&base, &pl, aggregation));
        }
    }
    // Compose view: an explicit pair, or the lifted `alg+alg:1` an
    // all-reduce resolves a bare algorithm to.
    let compose_view = match alg {
        Algorithm::Compose { rs, ag, segments } => Some((rs, ag, segments)),
        _ if coll == Collective::AllReduce => {
            patcol::core::PhaseAlg::from_algorithm(alg).ok().map(|p| (p, p, 1))
        }
        _ => None,
    };
    if let Some((rs, ag, segments)) = compose_view {
        let pl = if alg.uses_placement() {
            Some(placement_or_default(args, n)?)
        } else {
            placement_opt(args, n)?
        };
        let build = |a: Algorithm, c: Collective| match &pl {
            Some(p) => sched::generate_placed(a, c, p),
            None => sched::generate(a, c, n),
        };
        let rsp = build(rs.to_algorithm(), Collective::ReduceScatter)?;
        let agp = build(ag.to_algorithm(), Collective::AllGather)?;
        let layout = sched::compose::Layout::of(&rsp, &agp, segments);
        println!("{}", explain::render_compose_phases(&base, &layout));
    }
    if args.flag("trees") {
        println!("{}", explain::render_root_trees(&base));
    }
    let occ = sched::verify::verify_program(&prog)?;
    let s = prog.stats();
    println!(
        "steps={} channels={} messages={} chunk_transfers={} max_aggregation={} peak_buffer_slots={}",
        s.steps, prog.channels, s.messages, s.chunk_transfers, s.max_aggregation, occ.peak_slots
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 8)?;
    let size = args.bytes("size", 64 * 1024)?;
    let (alg, channels) = alg_channels(args)?;
    let coll = collective_for(args, alg)?;
    let datapath = match args.str("datapath", "scalar").as_str() {
        "pjrt" => DataPathKind::Pjrt,
        _ => DataPathKind::Scalar,
    };
    // Gradient bucketing (all-reduce): a bucket count, or a target bucket
    // size the payload is divided into — one or the other, not both.
    let mut buckets = match args.opt_str("buckets") {
        None => None,
        Some(s) => {
            let b: usize = s.parse().map_err(|_| {
                patcol::core::Error::Config(format!("--buckets: bad integer {s:?}"))
            })?;
            if b == 0 {
                return Err(patcol::core::Error::Config("--buckets must be >= 1".into()));
            }
            Some(b)
        }
    };
    if let Some(bb) = args.opt_str("bucket-bytes") {
        if buckets.is_some() {
            return Err(patcol::core::Error::Config(
                "--buckets and --bucket-bytes are mutually exclusive".into(),
            ));
        }
        let bb = parse_bytes(&bb)?.max(1);
        buckets = Some(size.div_ceil(bb).max(1));
    }
    let reduce_shards = match args.opt_str("reduce-shards") {
        None => None,
        Some(s) => {
            let r: usize = s.parse().map_err(|_| {
                patcol::core::Error::Config(format!("--reduce-shards: bad integer {s:?}"))
            })?;
            if r == 0 {
                return Err(patcol::core::Error::Config(
                    "--reduce-shards must be >= 1".into(),
                ));
            }
            Some(r)
        }
    };
    let trace_path = args.opt_str("trace");
    let comm = Communicator::new(CommConfig {
        nranks: n,
        algorithm: alg,
        buffer_slots: args.opt_str("buffer-slots").map(|s| parse_bytes(&s)).transpose()?,
        datapath,
        reduce_shards,
        placement: placement_opt(args, n)?,
        leaders_per_node: leaders_opt(args)?,
        channels,
        buckets,
        trace: trace_path.is_some(),
        calib_history: args.opt_str("calib-history").map(std::path::PathBuf::from),
        ..Default::default()
    })?;
    let chunk = (size / 4).max(1);
    let mut rng = Rng::new(7);
    let (rep, payload) = match coll {
        Collective::AllGather => {
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0f32; chunk];
                    rng.fill_f32(&mut v);
                    v
                })
                .collect();
            let (_, rep) = comm.all_gather_report(&inputs)?;
            (rep, (n - 1) * chunk * 4)
        }
        Collective::ReduceScatter => {
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0f32; n * chunk];
                    rng.fill_f32(&mut v);
                    v
                })
                .collect();
            let (_, rep) = comm.reduce_scatter_report(&inputs)?;
            (rep, (n - 1) * chunk * 4)
        }
        Collective::AllReduce => {
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0f32; chunk];
                    rng.fill_f32(&mut v);
                    v
                })
                .collect();
            let (_, rep) = comm.all_reduce_report(&inputs)?;
            // RS + AG payload per rank, the 2(n-1)/n · bytes convention
            (rep, 2 * (n - 1) * chunk * 4 / n.max(1))
        }
    };
    if let Some(path) = &trace_path {
        let trace = rep.transport.trace.as_ref().ok_or_else(|| {
            patcol::core::Error::Transport("transport returned no trace".into())
        })?;
        let json = patcol::obs::chrome_trace(trace, &patcol::obs::ChannelTags::plain());
        std::fs::write(path, json.to_pretty())?;
        println!("trace ({} events) -> {path}", trace.events.len());
    }
    let wall = rep.transport.wall.as_secs_f64();
    println!(
        "{} {} ranks={} chunk={} channels={} steps={} msgs={} bytes={} peak_slots={} wall={} algbw={}/s",
        rep.algorithm,
        coll,
        n,
        fmt_bytes(size),
        rep.channels,
        rep.steps,
        rep.transport.messages,
        fmt_bytes(rep.transport.bytes_moved),
        rep.transport.peak_slots,
        fmt_time_s(wall),
        fmt_bytes((payload as f64 / wall.max(1e-9)) as usize),
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let size = args.bytes("size", 64 * 1024)?;
    let (alg_opt, channels) = alg_channels(args)?;
    let alg = alg_opt.unwrap_or(Algorithm::Pat { aggregation: usize::MAX });
    let channels = channels.unwrap_or(1);
    let coll = collective_for(args, Some(alg))?;
    let topo = topology(args, n)?;
    let cost = CostModel::ib_hdr();
    if alg.uses_placement() {
        // Intra-node traffic must stay under one switch; reject placements
        // that straddle fat-tree leaves up front.
        let pl = placement_or_default(args, n)?;
        topo.check_placement(&pl)?;
    }
    let prog = sched::channel::split(&generate_for_cli(args, alg, coll, n)?, channels)?;
    // `--size` is the per-rank chunk payload before splitting; each of the
    // C stripes carries a 1/C-sized sub-chunk, rounded UP for odd sizes
    // (pad semantics, matching the Communicator — never simulate less
    // payload than requested).
    let size = size.div_ceil(channels).max(1);
    let rep = if let Some(trace_path) = args.opt_str("trace") {
        let mut rec = patcol::obs::TraceRecorder::new();
        let rep = sim::simulate_observed(&prog, &topo, &cost, size, &mut rec)?;
        let trace = rec.finish();
        let tags = trace_tags(args, alg, coll, n, channels)?;
        std::fs::write(&trace_path, patcol::obs::chrome_trace(&trace, &tags).to_pretty())?;
        println!("trace ({} events) -> {trace_path}", trace.events.len());
        rep
    } else {
        sim::simulate(&prog, &topo, &cost, size)?
    };
    println!(
        "{} {} ranks={} chunk={} channels={} topo={}",
        prog.algorithm,
        coll,
        n,
        fmt_bytes(size),
        prog.channels,
        topo.name
    );
    // Payload convention: AG/RS move (n-1) sub-chunks per rank per channel
    // stripe; all-reduce moves 2(n-1)/n of the full per-rank vector
    // (chunk_space sub-chunks). `size` is the per-stripe sub-chunk here.
    let stripes = (prog.chunk_space() / n.max(1)).max(1);
    let payload = match coll {
        Collective::AllReduce => 2 * (n - 1) * prog.chunk_space() * size / n.max(1),
        _ => (n - 1) * stripes * size,
    };
    println!(
        "  time={}  algbw={}/s  msgs={}  bytes={}  bytes_links={:.2e}",
        fmt_time_s(rep.total_time),
        fmt_bytes(rep.algbw(payload) as usize),
        rep.messages,
        fmt_bytes(rep.bytes_sent),
        rep.bytes_links,
    );
    for (lvl, b) in rep.bytes_by_level.iter().enumerate() {
        println!(
            "  level {lvl}: {} ({} msgs)",
            fmt_bytes(*b),
            rep.msgs_by_level[lvl]
        );
    }
    println!(
        "  busiest link: {} ({:.0}% busy)",
        fmt_bytes(rep.max_link_bytes),
        rep.busiest_link_utilization * 100.0
    );
    // Fault axes: rerun the same program under a deterministic fault
    // model and report the schedule-robustness slowdown.
    let jitter = args.f64("jitter", 0.0)?;
    let nflaps = args.usize("flaps", 0)?;
    if jitter > 0.0 || nflaps > 0 {
        let fseed = args.usize("fault-seed", 1)? as u64;
        let dur = args.f64("flap-dur", rep.total_time * 0.25)?;
        let flaps = sim::FaultModel::random_flaps(fseed, &topo, rep.total_time, nflaps, dur);
        let fm = sim::FaultModel::new(fseed, jitter).with_flaps(flaps);
        let frep = sim::simulate_faulted(&prog, &topo, &cost, size, &fm)?;
        println!(
            "  faults: jitter={:.0}% flaps={} (dur={}) -> time={}  slowdown={:.3}x",
            jitter * 100.0,
            nflaps,
            fmt_time_s(dur),
            fmt_time_s(frep.total_time),
            frep.total_time / rep.total_time.max(f64::MIN_POSITIVE),
        );
    }
    // Fabric contention (obs::metrics LinkStat): how long messages queued
    // behind busy links, and where. Zero on an uncontended run.
    let mut contended: Vec<_> = rep
        .link_stats
        .iter()
        .filter(|l| l.contended_s > 0.0)
        .collect();
    if !contended.is_empty() {
        let total: f64 = contended.iter().map(|l| l.contended_s).sum();
        contended.sort_by(|a, b| b.contended_s.total_cmp(&a.contended_s));
        println!(
            "  contention: {} of {} links queued messages, {} total queueing",
            contended.len(),
            rep.link_stats.len(),
            fmt_time_s(total)
        );
        for l in contended.iter().take(3) {
            println!(
                "    link {}: {} queued, {:.0}% busy, {} carried",
                l.link,
                fmt_time_s(l.contended_s),
                l.utilization * 100.0,
                fmt_bytes(l.bytes)
            );
        }
    }
    Ok(())
}

/// Channel tags for trace export. A composed all-reduce program's channels
/// *are* its pipeline segments, so tag them `seg{s}` and let the
/// [`sched::compose::Layout`] classify reduce-scatter vs all-gather
/// phases. Anything else — including a composed program re-split across
/// channels, where channel no longer equals segment — gets plain tags.
fn trace_tags(
    args: &Args,
    alg: Algorithm,
    coll: Collective,
    n: usize,
    extra_split: usize,
) -> Result<patcol::obs::ChannelTags> {
    use patcol::obs::ChannelTags;
    if coll != Collective::AllReduce || extra_split > 1 {
        return Ok(ChannelTags::plain());
    }
    let (rs, ag, segments) = match alg {
        Algorithm::Compose { rs, ag, segments } => (rs, ag, segments),
        _ => match patcol::core::PhaseAlg::from_algorithm(alg) {
            Ok(p) => (p, p, 1),
            Err(_) => return Ok(ChannelTags::plain()),
        },
    };
    let pl = if alg.uses_placement() {
        Some(placement_or_default(args, n)?)
    } else {
        placement_opt(args, n)?
    };
    let build = |a: Algorithm, c: Collective| match &pl {
        Some(p) => sched::generate_placed(a, c, p),
        None => sched::generate(a, c, n),
    };
    let rsp = build(rs.to_algorithm(), Collective::ReduceScatter)?;
    let agp = build(ag.to_algorithm(), Collective::AllGather)?;
    Ok(ChannelTags::composed(sched::compose::Layout::of(&rsp, &agp, segments)))
}

/// `patcol trace` — run one op through the observability layer on the
/// simulator and/or the real transport, write Chrome trace-event JSON for
/// each executor (one schema from both, Perfetto-loadable), and print the
/// per-(rank, channel) counters plus the Träff lower-bound comparison.
fn cmd_trace(args: &Args) -> Result<()> {
    use patcol::obs::{chrome_trace, ChannelTags, Trace, TraceRecorder};
    use patcol::transport::{run_allgather, run_allreduce, run_reduce_scatter, TransportOptions};

    let smoke = args.flag("smoke");
    let n = if smoke { 8 } else { args.usize("ranks", 16)? };
    let size = if smoke { 4 << 10 } else { args.bytes("size", 64 * 1024)? };
    let (alg_opt, channels) = alg_channels(args)?;
    let alg = alg_opt.unwrap_or(Algorithm::Pat { aggregation: usize::MAX });
    let channels = channels.unwrap_or(1);
    let coll = collective_for(args, Some(alg))?;
    let exec = args.str("exec", "both");
    let (want_sim, want_transport) = match exec.as_str() {
        "sim" => (true, false),
        "transport" => (false, true),
        "both" => (true, true),
        other => {
            return Err(patcol::core::Error::Config(format!(
                "--exec: expected sim|transport|both, got {other:?}"
            )))
        }
    };
    let out = args.str("out", "trace");
    let prog = sched::channel::split(&generate_for_cli(args, alg, coll, n)?, channels)?;
    let tags = trace_tags(args, alg, coll, n, channels)?;

    // `--size` is the per-rank payload in bytes, divided over the chunks
    // each rank slot is striped into (channel stripes × pipeline
    // segments), rounded up — the same pad semantics as `run`/`simulate`.
    let stripes = (prog.chunk_space() / n.max(1)).max(1);
    let per = (size / 4).div_ceil(stripes).max(1); // f32 elems per sub-chunk
    let total_bytes = n * stripes * per * 4; // full per-rank vector

    fn counters_table(title: &str, trace: &Trace, tags: &ChannelTags) {
        // Critical-path share per (rank, channel): how much of the
        // timed chain's covered time ran on this stream (obs::critpath).
        let share = patcol::obs::critical_path(trace)
            .map(|cp| cp.share)
            .unwrap_or_default();
        let mut t = Table::new([
            "rank", "ch", "tag", "tx msgs", "tx bytes", "rx msgs", "rx bytes", "stall",
            "crit %", "reduces", "pool peak", "arena hw", "allocs",
        ]);
        for (&(r, k), c) in &trace.counters {
            t.row([
                format!("{r}"),
                format!("{k}"),
                tags.tag(k).unwrap_or("-").to_string(),
                format!("{}", c.msgs_sent),
                fmt_bytes(c.bytes_sent),
                format!("{}", c.msgs_recv),
                fmt_bytes(c.bytes_recv),
                fmt_time_s(c.stall_seconds),
                match share.get(&(r, k)) {
                    Some(f) => format!("{:.0}%", f * 100.0),
                    None => "-".to_string(),
                },
                format!("{}", c.reduce_calls),
                format!("{}", c.pool_peak),
                fmt_bytes(c.arena_hw_bytes),
                format!("{}", c.allocs),
            ]);
        }
        println!("{title} per-(rank, channel) counters:");
        print!("{}", t.render());
    }

    println!(
        "{} {} ranks={} payload={}/rank channels={}",
        prog.algorithm,
        coll,
        n,
        fmt_bytes(total_bytes),
        prog.channels,
    );
    let mut written: Vec<String> = Vec::new();

    let mut sim_time = None;
    if want_sim {
        let topo = topology(args, n)?;
        let cost = CostModel::ib_hdr();
        let mut rec = TraceRecorder::new();
        let rep = sim::simulate_observed(&prog, &topo, &cost, per * 4, &mut rec)?;
        let trace = rec.finish();
        let path = format!("{out}.sim.json");
        std::fs::write(&path, chrome_trace(&trace, &tags).to_pretty())?;
        println!("sim trace ({} events) -> {path}", trace.events.len());
        counters_table("sim", &trace, &tags);
        written.push(path);
        sim_time = Some(rep.total_time);
    }

    let mut transport_wall = None;
    if want_transport {
        let opts = TransportOptions { trace: true, ..Default::default() };
        let mut rng = Rng::new(7);
        let mut fill = |len: usize| {
            let mut v = vec![0f32; len];
            rng.fill_f32(&mut v);
            v
        };
        let elems = stripes * per;
        let rep = match coll {
            Collective::AllGather => {
                let inputs: Vec<Vec<f32>> = (0..n).map(|_| fill(elems)).collect();
                run_allgather(&prog, &inputs, &opts)?.1
            }
            Collective::ReduceScatter => {
                let inputs: Vec<Vec<f32>> = (0..n).map(|_| fill(n * elems)).collect();
                run_reduce_scatter(&prog, &inputs, &opts)?.1
            }
            Collective::AllReduce => {
                let total = prog.chunk_space() * per;
                let inputs: Vec<Vec<f32>> = (0..n).map(|_| fill(total)).collect();
                run_allreduce(&prog, &inputs, &opts)?.1
            }
        };
        let trace = rep.trace.as_ref().ok_or_else(|| {
            patcol::core::Error::Transport("transport returned no trace".into())
        })?;
        let path = format!("{out}.transport.json");
        std::fs::write(&path, chrome_trace(trace, &tags).to_pretty())?;
        println!("transport trace ({} events) -> {path}", trace.events.len());
        counters_table("transport", trace, &tags);
        written.push(path);
        transport_wall = Some(rep.wall.as_secs_f64());
    }

    // Träff lower bounds (arXiv:2410.14234) under the default cost model:
    // all-reduce needs 2·⌈log2 n⌉ rounds and 2(n−1)/n of the payload
    // through every NIC; a single phase (AG/RS) needs half of each.
    let tuner = Tuner::default();
    let bound = match coll {
        Collective::AllReduce => tuner.allreduce_lower_bound(n, total_bytes),
        _ if n <= 1 => 0.0,
        _ => {
            let rounds = patcol::core::ceil_log2(n) as f64 * tuner.cost.alpha_base;
            let volume = (n - 1) as f64 / n as f64 * total_bytes as f64 / tuner.nic_bw;
            rounds.max(volume)
        }
    };
    println!(
        "Träff lower bound ({coll}, {} ranks, {} per rank): {}",
        n,
        fmt_bytes(total_bytes),
        fmt_time_s(bound)
    );
    if let Some(t) = sim_time {
        println!(
            "  sim modeled time: {} ({:.2}x bound)",
            fmt_time_s(t),
            t / bound.max(1e-12)
        );
    }
    if let Some(w) = transport_wall {
        println!(
            "  transport wall:   {} (in-process threads; wall clock, not the cost model)",
            fmt_time_s(w)
        );
    }

    if smoke {
        // Round-trip every file we wrote through the JSON parser and check
        // the trace is non-trivial — the CI gate for the exporter.
        for path in &written {
            let j = patcol::util::json::parse(&std::fs::read_to_string(path)?)?;
            let events = j
                .get("traceEvents")
                .and_then(|e| e.as_arr())
                .ok_or_else(|| {
                    patcol::core::Error::Verify(format!("{path}: no traceEvents array"))
                })?;
            if !events.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")) {
                return Err(patcol::core::Error::Verify(format!(
                    "{path}: no complete (ph=X) events in trace"
                )));
            }
        }
        println!("smoke OK: {} trace file(s) round-tripped", written.len());
    }
    Ok(())
}

/// `patcol analyze` — read an exported Chrome trace (either executor's)
/// back through [`patcol::obs::import_chrome_trace`] and report what the
/// timeline *means*: the critical path and its decomposition
/// ([`patcol::obs::critpath`]), the aggregate stall/occupancy metrics
/// ([`patcol::obs::metrics`]), and the elapsed time against Träff's
/// lower bound as an optimality-gap percentage.
fn cmd_analyze(args: &Args) -> Result<()> {
    use patcol::obs::{critical_path, import_chrome_trace, metrics};
    use patcol::util::json::Json;

    let path = args
        .positional()
        .first()
        .cloned()
        .or_else(|| args.opt_str("trace"))
        .ok_or_else(|| {
            patcol::core::Error::Config("usage: patcol analyze TRACE.json [--json]".into())
        })?;
    let doc = patcol::util::json::parse(&std::fs::read_to_string(&path)?)?;
    let trace = import_chrome_trace(&doc)?;
    let cp = critical_path(&trace).ok_or_else(|| {
        patcol::core::Error::Verify(format!("{path}: trace has no op spans to analyze"))
    })?;
    let m = metrics(&trace);

    // World shape: inferred from the trace, overridable for padded or
    // partial captures.
    let inferred_n = trace.events.iter().map(|e| e.rank + 1).max().unwrap_or(1);
    let n = args.usize("ranks", inferred_n)?;
    let coll = parse_collective(&args.str("collective", "ar"))?;
    // Per-rank payload for the lower bound: `--bytes`, or estimated from
    // the recorded wire traffic by inverting the volume convention
    // (all-reduce moves 2(n-1)/n of the payload per NIC, AG/RS (n-1)/n).
    let wire_bytes: usize = trace.counters.values().map(|c| c.bytes_sent).sum();
    let est = if n > 1 {
        let per_rank = wire_bytes / n;
        match coll {
            Collective::AllReduce => per_rank * n / (2 * (n - 1)),
            _ => per_rank * n / (n - 1),
        }
    } else {
        wire_bytes
    };
    let bytes = args.bytes("bytes", est)?;

    let tuner = Tuner::default();
    let bound = match coll {
        Collective::AllReduce => tuner.allreduce_lower_bound(n, bytes),
        _ if n <= 1 => 0.0,
        _ => {
            let rounds = patcol::core::ceil_log2(n) as f64 * tuner.cost.alpha_base;
            let volume = (n - 1) as f64 / n as f64 * bytes as f64 / tuner.nic_bw;
            rounds.max(volume)
        }
    };
    let gap_pct = if bound > 0.0 {
        100.0 * (cp.elapsed - bound) / bound
    } else {
        0.0
    };

    if args.flag("json") {
        let out = Json::obj(vec![
            ("schema_version", Json::num(patcol::obs::SCHEMA_VERSION as f64)),
            ("trace", Json::str(path)),
            ("critical_path", cp.to_json()),
            ("metrics", m.to_json()),
            (
                "optimality",
                Json::obj(vec![
                    ("collective", Json::str(format!("{coll}"))),
                    ("nranks", Json::num(n as f64)),
                    ("bytes_per_rank", Json::num(bytes as f64)),
                    ("lower_bound_s", Json::num(bound)),
                    ("elapsed_s", Json::num(cp.elapsed)),
                    ("gap_pct", Json::num(gap_pct)),
                ]),
            ),
        ]);
        println!("{}", out.to_pretty());
        return Ok(());
    }

    println!(
        "analyze {path}: {} events, {} ranks, {coll}, {} per rank",
        trace.events.len(),
        n,
        fmt_bytes(bytes)
    );
    if trace.dropped > 0 {
        println!("  NOTE: {} events were dropped at capture; figures are partial", trace.dropped);
    }
    println!(
        "critical path: {} ops (structural depth {}), elapsed {}, chain covers {} ({:.1}%)",
        cp.nodes.len(),
        cp.dag_depth,
        fmt_time_s(cp.elapsed),
        fmt_time_s(cp.covered),
        cp.coverage_pct()
    );
    let d = cp.decomp;
    let mut t = Table::new(["bucket", "seconds", "% of elapsed"]);
    let pct = |x: f64| {
        if cp.elapsed > 0.0 {
            format!("{:.1}%", 100.0 * x / cp.elapsed)
        } else {
            "-".to_string()
        }
    };
    for (name, v) in [
        ("send", d.send_s),
        ("wire", d.wire_s),
        ("recv", d.recv_s),
        ("reduce", d.reduce_s),
        ("stall", d.stall_s),
        ("wait", d.wait_s),
    ] {
        t.row([name.to_string(), fmt_time_s(v), pct(v)]);
    }
    print!("{}", t.render());

    let mut st = Table::new(["step", "send", "wire", "recv", "reduce", "stall", "wait"]);
    for (s, d) in &cp.per_step {
        st.row([
            format!("{s}"),
            fmt_time_s(d.send_s),
            fmt_time_s(d.wire_s),
            fmt_time_s(d.recv_s),
            fmt_time_s(d.reduce_s),
            fmt_time_s(d.stall_s),
            fmt_time_s(d.wait_s),
        ]);
    }
    println!("per-step decomposition:");
    print!("{}", st.render());

    // Stall taxonomy: nonzero rows only (every stream has a row; at 64
    // ranks the zero rows are noise), capped for readability.
    let nonzero: Vec<_> = m
        .stalls
        .iter()
        .filter(|(_, s)| s.total() > 0.0)
        .collect();
    println!(
        "stall taxonomy: {} of {} (rank, channel) streams stalled, total {}",
        nonzero.len(),
        m.stalls.len(),
        fmt_time_s(m.stall_total())
    );
    let mut sh = Table::new(["rank", "ch", "warmup", "steady"]);
    for (&(r, k), s) in nonzero.iter().take(20) {
        sh.row([
            format!("{r}"),
            format!("{k}"),
            fmt_time_s(s.warmup_s),
            fmt_time_s(s.steady_s),
        ]);
    }
    print!("{}", sh.render());
    if nonzero.len() > 20 {
        println!("  ... {} more rows (use --json for all)", nonzero.len() - 20);
    }

    if let Some(p) = m.pool {
        println!(
            "pool occupancy (slots): p50={} p90={} p99={} max={} over {} samples",
            p.p50, p.p90, p.p99, p.max, p.samples
        );
    }
    if let Some(a) = m.arena {
        println!(
            "arena occupancy: p50={} p90={} p99={} max={} over {} samples",
            fmt_bytes(a.p50),
            fmt_bytes(a.p90),
            fmt_bytes(a.p99),
            fmt_bytes(a.max),
            a.samples
        );
    }
    println!(
        "Träff lower bound ({coll}, {n} ranks, {} per rank): {} → gap {:+.1}%",
        fmt_bytes(bytes),
        fmt_time_s(bound),
        gap_pct
    );
    Ok(())
}

/// `patcol baseline` — compare a freshly written bench-baseline document
/// against the committed one ([`patcol::obs::baseline::check`]); exits
/// nonzero on any regression. The CI bench-baseline job's gate.
fn cmd_baseline(args: &Args) -> Result<()> {
    use patcol::obs::baseline;
    use std::path::Path;

    let current = args
        .opt_str("current")
        .or_else(|| args.positional().first().cloned())
        .ok_or_else(|| {
            patcol::core::Error::Config(
                "usage: patcol baseline --current NEW.json [--committed BENCH_8.json]".into(),
            )
        })?;
    let committed = args.str("committed", "BENCH_8.json");
    let cur = baseline::load(Path::new(&current))?;
    let base = baseline::load(Path::new(&committed))?;
    match (baseline::reduce_path_ratio(&cur), baseline::reduce_path_ratio(&base)) {
        (Some(c), Some(b)) => {
            println!("reduce-path slice@2/owned ratio: {c:.2} (committed {b:.2})")
        }
        (Some(c), None) => println!("reduce-path slice@2/owned ratio: {c:.2} (no committed figure)"),
        _ => {}
    }
    let base_gaps = baseline::optimality_gaps(&base);
    for (k, v) in baseline::optimality_gaps(&cur) {
        match base_gaps.iter().find(|(bk, _)| *bk == k) {
            Some((_, b)) => println!("{k}: {v:.2}% (committed {b:.2}%)"),
            None => println!("{k}: {v:.2}% (no committed figure)"),
        }
    }
    let fails = baseline::check(&cur, &base);
    if fails.is_empty() {
        println!("baseline OK: {current} vs {committed}");
        Ok(())
    } else {
        for f in &fails {
            eprintln!("REGRESSION: {f}");
        }
        Err(patcol::core::Error::Verify(format!(
            "{} baseline regression(s) vs {committed}",
            fails.len()
        )))
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let sizes = args.bytes_list(
        "sizes",
        &[256, 4 << 10, 64 << 10, 1 << 20, 16 << 20],
    )?;
    let coll = collective(args)?;
    let topo = topology(args, n)?;
    let cost = CostModel::ib_hdr();
    // The hier_pat column is only honest if its intra-node traffic really
    // stays under one switch — same validation as `simulate`.
    topo.check_placement(&placement_or_default(args, n)?)?;
    let algs: Vec<Algorithm> = vec![
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
        Algorithm::Pat { aggregation: usize::MAX },
        Algorithm::Pat { aggregation: 4 },
        Algorithm::Pat { aggregation: 1 },
        Algorithm::HierPat { aggregation: usize::MAX },
    ];
    let header: Vec<String> = std::iter::once("size".to_string())
        .chain(algs.iter().map(|a| a.name()))
        .collect();
    let mut t = Table::new(header);
    for size in sizes {
        let mut row = vec![fmt_bytes(size)];
        for alg in &algs {
            let prog = generate_for_cli(args, *alg, coll, n)?;
            let rep = sim::simulate(&prog, &topo, &cost, size)?;
            row.push(fmt_time_s(rep.total_time));
        }
        t.row(row);
    }
    println!("{} on {} ({} ranks):", coll, topo.name, n);
    print!("{}", t.render());
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let size = args.bytes("size", 64 * 1024)?;
    let slots = args.usize("buffer-slots", 64)?;
    let coll = collective(args)?;
    let inter_gbps = args.f64("inter-gbps", 0.0)?;
    let links = args.usize("parallel-links", 1)?.max(1);
    let tuner = Tuner {
        inter_bw: if inter_gbps > 0.0 { Some(inter_gbps * 1e9) } else { None },
        parallel_links: links,
        ..Tuner::default()
    };
    let placement = placement_opt(args, n)?;
    let choice = if coll == Collective::AllReduce {
        // --size is the per-rank payload; the all-reduce sweep costs
        // candidates at the single-segment per-chunk size (size / n),
        // matching Communicator::all_reduce_report's resolution.
        tuner.choose_allreduce(n, (size / n.max(1)).max(1), slots, placement.as_ref())
    } else {
        tuner.choose_placed(n, size, slots, coll, placement.as_ref())
    };
    println!(
        "tune: ranks={n} chunk={} buffer_slots={slots} {coll}{}",
        fmt_bytes(size),
        match &placement {
            Some(p) => format!(" [{}]", p.describe()),
            None => String::new(),
        }
    );
    let mut t = Table::new(["algorithm", "predicted"]);
    for (alg, cost) in &choice.candidates {
        t.row([alg.name(), fmt_time_s(*cost)]);
    }
    print!("{}", t.render());
    // Channel-count crossover at the chosen algorithm's aggregation:
    // latency tax × C vs bandwidth ÷ min(C, parallel links).
    let agg = match choice.algorithm {
        Algorithm::Pat { aggregation } | Algorithm::HierPat { aggregation } => aggregation,
        _ => usize::MAX,
    };
    let ch = tuner.choose_channels(n, agg, size);
    let mut ct = Table::new(["channels", "predicted"]);
    for (c, cost) in &ch.candidates {
        ct.row([format!("{c}"), fmt_time_s(*cost)]);
    }
    print!("{}", ct.render());
    if coll == Collective::AllReduce {
        // Gradient-bucket crossover: bucket count × (equal | ramp-shaped
        // first bucket) under the pipelined closed form, floored at the
        // non-pipelined round/volume lower bound.
        let bc = tuner.choose_bucketed(n, size, slots, placement.as_ref());
        let mut bt = Table::new(["buckets", "shape", "predicted"]);
        for (b, ramp, cost) in &bc.candidates {
            bt.row([
                format!("{b}"),
                (if *ramp { "ramp" } else { "equal" }).to_string(),
                fmt_time_s(*cost),
            ]);
        }
        print!("{}", bt.render());
        println!(
            "bucketing: {} buckets, first {}",
            bc.bucket_bytes.len(),
            fmt_bytes(bc.bucket_bytes.first().copied().unwrap_or(0)),
        );
    }
    println!(
        "chosen: {} channels={} (parallel_links={links})",
        choice.algorithm, ch.channels
    );
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let max = args.usize("max-ranks", 33)?;
    let mut count = 0usize;
    for n in 1..=max {
        for alg in [
            Algorithm::Ring,
            Algorithm::BruckNearFirst,
            Algorithm::BruckFarFirst,
            Algorithm::Recursive,
            Algorithm::Pat { aggregation: 1 },
            Algorithm::Pat { aggregation: 2 },
            Algorithm::Pat { aggregation: 7 },
            Algorithm::Pat { aggregation: usize::MAX },
            Algorithm::HierPat { aggregation: 2 },
            Algorithm::HierPat { aggregation: usize::MAX },
        ] {
            if !alg.supports(n) {
                continue;
            }
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let prog = sched::generate(alg, coll, n)?;
                sched::verify::verify_program(&prog).map_err(|e| {
                    patcol::core::Error::Verify(format!("{alg} {coll} n={n}: {e}"))
                })?;
                count += 1;
            }
        }
    }
    // All-reduce compositions: mixed pairs × segment counts.
    for n in 2..=max.min(17) {
        for spec in ["pat+pat", "pat:2+ring:2", "ring+pat:4", "hier_pat:2+pat:2"] {
            let alg = Algorithm::parse(spec)?;
            for segments in [1usize, 2, 4] {
                let alg = match alg {
                    Algorithm::Compose { rs, ag, .. } => {
                        Algorithm::Compose { rs, ag, segments }
                    }
                    other => other,
                };
                let prog = sched::generate(alg, Collective::AllReduce, n)?;
                sched::verify::verify_program(&prog).map_err(|e| {
                    patcol::core::Error::Verify(format!("{alg} all_reduce n={n}: {e}"))
                })?;
                count += 1;
            }
        }
    }
    // Channel-split axis: primitive collectives sharded across channels.
    for n in [2usize, 5, 8, 16, 33] {
        if n > max {
            continue;
        }
        for alg in [Algorithm::Ring, Algorithm::Pat { aggregation: 2 }] {
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let base = sched::generate(alg, coll, n)?;
                for c in [2usize, 4] {
                    let p = sched::channel::split(&base, c)?;
                    sched::verify::verify_program(&p).map_err(|e| {
                        patcol::core::Error::Verify(format!("{alg}*{c} {coll} n={n}: {e}"))
                    })?;
                    count += 1;
                }
            }
        }
    }
    // Bucketed axis: back-to-back all-reduces fused into one program.
    for n in [2usize, 5, 8, 16, 33] {
        if n > max {
            continue;
        }
        let rsp = sched::generate(
            Algorithm::Pat { aggregation: 2 },
            Collective::ReduceScatter,
            n,
        )?;
        let agp = sched::generate(Algorithm::Pat { aggregation: 2 }, Collective::AllGather, n)?;
        for b in [2usize, 4] {
            let p = sched::bucket::fuse(&sched::bucket::uniform(&rsp, &agp, b, 1))?;
            sched::verify::verify_program(&p).map_err(|e| {
                patcol::core::Error::Verify(format!("bkt{b}(pat:2+pat:2) n={n}: {e}"))
            })?;
            count += 1;
        }
    }
    // Spot-check PAT tree phases against the paper's figures.
    assert_eq!(pat::phase_counts(8, 2), (1, 3));
    assert_eq!(pat::phase_counts(16, 2), (1, 7));
    println!("selftest OK: {count} (algorithm, collective, nranks) cases verified");
    Ok(())
}

/// `patcol adversary` — schedule-exploration episodes, trace replay,
/// and the CI smoke matrix. See `crate::adversary` (library side) for
/// the episode/shrink machinery.
fn cmd_adversary(args: &Args) -> Result<()> {
    use patcol::adversary::{self, PolicySpec, Preset, ReplayTrace, Workload};
    use patcol::core::Error;

    // Replay mode first: replay() arms the trace's own recorded
    // sentinel, so --sentinel must not also hold the sentinel lock here.
    if let Some(path) = args.opt_str("replay") {
        if args.opt_str("sentinel").is_some() {
            return Err(Error::Config(
                "--replay uses the trace's recorded sentinel; drop --sentinel".into(),
            ));
        }
        let trace = ReplayTrace::load(std::path::Path::new(&path))?;
        println!(
            "replay {path}: {} · {} deviations · sentinel {}",
            trace.workload.describe(),
            trace.deviations.len(),
            trace.sentinel.as_deref().unwrap_or("none"),
        );
        return match adversary::replay(&trace)? {
            Some(f) if f.blame == trace.blame => {
                println!("reproduced: {}", f.blame.describe());
                Ok(())
            }
            Some(f) => Err(Error::Verify(format!(
                "blame mismatch: recorded [{}] but replay produced [{}]",
                trace.blame.describe(),
                f.blame.describe()
            ))),
            None => Err(Error::Verify(format!(
                "replay produced no failure (recorded [{}])",
                trace.blame.describe()
            ))),
        };
    }

    // Optionally arm a transport mutation sentinel for the whole sweep
    // (demonstrates the harness catching a real invariant violation).
    // The sentinels only exist under cfg(test) or --features adversary.
    #[cfg(feature = "adversary")]
    let _armed = match args.opt_str("sentinel") {
        Some(s) => {
            use patcol::transport::delivery::sentinel;
            Some(sentinel::arm(sentinel::Sentinel::parse(&s)?))
        }
        None => None,
    };
    #[cfg(not(feature = "adversary"))]
    if args.opt_str("sentinel").is_some() {
        return Err(Error::Config(
            "--sentinel needs the mutation sentinels: rebuild with --features adversary".into(),
        ));
    }

    let seed = args.usize("seed", 1)? as u64;
    let mut policy = PolicySpec::parse(&args.str("policy", "reorder"))?;
    if policy.seed == 0 {
        policy.seed = seed;
    }
    let episodes = args.usize("episodes", if args.flag("smoke") { 200 } else { 64 })? as u64;
    let out = args.str("out", "adversary_trace.json");

    if args.flag("smoke") {
        // The CI matrix: small points across rank count × algorithm ×
        // channels × collective, total episode budget split across them.
        let mut points = Vec::new();
        for &(n, alg) in &[(4usize, "ring"), (4, "pat:2"), (8, "ring"), (8, "pat:2")] {
            for c in [1usize, 2] {
                for coll in [Collective::AllGather, Collective::ReduceScatter] {
                    let spec = AlgSpec::parse(&format!("{alg}*{c}"))?;
                    points.push(Workload::new(coll, spec, n, 64, seed));
                }
            }
        }
        let per = (episodes / points.len() as u64).max(1);
        let mut ran = 0u64;
        let mut failures = 0usize;
        for (i, w) in points.iter().enumerate() {
            let pol = PolicySpec {
                preset: if i % 2 == 0 { Preset::Delay } else { Preset::Reorder },
                seed: seed.wrapping_add(i as u64),
            };
            let rep = adversary::explore(w, &pol, per, None)?;
            ran += rep.episodes_run;
            failures += rep.failures;
            println!(
                "  {} policy={}: {} episodes, {} failures ({} timeouts skipped)",
                w.describe(),
                pol.spec(),
                rep.episodes_run,
                rep.failures,
                rep.timeouts_skipped
            );
            if let Some(ce) = &rep.counterexample {
                ce.save(std::path::Path::new(&out))?;
                return Err(Error::Verify(format!(
                    "adversary smoke found a counterexample [{}]; shrunk trace -> {out}",
                    ce.blame.describe()
                )));
            }
        }
        println!(
            "adversary smoke clean: {ran} episodes over {} points, {failures} failures, \
             0 counterexamples",
            points.len()
        );
        return Ok(());
    }

    let coll = parse_collective(&args.str("collective", "ag"))?;
    let (alg_opt, channels) = alg_channels(args)?;
    let alg = alg_opt.unwrap_or(Algorithm::Pat { aggregation: 2 });
    let spec = AlgSpec { alg, channels: channels.unwrap_or(1) };
    let n = args.usize("ranks", 8)?;
    let elems = args.usize("elems", 64)?;
    let w = Workload::new(coll, spec, n, elems, seed);

    let mut rec = args
        .opt_str("trace")
        .map(|p| (p, patcol::obs::TraceRecorder::new()));
    let report = adversary::explore(&w, &policy, episodes, rec.as_mut().map(|(_, r)| r))?;
    println!(
        "{} policy={}: {} episodes, {} failures ({} timeouts skipped), \
         {} deviations over {} decisions",
        w.describe(),
        policy.spec(),
        report.episodes_run,
        report.failures,
        report.timeouts_skipped,
        report.total_deviations,
        report.total_decisions
    );
    if let Some((path, r)) = rec {
        let trace = r.finish();
        std::fs::write(
            &path,
            patcol::obs::chrome_trace(&trace, &patcol::obs::ChannelTags::plain()).to_pretty(),
        )?;
        println!("episode/shrink trace ({} events) -> {path}", trace.events.len());
    }
    match &report.counterexample {
        Some(ce) => {
            ce.save(std::path::Path::new(&out))?;
            println!(
                "counterexample at episode {}: {} ({} -> {} deviations in {} shrink trials)",
                ce.episode,
                ce.blame.describe(),
                ce.initial_deviations,
                ce.deviations.len(),
                ce.shrink_trials
            );
            Err(Error::Verify(format!(
                "adversarial schedule broke the transport; shrunk replayable trace -> {out}"
            )))
        }
        None => {
            println!("no counterexample found");
            Ok(())
        }
    }
}
