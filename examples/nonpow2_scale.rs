//! Non-power-of-two rank counts (paper claim P4 / Fig. 4).
//!
//! Recursive doubling requires power-of-two ranks — "a significant
//! constraint … given a large portion of the AI use cases do not use a
//! power of two as their data-parallelism dimension". PAT works on any
//! count via truncated binomial trees. This example runs real-byte
//! collectives on awkward counts and compares simulated latency against
//! ring at scale.
//!
//!     cargo run --release --example nonpow2_scale

use patcol::coordinator::{CommConfig, Communicator};
use patcol::core::{Algorithm, Collective};
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::table::{fmt_time_s, Table};
use patcol::util::Rng;

fn main() -> patcol::core::Result<()> {
    // --- correctness on real bytes for awkward counts ---------------------
    println!("transport correctness on non-power-of-two rank counts:");
    let chunk = 512;
    for n in [3usize, 5, 6, 7, 9, 11, 13, 23] {
        // recursive doubling refuses
        assert!(sched::generate(Algorithm::Recursive, Collective::AllGather, n).is_err());

        let comm = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 4 }),
            ..Default::default()
        })?;
        let mut rng = Rng::new(n as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * chunk).map(|_| rng.below(100) as f32).collect())
            .collect();
        let out = comm.reduce_scatter(&inputs)?;
        for r in 0..n {
            for i in 0..chunk {
                let want: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                assert_eq!(out[r][i], want);
            }
        }
        println!("  n={n:>3}: reduce-scatter exact (recursive-doubling: unsupported)");
    }

    // --- simulated latency at scale, awkward counts -----------------------
    println!("\nsimulated small-message all-gather latency (1 KiB/rank, flat fabric):");
    let cost = CostModel::ib_hdr();
    let mut t = Table::new(["ranks", "ring", "pat(full)", "pat:4", "speedup"]);
    for n in [48usize, 96, 192, 384, 768, 1000] {
        let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
        let ring = simulate(
            &sched::generate(Algorithm::Ring, Collective::AllGather, n)?,
            &topo,
            &cost,
            1024,
        )?
        .total_time;
        let patf = simulate(
            &sched::generate(
                Algorithm::Pat { aggregation: usize::MAX },
                Collective::AllGather,
                n,
            )?,
            &topo,
            &cost,
            1024,
        )?
        .total_time;
        let pat4 = simulate(
            &sched::generate(Algorithm::Pat { aggregation: 4 }, Collective::AllGather, n)?,
            &topo,
            &cost,
            1024,
        )?
        .total_time;
        t.row([
            format!("{n}"),
            fmt_time_s(ring),
            fmt_time_s(patf),
            fmt_time_s(pat4),
            format!("{:.1}x", ring / patf),
        ]);
    }
    print!("{}", t.render());
    println!("(speedup = ring / pat(full); grows ~n/log n as the paper predicts)");
    Ok(())
}
