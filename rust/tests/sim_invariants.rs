//! Simulator invariants: conservation, monotonicity, contention and
//! routing properties that must hold for any schedule on any topology.

use patcol::core::{Algorithm, Collective};
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::Rng;

fn topos(n: usize) -> Vec<Topology> {
    let nic = 25e9;
    let mut v = vec![Topology::flat(n, nic)];
    if n % 8 == 0 {
        v.push(Topology::leaf_spine(n, 8, 4, nic, 0.5).unwrap());
        v.push(Topology::dragonfly(n, 8, nic, 12.5e9).unwrap());
    }
    if n % 16 == 0 {
        v.push(Topology::three_level(n, 4, 4, 4, 2, nic, 1.0, 0.5).unwrap());
    }
    v
}

/// Bytes injected = messages × payload for single-chunk schedules; level
/// accounting partitions total bytes.
#[test]
fn byte_conservation_across_topologies() {
    let n = 32;
    for topo in topos(n) {
        for alg in [Algorithm::Ring, Algorithm::Pat { aggregation: 4 }] {
            let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
            let rep = simulate(&prog, &topo, &CostModel::ideal(), 128).unwrap();
            let expect: usize = prog
                .messages()
                .iter()
                .map(|m| m.chunks.len() * 128)
                .sum();
            assert_eq!(rep.bytes_sent, expect, "{} {}", topo.name, alg);
            assert_eq!(
                rep.bytes_by_level.iter().sum::<usize>(),
                rep.bytes_sent,
                "{} {}",
                topo.name,
                alg
            );
        }
    }
}

/// Simulated time grows monotonically with chunk size and with every cost
/// parameter.
#[test]
fn monotonicity() {
    let n = 16;
    let topo = Topology::flat(n, 25e9);
    let prog = sched::generate(Algorithm::Pat { aggregation: 2 }, Collective::AllGather, n)
        .unwrap();
    let base = CostModel::ib_hdr();
    let t0 = simulate(&prog, &topo, &base, 1024).unwrap().total_time;
    // size up
    let t_big = simulate(&prog, &topo, &base, 64 * 1024).unwrap().total_time;
    assert!(t_big > t0);
    // each knob up
    for knob in 0..4 {
        let mut c = base;
        match knob {
            0 => c.alpha_base *= 10.0,
            1 => c.alpha_hop *= 100.0,
            2 => c.gamma_chunk *= 100.0,
            _ => c.msg_gap *= 1000.0,
        }
        let t = simulate(&prog, &topo, &c, 1024).unwrap().total_time;
        assert!(t >= t0, "knob {knob}: {t} < {t0}");
    }
}

/// A tapered fabric is never faster than the full-bisection one.
#[test]
fn taper_never_helps() {
    let n = 64;
    let full = Topology::leaf_spine(n, 8, 8, 25e9, 1.0).unwrap();
    let tapered = Topology::leaf_spine(n, 8, 2, 25e9, 0.25).unwrap();
    for alg in [
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
        Algorithm::Pat { aggregation: 4 },
    ] {
        let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
        let tf = simulate(&prog, &full, &CostModel::ib_hdr(), 64 << 10)
            .unwrap()
            .total_time;
        let tt = simulate(&prog, &tapered, &CostModel::ib_hdr(), 64 << 10)
            .unwrap()
            .total_time;
        assert!(tt >= tf * 0.999, "{alg}: tapered {tt} < full {tf}");
    }
}

/// Static routing: repeated simulation is bit-identical (determinism), and
/// routes do not depend on call order.
#[test]
fn deterministic_simulation() {
    let n = 48;
    let topo = Topology::leaf_spine(n, 8, 4, 25e9, 0.5).unwrap();
    let prog = sched::generate(Algorithm::BruckNearFirst, Collective::AllGather, n).unwrap();
    let a = simulate(&prog, &topo, &CostModel::ib_hdr(), 4096).unwrap();
    let b = simulate(&prog, &topo, &CostModel::ib_hdr(), 4096).unwrap();
    assert_eq!(a.total_time, b.total_time);
    assert_eq!(a.finish, b.finish);
    assert_eq!(a.max_link_bytes, b.max_link_bytes);
}

/// Reduce-scatter simulation accounts reduction cost.
#[test]
fn rs_costs_more_than_ag_with_reduce_cost() {
    let n = 16;
    let topo = Topology::flat(n, 25e9);
    let mut cost = CostModel::ib_hdr();
    cost.reduce_byte = 1.0 / 1e9; // expensive reduction
    let ag = sched::generate(Algorithm::Pat { aggregation: 4 }, Collective::AllGather, n)
        .unwrap();
    let rs = sched::generate(
        Algorithm::Pat { aggregation: 4 },
        Collective::ReduceScatter,
        n,
    )
    .unwrap();
    let t_ag = simulate(&ag, &topo, &cost, 256 << 10).unwrap().total_time;
    let t_rs = simulate(&rs, &topo, &cost, 256 << 10).unwrap().total_time;
    assert!(t_rs > t_ag, "rs {t_rs} should exceed ag {t_ag}");
}

/// Random schedules through random topologies never panic and never stall
/// (verified generators only).
#[test]
fn random_sweep_never_stalls() {
    let mut rng = Rng::new(77);
    for _ in 0..40 {
        let n = 8 * rng.range(1, 6); // 8..48, divisible by 8
        let algs = [
            Algorithm::Ring,
            Algorithm::BruckFarFirst,
            Algorithm::Pat { aggregation: rng.range(1, 9) },
        ];
        let alg = algs[rng.below(algs.len())];
        let coll = if rng.chance(0.5) {
            Collective::AllGather
        } else {
            Collective::ReduceScatter
        };
        let prog = sched::generate(alg, coll, n).unwrap();
        for topo in topos(n) {
            let rep = simulate(&prog, &topo, &CostModel::ib_hdr(), 512).unwrap();
            assert!(rep.total_time.is_finite() && rep.total_time > 0.0);
        }
    }
}
