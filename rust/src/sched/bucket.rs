//! Bucketed multi-collective fusion: a stream of back-to-back all-reduce
//! *operations* (gradient buckets) fused into ONE multi-channel
//! [`Program`], pipelined so bucket `i+1`'s reduce-scatter overlaps bucket
//! `i`'s all-gather.
//!
//! The dominant real workload with PAT's small-message shape is
//! data-parallel training traffic: frameworks chop the gradient into
//! *buckets* and launch one all-reduce per bucket as soon as its backward
//! slice is ready — a chain of medium-sized operations, not one large one.
//! Run naively, each operation pays its full latency chain back to back
//! and imbalanced per-operation arrival leaves the fabric idle between
//! them (Proficz, arXiv:1804.05349). This module generalizes the
//! composer's *segment* pipelining ([`crate::sched::compose`]) across
//! **operations**: where compose splits one payload into `S` segments,
//! the bucket fuser takes `B` independent all-reduce requests — sizes may
//! differ, per-bucket segment counts may differ, even the phase
//! generators may differ per bucket — and emits one fused program.
//!
//! ## Construction (all machinery reused, none re-derived)
//!
//! * **Chunk-space renaming per bucket** — bucket `b` occupies chunk ids
//!   `[chunk_base_b, chunk_base_b + S_b·n)`; every base is a multiple of
//!   `n`, so ownership stays `id mod n` and the verifier / transport /
//!   simulator execute all buckets through the same state machines that
//!   run a single composed all-reduce (the concatenated chunk space *is*
//!   the per-bucket reduction semantics: the reference executor checks
//!   exact sums chunk by chunk, which is per-bucket correctness).
//! * **Step staggering across operations** — bucket `b+1`'s first
//!   reduce-scatter shares its global step range with bucket `b`'s last
//!   all-gather, exactly the compose stagger lifted one level up. With
//!   uniform buckets of one segment each, the fused program is
//!   op-for-op identical to [`crate::sched::compose::fuse`]`(rs, ag, B)`
//!   (asserted by
//!   the regression test below) — buckets *are* the segments of a virtual
//!   concatenated operation; the generalization is that they no longer
//!   have to be equal slices of one payload.
//! * **FIFO-safe stream merging** — each rank's fused op list is one
//!   [`crate::sched::channel::merge_rank_streams`] merge over all
//!   `Σ_b 2·S_b` phase streams, built in the same (bucket, segment,
//!   phase) order on every rank. The merge key `(global step, stream
//!   index)` is identical at both endpoints of every connection, so the
//!   k-th send on a channel still faces the k-th recv — the channel
//!   module's FIFO argument applies verbatim.
//! * **Per-bucket channel assignment** — (bucket `b`, segment `s`) runs on
//!   channel `channel_base_b + s`. Every bucket gets its own NCCL-style
//!   connections with their own statically-hashed ECMP flows, so
//!   concurrent buckets recruit parallel spines/cores instead of queueing
//!   behind one flow (see [`crate::sim`]'s channel-salted router).
//!
//! Unequal bucket *sizes* live outside the IR: the program only names
//! chunk ids; per-chunk element counts come from [`BucketLayout`] and are
//! consumed by [`crate::transport::run_allreduce_batch`] (real bytes) and
//! `crate::sim::simulate_sized` (per-chunk byte costs). A ramp-shaped
//! schedule (smaller first bucket, filling the pipeline faster — the
//! classic answer to the composer's open unequal-segment-sizes item) is
//! just a size vector; see `crate::coordinator::tuner::bucket_sizes`.
//!
//! ## Cross-bucket channel striping
//!
//! Latency-bound small buckets want one channel (each extra channel costs
//! a full per-round message tax); bandwidth-bound big buckets want
//! several (each channel is its own ECMP flow and can recruit its own
//! fabric rail). [`channel::split`] on the fused program can only stripe
//! *every* bucket uniformly. [`fuse_striped`] stripes per bucket: bucket
//! `b` with `stripes_b` copies runs each pipeline segment as `stripes_b`
//! side-by-side streams — each on its own channel, each owning a disjoint
//! mod-`n` chunk range carrying `1/stripes_b` of the segment payload
//! (exactly the [`channel::split`] contract, applied selectively).
//! [`stripe_plan`] picks the vector: extra channels only for buckets at
//! or above a byte threshold.

use crate::core::{ChunkId, Collective, Error, Result};
use crate::sched::channel;
use crate::sched::compose::{Layout, Phase};
use crate::sched::program::Program;

/// One bucket of the batch: its two phase programs and how many pipeline
/// segments to split it into internally (1 = the bucket is the pipeline
/// unit; bucket- and segment-level pipelining compose).
#[derive(Debug, Clone)]
pub struct BucketPhases {
    /// Reduce-scatter phase program (single-channel).
    pub rs: Program,
    /// All-gather phase program (single-channel).
    pub ag: Program,
    /// Pipeline segments within this bucket (>= 1).
    pub segments: usize,
}

/// `nbuckets` identical buckets over one (rs, ag) phase pair — the common
/// uniform gradient-bucket case, and the shape that coincides with
/// [`crate::sched::compose::fuse`]'s segment pipelining.
pub fn uniform(rs: &Program, ag: &Program, nbuckets: usize, segments: usize) -> Vec<BucketPhases> {
    (0..nbuckets)
        .map(|_| BucketPhases { rs: rs.clone(), ag: ag.clone(), segments })
        .collect()
}

/// Where each bucket of a fused program sits on the global step, chunk and
/// channel grids. Built by [`BucketLayout::of`] from the same bucket list
/// handed to [`fuse`]; the executors use it to map per-bucket payload
/// sizes onto chunk ids and to attribute simulated time back to buckets.
#[derive(Debug, Clone)]
pub struct BucketLayout {
    pub nranks: usize,
    /// Per-bucket compose layout (segment step grid within the bucket).
    pub per_bucket: Vec<Layout>,
    /// Channel stripes per bucket (all ones unless built by
    /// [`BucketLayout::of_striped`]; see [`fuse_striped`]).
    pub stripes: Vec<usize>,
    /// Global step at which each bucket's first reduce-scatter starts.
    pub step_base: Vec<usize>,
    /// First chunk id of each bucket (always a multiple of `nranks`).
    pub chunk_base: Vec<usize>,
    /// First channel of each bucket (bucket `b` spans
    /// `segments_b · stripes_b` channels).
    pub channel_base: Vec<usize>,
}

impl BucketLayout {
    /// Layout of [`fuse`]`(buckets)` without building the fused program.
    pub fn of(buckets: &[BucketPhases]) -> BucketLayout {
        Self::of_striped(buckets, &vec![1; buckets.len()])
    }

    /// Layout of [`fuse_striped`]`(buckets, stripes)` without building the
    /// fused program. `stripes` must be per-bucket and all `>= 1`.
    pub fn of_striped(buckets: &[BucketPhases], stripes: &[usize]) -> BucketLayout {
        debug_assert_eq!(buckets.len(), stripes.len());
        let nranks = buckets.first().map(|b| b.rs.nranks).unwrap_or(0);
        let mut per_bucket = Vec::with_capacity(buckets.len());
        let mut step_base = Vec::with_capacity(buckets.len());
        let mut chunk_base = Vec::with_capacity(buckets.len());
        let mut channel_base = Vec::with_capacity(buckets.len());
        let (mut step, mut chunk, mut chan) = (0usize, 0usize, 0usize);
        for (b, st) in buckets.iter().zip(stripes) {
            let lay = Layout::of(&b.rs, &b.ag, b.segments);
            step_base.push(step);
            chunk_base.push(chunk);
            channel_base.push(chan);
            // The next bucket starts where this bucket's *last* segment's
            // all-gather starts, so the two share a step range — the
            // cross-operation overlap. Stripes share their segment's step
            // span (they run side by side on their own channels), so the
            // stagger grid does not see them.
            step += b.segments * lay.rs_steps;
            chunk += b.segments * st * nranks;
            chan += b.segments * st;
            per_bucket.push(lay);
        }
        BucketLayout {
            nranks,
            per_bucket,
            stripes: stripes.to_vec(),
            step_base,
            chunk_base,
            channel_base,
        }
    }

    pub fn nbuckets(&self) -> usize {
        self.per_bucket.len()
    }

    /// Total chunk id space of the fused program
    /// (`Σ_b segments_b · stripes_b · n`).
    pub fn chunk_space(&self) -> usize {
        match (self.chunk_base.last(), self.per_bucket.last(), self.stripes.last()) {
            (Some(&base), Some(lay), Some(&st)) => base + lay.segments * st * self.nranks,
            _ => 0,
        }
    }

    /// Total channel count of the fused program
    /// (`Σ_b segments_b · stripes_b`).
    pub fn channels(&self) -> usize {
        match (self.channel_base.last(), self.per_bucket.last(), self.stripes.last()) {
            (Some(&base), Some(lay), Some(&st)) => base + lay.segments * st,
            _ => 0,
        }
    }

    /// Global channel range `[start, end)` owned by `bucket`.
    pub fn channel_range(&self, bucket: usize) -> (usize, usize) {
        let lo = self.channel_base[bucket];
        (lo, lo + self.per_bucket[bucket].segments * self.stripes[bucket])
    }

    /// Global step range `[start, end)` of `bucket` (first segment's
    /// reduce-scatter through last segment's all-gather). Adjacent buckets
    /// overlap by construction.
    pub fn step_span(&self, bucket: usize) -> (usize, usize) {
        let lay = &self.per_bucket[bucket];
        let (_, end) = lay.span(lay.segments - 1, Phase::AllGather);
        (self.step_base[bucket], self.step_base[bucket] + end)
    }

    /// Which bucket a chunk id belongs to.
    pub fn bucket_of_chunk(&self, chunk: ChunkId) -> usize {
        match self.chunk_base.binary_search(&chunk) {
            Ok(b) => b,
            Err(ins) => ins.saturating_sub(1),
        }
    }

    /// Per-chunk element counts for the whole fused chunk space, given the
    /// per-chunk element count of each bucket (`elems[b]` = elements in
    /// one of bucket `b`'s `segments_b · stripes_b · n` chunks). This is
    /// the grid [`crate::transport::run_allreduce_batch`] executes, and ×
    /// `dtype size` the per-chunk byte vector `crate::sim::simulate_sized`
    /// costs.
    pub fn chunk_elems(&self, elems: &[usize]) -> Vec<usize> {
        debug_assert_eq!(elems.len(), self.nbuckets());
        let mut out = Vec::with_capacity(self.chunk_space());
        for (b, lay) in self.per_bucket.iter().enumerate() {
            out.resize(out.len() + lay.segments * self.stripes[b] * self.nranks, elems[b]);
        }
        out
    }
}

/// The wall-clock window one bucket occupied in a simulation — built from
/// the simulator's per-channel spans (`crate::sim::SimReport::channel_spans`),
/// since each bucket owns a disjoint channel range. Inter-bucket overlap
/// (bucket `i+1` starting before bucket `i` ends) is directly visible.
#[derive(Debug, Clone, PartialEq)]
pub struct BucketWindow {
    pub bucket: usize,
    /// Global step range `[start, end)`.
    pub steps: (usize, usize),
    /// Earliest link-serialization start of any of the bucket's messages.
    pub t_start: f64,
    /// Latest arrival of any of the bucket's messages.
    pub t_end: f64,
}

/// Aggregate per-channel `(start, end)` spans into per-bucket windows.
/// Channels with no traffic (the simulator's `(+inf, -inf)` sentinel) are
/// skipped; buckets with no traffic at all are omitted.
pub fn bucket_windows(layout: &BucketLayout, channel_spans: &[(f64, f64)]) -> Vec<BucketWindow> {
    let mut out = Vec::new();
    for b in 0..layout.nbuckets() {
        let (lo, hi) = layout.channel_range(b);
        let mut t_start = f64::INFINITY;
        let mut t_end = f64::NEG_INFINITY;
        for &(s, e) in channel_spans.iter().take(hi).skip(lo) {
            if s.is_finite() {
                t_start = t_start.min(s);
                t_end = t_end.max(e);
            }
        }
        if t_start.is_finite() {
            out.push(BucketWindow { bucket: b, steps: layout.step_span(b), t_start, t_end });
        }
    }
    out
}

/// Pick per-bucket channel stripe counts from per-bucket payload bytes:
/// buckets at or above `threshold_bytes` get `channels` stripes (their
/// extra ECMP flows), smaller buckets stay on one channel and skip the
/// per-round channel tax. Feed the result to [`fuse_striped`].
pub fn stripe_plan(bucket_bytes: &[usize], threshold_bytes: usize, channels: usize) -> Vec<usize> {
    let c = channels.max(1);
    bucket_bytes
        .iter()
        .map(|&b| if c > 1 && b >= threshold_bytes { c } else { 1 })
        .collect()
}

/// Fuse a batch of per-bucket all-reduce requests into one pipelined
/// multi-channel all-reduce program (see the module docs for the
/// construction and the FIFO argument). All buckets must share the rank
/// count; phase programs must be single-channel (apply
/// [`channel::split`] to the *fused* program — channels compose that
/// way, exactly as for [`crate::sched::compose::fuse`]).
pub fn fuse(buckets: &[BucketPhases]) -> Result<Program> {
    fuse_striped(buckets, &vec![1; buckets.len()])
}

/// [`fuse`] with per-bucket channel striping (see the module docs):
/// bucket `b` runs each of its segments as `stripes[b]` side-by-side
/// copies, each on its own channel over its own mod-`n` chunk range, each
/// carrying `1/stripes[b]` of the segment payload (the executors see that
/// through [`BucketLayout::chunk_elems`] — the caller divides bucket
/// `b`'s per-chunk element count by its stripe count exactly as for
/// [`channel::split`]). `stripes` all ones reduces to [`fuse`].
pub fn fuse_striped(buckets: &[BucketPhases], stripes: &[usize]) -> Result<Program> {
    if buckets.is_empty() {
        return Err(Error::Schedule("bucket fuse: at least one bucket required".into()));
    }
    if stripes.len() != buckets.len() {
        return Err(Error::Schedule(format!(
            "bucket fuse: {} stripe counts for {} buckets",
            stripes.len(),
            buckets.len()
        )));
    }
    if let Some(b) = stripes.iter().position(|&s| s == 0) {
        return Err(Error::Schedule(format!("bucket {b}: stripes must be >= 1")));
    }
    let n = buckets[0].rs.nranks;
    for (b, bk) in buckets.iter().enumerate() {
        if bk.rs.collective != Collective::ReduceScatter {
            return Err(Error::Schedule(format!(
                "bucket {b}: reduce-scatter phase is a {} program",
                bk.rs.collective
            )));
        }
        if bk.ag.collective != Collective::AllGather {
            return Err(Error::Schedule(format!(
                "bucket {b}: all-gather phase is a {} program",
                bk.ag.collective
            )));
        }
        if bk.rs.nranks != n || bk.ag.nranks != n {
            return Err(Error::Schedule(format!(
                "bucket {b}: rank count {}/{} differs from bucket 0's {n}",
                bk.rs.nranks, bk.ag.nranks
            )));
        }
        if bk.segments == 0 {
            return Err(Error::Schedule(format!("bucket {b}: segments must be >= 1")));
        }
        if bk.rs.channels > 1 || bk.ag.channels > 1 {
            return Err(Error::Schedule(format!(
                "bucket {b}: phase programs must be single-channel (apply \
                 channel::split to the fused program)"
            )));
        }
    }
    let layout = BucketLayout::of_striped(buckets, stripes);
    let specs: Vec<String> = buckets
        .iter()
        .zip(stripes)
        .map(|(b, &st)| {
            let stripe = if st > 1 { format!("*{st}") } else { String::new() };
            format!("{}+{}:{}{stripe}", b.rs.algorithm, b.ag.algorithm, b.segments)
        })
        .collect();
    let name = if specs.windows(2).all(|w| w[0] == w[1]) {
        format!("bkt{}({})", specs.len(), specs[0])
    } else {
        format!("bkt({})", specs.join("|"))
    };
    let mut out = Program::new(n, Collective::AllReduce, name);

    // Per rank: merge all buckets' 2·S_b·stripes_b phase streams by
    // (global step, stream index = position in this list), preserving
    // in-stream order. The stream list is built in the same (bucket,
    // segment, stripe, RS-then-AG) order on every rank — the tie-break
    // both endpoints agree on. Stripes of one segment share their step
    // spans but own disjoint channels, so the per-channel FIFO argument
    // is untouched.
    for rank in 0..n {
        let mut streams: Vec<channel::Stream<'_>> = Vec::new();
        for ((b, bk), &nstripes) in buckets.iter().enumerate().zip(stripes) {
            let lay = &layout.per_bucket[b];
            for seg in 0..bk.segments {
                for stripe in 0..nstripes {
                    let lane = seg * nstripes + stripe;
                    let (rs_lo, _) = lay.span(seg, Phase::ReduceScatter);
                    let (ag_lo, _) = lay.span(seg, Phase::AllGather);
                    streams.push(channel::Stream {
                        ops: &bk.rs.ranks[rank],
                        step_base: layout.step_base[b] + rs_lo,
                        chunk_base: layout.chunk_base[b] + lane * n,
                        channel_base: layout.channel_base[b] + lane,
                    });
                    streams.push(channel::Stream {
                        ops: &bk.ag.ranks[rank],
                        step_base: layout.step_base[b] + ag_lo,
                        chunk_base: layout.chunk_base[b] + lane * n,
                        channel_base: layout.channel_base[b] + lane,
                    });
                }
            }
        }
        channel::merge_rank_streams(&mut out, rank, &streams);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::verify::verify_program;
    use crate::sched::{compose, pat, ring};

    fn phases(n: usize) -> (Program, Program) {
        (pat::reduce_scatter(n, 2), pat::allgather(n, 2))
    }

    #[test]
    fn rejects_bad_inputs() {
        let (rs, ag) = phases(8);
        assert!(fuse(&[]).is_err());
        // wrong collectives in either slot
        assert!(fuse(&[BucketPhases { rs: ag.clone(), ag: ag.clone(), segments: 1 }]).is_err());
        assert!(fuse(&[BucketPhases { rs: rs.clone(), ag: rs.clone(), segments: 1 }]).is_err());
        // rank mismatch across buckets
        let (rs4, ag4) = phases(4);
        assert!(fuse(&[
            BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 1 },
            BucketPhases { rs: rs4, ag: ag4, segments: 1 },
        ])
        .is_err());
        // zero segments
        assert!(fuse(&[BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 0 }]).is_err());
        // multi-channel phases: split the fused program instead
        let split_rs = crate::sched::channel::split(&rs, 2).unwrap();
        assert!(fuse(&[BucketPhases { rs: split_rs, ag, segments: 1 }]).is_err());
    }

    /// The structural anchor: `B` uniform single-segment buckets fuse to
    /// exactly the op streams of the `B`-segment composition — buckets
    /// generalize segments, they do not reinvent them.
    #[test]
    fn uniform_buckets_equal_compose_segments() {
        for n in [2usize, 7, 12] {
            for b in [1usize, 2, 4] {
                let (rs, ag) = phases(n);
                let bucketed = fuse(&uniform(&rs, &ag, b, 1)).unwrap();
                let composed = compose::fuse(&rs, &ag, b).unwrap();
                assert_eq!(bucketed.ranks, composed.ranks, "n={n} b={b}");
                assert_eq!(bucketed.steps, composed.steps, "n={n} b={b}");
                assert_eq!(bucketed.channels, composed.channels, "n={n} b={b}");
                assert_eq!(bucketed.collective, Collective::AllReduce);
            }
        }
    }

    /// Fused programs verify: per-bucket reduction correctness over the
    /// concatenated chunk space is exactly what the all-reduce reference
    /// executor checks chunk by chunk.
    #[test]
    fn mixed_buckets_verify() {
        for n in [2usize, 3, 7, 12, 16] {
            let buckets = vec![
                // bucket 0: two internal segments of pat+pat
                BucketPhases {
                    rs: pat::reduce_scatter(n, 2),
                    ag: pat::allgather(n, 2),
                    segments: 2,
                },
                // bucket 1: single-segment ring+ring
                BucketPhases {
                    rs: ring::reduce_scatter(n),
                    ag: ring::allgather(n),
                    segments: 1,
                },
                // bucket 2: mixed pair, fully aggregated PAT
                BucketPhases {
                    rs: pat::reduce_scatter(n, usize::MAX),
                    ag: ring::allgather(n),
                    segments: 1,
                },
            ];
            let p = fuse(&buckets).unwrap();
            let layout = BucketLayout::of(&buckets);
            assert_eq!(p.chunk_space(), layout.chunk_space(), "n={n}");
            assert_eq!(p.channels, layout.channels(), "n={n}");
            verify_program(&p).unwrap_or_else(|e| panic!("n={n}: {e}"));
            // each phase of each (bucket, segment) moves n(n-1) chunks
            assert_eq!(p.stats().chunk_transfers, 2 * 4 * n * (n - 1), "n={n}");
        }
    }

    /// Adjacent buckets overlap on the step grid: bucket b's last
    /// all-gather shares its range with bucket b+1's first reduce-scatter.
    #[test]
    fn layout_staggers_adjacent_buckets() {
        let (rs, ag) = phases(8);
        let buckets = uniform(&rs, &ag, 3, 2);
        let layout = BucketLayout::of(&buckets);
        assert_eq!(layout.nbuckets(), 3);
        for b in 0..2 {
            let (_, end_b) = layout.step_span(b);
            let (start_next, _) = layout.step_span(b + 1);
            assert!(
                start_next < end_b,
                "bucket {b} ends at {end_b}, bucket {} starts at {start_next}",
                b + 1
            );
        }
        // chunk bases are multiples of n (ownership is preserved) and
        // channel ranges are disjoint and contiguous
        for b in 0..3 {
            assert_eq!(layout.chunk_base[b] % 8, 0);
            assert_eq!(layout.channel_range(b), (b * 2, b * 2 + 2));
        }
        let p = fuse(&buckets).unwrap();
        assert_eq!(p.channels, 6);
        assert_eq!(p.chunk_space(), 6 * 8);
    }

    #[test]
    fn bucket_of_chunk_maps_the_grid() {
        let (rs, ag) = phases(4);
        let buckets = vec![
            BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 2 },
            BucketPhases { rs, ag, segments: 1 },
        ];
        let layout = BucketLayout::of(&buckets);
        // bucket 0: chunks [0, 8), bucket 1: chunks [8, 12)
        assert_eq!(layout.bucket_of_chunk(0), 0);
        assert_eq!(layout.bucket_of_chunk(7), 0);
        assert_eq!(layout.bucket_of_chunk(8), 1);
        assert_eq!(layout.bucket_of_chunk(11), 1);
        assert_eq!(layout.chunk_elems(&[3, 5]), {
            let mut v = vec![3usize; 8];
            v.extend(vec![5usize; 4]);
            v
        });
    }

    /// Channel-splitting composes on top of bucketing, and the split
    /// program still verifies (channels multiply).
    #[test]
    fn split_composes_with_bucketing() {
        let (rs, ag) = phases(6);
        let fused = fuse(&uniform(&rs, &ag, 2, 1)).unwrap();
        assert_eq!(fused.channels, 2);
        let s = crate::sched::channel::split(&fused, 2).unwrap();
        assert_eq!(s.channels, 4);
        verify_program(&s).unwrap();
    }

    /// Ownership is preserved through the per-bucket renaming: every
    /// chunk id stays inside the layout's grid, and the grid is a whole
    /// number of mod-n ownership cycles. (The verifier enforces the full
    /// causality property; this pins the chunk arithmetic.)
    #[test]
    fn chunk_bases_preserve_ownership() {
        let (rs, ag) = phases(10);
        let p = fuse(&uniform(&rs, &ag, 3, 1)).unwrap();
        let space = p.chunk_space();
        for ops in &p.ranks {
            for op in ops {
                for &c in op.chunks() {
                    assert!(c < space);
                }
            }
        }
        assert_eq!(space % p.nranks, 0);
        assert_eq!(space, 3 * 10);
    }

    #[test]
    fn bucket_windows_union_channel_spans() {
        let (rs, ag) = phases(4);
        let buckets = vec![
            BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 2 },
            BucketPhases { rs, ag, segments: 1 },
        ];
        let layout = BucketLayout::of(&buckets);
        // channels 0..2 belong to bucket 0, channel 2 to bucket 1
        let spans = vec![(1.0, 4.0), (2.0, 6.0), (5.0, 9.0)];
        let w = bucket_windows(&layout, &spans);
        assert_eq!(w.len(), 2);
        assert_eq!((w[0].bucket, w[0].t_start, w[0].t_end), (0, 1.0, 6.0));
        assert_eq!((w[1].bucket, w[1].t_start, w[1].t_end), (1, 5.0, 9.0));
        // a silent channel keeps its bucket out of the report
        let quiet = vec![(f64::INFINITY, f64::NEG_INFINITY); 3];
        assert!(bucket_windows(&layout, &quiet).is_empty());
    }

    #[test]
    fn stripe_plan_thresholds() {
        // only buckets at/above the threshold get the extra channels
        assert_eq!(stripe_plan(&[1 << 10, 256 << 10, 255 << 10], 256 << 10, 4), vec![1, 4, 1]);
        // channels = 1 (or 0) stripes nothing
        assert_eq!(stripe_plan(&[1 << 20, 1 << 20], 0, 1), vec![1, 1]);
        assert_eq!(stripe_plan(&[1 << 20], 0, 0), vec![1]);
    }

    /// All-ones stripes are exactly [`fuse`] — striping is opt-in per
    /// bucket, not a new construction.
    #[test]
    fn unit_stripes_equal_fuse() {
        let (rs, ag) = phases(8);
        let buckets = uniform(&rs, &ag, 3, 2);
        let plain = fuse(&buckets).unwrap();
        let striped = fuse_striped(&buckets, &[1, 1, 1]).unwrap();
        assert_eq!(plain.ranks, striped.ranks);
        assert_eq!(plain.channels, striped.channels);
        assert_eq!(plain.steps, striped.steps);
    }

    /// Mixed stripes verify and land on the right chunk/channel grid:
    /// a striped bucket's extra copies each own a disjoint mod-n range
    /// and their own channel, and the fused program still passes the
    /// reference executor.
    #[test]
    fn striped_buckets_verify() {
        for n in [2usize, 7, 12] {
            let (rs, ag) = phases(n);
            let buckets = vec![
                BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 1 },
                BucketPhases { rs: rs.clone(), ag: ag.clone(), segments: 2 },
                BucketPhases { rs, ag, segments: 1 },
            ];
            let stripes = [1usize, 1, 4];
            let p = fuse_striped(&buckets, &stripes).unwrap();
            let layout = BucketLayout::of_striped(&buckets, &stripes);
            // channels: 1·1 + 2·1 + 1·4 = 7; chunks: (1 + 2 + 4)·n
            assert_eq!(p.channels, 7, "n={n}");
            assert_eq!(layout.channels(), 7, "n={n}");
            assert_eq!(p.chunk_space(), 7 * n, "n={n}");
            assert_eq!(layout.chunk_space(), 7 * n, "n={n}");
            assert_eq!(layout.channel_range(2), (3, 7), "n={n}");
            assert_eq!(layout.chunk_base, vec![0, n, 3 * n], "n={n}");
            verify_program(&p).unwrap_or_else(|e| panic!("n={n}: {e}"));
            // per-chunk grid: stripes repeat the bucket's element count
            // over its whole segments·stripes·n range
            let elems = layout.chunk_elems(&[5, 3, 2]);
            assert_eq!(elems.len(), 7 * n);
            assert!(elems[..n].iter().all(|&e| e == 5));
            assert!(elems[n..3 * n].iter().all(|&e| e == 3));
            assert!(elems[3 * n..].iter().all(|&e| e == 2));
        }
    }

    #[test]
    fn striped_fuse_rejects_bad_stripe_vectors() {
        let (rs, ag) = phases(4);
        let buckets = uniform(&rs, &ag, 2, 1);
        assert!(fuse_striped(&buckets, &[1]).is_err()); // length mismatch
        assert!(fuse_striped(&buckets, &[1, 0]).is_err()); // zero stripes
    }

    #[test]
    fn degenerate_single_rank() {
        let rs = pat::reduce_scatter(1, 1);
        let ag = pat::allgather(1, 1);
        let p = fuse(&uniform(&rs, &ag, 3, 1)).unwrap();
        assert_eq!(p.total_ops(), 0);
        verify_program(&p).unwrap();
    }
}
