//! The adversarial delivery policies: seeded random exploration, the
//! DPOR-lite systematic permuter, and the pinned replayer.
//!
//! All policies speak the [`crate::transport::delivery`] protocol and
//! record what they *actually did* (not what they rolled) as a list of
//! [`Deviation`]s keyed by the deterministic decision coordinates
//! `(rank, src, channel, nth)` — the key that stays stable when the
//! shrinker replays a subset of the deviations (see the delivery-layer
//! module docs for why per-connection match counts are
//! schedule-independent). Each rank's policy flushes its record into a
//! [`SharedLog`] sink when the rank thread drops it, so the episode
//! runner sees one merged perturbation list after the run joins.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::core::Rank;
use crate::transport::delivery::{Decision, DeliveryFactory, DeliveryPolicy, Verdict};
use crate::util::Rng;

/// What a deviation did to its decision point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DevKind {
    /// The match was deferred for `cycles` scheduler decisions before
    /// delivering (or being force-released by the bounded-hold rule).
    Hold { cycles: u32 },
    /// FIFO entry `depth` (> 0) was delivered instead of the head —
    /// in-connection reordering, only possible with the FIFO-ordering
    /// sentinel armed.
    Skip { depth: usize },
}

impl DevKind {
    pub fn name(&self) -> &'static str {
        match self {
            DevKind::Hold { .. } => "hold",
            DevKind::Skip { .. } => "skip",
        }
    }
}

/// One recorded perturbation at a deterministic decision point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Deviation {
    /// The receiving rank whose schedule was perturbed.
    pub rank: Rank,
    /// Source rank of the perturbed connection.
    pub src: Rank,
    /// Channel of the perturbed connection.
    pub channel: usize,
    /// Which match on that connection was perturbed (0-based).
    pub nth: u64,
    pub kind: DevKind,
}

impl Deviation {
    /// Stable sort key so merged logs are deterministic regardless of
    /// rank-thread drop order.
    fn key(&self) -> (Rank, Rank, usize, u64) {
        (self.rank, self.src, self.channel, self.nth)
    }
}

/// The merged perturbation record of one episode.
#[derive(Debug, Default, Clone)]
pub struct EpisodeLog {
    /// Every deviation actually applied, sorted by
    /// (rank, src, channel, nth).
    pub deviations: Vec<Deviation>,
    /// Deliveries forced by the engine's bounded-hold rule.
    pub forced: usize,
    /// Total decision points seen across all ranks.
    pub decisions: u64,
}

/// Cross-thread sink the per-rank policies flush into on drop.
pub type SharedLog = Arc<Mutex<EpisodeLog>>;

/// Fresh empty sink for one episode.
pub fn new_log() -> SharedLog {
    Arc::new(Mutex::new(EpisodeLog::default()))
}

/// Take the merged record out of a sink (after the transport run has
/// joined — every policy has flushed by then), sorted canonically.
pub fn drain_log(log: &SharedLog) -> EpisodeLog {
    let mut inner = log.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = std::mem::take(&mut *inner);
    out.deviations.sort_by_key(|d| d.key());
    out
}

/// Knobs of the seeded random explorer (probabilities in parts per
/// million so configs stay integer and hashable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExploreCfg {
    /// Probability of soft-holding a decision point.
    pub hold_ppm: u32,
    /// Holds last 1..=max_hold scheduler decisions.
    pub max_hold: u32,
    /// Probability of delivering out of order when the FIFO is ≥ 2 deep
    /// (only effective with the FIFO-ordering sentinel armed; otherwise
    /// the engine clamps the index back to the head).
    pub skip_ppm: u32,
    /// Skips reach at most this FIFO index.
    pub max_depth: usize,
}

/// Named policy presets (the `--policy` axis of `patcol adversary`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// Seeded random delay: soft holds only.
    Delay,
    /// Reordering windows: holds to deepen FIFOs plus out-of-order
    /// delivery attempts within a connection.
    Reorder,
    /// Worst-step slot pressure: hold *every* decision point the maximum
    /// time, so arrivals pile up and every queue runs at peak depth.
    Pressure,
    /// DPOR-lite: deterministically permute cross-channel arrival order
    /// at the first decision points of each rank, driven by the episode
    /// index bits (episode e explores deferral pattern e).
    Dpor,
    /// Rotate delay → reorder → pressure by episode index.
    Mix,
}

impl Preset {
    pub fn name(&self) -> &'static str {
        match self {
            Preset::Delay => "delay",
            Preset::Reorder => "reorder",
            Preset::Pressure => "pressure",
            Preset::Dpor => "dpor",
            Preset::Mix => "mix",
        }
    }

    fn explore_cfg(&self) -> ExploreCfg {
        match self {
            Preset::Delay => {
                ExploreCfg { hold_ppm: 300_000, max_hold: 3, skip_ppm: 0, max_depth: 0 }
            }
            Preset::Reorder => {
                ExploreCfg { hold_ppm: 350_000, max_hold: 3, skip_ppm: 500_000, max_depth: 3 }
            }
            Preset::Pressure => {
                ExploreCfg { hold_ppm: 1_000_000, max_hold: 2, skip_ppm: 0, max_depth: 0 }
            }
            // Dpor/Mix dispatch elsewhere; cfg unused.
            _ => ExploreCfg { hold_ppm: 0, max_hold: 0, skip_ppm: 0, max_depth: 0 },
        }
    }
}

/// A parsed `--policy` argument: preset plus base seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PolicySpec {
    pub preset: Preset,
    /// Base seed, combined with the episode index and rank to derive
    /// per-rank streams.
    pub seed: u64,
}

impl PolicySpec {
    /// Parse `<preset>[:<seed>]`, e.g. `delay`, `reorder:7`.
    pub fn parse(s: &str) -> crate::core::Result<PolicySpec> {
        let (name, seed) = match s.split_once(':') {
            Some((n, sd)) => {
                let seed: u64 = sd.trim().parse().map_err(|_| {
                    crate::core::Error::Config(format!("bad policy seed {:?} in {s:?}", sd.trim()))
                })?;
                (n.trim(), seed)
            }
            None => (s.trim(), 0),
        };
        let preset = match name {
            "delay" => Preset::Delay,
            "reorder" => Preset::Reorder,
            "pressure" => Preset::Pressure,
            "dpor" => Preset::Dpor,
            "mix" => Preset::Mix,
            other => {
                return Err(crate::core::Error::Config(format!(
                    "unknown delivery policy {other:?} (want delay|reorder|pressure|dpor|mix)"
                )))
            }
        };
        Ok(PolicySpec { preset, seed })
    }

    /// Canonical spelling (round-trips through [`PolicySpec::parse`]).
    pub fn spec(&self) -> String {
        if self.seed == 0 {
            self.preset.name().to_string()
        } else {
            format!("{}:{}", self.preset.name(), self.seed)
        }
    }

    /// Build the per-rank policy factory for one episode, flushing into
    /// `sink`.
    pub fn factory(&self, episode: u64, sink: SharedLog) -> DeliveryFactory {
        let spec = *self;
        Arc::new(move |rank: Rank| -> Box<dyn DeliveryPolicy> {
            let seed = mix_seed(spec.seed, episode, rank);
            let preset = match spec.preset {
                Preset::Mix => match episode % 3 {
                    0 => Preset::Delay,
                    1 => Preset::Reorder,
                    _ => Preset::Pressure,
                },
                p => p,
            };
            match preset {
                Preset::Dpor => Box::new(DporPolicy::new(rank, episode, sink.clone())),
                p => Box::new(ExplorePolicy::new(rank, seed, p, sink.clone())),
            }
        })
    }

    /// Factory for steady-state use through
    /// [`crate::coordinator::CommConfig::adversary`], where nobody reads
    /// the perturbation record: episode 0, private sink.
    pub fn transport_factory(&self) -> DeliveryFactory {
        self.factory(0, new_log())
    }
}

/// Derive a per-(seed, episode, rank) stream that differs in every
/// coordinate (splitmix-style odd-constant mixing).
fn mix_seed(seed: u64, episode: u64, rank: Rank) -> u64 {
    let mut x = seed
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(episode.wrapping_mul(0xD1B5_4A32_D192_ED03))
        .wrapping_add((rank as u64).wrapping_mul(0x2545_F491_4F6C_DD1D))
        .wrapping_add(0xA076_1D64_78BD_642F);
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    x ^= x >> 33;
    x
}

/// The choice a policy committed to at a decision point (re-served on
/// every re-poll so a decision is consistent across scheduler passes).
#[derive(Debug, Clone, Copy)]
enum Pending {
    /// Soft-hold for this many more decide calls; `total` remembers the
    /// roll for the record.
    Hold { left: u32, total: u32 },
    Deliver(usize),
}

/// Book-keeping shared by every concrete policy: remembers committed
/// choices per decision point and accumulates the rank-local record.
#[derive(Debug)]
struct DecisionBook {
    rank: Rank,
    pending: HashMap<(Rank, usize, u64), Pending>,
    local: Vec<Deviation>,
    decisions: u64,
    forced: usize,
    sink: SharedLog,
    label: &'static str,
}

impl DecisionBook {
    fn new(rank: Rank, label: &'static str, sink: SharedLog) -> DecisionBook {
        DecisionBook {
            rank,
            pending: HashMap::new(),
            local: Vec::new(),
            decisions: 0,
            forced: 0,
            sink,
            label,
        }
    }

    /// Serve the committed choice for `d`, committing via `roll` on
    /// first sight.
    fn decide(&mut self, d: Decision, roll: impl FnOnce(Decision) -> Pending) -> Verdict {
        let key = (d.src, d.channel, d.nth);
        if !self.pending.contains_key(&key) {
            self.decisions += 1;
            let choice = roll(d);
            self.pending.insert(key, choice);
        }
        match self.pending.get_mut(&key).expect("just inserted") {
            Pending::Hold { left, .. } if *left > 0 => {
                *left -= 1;
                Verdict::Hold
            }
            Pending::Hold { .. } => Verdict::Deliver(0),
            Pending::Deliver(i) => Verdict::Deliver(*i),
        }
    }

    /// Record what actually happened at `d`.
    fn delivered(&mut self, d: Decision, idx: usize, forced: bool) {
        let key = (d.src, d.channel, d.nth);
        if forced {
            self.forced += 1;
        }
        if let Some(Pending::Hold { left, total }) = self.pending.remove(&key) {
            let held = total - left;
            if held > 0 {
                self.local.push(Deviation {
                    rank: self.rank,
                    src: d.src,
                    channel: d.channel,
                    nth: d.nth,
                    kind: DevKind::Hold { cycles: held },
                });
            }
        }
        if idx > 0 {
            self.local.push(Deviation {
                rank: self.rank,
                src: d.src,
                channel: d.channel,
                nth: d.nth,
                kind: DevKind::Skip { depth: idx },
            });
        }
    }

    fn log(&self) -> String {
        let holds = self
            .local
            .iter()
            .filter(|d| matches!(d.kind, DevKind::Hold { .. }))
            .count();
        let skips = self.local.len() - holds;
        let mut s = format!(
            "rank {}: policy={} decisions={} holds={} (forced={}) reorders={}",
            self.rank, self.label, self.decisions, holds, self.forced, skips
        );
        for d in &self.local {
            match d.kind {
                DevKind::Hold { cycles } => s.push_str(&format!(
                    "\n  hold src={} ch={} nth={} cycles={cycles}",
                    d.src, d.channel, d.nth
                )),
                DevKind::Skip { depth } => s.push_str(&format!(
                    "\n  skip src={} ch={} nth={} depth={depth}",
                    d.src, d.channel, d.nth
                )),
            }
        }
        s
    }
}

impl Drop for DecisionBook {
    fn drop(&mut self) {
        let mut sink = self.sink.lock().unwrap_or_else(|e| e.into_inner());
        sink.deviations.append(&mut self.local);
        sink.deviations.sort_by_key(|d| d.key());
        sink.forced += self.forced;
        sink.decisions += self.decisions;
    }
}

/// Seeded random explorer (delay / reorder / pressure presets).
///
/// Skips are **opportunistic**: the dice only roll a skip when the FIFO
/// is already ≥ 2 deep at first sight of the decision point, so the
/// policy never waits for traffic that may not come — exploration can
/// slow a schedule but not wedge it.
pub struct ExplorePolicy {
    rng: Rng,
    cfg: ExploreCfg,
    book: DecisionBook,
}

impl ExplorePolicy {
    pub fn new(rank: Rank, seed: u64, preset: Preset, sink: SharedLog) -> ExplorePolicy {
        ExplorePolicy {
            rng: Rng::new(seed),
            cfg: preset.explore_cfg(),
            book: DecisionBook::new(rank, preset.name(), sink),
        }
    }
}

impl DeliveryPolicy for ExplorePolicy {
    fn decide(&mut self, d: Decision) -> Verdict {
        let rng = &mut self.rng;
        let cfg = self.cfg;
        self.book.decide(d, |d| {
            if d.depth >= 2
                && cfg.skip_ppm > 0
                && rng.below(1_000_000) < cfg.skip_ppm as usize
            {
                let reach = d.depth.min(cfg.max_depth + 1);
                return Pending::Deliver(1 + rng.below(reach.saturating_sub(1).max(1)));
            }
            if cfg.hold_ppm > 0 && rng.below(1_000_000) < cfg.hold_ppm as usize {
                let c = 1 + rng.below(cfg.max_hold.max(1) as usize) as u32;
                return Pending::Hold { left: c, total: c };
            }
            Pending::Deliver(0)
        })
    }

    fn delivered(&mut self, d: Decision, idx: usize, forced: bool) {
        self.book.delivered(d, idx, forced);
    }

    fn perturbation_log(&self) -> String {
        self.book.log()
    }
}

/// DPOR-lite: a deterministic schedule permuter. Each rank numbers its
/// decision points in discovery order; decision point `i` is deferred
/// one cycle iff bit `(i + rank·7) mod 61` of the episode index is set.
/// Sweeping the episode index therefore sweeps deferral patterns — at
/// each deferred point the scheduler moves on to other channels first,
/// systematically permuting cross-channel arrival order without any
/// randomness (episode `e` is its own replay key).
pub struct DporPolicy {
    episode: u64,
    point: u64,
    book: DecisionBook,
}

/// Decision points beyond this index are left eager (keeps the explored
/// prefix aligned with the episode index's bit budget).
const DPOR_POINTS: u64 = 61;

impl DporPolicy {
    pub fn new(rank: Rank, episode: u64, sink: SharedLog) -> DporPolicy {
        DporPolicy { episode, point: 0, book: DecisionBook::new(rank, "dpor", sink) }
    }
}

impl DeliveryPolicy for DporPolicy {
    fn decide(&mut self, d: Decision) -> Verdict {
        let episode = self.episode;
        let rank = self.book.rank as u64;
        let point = &mut self.point;
        self.book.decide(d, |_| {
            let i = *point;
            *point += 1;
            let defer = i < DPOR_POINTS && (episode >> ((i + rank * 7) % 61)) & 1 == 1;
            if defer {
                Pending::Hold { left: 1, total: 1 }
            } else {
                Pending::Deliver(0)
            }
        })
    }

    fn delivered(&mut self, d: Decision, idx: usize, forced: bool) {
        self.book.delivered(d, idx, forced);
    }

    fn perturbation_log(&self) -> String {
        self.book.log()
    }
}

/// Replay a recorded deviation list exactly.
///
/// Holds re-apply as soft holds (their only effect is scheduling
/// pressure). Skips are the semantic deviations, and replaying one must
/// not race: the policy answers [`Verdict::HoldFirm`] until the FIFO is
/// deeper than the recorded index, parking the rank until the messages
/// that provably existed at record time (the recorder saw them) arrive
/// again — which they do, because everything causally preceding the
/// recorded match is reachable without this rank's post-match actions.
/// The watchdog still backstops replays of traces against a schedule
/// that cannot supply the recorded depth (e.g. a hand-edited trace): the
/// run fails with a timeout blame instead of hanging.
pub struct PinnedPolicy {
    map: HashMap<(Rank, usize, u64), DevKind>,
    book: DecisionBook,
}

impl PinnedPolicy {
    pub fn new(rank: Rank, deviations: &[Deviation], sink: SharedLog) -> PinnedPolicy {
        let map = deviations
            .iter()
            .filter(|d| d.rank == rank)
            .map(|d| ((d.src, d.channel, d.nth), d.kind))
            .collect();
        PinnedPolicy { map, book: DecisionBook::new(rank, "pinned", sink) }
    }

    /// Factory over a shared deviation list.
    pub fn factory(deviations: Arc<Vec<Deviation>>, sink: SharedLog) -> DeliveryFactory {
        Arc::new(move |rank: Rank| -> Box<dyn DeliveryPolicy> {
            Box::new(PinnedPolicy::new(rank, &deviations, sink.clone()))
        })
    }
}

impl DeliveryPolicy for PinnedPolicy {
    fn decide(&mut self, d: Decision) -> Verdict {
        let pinned = self.map.get(&(d.src, d.channel, d.nth)).copied();
        match pinned {
            Some(DevKind::Skip { depth }) if d.depth <= depth => Verdict::HoldFirm,
            Some(DevKind::Skip { depth }) => {
                // Depth reached: record and deliver out of order. No
                // Pending entry needed — delivery is immediate.
                self.book.decide(d, |_| Pending::Deliver(depth))
            }
            Some(DevKind::Hold { cycles }) => {
                self.book.decide(d, |_| Pending::Hold { left: cycles, total: cycles })
            }
            None => self.book.decide(d, |_| Pending::Deliver(0)),
        }
    }

    fn delivered(&mut self, d: Decision, idx: usize, forced: bool) {
        self.book.delivered(d, idx, forced);
    }

    fn perturbation_log(&self) -> String {
        self.book.log()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(src: Rank, nth: u64, depth: usize) -> Decision {
        Decision { rank: 0, src, channel: 0, depth, nth, vtime: nth }
    }

    #[test]
    fn policy_spec_roundtrip() {
        for s in ["delay", "reorder", "pressure", "dpor", "mix", "delay:7", "reorder:42"] {
            let spec = PolicySpec::parse(s).unwrap();
            assert_eq!(spec.spec(), s);
            assert_eq!(PolicySpec::parse(&spec.spec()).unwrap(), spec);
        }
        assert!(PolicySpec::parse("eager?").is_err());
        assert!(PolicySpec::parse("delay:x").is_err());
    }

    #[test]
    fn explore_decisions_are_consistent_across_repolls() {
        // Re-asking the same decision point must never change the
        // committed choice (the scheduler re-polls held channels).
        let sink = new_log();
        let mut p = ExplorePolicy::new(0, 42, Preset::Reorder, sink.clone());
        for nth in 0..50u64 {
            let first = p.decide(d(1, nth, 3));
            let second = p.decide(d(1, nth, 3));
            match (first, second) {
                (Verdict::Hold, Verdict::Hold | Verdict::Deliver(0)) => {}
                (a, b) => assert_eq!(a, b, "decision at nth={nth} drifted"),
            }
            p.delivered(d(1, nth, 3), 0, false);
        }
        drop(p);
        let log = drain_log(&sink);
        assert_eq!(log.decisions, 50);
    }

    #[test]
    fn explore_skips_only_with_depth() {
        // With depth 1 a reorder policy may hold but never skip.
        let sink = new_log();
        let mut p = ExplorePolicy::new(0, 9, Preset::Reorder, sink.clone());
        for nth in 0..100u64 {
            loop {
                match p.decide(d(2, nth, 1)) {
                    Verdict::Deliver(i) => {
                        assert_eq!(i, 0);
                        p.delivered(d(2, nth, 1), i, false);
                        break;
                    }
                    Verdict::Hold => continue,
                    Verdict::HoldFirm => panic!("explorer must not hold firm"),
                }
            }
        }
        drop(p);
        assert!(drain_log(&sink)
            .deviations
            .iter()
            .all(|dev| matches!(dev.kind, DevKind::Hold { .. })));
    }

    #[test]
    fn delay_preset_records_holds() {
        let sink = new_log();
        let mut p = ExplorePolicy::new(3, 1, Preset::Delay, sink.clone());
        let mut delivered = 0;
        for nth in 0..200u64 {
            let mut spins = 0;
            loop {
                match p.decide(d(0, nth, 1)) {
                    Verdict::Deliver(i) => {
                        p.delivered(d(0, nth, 1), i, false);
                        delivered += 1;
                        break;
                    }
                    Verdict::Hold => {
                        spins += 1;
                        assert!(spins <= 3, "delay holds are bounded by max_hold");
                    }
                    Verdict::HoldFirm => panic!("explorer must not hold firm"),
                }
            }
        }
        assert_eq!(delivered, 200);
        drop(p);
        let log = drain_log(&sink);
        assert!(!log.deviations.is_empty(), "300k ppm over 200 points must hold sometimes");
        assert!(log.deviations.iter().all(|dev| dev.rank == 3));
    }

    #[test]
    fn dpor_is_deterministic_in_episode() {
        let run = |episode: u64| {
            let sink = new_log();
            let mut p = DporPolicy::new(1, episode, sink.clone());
            for nth in 0..30u64 {
                loop {
                    if let Verdict::Deliver(i) = p.decide(d(0, nth, 1)) {
                        p.delivered(d(0, nth, 1), i, false);
                        break;
                    }
                }
            }
            drop(p);
            drain_log(&sink).deviations
        };
        assert_eq!(run(0b1011), run(0b1011));
        assert_ne!(run(0b1011), run(0)); // episode 0 defers nothing
        assert!(run(0).is_empty());
    }

    #[test]
    fn pinned_skip_waits_for_depth() {
        let sink = new_log();
        let devs =
            vec![Deviation { rank: 0, src: 1, channel: 0, nth: 0, kind: DevKind::Skip { depth: 1 } }];
        let mut p = PinnedPolicy::new(0, &devs, sink.clone());
        // Depth 1: not enough to take entry 1 — must park, not improvise.
        assert_eq!(p.decide(d(1, 0, 1)), Verdict::HoldFirm);
        // Depth 2: deliver the recorded index.
        assert_eq!(p.decide(d(1, 0, 2)), Verdict::Deliver(1));
        p.delivered(d(1, 0, 2), 1, false);
        // Undeviated points stay eager.
        assert_eq!(p.decide(d(1, 1, 1)), Verdict::Deliver(0));
        p.delivered(d(1, 1, 1), 0, false);
        drop(p);
        let log = drain_log(&sink);
        assert_eq!(log.deviations, devs);
    }

    #[test]
    fn pinned_policies_ignore_other_ranks() {
        let sink = new_log();
        let devs =
            vec![Deviation { rank: 2, src: 1, channel: 0, nth: 0, kind: DevKind::Skip { depth: 1 } }];
        let mut p = PinnedPolicy::new(0, &devs, sink);
        assert_eq!(p.decide(d(1, 0, 1)), Verdict::Deliver(0));
    }

    #[test]
    fn perturbation_log_names_the_policy() {
        let sink = new_log();
        let p = ExplorePolicy::new(5, 7, Preset::Delay, sink);
        let log = p.perturbation_log();
        assert!(log.contains("rank 5"));
        assert!(log.contains("policy=delay"));
    }
}
