//! Critical-path extraction over the unified trace.
//!
//! Answers the question raw spans cannot: *which chain of operations
//! determined the elapsed time, and what was that chain doing?* The
//! analyzer rebuilds the op-span dependency graph from a [`Trace`]
//! (either executor's — the schema is shared) and walks the longest
//! chain through it:
//!
//! * **Stream edges**: ops on one (rank, channel) stream retire in
//!   order, so each `send`/`recv` span depends on its predecessor in
//!   the same stream.
//! * **Message edges**: per (src, dst, channel) connection, wires are
//!   FIFO — the i-th `wire` span depends on the i-th `send`, and the
//!   i-th `recv` on the i-th `wire`.
//!
//! Two chain notions come out of the same graph:
//!
//! * The **timed chain** — from the globally latest-ending op, walk
//!   backward always choosing the predecessor that ended last. Its
//!   spans are then *tiled* onto the run window with a chronological
//!   cursor, so each node contributes only time not already covered by
//!   an earlier chain node, and uncovered time appears as explicit
//!   gaps. Tiled contributions plus gaps sum to the elapsed time
//!   exactly, which is what makes the decomposition an accounting
//!   identity rather than an estimate.
//! * The **structural depth** ([`CritPath::dag_depth`]) — the longest
//!   chain by dependency structure alone, ignoring timestamps. Stream
//!   order and FIFO matching are program-determined, so this count is
//!   identical for a simulator run and a transport run of the same
//!   program (the cross-executor test in `tests/observability.rs`
//!   asserts exactly that); the timed chain, by contrast, legitimately
//!   differs with timing noise.
//!
//! Decomposition buckets ([`Decomposition`]): `send` (pack + post),
//! `wire` (serialization + transit), `recv` (match + unpack), `reduce`
//! (kernel time, carved out of its recv via the matching `reduce`
//! span), `stall` (chain gaps overlapping a recorded `stall` span —
//! the stream was blocked on an unmatched receive), and `wait`
//! (remaining gaps: link contention in the simulator, scheduler/queue
//! wait in the transport — the slot/slack bucket). The same six
//! buckets are reported per step, which for composed programs is the
//! phase/level axis.

use std::collections::{BTreeMap, VecDeque};

use crate::core::Rank;
use crate::obs::trace::{EventKind, Trace};
use crate::util::json::Json;

/// Wall-time decomposition in seconds; the six buckets partition the
/// interval they describe (see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Decomposition {
    pub send_s: f64,
    pub wire_s: f64,
    pub recv_s: f64,
    pub reduce_s: f64,
    pub stall_s: f64,
    pub wait_s: f64,
}

impl Decomposition {
    pub fn sum(&self) -> f64 {
        self.send_s + self.wire_s + self.recv_s + self.reduce_s + self.stall_s + self.wait_s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("send_s", Json::num(self.send_s)),
            ("wire_s", Json::num(self.wire_s)),
            ("recv_s", Json::num(self.recv_s)),
            ("reduce_s", Json::num(self.reduce_s)),
            ("stall_s", Json::num(self.stall_s)),
            ("wait_s", Json::num(self.wait_s)),
        ])
    }
}

/// One node of the timed critical chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CritNode {
    pub kind: EventKind,
    pub rank: Rank,
    pub channel: usize,
    pub step: usize,
    pub peer: Option<Rank>,
    pub bytes: usize,
    pub t_start: f64,
    pub t_end: f64,
    /// Exclusive (tiled) contribution to the elapsed time, seconds.
    pub contrib: f64,
    /// Uncovered time between the previous chain coverage and this
    /// node's start — stall or wait, classified in the decomposition.
    pub gap_before: f64,
}

/// The extracted critical path and its accounting (see module docs).
#[derive(Debug, Clone)]
pub struct CritPath {
    /// Timed chain, in execution order.
    pub nodes: Vec<CritNode>,
    /// First op start, seconds from the trace origin.
    pub t0: f64,
    /// Last op end minus first op start — the measured elapsed time the
    /// decomposition partitions.
    pub elapsed: f64,
    /// Σ raw chain-span durations (spans may overlap; compare against
    /// `elapsed` for the ≥ 95 % coverage acceptance criterion).
    pub span_sum: f64,
    /// Σ exclusive contributions (tiled; `covered + gap_sum == elapsed`).
    pub covered: f64,
    /// Σ chain gaps, including the lead-in before the first chain op.
    pub gap_sum: f64,
    /// Longest dependency chain by structure alone (op count) — the
    /// executor-invariant figure.
    pub dag_depth: usize,
    /// Whole-run decomposition; `decomp.sum() == elapsed` up to fp.
    pub decomp: Decomposition,
    /// The same buckets per program step (the phase/level axis).
    pub per_step: BTreeMap<usize, Decomposition>,
    /// Fraction of the chain's covered time spent on each (rank,
    /// channel) — the `crit %` column of `patcol trace`.
    pub share: BTreeMap<(Rank, usize), f64>,
}

impl CritPath {
    /// Coverage of the elapsed window by chain spans, in percent.
    pub fn coverage_pct(&self) -> f64 {
        if self.elapsed > 0.0 {
            100.0 * self.covered / self.elapsed
        } else {
            100.0
        }
    }

    pub fn to_json(&self) -> Json {
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                let mut pairs = vec![
                    ("kind", Json::str(n.kind.name())),
                    ("rank", Json::num(n.rank as f64)),
                    ("channel", Json::num(n.channel as f64)),
                    ("step", Json::num(n.step as f64)),
                    ("t_start", Json::num(n.t_start)),
                    ("t_end", Json::num(n.t_end)),
                    ("contrib_s", Json::num(n.contrib)),
                    ("gap_before_s", Json::num(n.gap_before)),
                ];
                if let Some(p) = n.peer {
                    pairs.push(("peer", Json::num(p as f64)));
                }
                if n.bytes > 0 {
                    pairs.push(("bytes", Json::num(n.bytes as f64)));
                }
                Json::obj(pairs)
            })
            .collect();
        let per_step: Vec<Json> = self
            .per_step
            .iter()
            .map(|(s, d)| {
                let mut o = d.to_json();
                if let Json::Obj(m) = &mut o {
                    m.insert("step".into(), Json::num(*s as f64));
                }
                o
            })
            .collect();
        let share: Vec<Json> = self
            .share
            .iter()
            .map(|(&(r, k), &f)| {
                Json::obj(vec![
                    ("rank", Json::num(r as f64)),
                    ("channel", Json::num(k as f64)),
                    ("share", Json::num(f)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("elapsed_s", Json::num(self.elapsed)),
            ("span_sum_s", Json::num(self.span_sum)),
            ("covered_s", Json::num(self.covered)),
            ("gap_s", Json::num(self.gap_sum)),
            ("coverage_pct", Json::num(self.coverage_pct())),
            ("dag_depth", Json::num(self.dag_depth as f64)),
            ("decomposition", self.decomp.to_json()),
            ("per_step", Json::Arr(per_step)),
            ("share", Json::Arr(share)),
            ("chain", Json::Arr(nodes)),
        ])
    }
}

/// Extract the critical path of `trace` (see module docs). Returns
/// `None` when the trace holds no op spans at all.
pub fn critical_path(trace: &Trace) -> Option<CritPath> {
    // Op nodes: send/recv/wire spans, in the trace's t_start order.
    let mut ops: Vec<usize> = Vec::new();
    for (i, ev) in trace.events.iter().enumerate() {
        if matches!(ev.kind, EventKind::SendOp | EventKind::RecvOp | EventKind::Wire) {
            ops.push(i);
        }
    }
    if ops.is_empty() {
        return None;
    }
    let ev = |o: usize| &trace.events[ops[o]];

    // Dependency edges (indices into `ops`).
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); ops.len()];
    // Stream edges: consecutive send/recv ops on one (rank, channel).
    let mut streams: BTreeMap<(Rank, usize), usize> = BTreeMap::new();
    // FIFO lanes per (src, dst, channel) connection.
    let mut sends: BTreeMap<(Rank, Rank, usize), VecDeque<usize>> = BTreeMap::new();
    let mut wires: BTreeMap<(Rank, Rank, usize), VecDeque<usize>> = BTreeMap::new();
    for o in 0..ops.len() {
        let e = ev(o);
        match e.kind {
            EventKind::SendOp | EventKind::RecvOp => {
                if let Some(prev) = streams.insert((e.rank, e.channel), o) {
                    preds[o].push(prev);
                }
                if let Some(peer) = e.peer {
                    if e.kind == EventKind::SendOp {
                        sends.entry((e.rank, peer, e.channel)).or_default().push_back(o);
                    } else if let Some(w) =
                        wires.get_mut(&(peer, e.rank, e.channel)).and_then(|q| q.pop_front())
                    {
                        preds[o].push(w);
                    }
                }
            }
            EventKind::Wire => {
                if let Some(peer) = e.peer {
                    if let Some(s) =
                        sends.get_mut(&(e.rank, peer, e.channel)).and_then(|q| q.pop_front())
                    {
                        preds[o].push(s);
                    }
                    wires.entry((e.rank, peer, e.channel)).or_default().push_back(o);
                }
            }
            _ => unreachable!("only op kinds are collected"),
        }
    }

    // Structural depth by Kahn order — robust to timestamp ties.
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); ops.len()];
    let mut indeg = vec![0usize; ops.len()];
    for (o, ps) in preds.iter().enumerate() {
        indeg[o] = ps.len();
        for &p in ps {
            succs[p].push(o);
        }
    }
    let mut depth = vec![1usize; ops.len()];
    let mut queue: VecDeque<usize> =
        (0..ops.len()).filter(|&o| indeg[o] == 0).collect();
    let mut seen = 0usize;
    while let Some(o) = queue.pop_front() {
        seen += 1;
        for &s in &succs[o] {
            depth[s] = depth[s].max(depth[o] + 1);
            indeg[s] -= 1;
            if indeg[s] == 0 {
                queue.push_back(s);
            }
        }
    }
    debug_assert_eq!(seen, ops.len(), "op dependency graph has a cycle");
    let dag_depth = depth.iter().copied().max().unwrap_or(1);

    // Timed chain: from the latest-ending op, walk the latest-ending
    // predecessor backward.
    let last = (0..ops.len())
        .max_by(|&a, &b| ev(a).t_end.total_cmp(&ev(b).t_end))
        .expect("ops nonempty");
    let mut chain = vec![last];
    let mut cur = last;
    while let Some(&p) = preds[cur]
        .iter()
        .max_by(|&&a, &&b| ev(a).t_end.total_cmp(&ev(b).t_end))
    {
        chain.push(p);
        cur = p;
    }
    chain.reverse();

    // Stall intervals per (rank, channel), for gap classification.
    let mut stalls: BTreeMap<(Rank, usize), Vec<(f64, f64)>> = BTreeMap::new();
    // Reduce-kernel seconds per (rank, channel, step), carved out of the
    // matching recv's contribution.
    let mut reduces: BTreeMap<(Rank, usize, usize), f64> = BTreeMap::new();
    for e in &trace.events {
        match e.kind {
            EventKind::Stall => stalls
                .entry((e.rank, e.channel))
                .or_default()
                .push((e.t_start, e.t_end)),
            EventKind::Reduce => {
                *reduces.entry((e.rank, e.channel, e.step)).or_default() += e.duration()
            }
            _ => {}
        }
    }

    let t0 = ops.iter().map(|&i| trace.events[i].t_start).fold(f64::INFINITY, f64::min);
    let t1 = ops.iter().map(|&i| trace.events[i].t_end).fold(f64::NEG_INFINITY, f64::max);
    let elapsed = (t1 - t0).max(0.0);

    // Tile the chain onto [t0, t1]: exclusive contributions plus
    // explicit gaps partition the window exactly.
    let mut nodes = Vec::with_capacity(chain.len());
    let mut decomp = Decomposition::default();
    let mut per_step: BTreeMap<usize, Decomposition> = BTreeMap::new();
    let mut share: BTreeMap<(Rank, usize), f64> = BTreeMap::new();
    let (mut cursor, mut span_sum, mut covered, mut gap_sum) = (t0, 0.0, 0.0, 0.0);
    for &o in &chain {
        let e = ev(o);
        let gap = (e.t_start - cursor).max(0.0);
        let contrib = (e.t_end - cursor.max(e.t_start)).max(0.0);
        span_sum += e.duration();
        covered += contrib;
        gap_sum += gap;

        let d = per_step.entry(e.step).or_default();
        if gap > 0.0 {
            // The stream owning this node was the one waiting: split the
            // gap into recorded stall overlap vs everything else.
            let (g0, g1) = (cursor, cursor + gap);
            let mut stall = 0.0;
            if let Some(iv) = stalls.get(&(e.rank, e.channel)) {
                for &(s0, s1) in iv {
                    stall += (s1.min(g1) - s0.max(g0)).max(0.0);
                }
            }
            let stall = stall.min(gap);
            decomp.stall_s += stall;
            decomp.wait_s += gap - stall;
            d.stall_s += stall;
            d.wait_s += gap - stall;
        }
        match e.kind {
            EventKind::SendOp => {
                decomp.send_s += contrib;
                d.send_s += contrib;
            }
            EventKind::Wire => {
                decomp.wire_s += contrib;
                d.wire_s += contrib;
            }
            EventKind::RecvOp => {
                let rd = reduces
                    .get(&(e.rank, e.channel, e.step))
                    .copied()
                    .unwrap_or(0.0)
                    .min(contrib);
                decomp.reduce_s += rd;
                decomp.recv_s += contrib - rd;
                d.reduce_s += rd;
                d.recv_s += contrib - rd;
            }
            _ => unreachable!("only op kinds are collected"),
        }
        *share.entry((e.rank, e.channel)).or_default() += contrib;

        nodes.push(CritNode {
            kind: e.kind,
            rank: e.rank,
            channel: e.channel,
            step: e.step,
            peer: e.peer,
            bytes: e.bytes,
            t_start: e.t_start,
            t_end: e.t_end,
            contrib,
            gap_before: gap,
        });
        cursor = cursor.max(e.t_end);
    }
    // Anything after the last chain op would contradict its maximality;
    // anything before t0 cannot exist. The identity is therefore exact.
    if covered > 0.0 {
        for v in share.values_mut() {
            *v /= covered;
        }
    }

    Some(CritPath {
        nodes,
        t0,
        elapsed,
        span_sum,
        covered,
        gap_sum,
        dag_depth,
        decomp,
        per_step,
        share,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Event, TraceRecorder};

    /// Hand-built 4-rank trace with a known longest chain:
    ///
    /// ```text
    /// r0 send[0,1] → wire[1,3] → r1 recv[3,4] (reduce [3.5,4])
    ///   → r1 send[4,5] → wire[5,7] → (stall gap [7,8]) → r2 recv[8,9]
    /// ```
    ///
    /// plus a decoy short chain r3 → r0 that must not win.
    fn golden_trace() -> Trace {
        let mut rec = TraceRecorder::new();
        let sp = Event::span;
        use EventKind::*;
        // main chain
        rec.record(sp(SendOp, 0, 0, 0, 0.0, 1.0).with_peer(1).with_bytes(64));
        rec.record(sp(Wire, 0, 0, 0, 1.0, 3.0).with_peer(1).with_bytes(64));
        rec.record(sp(RecvOp, 1, 0, 0, 3.0, 4.0).with_peer(0).with_bytes(64));
        rec.record(sp(Reduce, 1, 0, 0, 3.5, 4.0).with_bytes(64));
        rec.record(sp(SendOp, 1, 0, 1, 4.0, 5.0).with_peer(2).with_bytes(64));
        rec.record(sp(Wire, 1, 0, 1, 5.0, 7.0).with_peer(2).with_bytes(64));
        rec.record(sp(Stall, 2, 0, 1, 6.5, 8.0).with_peer(1));
        rec.record(sp(RecvOp, 2, 0, 1, 8.0, 9.0).with_peer(1).with_bytes(64));
        // decoy chain, fully inside the run window
        rec.record(sp(SendOp, 3, 0, 0, 0.0, 0.5).with_peer(0).with_bytes(8));
        rec.record(sp(Wire, 3, 0, 0, 0.5, 1.0).with_peer(0).with_bytes(8));
        rec.record(sp(RecvOp, 0, 0, 0, 1.0, 1.5).with_peer(3).with_bytes(8));
        rec.finish()
    }

    #[test]
    fn golden_chain_is_extracted_exactly() {
        let cp = critical_path(&golden_trace()).expect("ops present");
        use EventKind::*;
        let got: Vec<(EventKind, Rank, usize)> =
            cp.nodes.iter().map(|n| (n.kind, n.rank, n.step)).collect();
        assert_eq!(
            got,
            vec![
                (SendOp, 0, 0),
                (Wire, 0, 0),
                (RecvOp, 1, 0),
                (SendOp, 1, 1),
                (Wire, 1, 1),
                (RecvOp, 2, 1),
            ]
        );
        assert_eq!(cp.dag_depth, 6);
        assert!((cp.elapsed - 9.0).abs() < 1e-12);
        // exact accounting identity: contributions + gaps == elapsed
        assert!((cp.covered + cp.gap_sum - cp.elapsed).abs() < 1e-12);
        assert!((cp.decomp.sum() - cp.elapsed).abs() < 1e-12);
    }

    #[test]
    fn golden_decomposition_matches_hand_count() {
        let cp = critical_path(&golden_trace()).unwrap();
        let d = cp.decomp;
        assert!((d.send_s - 2.0).abs() < 1e-12, "send {}", d.send_s);
        assert!((d.wire_s - 4.0).abs() < 1e-12, "wire {}", d.wire_s);
        assert!((d.recv_s - 1.5).abs() < 1e-12, "recv {}", d.recv_s);
        assert!((d.reduce_s - 0.5).abs() < 1e-12, "reduce {}", d.reduce_s);
        // the [7,8] gap lies inside r2's recorded stall window
        assert!((d.stall_s - 1.0).abs() < 1e-12, "stall {}", d.stall_s);
        assert!(d.wait_s.abs() < 1e-12, "wait {}", d.wait_s);
        // gap attribution lands on the stalled recv node
        let recv = cp.nodes.last().unwrap();
        assert!((recv.gap_before - 1.0).abs() < 1e-12);
        // per-step buckets partition the same totals
        let per: f64 = cp.per_step.values().map(|d| d.sum()).sum();
        assert!((per - cp.elapsed).abs() < 1e-12);
        // chain share: every contribution fraction sums to one
        let s: f64 = cp.share.values().sum();
        assert!((s - 1.0).abs() < 1e-12);
        assert!(cp.coverage_pct() > 88.0, "coverage {}", cp.coverage_pct());
    }

    #[test]
    fn empty_trace_has_no_path() {
        assert!(critical_path(&Trace::default()).is_none());
        // counter-only traces have no op spans either
        let mut rec = TraceRecorder::new();
        rec.record(Event::span(EventKind::Pool, 0, 0, 0, 0.0, 0.0).with_value(1));
        assert!(critical_path(&rec.finish()).is_none());
    }

    #[test]
    fn json_shape_is_stable() {
        let cp = critical_path(&golden_trace()).unwrap();
        let j = cp.to_json();
        for key in [
            "elapsed_s",
            "span_sum_s",
            "covered_s",
            "coverage_pct",
            "dag_depth",
            "decomposition",
            "per_step",
            "share",
            "chain",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("dag_depth").unwrap().as_usize(), Some(6));
        assert_eq!(j.get("chain").unwrap().as_arr().unwrap().len(), 6);
    }
}
