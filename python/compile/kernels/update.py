"""L1 Pallas kernel for the sharded optimizer update (ZeRO-style example).

``scale_add(p, g, lr) = p - lr * g`` over a parameter shard. Same (rows,
128) tiling as the reduce kernels; ``lr`` is a (1, 1) scalar operand
broadcast inside the kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.reduce import padded_2d, _tiles, LANES


def _scale_add_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0, 0] * g_ref[...]


def scale_add(p: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    """SGD shard step ``p - lr*g``; ``lr`` has shape (1,)."""
    (n,) = p.shape
    rows, lanes = padded_2d(n)
    pad = rows * lanes - n
    p2 = jnp.pad(p, (0, pad)).reshape(rows, lanes)
    g2 = jnp.pad(g, (0, pad)).reshape(rows, lanes)
    lr2 = lr.reshape(1, 1)
    block, grid = _tiles(rows)
    spec = pl.BlockSpec((block, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _scale_add_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), p.dtype),
        grid=(grid,),
        in_specs=[spec, spec, pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=spec,
        interpret=True,
    )(p2, g2, lr2)
    return out.reshape(-1)[:n]
