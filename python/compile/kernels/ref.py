"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
ground truth (pytest compares kernel outputs against these)."""

import jax
import jax.numpy as jnp


def ref_reduce2(a: jax.Array, b: jax.Array) -> jax.Array:
    return a + b


def ref_reduce_k(acc: jax.Array, *xs: jax.Array) -> jax.Array:
    out = acc
    for x in xs:
        out = out + x
    return out


def ref_scale_add(p: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    return p - lr[0] * g


def ref_softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean cross-entropy over all positions; logits [..., V], int targets."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
