//! Self-contained utilities: deterministic PRNG, statistics, JSON
//! emission/parsing, and text tables. The offline build environment has no
//! `rand`/`serde`/`criterion`, so these substrates are implemented here.

pub mod rng;
pub mod stats;
pub mod json;
pub mod table;

pub use rng::Rng;
pub use stats::Summary;
