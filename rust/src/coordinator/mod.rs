//! The coordinator: the NCCL-like public API ([`Communicator`]), the
//! algorithm auto-tuner ([`tuner`]), and configuration ([`config`]).
//!
//! This is the layer a downstream user programs against:
//!
//! ```no_run
//! use patcol::coordinator::{CommConfig, Communicator};
//! let comm = Communicator::new(CommConfig { nranks: 8, ..Default::default() }).unwrap();
//! let inputs: Vec<Vec<f32>> = (0..8).map(|r| vec![r as f32; 256]).collect();
//! let out = comm.all_gather(&inputs).unwrap();
//! ```

pub mod communicator;
pub mod tuner;
pub mod config;

pub use communicator::{CollectiveReport, CommConfig, Communicator, DataPathKind};
pub use tuner::{BucketChoice, Tuner, TunerChoice};
