//! Artifact registry: reads `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), lazily compiles artifacts on first use, and
//! selects size classes for the reduction datapath.
//!
//! The reduce kernels are compiled at a small set of fixed sizes
//! (AOT-compiled graphs have static shapes); [`Registry::reduce_f32`]
//! segments an arbitrary-length reduction over the largest fitting class
//! and pads the tail.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::core::{Error, Result};
use crate::runtime::client::{Executable, PjrtContext};
use crate::util::json::{self};

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `(a[n], b[n]) -> (a + b,)` — the RS datapath reduction (Pallas).
    Reduce,
    /// `(acc[n], x0[n], .., x{k-1}[n]) -> (acc + Σ xi,)` — fused k-way
    /// reduction (Pallas), used to batch the linear phase.
    ReduceK,
    /// `(p[n], g[n], lr[1]) -> (p - lr*g,)` — optimizer shard update
    /// (Pallas).
    ScaleAdd,
    /// Transformer LM: `(params, tokens) -> (loss, grads)`.
    TrainStep,
    /// Transformer LM loss only: `(params, tokens) -> (loss,)`.
    EvalLoss,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<ArtifactKind> {
        Ok(match s {
            "reduce" => ArtifactKind::Reduce,
            "reduce_k" => ArtifactKind::ReduceK,
            "scale_add" => ArtifactKind::ScaleAdd,
            "train_step" => ArtifactKind::TrainStep,
            "eval_loss" => ArtifactKind::EvalLoss,
            other => return Err(Error::Config(format!("unknown artifact kind {other:?}"))),
        })
    }
}

/// Manifest entry for one artifact.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    /// Element count for elementwise kernels; parameter count for models.
    pub n: usize,
    /// Fan-in for `ReduceK`.
    pub k: usize,
    /// Extra integers (model artifacts): [batch, seq, vocab] etc.
    pub extra: HashMap<String, usize>,
}

/// Artifact registry with lazy compilation cache.
pub struct Registry {
    ctx: PjrtContext,
    dir: PathBuf,
    metas: Vec<ArtifactMeta>,
    cache: Mutex<HashMap<String, Executable>>,
}

impl Registry {
    /// Load `<dir>/manifest.json`. Fails with a pointer to `make artifacts`
    /// if missing.
    pub fn load(ctx: PjrtContext, dir: &Path) -> Result<Registry> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                manifest.display()
            ))
        })?;
        let root = json::parse(&text)?;
        let arts = root
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| Error::Config("manifest missing 'artifacts' array".into()))?;
        let mut metas = Vec::new();
        for a in arts {
            let get_str = |k: &str| -> Result<String> {
                a.get(k)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| Error::Config(format!("artifact missing '{k}'")))
            };
            let name = get_str("name")?;
            let file = get_str("file")?;
            let kind = ArtifactKind::parse(&get_str("kind")?)?;
            let n = a.get("n").and_then(|v| v.as_usize()).unwrap_or(0);
            let k = a.get("k").and_then(|v| v.as_usize()).unwrap_or(2);
            let mut extra = HashMap::new();
            if let Some(obj) = a.get("extra").and_then(|v| v.as_obj()) {
                for (key, val) in obj {
                    if let Some(x) = val.as_usize() {
                        extra.insert(key.clone(), x);
                    }
                }
            }
            metas.push(ArtifactMeta { name, file, kind, n, k, extra });
        }
        Ok(Registry { ctx, dir: dir.to_path_buf(), metas, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifact directory: `$PATCOL_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("PATCOL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn metas(&self) -> &[ArtifactMeta] {
        &self.metas
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.iter().find(|m| m.name == name)
    }

    /// Get (compiling if needed) the executable for `name`.
    pub fn get(&self, name: &str) -> Result<Executable> {
        {
            let cache = self.cache.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
        }
        let meta = self
            .meta(name)
            .ok_or_else(|| Error::Runtime(format!("no artifact named {name:?} in manifest")))?
            .clone();
        let exe = self.ctx.load_hlo_text(&self.dir.join(&meta.file), name)?;
        let mut cache = self.cache.lock().unwrap();
        Ok(cache.entry(name.to_string()).or_insert(exe).clone())
    }

    /// Size classes available for a kind, ascending by n.
    pub fn size_classes(&self, kind: ArtifactKind) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self.metas.iter().filter(|m| m.kind == kind).collect();
        v.sort_by_key(|m| m.n);
        v
    }

    /// Pick the best reduce artifact for a length-`n` operand: the smallest
    /// class ≥ n, else the largest class (the caller segments).
    pub fn pick_class(&self, kind: ArtifactKind, n: usize) -> Result<&ArtifactMeta> {
        let classes = self.size_classes(kind);
        if classes.is_empty() {
            return Err(Error::Runtime(format!(
                "no artifacts of kind {kind:?}; re-run `make artifacts`"
            )));
        }
        Ok(classes
            .iter()
            .find(|m| m.n >= n)
            .copied()
            .unwrap_or(*classes.last().unwrap()))
    }

    /// `acc += x` via the Pallas reduce kernel, segmenting + padding to the
    /// artifact's static shape. This is the reduce-scatter datapath.
    pub fn reduce_f32(&self, acc: &mut [f32], x: &[f32]) -> Result<()> {
        if acc.len() != x.len() {
            return Err(Error::Runtime(format!(
                "reduce_f32 length mismatch: {} vs {}",
                acc.len(),
                x.len()
            )));
        }
        if acc.is_empty() {
            return Ok(());
        }
        let meta = self.pick_class(ArtifactKind::Reduce, acc.len())?;
        let class_n = meta.n;
        let exe = self.get(&meta.name.clone())?;
        let mut start = 0usize;
        let mut abuf = vec![0f32; class_n];
        let mut xbuf = vec![0f32; class_n];
        while start < acc.len() {
            let end = (start + class_n).min(acc.len());
            let len = end - start;
            abuf[..len].copy_from_slice(&acc[start..end]);
            abuf[len..].fill(0.0);
            xbuf[..len].copy_from_slice(&x[start..end]);
            xbuf[len..].fill(0.0);
            let dims = [class_n as i64];
            let out = exe.run_f32(&[(&abuf, &dims), (&xbuf, &dims)])?;
            acc[start..end].copy_from_slice(&out[0][..len]);
            start = end;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_and_class_pick() {
        let Ok(ctx) = PjrtContext::cpu() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let dir = std::env::temp_dir().join("patcol_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
                {"name": "reduce_f32_1024", "file": "r1024.hlo.txt", "kind": "reduce", "n": 1024},
                {"name": "reduce_f32_65536", "file": "r65536.hlo.txt", "kind": "reduce", "n": 65536},
                {"name": "train_step", "file": "t.hlo.txt", "kind": "train_step", "n": 123,
                 "extra": {"batch": 4, "seq": 64}}
            ]}"#,
        )
        .unwrap();
        let reg = Registry::load(ctx, &dir).unwrap();
        assert_eq!(reg.metas().len(), 3);
        assert_eq!(reg.pick_class(ArtifactKind::Reduce, 100).unwrap().n, 1024);
        assert_eq!(reg.pick_class(ArtifactKind::Reduce, 2048).unwrap().n, 65536);
        assert_eq!(reg.pick_class(ArtifactKind::Reduce, 1 << 20).unwrap().n, 65536);
        assert_eq!(reg.meta("train_step").unwrap().extra["batch"], 4);
        assert!(reg.pick_class(ArtifactKind::ScaleAdd, 4).is_err());
    }

    #[test]
    fn missing_manifest_mentions_make() {
        let Ok(ctx) = PjrtContext::cpu() else {
            eprintln!("skipping: PJRT backend unavailable");
            return;
        };
        let err = Registry::load(ctx, Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("dir", &self.dir)
            .field("artifacts", &self.metas.len())
            .finish()
    }
}
