//! Transport robustness: repeated operations (arena-reuse steady-state),
//! wider worlds, concurrent communicators, auto-tuned algorithm paths,
//! and failure behaviour (timeouts surface as errors, not hangs).

use std::time::Duration;

use patcol::coordinator::{CommConfig, Communicator};
use patcol::core::{Algorithm, Collective};
use patcol::sched::pat;
use patcol::sched::program::{Op, Program};
use patcol::transport::{run_allgather, run_allgather_into, run_reduce_scatter, TransportOptions};
use patcol::util::Rng;

/// Steady-state reuse: 25 back-to-back reduce-scatters through one
/// communicator produce identical results every time (recycled buffers
/// never leak stale data).
#[test]
fn repeated_ops_are_deterministic() {
    let n = 8;
    let chunk = 257; // deliberately unaligned
    let comm = Communicator::new(CommConfig {
        nranks: n,
        algorithm: Some(Algorithm::Pat { aggregation: 2 }),
        ..Default::default()
    })
    .unwrap();
    let mut rng = Rng::new(1234);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..n * chunk).map(|_| rng.below(1000) as f32).collect())
        .collect();
    let first = comm.reduce_scatter(&inputs).unwrap();
    for rep in 0..24 {
        let again = comm.reduce_scatter(&inputs).unwrap();
        assert_eq!(again, first, "repetition {rep} diverged");
    }
}

/// run_allgather_into with reused output buffers across calls: outputs are
/// fully overwritten (no stale chunks from the previous call).
#[test]
fn into_buffers_fully_overwritten() {
    let n = 6;
    let chunk = 33;
    let prog = pat::allgather(n, 2);
    let opts = TransportOptions { validate: false, ..Default::default() };
    let mut outputs: Vec<Vec<f32>> = vec![vec![f32::NAN; n * chunk]; n];
    for round in 0..3 {
        let inputs: Vec<Vec<f32>> =
            (0..n).map(|r| vec![(round * 100 + r) as f32; chunk]).collect();
        run_allgather_into(&prog, &inputs, &mut outputs, &opts).unwrap();
        for (r, o) in outputs.iter().enumerate() {
            for src in 0..n {
                assert!(
                    o[src * chunk..(src + 1) * chunk]
                        .iter()
                        .all(|&v| v == (round * 100 + src) as f32),
                    "round {round} rank {r} chunk {src}"
                );
            }
        }
    }
}

/// 32 rank threads on this host still complete correctly (oversubscribed
/// scheduling stresses the FIFO reordering path).
#[test]
fn wide_world_32_ranks() {
    let n = 32;
    let chunk = 16;
    let mut rng = Rng::new(9);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..chunk).map(|_| rng.below(100) as f32).collect())
        .collect();
    let mut want = Vec::new();
    for i in &inputs {
        want.extend_from_slice(i);
    }
    for alg in [
        Algorithm::Pat { aggregation: 4 },
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
    ] {
        let prog = patcol::sched::generate(alg, Collective::AllGather, n).unwrap();
        let (outs, _) =
            run_allgather(&prog, &inputs, &TransportOptions::default()).unwrap();
        assert_eq!(outs[n - 1], want, "{alg}");
    }
}

/// Two communicators running interleaved collectives don't interfere.
#[test]
fn concurrent_communicators() {
    let mk = |n: usize, a: usize| {
        Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: a }),
            ..Default::default()
        })
        .unwrap()
    };
    let c1 = mk(4, 1);
    let c2 = mk(6, 2);
    std::thread::scope(|s| {
        let h1 = s.spawn(|| {
            let inputs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 64]).collect();
            for _ in 0..10 {
                let out = c1.all_gather(&inputs).unwrap();
                assert_eq!(out[0].len(), 4 * 64);
            }
        });
        let h2 = s.spawn(|| {
            let inputs: Vec<Vec<f32>> = (0..6).map(|r| vec![r as f32; 48]).collect();
            for _ in 0..10 {
                let out = c2.all_gather(&inputs).unwrap();
                assert_eq!(out[5].len(), 6 * 48);
            }
        });
        h1.join().unwrap();
        h2.join().unwrap();
    });
}

/// The auto-tuned path end-to-end: PatAuto resolves per size and still
/// computes exact results at both extremes.
#[test]
fn pat_auto_both_regimes() {
    let n = 8;
    let comm = Communicator::new(CommConfig {
        nranks: n,
        algorithm: Some(Algorithm::PatAuto),
        buffer_slots: Some(16),
        ..Default::default()
    })
    .unwrap();
    for chunk in [4usize, 32 * 1024] {
        let mut rng = Rng::new(chunk as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * chunk).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (outs, rep) = comm.reduce_scatter_report(&inputs).unwrap();
        for r in 0..n {
            for i in 0..chunk {
                let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                assert_eq!(outs[r][i], w, "chunk={chunk} rank={r}");
            }
        }
        // resolved to a concrete algorithm, never PatAuto itself
        assert!(!matches!(rep.algorithm, Algorithm::PatAuto));
    }
}

/// A deliberately deadlocked program fails with a timeout error instead of
/// hanging the process (watchdog path).
#[test]
fn timeout_instead_of_hang() {
    // rank 0 waits for a message rank 1 never sends
    let mut p = Program::new(2, Collective::AllGather, "broken");
    p.push(0, Op::recv(1, vec![1], false, 0));
    p.push(0, Op::send(1, vec![0], 0));
    p.push(1, Op::recv(0, vec![0], false, 0));
    let opts = TransportOptions {
        validate: false, // skip the verifier to reach the runtime watchdog
        recv_timeout: Duration::from_millis(200),
        ..Default::default()
    };
    let inputs = vec![vec![1.0f32], vec![2.0f32]];
    let err = run_allgather(&p, &inputs, &opts).unwrap_err();
    assert!(err.to_string().contains("timed out"), "{err}");
}

/// Arena reuse across calls: a shared [`patcol::transport::ArenaCache`]
/// leases the same backing allocation to every run, results stay exact,
/// and after the first call the steady state allocates nothing (no fresh
/// arena, no heap-fallback pool slots).
#[test]
fn arena_reuse_steady_state_correct() {
    let n = 8;
    let prog = pat::reduce_scatter(n, 2);
    let mut rng = Rng::new(3);
    let chunk = 100;
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..n * chunk).map(|_| rng.below(100) as f32).collect())
        .collect();
    let opts = TransportOptions {
        arena: Some(patcol::transport::ArenaCache::new()),
        ..Default::default()
    };
    for round in 0..5 {
        let (outs, rep) = run_reduce_scatter(&prog, &inputs, &opts).unwrap();
        for r in 0..n {
            for i in 0..chunk {
                let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                assert_eq!(outs[r][i], w, "round {round} rank {r} idx {i}");
            }
        }
        if round == 0 {
            assert_eq!(rep.arena_allocs, 1, "first call populates the cache");
        } else {
            assert_eq!(rep.arena_allocs, 0, "round {round} re-allocated the arena");
        }
        assert_eq!(rep.slots_allocated, 0, "round {round} fell back to the heap");
    }
}

/// all_reduce at awkward lengths (not divisible by nranks), repeated.
#[test]
fn all_reduce_awkward_lengths() {
    let n = 5;
    let comm = Communicator::new(CommConfig {
        nranks: n,
        algorithm: Some(Algorithm::Pat { aggregation: 2 }),
        ..Default::default()
    })
    .unwrap();
    for len in [1usize, 4, 5, 17, 101] {
        let mut rng = Rng::new(len as u64);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.below(50) as f32).collect())
            .collect();
        let outs = comm.all_reduce(&inputs).unwrap();
        for (r, o) in outs.iter().enumerate() {
            assert_eq!(o.len(), len);
            for i in 0..len {
                let w: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(o[i], w, "len={len} rank={r} idx={i}");
            }
        }
    }
}
