//! Deterministic PRNG (xoshiro256** seeded via splitmix64).
//!
//! Used for workload generation, property-test case generation and ECMP
//! hashing salt. Deterministic across runs and platforms so golden tests and
//! benches are reproducible.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut st = seed;
        let s = [
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
            splitmix64(&mut st),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // Lemire-style rejection-free enough for non-crypto use.
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard-normal-ish (Irwin-Hall of 4 for speed; adequate for jitter).
    pub fn gauss(&mut self) -> f64 {
        let s: f64 = (0..4).map(|_| self.f64()).sum();
        (s - 2.0) * (12.0f64 / 4.0).sqrt()
    }

    /// Fill a float vec with reproducible values in [-1, 1).
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.f32() * 2.0 - 1.0;
        }
    }

    /// Random boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
