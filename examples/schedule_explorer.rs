//! Regenerates every schedule figure of the paper as text.
//!
//!     cargo run --release --example schedule_explorer
//!
//! Fig. 1  — classic Bruck (nearest dimension first), 8 ranks
//! Fig. 2  — its per-root binomial trees
//! Fig. 3  — dimension-reversed Bruck (farthest first), 8 ranks
//! Fig. 4  — truncated trees on 7 ranks
//! Fig. 5  — PAT, 8 ranks, aggregation 2 (the split red/blue step)
//! Fig. 6  — the PAT tree with its log/linear phases
//! Figs 7-9 — PAT on 16 ranks with 8/4/2 parallel trees
//! Fig. 10 — fully linear PAT (aggregation 1)
//! Fig. 11 — the reduce-scatter mirror

use patcol::core::Collective;
use patcol::sched::{bruck, explain, pat};

fn header(title: &str) {
    println!("\n=============================================================");
    println!("{title}");
    println!("=============================================================");
}

fn main() {
    header("Fig. 1 — Bruck all-gather, nearest dimension first, 8 ranks");
    let p = bruck::allgather_near_first(8);
    print!("{}", explain::render_steps(&p));
    println!("note: the LAST step sends 4 chunks at distance 4 — big and far,");
    println!("the combination that collides under static routing (paper §1).");

    header("Fig. 2 — per-root binomial trees of the same schedule");
    print!("{}", explain::render_root_trees(&p));

    header("Fig. 3 — dimension-reversed Bruck (farthest dimension first)");
    let p = bruck::allgather_far_first(8);
    print!("{}", explain::render_steps(&p));
    println!("note: distances now shrink as payloads grow — 1 chunk goes far,");
    println!("4 chunks go next door; but the 4-chunk payload is non-contiguous");
    println!("(stride-2 roots), which is why aggregation needs buffering.");

    header("Fig. 4 — truncated trees: 7 ranks, farthest first");
    let p = bruck::allgather_far_first(7);
    print!("{}", explain::render_steps(&p));

    header("Fig. 5 — PAT, 8 ranks, aggregation limited to 2");
    let p = pat::allgather(8, 2);
    print!("{}", explain::render_steps(&p));
    println!("the 4-chunk dimension-0 round of Fig. 3 is split into two");
    println!("2-chunk rounds executed within the two parallel trees.");

    header("Fig. 6 — the PAT tree for 8 ranks / 2 trees (phases)");
    print!("{}", explain::render_pat_tree(8, 2));

    for (fig, a) in [(7, 8), (8, 4), (9, 2)] {
        header(&format!(
            "Fig. {fig} — PAT tree, 16 ranks, {a} parallel trees"
        ));
        print!("{}", explain::render_pat_tree(16, a));
    }

    header("Fig. 10 — fully linear PAT (aggregation 1), 8 ranks");
    print!("{}", explain::render_pat_tree(8, 1));
    let p = pat::allgather(8, 1);
    print!("{}", explain::render_steps(&p));
    println!("far transfers first, progressively closing on the root; every");
    println!("transfer moves one full buffer at peak bandwidth.");

    header("Fig. 11 — PAT reduce-scatter (mirror of all-gather)");
    let rs = pat::reduce_scatter(8, 2);
    assert_eq!(rs.collective, Collective::ReduceScatter);
    print!("{}", explain::render_steps(&rs));
    println!("time and direction reversed: nearest dimensions first, reversed");
    println!("tree, reduce on receive; the parallel (linear) phase runs before");
    println!("the logarithmic bottom. Rank 0's op list:");
    print!("{}", explain::render_rank(&rs, 0));
}
