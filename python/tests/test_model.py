"""L2 model checks: shapes, loss sanity, gradient flow, and a short
training-loss-decreases run (the python-side counterpart of the rust
ZeRO-style end-to-end example)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


CFG = model.ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1, d_ff=64, seq=16, batch=2)


def tokens_for(cfg, seed=0):
    rng = np.random.default_rng(seed)
    # learnable structure: arithmetic progression with noise
    base = (np.arange(cfg.seq + 1)[None, :] * 7 + rng.integers(0, 3, (cfg.batch, 1))) % cfg.vocab
    return jnp.asarray(base, dtype=jnp.int32)


def test_param_count_and_flat_roundtrip():
    flat, unravel = model.init_flat(CFG)
    assert flat.ndim == 1 and flat.dtype == jnp.float32
    params = unravel(flat)
    refl, _ = jax.flatten_util.ravel_pytree(params)
    np.testing.assert_array_equal(np.asarray(refl), np.asarray(flat))


def test_loss_is_finite_and_near_uniform_at_init():
    flat, unravel = model.init_flat(CFG)
    loss = model.forward_loss(unravel(flat), tokens_for(CFG), CFG)
    assert np.isfinite(float(loss))
    # near log(V) at init
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_train_step_graph_shapes():
    fn, specs, nparams, flat0 = model.train_step_graph(CFG)
    assert flat0.shape == (nparams,)
    toks = tokens_for(CFG)
    loss, grads = jax.jit(fn)(flat0, toks)
    assert loss.shape == ()
    assert grads.shape == (nparams,)
    assert float(jnp.abs(grads).max()) > 0.0


def test_loss_decreases_under_sgd():
    fn, _, nparams, flat = model.train_step_graph(CFG)
    step = jax.jit(fn)
    toks = tokens_for(CFG)
    first = None
    lr = 0.5
    for i in range(40):
        loss, grads = step(flat, toks)
        if first is None:
            first = float(loss)
        flat = flat - lr * grads
    last = float(loss)
    assert last < first * 0.7, f"loss did not decrease: {first} -> {last}"


def test_causality():
    """Changing a future token must not affect earlier-position losses.

    Compare per-position logits instead of the scalar loss.
    """
    flat, unravel = model.init_flat(CFG)
    params = unravel(flat)
    toks = tokens_for(CFG)

    def logits_at(tokens):
        inp = tokens[:, :-1]
        x = params["embed"][inp] + params["pos"][None, : inp.shape[1]]
        for layer in params["layers"]:
            x = x + model._attention(
                model._layernorm(x, layer["ln1"]["g"], layer["ln1"]["b"]), layer, CFG
            )
            hdn = model._layernorm(x, layer["ln2"]["g"], layer["ln2"]["b"])
            x = x + jax.nn.gelu(hdn @ layer["w1"]) @ layer["w2"]
        return x

    base = logits_at(toks)
    mod = toks.at[:, -1].set((toks[:, -1] + 5) % CFG.vocab)
    pert = logits_at(mod)
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], rtol=1e-5, atol=1e-6)


def test_default_config_param_count_is_shardable():
    cfg = model.ModelConfig()
    flat, _ = model.init_flat(cfg)
    # the zero_train example shards over 8 ranks with 128-lane padding
    assert flat.shape[0] > 100_000
