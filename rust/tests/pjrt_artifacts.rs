//! Integration: AOT artifacts (L1 Pallas → L2 jax → HLO text) load and run
//! through the rust PJRT runtime, and the transport engine produces
//! identical results on the scalar and PJRT datapaths.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use std::path::PathBuf;

use patcol::runtime::{ArtifactKind, PjrtContext, PjrtService, Registry};
use patcol::sched::pat;
use patcol::transport::{run_reduce_scatter, DataPath, TransportOptions};
use patcol::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("PATCOL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!(
            "skipping: no artifacts at {} (run `make artifacts`)",
            dir.display()
        );
        None
    }
}

#[test]
fn pallas_reduce_matches_scalar() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let reg = Registry::load(ctx, &dir).unwrap();
    let mut rng = Rng::new(42);
    // cover: smaller than class, exact class, needs segmentation
    for n in [100usize, 1024, 1500, 20000] {
        let mut acc = vec![0f32; n];
        let mut x = vec![0f32; n];
        rng.fill_f32(&mut acc);
        rng.fill_f32(&mut x);
        let mut want = acc.clone();
        for (w, xi) in want.iter_mut().zip(&x) {
            *w += *xi;
        }
        reg.reduce_f32(&mut acc, &x).unwrap();
        for (i, (a, w)) in acc.iter().zip(&want).enumerate() {
            assert!((a - w).abs() < 1e-5, "n={n} idx={i}: {a} vs {w}");
        }
    }
}

#[test]
fn scale_add_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let reg = Registry::load(ctx, &dir).unwrap();
    let meta = reg.pick_class(ArtifactKind::ScaleAdd, 4096).unwrap();
    let n = meta.n;
    let exe = reg.get(&meta.name.clone()).unwrap();
    let p = vec![1.0f32; n];
    let g = vec![2.0f32; n];
    let lr = vec![0.5f32];
    let dims = [n as i64];
    let out = exe
        .run_f32(&[(&p, &dims), (&g, &dims), (&lr, &[1])])
        .unwrap();
    assert!(out[0].iter().all(|&v| (v - 0.0).abs() < 1e-6));
}

#[test]
fn train_step_artifact_runs_and_loss_is_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let ctx = PjrtContext::cpu().unwrap();
    let reg = Registry::load(ctx, &dir).unwrap();
    let Some(meta) = reg.meta("train_step") else {
        eprintln!("skipping: no train_step artifact");
        return;
    };
    let nparams = meta.extra["params"];
    let batch = meta.extra["batch"];
    let seq = meta.extra["seq"];
    let vocab = meta.extra["vocab"] as i32;
    // initial params from the AOT dump
    let raw = std::fs::read(dir.join("init_params.f32")).unwrap();
    assert_eq!(raw.len(), nparams * 4);
    let params: Vec<f32> = raw
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    let mut rng = Rng::new(7);
    let tokens: Vec<i32> = (0..batch * (seq + 1))
        .map(|_| (rng.below(vocab as usize)) as i32)
        .collect();
    let exe = reg.get("train_step").unwrap();
    let plit = xla::Literal::vec1(&params);
    let tlit = xla::Literal::vec1(&tokens)
        .reshape(&[batch as i64, (seq + 1) as i64])
        .unwrap();
    let outs = exe.run_literals(&[plit, tlit]).unwrap();
    let loss = outs[0].to_vec::<f32>().unwrap()[0];
    let grads = outs[1].to_vec::<f32>().unwrap();
    assert_eq!(grads.len(), nparams);
    // random tokens: loss near ln(vocab)
    assert!(
        (loss - (vocab as f32).ln()).abs() < 1.5,
        "loss {loss} vs ln(V) {}",
        (vocab as f32).ln()
    );
    assert!(grads.iter().any(|g| g.abs() > 1e-8));
}

#[test]
fn transport_pjrt_datapath_matches_scalar() {
    let Some(dir) = artifacts_dir() else { return };
    let (_svc, handle) = PjrtService::spawn(dir).unwrap();
    let n = 8usize;
    let chunk = 300usize; // not lane-aligned on purpose
    let mut rng = Rng::new(99);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..n * chunk).map(|_| rng.below(100) as f32).collect())
        .collect();
    let p = pat::reduce_scatter(n, 2);
    let scalar_opts = TransportOptions::default();
    let (want, _) = run_reduce_scatter(&p, &inputs, &scalar_opts).unwrap();
    let pjrt_opts = TransportOptions {
        datapath: DataPath::Pjrt(handle),
        ..Default::default()
    };
    let (got, _) = run_reduce_scatter(&p, &inputs, &pjrt_opts).unwrap();
    for r in 0..n {
        for i in 0..chunk {
            assert!(
                (got[r][i] - want[r][i]).abs() < 1e-4,
                "rank {r} idx {i}: {} vs {}",
                got[r][i],
                want[r][i]
            );
        }
    }
}
