//! # patcol — PAT collective communication library
//!
//! A production-shaped reproduction of *"PAT: a new algorithm for all-gather
//! and reduce-scatter operations at scale"* (Sylvain Jeaugey, NVIDIA, 2025).
//!
//! PAT (Parallel Aggregated Trees) implements all-gather and reduce-scatter
//! with a logarithmic number of network transfers for small operations,
//! minimal long-distance communication, and a logarithmic amount of internal
//! buffering independent of the operation size — degrading gracefully to a
//! full-bandwidth linear schedule as buffer pressure grows.
//!
//! The crate is organized as an NCCL-like stack:
//!
//! * [`sched`] — schedule generators (PAT plus the Ring, Bruck, recursive
//!   doubling/halving baselines) emitting a common per-rank program IR, and
//!   the hierarchical tier ([`sched::hier`]): two-level, topology-aware
//!   schedules over a rank [`core::Placement`] (intra-node tree → inter-node
//!   PAT among per-node leaders → intra-node fan-out; uneven node sizes
//!   supported), selected as [`core::Algorithm::HierPat`] and generated
//!   through the placement-aware [`sched::generate_placed`].
//! * [`sched::compose`] — the collective-composition tier:
//!   [`core::Collective::AllReduce`] programs fused from *any*
//!   reduce-scatter × *any* all-gather phase pair
//!   ([`core::Algorithm::Compose`], spelled `rs+ag[:segments]`, e.g.
//!   `pat+ring:4`), with the payload split into pipeline segments so one
//!   segment's all-gather overlaps the next segment's reduce-scatter —
//!   an IR-to-IR transform (chunk renaming, step staggering, FIFO-safe
//!   stream interleaving, mirror reuse), not a third hand-written schedule.
//! * [`sched::channel`] — the multi-channel tier: channels are a
//!   first-class dimension of the IR (`Op::channel`; message FIFO is per
//!   (src, dst, channel) connection), and [`sched::channel::split`] shards
//!   *any* generated program across `C` NCCL-style channels by chunk
//!   striping (spelled `alg*C`, e.g. `pat*4` — config/CLI `channels`
//!   knob). Each channel is its own in-order proxy stream and its own
//!   statically-hashed flow, so bandwidth-bound collectives recruit
//!   parallel fabric links; compose's pipeline segments are channels of
//!   the fused program, built on the same merge machinery.
//! * [`sched::bucket`] — the multi-*operation* tier: a batch of
//!   back-to-back all-reduces (gradient-bucket traffic; sizes may differ
//!   per bucket) fused into ONE program, bucket `i+1`'s reduce-scatter
//!   overlapping bucket `i`'s all-gather, every bucket on its own
//!   channels — compose's segment stagger generalized across operations
//!   ([`coordinator::Communicator::all_reduce_batch`], config/CLI
//!   `buckets` / `--bucket-bytes` knobs).
//! * [`transport`] — an in-process, threaded, real-byte-moving execution
//!   engine with staging/accumulator buffer pools (the PAT buffer-occupancy
//!   invariants are enforced here; for all-reduce one pool bounds the fused
//!   accumulator + rebroadcast-staging footprint across both phases).
//! * [`sim`] — an event-driven network simulator (fat-tree topologies,
//!   optional NVLink-class intra-node links via
//!   [`sim::Topology::with_intra_node`], static ECMP routing, α-β-γ cost
//!   model with link contention) used for at-scale evaluation; its
//!   per-step spans make composed-phase overlap directly measurable.
//! * [`runtime`] — PJRT bridge executing AOT-compiled JAX/Pallas reduction
//!   kernels (HLO text artifacts) on the reduce-scatter datapath.
//! * [`obs`] — unified observability: one append-only event schema
//!   ([`obs::Event`] / [`obs::EventKind`]) both executors emit into, a
//!   per-(rank, channel) [`obs::Counters`] set, a lock-free per-thread
//!   flight recorder for the transport ([`obs::FlightRecorder`], dumped
//!   by the recv-timeout watchdog), and a Chrome trace-event exporter
//!   ([`obs::chrome_trace`], Perfetto-loadable) — surfaced as
//!   `patcol trace` and `--trace <path>` on `run`/`simulate`.
//! * [`adversary`] — schedule-exploration harness: seeded adversarial
//!   delivery policies ([`transport::DeliveryPolicy`]) drive the *real*
//!   transport through hostile arrival orders, failures are blamed and
//!   shrunk to minimal replayable JSON traces, and mutation sentinels
//!   let the test suite prove the explorer finds real invariant
//!   violations (`patcol adversary`).
//! * [`coordinator`] — the public [`coordinator::Communicator`] API plus the
//!   algorithm auto-tuner (the flat-vs-hierarchical crossover on tapered
//!   fabrics and the all-reduce pair × segment-count crossover) and
//!   configuration (`placement` / `ranks_per_node` / `inter_gbps` /
//!   `segments` knobs).
//!
//! ## Pipeline
//!
//! Data flows through the stack in one direction (`ARCHITECTURE.md` at
//! the repository root walks the same pipeline layer by layer with file
//! pointers):
//!
//! ```text
//!    core::Algorithm ──► sched (generate / generate_placed / compose)
//!                              │  Program IR (per-rank, per-channel
//!                              │  Send/Recv streams; channel::split
//!                              │  shards any program across C channels;
//!                              │  bucket::fuse joins B all-reduce ops
//!                              │  into one pipelined program)
//!                              ▼
//!                        sched::verify  ← ground truth: per-channel FIFO,
//!                              │           deadlock, exact sums, occupancy
//!              ┌───────────────┴────────────────┐
//!              ▼                                ▼
//!        transport (real bytes,           sim (event-driven, topology +
//!        threads, buffer pools,           α-β-γ costs, link contention,
//!        per-channel connections)         per-channel flows/streams)
//!              │                                │
//!              │   obs (one event schema: flight-recorder rings on the
//!              ├─── transport threads, TraceRecorder in the sim loop ───┤
//!              │     → Trace → Chrome JSON / counters / stall blame)    │
//!              │                                │
//!              └───────────────┬────────────────┘
//!                              ▼
//!                    coordinator (tuner crossovers incl. channel count,
//!                    Communicator, config/CLI) — picks algorithms from
//!                    closed forms calibrated against the simulator
//! ```
//!
//! Every generator — flat, hierarchical, composed, channel-split, or
//! bucketed — emits the same IR, is validated by the same verifier, and
//! runs unmodified on both executors; that is the invariant that keeps
//! the layers independent. Execution semantics of the IR: ops on one (rank,
//! channel) retire in order, channels progress independently, and
//! messages are FIFO per (src, dst, channel) connection.
//!
//! ## Quickstart
//!
//! ```no_run
//! use patcol::coordinator::{Communicator, CommConfig};
//! use patcol::core::Algorithm;
//!
//! let comm = Communicator::new(CommConfig {
//!     nranks: 8,
//!     algorithm: Some(Algorithm::Pat { aggregation: 2 }),
//!     ..Default::default()
//! }).unwrap();
//! // one send buffer per rank, 1024 f32 each
//! let inputs: Vec<Vec<f32>> = (0..8).map(|r| vec![r as f32; 1024]).collect();
//! let gathered = comm.all_gather(&inputs).unwrap();
//! assert_eq!(gathered[0].len(), 8 * 1024);
//! ```

pub mod adversary;
pub mod core;
pub mod util;
pub mod sched;
pub mod sim;
pub mod transport;
pub mod obs;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod cli;
pub mod report;

pub use crate::core::{Algorithm, Collective, Rank};
pub use crate::coordinator::{CommConfig, Communicator};
