//! The per-rank program IR shared by the verifier, the threaded transport,
//! the network simulator and the benches.
//!
//! A [`Program`] holds, for each rank, an ordered list of [`Op`]s. Execution
//! semantics:
//!
//! * Every op belongs to a **channel** ([`Op::channel`]) — an NCCL-style
//!   connection + proxy stream. A rank's ops on one channel execute in
//!   list order; distinct channels are independent in-order streams that
//!   the executors may progress concurrently (the simulator and the
//!   threaded transport do; the reference executor conservatively runs the
//!   merged list). Single-channel programs put everything on channel 0,
//!   which reproduces the classic one-stream-per-rank model exactly.
//! * Messages are FIFO per **(src, dst, channel)** — each channel is its
//!   own connection: the k-th `Recv` from a peer on a channel matches the
//!   k-th `Send` to us on that channel. Distinct channels of the same rank
//!   pair are independent wires and may overtake each other.
//! * `Send` is non-blocking (buffered), `Recv` blocks its channel — the
//!   NCCL-like model where the sender writes into a pre-mapped remote
//!   staging buffer.
//!
//! Chunk semantics depend on the collective. Chunk `c` is *owned* by rank
//! `c % nranks`; multi-channel and composed programs use chunk ids beyond
//! `nranks` (channel `k` of a split program renames chunk `c` to
//! `k·chunk_space + c`, see [`crate::sched::channel`]), so ownership is
//! always `id mod nranks`:
//!
//! * **All-gather**: rank `r` initially owns its chunks (`c % n == r`).
//!   `Send` transmits copies of owned chunks; `Recv` takes ownership of
//!   new chunks. At completion every rank owns every chunk.
//! * **Reduce-scatter**: rank `r` holds a contribution to *every* chunk.
//!   `Recv { reduce: true }` folds the incoming partial sums into per-chunk
//!   accumulators; `Send` transmits `own contribution (+ accumulator)` for
//!   each chunk and consumes both. At completion rank `r` holds the full
//!   sum for its own chunks only.

use std::collections::BTreeMap;

use crate::core::{ChunkId, Collective, Rank};

/// One operation in a rank's program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Send `chunks` (aggregated into a single message) to `peer`.
    Send {
        peer: Rank,
        chunks: Vec<ChunkId>,
        /// Logical schedule step (for display/grouping; not needed for
        /// execution, which relies on per-channel order + per-connection
        /// FIFO).
        step: usize,
        /// The channel (connection + proxy stream) this op runs on.
        channel: usize,
    },
    /// Receive a message of `chunks` from `peer`. `reduce` folds into
    /// accumulators (reduce-scatter) instead of taking ownership
    /// (all-gather).
    Recv {
        peer: Rank,
        chunks: Vec<ChunkId>,
        reduce: bool,
        step: usize,
        /// The channel (connection + proxy stream) this op runs on.
        channel: usize,
    },
}

impl Op {
    /// A send on channel 0 — what the single-channel generators emit; the
    /// channel splitter ([`crate::sched::channel::split`]) and the composer
    /// re-home ops onto other channels.
    pub fn send(peer: Rank, chunks: Vec<ChunkId>, step: usize) -> Op {
        Op::Send { peer, chunks, step, channel: 0 }
    }

    /// A receive on channel 0 (see [`Op::send`]).
    pub fn recv(peer: Rank, chunks: Vec<ChunkId>, reduce: bool, step: usize) -> Op {
        Op::Recv { peer, chunks, reduce, step, channel: 0 }
    }

    pub fn step(&self) -> usize {
        match self {
            Op::Send { step, .. } | Op::Recv { step, .. } => *step,
        }
    }
    pub fn channel(&self) -> usize {
        match self {
            Op::Send { channel, .. } | Op::Recv { channel, .. } => *channel,
        }
    }
    pub fn chunks(&self) -> &[ChunkId] {
        match self {
            Op::Send { chunks, .. } | Op::Recv { chunks, .. } => chunks,
        }
    }
    pub fn peer(&self) -> Rank {
        match self {
            Op::Send { peer, .. } | Op::Recv { peer, .. } => *peer,
        }
    }
    pub fn is_send(&self) -> bool {
        matches!(self, Op::Send { .. })
    }
}

/// A complete collective schedule for `nranks`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Program {
    pub nranks: usize,
    pub collective: Collective,
    /// Human-readable generator name, e.g. `pat(a=2)`.
    pub algorithm: String,
    /// `ranks[r]` is rank `r`'s ordered op list (the merge of its
    /// per-channel streams; filter by [`Op::channel`] to recover them).
    pub ranks: Vec<Vec<Op>>,
    /// Number of logical steps (max `Op::step` + 1).
    pub steps: usize,
    /// Number of channels (max `Op::channel` + 1, at least 1). Maintained
    /// by [`Program::push`].
    pub channels: usize,
}

impl Program {
    pub fn new(nranks: usize, collective: Collective, algorithm: impl Into<String>) -> Program {
        Program {
            nranks,
            collective,
            algorithm: algorithm.into(),
            ranks: vec![Vec::new(); nranks],
            steps: 0,
            channels: 1,
        }
    }

    pub fn push(&mut self, rank: Rank, op: Op) {
        self.steps = self.steps.max(op.step() + 1);
        self.channels = self.channels.max(op.channel() + 1);
        self.ranks[rank].push(op);
    }

    /// Mirror a program between the two primitive collectives: reverse each
    /// rank's op order, swap `Send`↔`Recv`, and set the `reduce` flag to
    /// match the mirrored collective (all-gather → reduce-scatter gains
    /// reducing receives; reduce-scatter → all-gather loses them). Steps
    /// are renumbered so the mirrored first step is step 0; channels are
    /// preserved (the mirror of a multi-channel program runs the same
    /// channels backwards). The operation is an involution:
    /// `p.mirror().mirror() == p`.
    ///
    /// Why this is correct: in a valid all-gather, every `Recv` of a chunk
    /// precedes all later `Send`s of that chunk on the same rank
    /// (causality), and per-connection FIFO matching holds. Reversal flips
    /// both: all reduced receives of a chunk now precede its single send
    /// (the accumulator is complete before forwarding), and per-connection
    /// sequences reverse consistently on both sides, so FIFO matching is
    /// preserved. This is the paper's reduce-scatter construction: reversed
    /// tree, nearest dimensions first, parallel (linear) phase before the
    /// logarithmic phase. The same argument read backwards takes a valid
    /// reduce-scatter to a valid all-gather.
    ///
    /// All-reduce programs are compositions, not mirrors of anything —
    /// mirroring one is a caller bug and panics.
    pub fn mirror(&self) -> Program {
        let (to, reduce_on_recv) = match self.collective {
            Collective::AllGather => (Collective::ReduceScatter, true),
            Collective::ReduceScatter => (Collective::AllGather, false),
            Collective::AllReduce => {
                panic!("mirror() is defined on all-gather/reduce-scatter programs only")
            }
        };
        let last = self.steps.saturating_sub(1);
        let mut out = Program::new(self.nranks, to, self.algorithm.clone());
        for (r, ops) in self.ranks.iter().enumerate() {
            for op in ops.iter().rev() {
                let m = match op {
                    Op::Send { peer, chunks, step, channel } => Op::Recv {
                        peer: *peer,
                        chunks: chunks.clone(),
                        reduce: reduce_on_recv,
                        step: last - *step,
                        channel: *channel,
                    },
                    Op::Recv { peer, chunks, step, channel, .. } => Op::Send {
                        peer: *peer,
                        chunks: chunks.clone(),
                        step: last - *step,
                        channel: *channel,
                    },
                };
                out.push(r, m);
            }
        }
        out
    }

    /// The chunk id space of this program: one past the largest chunk id
    /// any op touches, and at least `nranks` (the primitive collectives'
    /// chunk space). Composed all-reduce programs use `segments × nranks`
    /// ids (see [`crate::sched::compose`]) and channel-split programs
    /// `channels × base` ids (see [`crate::sched::channel`]); the
    /// transport sizes buffers from this.
    pub fn chunk_space(&self) -> usize {
        self.ranks
            .iter()
            .flat_map(|ops| ops.iter())
            .flat_map(|op| op.chunks().iter().copied())
            .max()
            .map(|m| m + 1)
            .unwrap_or(0)
            .max(self.nranks)
    }

    /// All (src, dst, chunks, step, channel) message tuples, in global step
    /// order (ties broken by src). Convenient for printing and traffic
    /// analysis.
    pub fn messages(&self) -> Vec<Message> {
        let mut msgs = Vec::new();
        for (src, ops) in self.ranks.iter().enumerate() {
            for op in ops {
                if let Op::Send { peer, chunks, step, channel } = op {
                    msgs.push(Message {
                        src,
                        dst: *peer,
                        chunks: chunks.clone(),
                        step: *step,
                        channel: *channel,
                    });
                }
            }
        }
        msgs.sort_by_key(|m| (m.step, m.src));
        msgs
    }

    /// Aggregate statistics used by benches and the tuner cost model.
    pub fn stats(&self) -> ProgramStats {
        let msgs = self.messages();
        let nmsg = msgs.len();
        let total_chunk_sends: usize = msgs.iter().map(|m| m.chunks.len()).sum();
        let max_agg = msgs.iter().map(|m| m.chunks.len()).max().unwrap_or(0);
        let mut per_rank_msgs: Vec<usize> = vec![0; self.nranks];
        let mut per_rank_chunks: Vec<usize> = vec![0; self.nranks];
        for m in &msgs {
            per_rank_msgs[m.src] += 1;
            per_rank_chunks[m.src] += m.chunks.len();
        }
        // Serial depth per rank: number of ops in the longest rank program.
        let depth = self.ranks.iter().map(|o| o.len()).max().unwrap_or(0);
        ProgramStats {
            steps: self.steps,
            messages: nmsg,
            chunk_transfers: total_chunk_sends,
            max_aggregation: max_agg,
            max_rank_messages: per_rank_msgs.iter().copied().max().unwrap_or(0),
            max_rank_chunks: per_rank_chunks.iter().copied().max().unwrap_or(0),
            depth,
        }
    }

    /// Group messages by logical step — the "rounds" shown in the paper's
    /// figures.
    pub fn rounds(&self) -> BTreeMap<usize, Vec<Message>> {
        let mut by_step: BTreeMap<usize, Vec<Message>> = BTreeMap::new();
        for m in self.messages() {
            by_step.entry(m.step).or_default().push(m);
        }
        by_step
    }

    pub fn total_ops(&self) -> usize {
        self.ranks.iter().map(|o| o.len()).sum()
    }
}

/// A single message extracted from a program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Message {
    pub src: Rank,
    pub dst: Rank,
    pub chunks: Vec<ChunkId>,
    pub step: usize,
    pub channel: usize,
}

/// Summary statistics of a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgramStats {
    /// Logical step count (the paper's "number of network transfers" per
    /// rank for symmetric schedules).
    pub steps: usize,
    /// Total messages across all ranks.
    pub messages: usize,
    /// Total chunk transfers (sum of message aggregation counts).
    pub chunk_transfers: usize,
    /// Largest number of chunks aggregated into one message.
    pub max_aggregation: usize,
    /// Max messages sent by any single rank.
    pub max_rank_messages: usize,
    /// Max chunk transfers sent by any single rank.
    pub max_rank_chunks: usize,
    /// Longest per-rank op list (serial depth).
    pub depth: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_ag() -> Program {
        // 2 ranks: 0 sends chunk 0 to 1; 1 sends chunk 1 to 0.
        let mut p = Program::new(2, Collective::AllGather, "toy");
        p.push(0, Op::send(1, vec![0], 0));
        p.push(0, Op::recv(1, vec![1], false, 0));
        p.push(1, Op::send(0, vec![1], 0));
        p.push(1, Op::recv(0, vec![0], false, 0));
        p
    }

    #[test]
    fn mirror_swaps_and_reverses() {
        let ag = toy_ag();
        let rs = ag.mirror();
        assert_eq!(rs.collective, Collective::ReduceScatter);
        // rank 0: originally [Send c0, Recv c1] -> mirrored [Send c1, Recv c0 reduce]
        assert_eq!(
            rs.ranks[0],
            vec![Op::send(1, vec![1], 0), Op::recv(1, vec![0], true, 0)]
        );
        assert_eq!(rs.steps, 1);
    }

    #[test]
    fn stats_counts() {
        let s = toy_ag().stats();
        assert_eq!(s.steps, 1);
        assert_eq!(s.messages, 2);
        assert_eq!(s.chunk_transfers, 2);
        assert_eq!(s.max_aggregation, 1);
        assert_eq!(s.depth, 2);
    }

    #[test]
    fn mirror_is_involution_on_toy() {
        let ag = toy_ag();
        let back = ag.mirror().mirror();
        assert_eq!(back, ag);
    }

    #[test]
    fn chunk_space_covers_ids_and_ranks() {
        assert_eq!(toy_ag().chunk_space(), 2);
        let mut p = Program::new(2, Collective::AllReduce, "t");
        p.push(0, Op::send(1, vec![5], 0));
        assert_eq!(p.chunk_space(), 6);
        // opless programs fall back to nranks
        assert_eq!(Program::new(3, Collective::AllReduce, "t").chunk_space(), 3);
    }

    #[test]
    fn messages_ordered_by_step() {
        let mut p = Program::new(2, Collective::AllGather, "t");
        p.push(1, Op::send(0, vec![1], 1));
        p.push(0, Op::send(1, vec![0], 0));
        let m = p.messages();
        assert_eq!(m[0].step, 0);
        assert_eq!(m[1].step, 1);
    }

    /// Channels are tracked by push, surfaced in messages, and preserved —
    /// in both directions — by the mirror.
    #[test]
    fn channels_tracked_and_mirrored() {
        let mut p = Program::new(2, Collective::AllGather, "t");
        assert_eq!(p.channels, 1);
        p.push(0, Op::send(1, vec![0], 0));
        p.push(1, Op::recv(0, vec![0], false, 0));
        p.push(0, Op::Send { peer: 1, chunks: vec![2], step: 0, channel: 1 });
        p.push(1, Op::Recv { peer: 0, chunks: vec![2], reduce: false, step: 0, channel: 1 });
        assert_eq!(p.channels, 2);
        let by_chan: Vec<usize> = p.messages().iter().map(|m| m.channel).collect();
        assert_eq!(by_chan, vec![0, 1]);
        let rs = p.mirror();
        assert_eq!(rs.channels, 2);
        assert_eq!(rs.ranks[0][0].channel(), 1); // reversed order, channel kept
        assert_eq!(rs.mirror(), p);
    }
}
