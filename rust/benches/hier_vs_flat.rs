//! Hierarchical vs flat PAT at scale on a tapered three-level fat-tree.
//!
//! The production question the `sched::hier` subsystem answers: once the
//! fabric's upper tiers are tapered and ranks are packed 8-to-a-leaf, how
//! much does running PAT *between nodes only* (leaders), with the chatty
//! phases kept under the leaf switches, buy over the flat schedule? This
//! bench sweeps 64–1024 simulated ranks at equal aggregation and reports
//! completion time plus the cross-leaf traffic metrics (messages and bytes
//! at fabric level ≥ 1) for both, emitting the usual JSON report.

use patcol::core::{Algorithm, Collective, Placement};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, SimReport, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn cross_msgs(r: &SimReport) -> usize {
    r.msgs_by_level[1..].iter().sum()
}

fn cross_bytes(r: &SimReport) -> usize {
    r.bytes_by_level[1..].iter().sum()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let ranks_per_leaf = 8usize;
    let leaves_per_pod = 4usize;
    let taper = 0.25f64;
    let chunk = 4 << 10; // latency-relevant size, the paper's PAT regime
    let agg = 4usize;
    let cost = CostModel::ib_hdr();

    let mut report = Report::new("hier_vs_flat");
    report.param("ranks_per_leaf", Json::num(ranks_per_leaf as f64));
    report.param("leaves_per_pod", Json::num(leaves_per_pod as f64));
    report.param("core_taper", Json::num(taper));
    report.param("chunk_bytes", Json::num(chunk as f64));
    report.param("aggregation", Json::num(agg as f64));

    println!(
        "\nall-gather, pat(a={agg}) vs hier_pat(a={agg}) on tapered three-level fat-trees \
         ({} per rank, top tier x{taper}):",
        fmt_bytes(chunk)
    );
    let mut t = Table::new([
        "ranks",
        "flat time",
        "hier time",
        "speedup",
        "flat x-leaf msgs",
        "hier x-leaf msgs",
        "flat x-leaf bytes",
        "hier x-leaf bytes",
    ]);

    let rank_sweep: &[usize] = if smoke {
        &[64]
    } else {
        &[64, 128, 256, 512, 1024]
    };
    for &n in rank_sweep {
        let topo = Topology::three_level(
            n,
            ranks_per_leaf,
            leaves_per_pod,
            4,
            2,
            CostModel::ib_hdr_nic_bw(),
            1.0,
            taper,
        )
        .unwrap();
        let pl = Placement::uniform(n, ranks_per_leaf).unwrap();
        topo.check_placement(&pl).unwrap();

        let flat_prog =
            sched::generate(Algorithm::Pat { aggregation: agg }, Collective::AllGather, n)
                .unwrap();
        let hier_prog = sched::generate_placed(
            Algorithm::HierPat { aggregation: agg },
            Collective::AllGather,
            &pl,
        )
        .unwrap();

        let flat = simulate(&flat_prog, &topo, &cost, chunk).unwrap();
        let hier = simulate(&hier_prog, &topo, &cost, chunk).unwrap();

        t.row([
            n.to_string(),
            fmt_time_s(flat.total_time),
            fmt_time_s(hier.total_time),
            format!("{:.2}x", flat.total_time / hier.total_time),
            cross_msgs(&flat).to_string(),
            cross_msgs(&hier).to_string(),
            fmt_bytes(cross_bytes(&flat)),
            fmt_bytes(cross_bytes(&hier)),
        ]);
        report.rows.push(Json::obj(vec![
            ("nranks", Json::num(n as f64)),
            ("flat_time", Json::num(flat.total_time)),
            ("hier_time", Json::num(hier.total_time)),
            ("flat_cross_msgs", Json::num(cross_msgs(&flat) as f64)),
            ("hier_cross_msgs", Json::num(cross_msgs(&hier) as f64)),
            ("flat_cross_bytes", Json::num(cross_bytes(&flat) as f64)),
            ("hier_cross_bytes", Json::num(cross_bytes(&hier) as f64)),
            ("flat_busiest_util", Json::num(flat.busiest_link_utilization)),
            ("hier_busiest_util", Json::num(hier.busiest_link_utilization)),
        ]));

        assert!(
            cross_msgs(&hier) < cross_msgs(&flat),
            "n={n}: hier must cross leaves less than flat"
        );
    }
    print!("{}", t.render());
    report.save().unwrap();
}
