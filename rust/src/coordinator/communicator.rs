//! The public communicator API — the NCCL-equivalent object a framework
//! holds per process group.
//!
//! A [`Communicator`] owns: the resolved datapath (scalar or the PJRT
//! service running the AOT Pallas kernels), a program cache (schedules are
//! generated once per (collective, algorithm, nranks) and reused), and the
//! tuner used when no algorithm is pinned.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::core::{Algorithm, Collective, Error, PhaseAlg, Placement, Result};
use crate::coordinator::tuner::{self, Tuner};
use crate::runtime::{default_reduce_shards, PjrtService, Registry};
use crate::sched::{self, program::Program};
use crate::transport::{self, ArenaCache, DataPath, TransportOptions, TransportReport};

/// Which reduction backend the communicator uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DataPathKind {
    /// Pure-rust reduction (always available).
    #[default]
    Scalar,
    /// AOT Pallas kernels through PJRT (requires `make artifacts`).
    Pjrt,
}

/// Communicator configuration.
#[derive(Debug, Clone)]
pub struct CommConfig {
    pub nranks: usize,
    /// Pinned algorithm; `None` lets the tuner decide per call.
    pub algorithm: Option<Algorithm>,
    /// Intermediate-buffer budget in chunk slots (drives PAT aggregation
    /// and is enforced by the transport buffer pool).
    pub buffer_slots: Option<usize>,
    pub datapath: DataPathKind,
    /// Shard count for the PJRT reduction service (config key
    /// `reduce_shards`, CLI `--reduce-shards`): worker threads each owning
    /// a PJRT client, with requests routed by `(rank, channel)` hash.
    /// `None` auto-sizes to `min(cores, nranks)`
    /// ([`default_reduce_shards`]). Ignored on the scalar datapath.
    pub reduce_shards: Option<usize>,
    /// Artifact directory for the PJRT datapath (default: $PATCOL_ARTIFACTS
    /// or ./artifacts).
    pub artifacts_dir: Option<PathBuf>,
    /// Verify programs before first use (cheap; cached).
    pub validate: bool,
    /// Rank → node placement for hierarchical algorithms and the
    /// placement-aware tuner (config keys `placement` / `ranks_per_node`).
    /// `None` assumes contiguous nodes of
    /// [`crate::sched::DEFAULT_RANKS_PER_NODE`] when a hierarchical
    /// algorithm is pinned.
    pub placement: Option<Placement>,
    /// Stripe leaders per node for hierarchical algorithms (config key
    /// `leaders_per_node`, CLI `--leaders-per-node`): each leader owns an
    /// interleaved chunk stripe and its own inter-node channel, so a
    /// node's uplink traffic rides `L` distinct ECMP flows
    /// ([`crate::sched::hier`]). Applied to the configured (or default)
    /// placement at construction; clamped to the smallest node size.
    /// `None` = 1 leader per node (the classic two-level schedule).
    pub leaders_per_node: Option<usize>,
    /// Per-node uplink bandwidth (bytes/s) for the tuner's
    /// flat-vs-hierarchical crossover (config key `inter_gbps`); `None`
    /// models a non-blocking fabric.
    pub inter_bw: Option<f64>,
    /// Number of NCCL-style channels to split every collective across
    /// (config key `channels`, CLI `--channels`, or the `alg*C` spelling).
    /// `None` lets the tuner decide per call
    /// ([`Tuner::choose_channels`] — one channel unless `parallel_links`
    /// says the fabric has rails to recruit).
    pub channels: Option<usize>,
    /// Parallel fabric links per rank for the tuner's channel-count
    /// crossover (config key `parallel_links`); `None` = 1, which keeps
    /// auto channel selection at a single channel.
    pub parallel_links: Option<usize>,
    /// Number of gradient buckets every [`Communicator::all_reduce`] is
    /// split into (config key `buckets`, CLI `--buckets` /
    /// `--bucket-bytes`): the payload is cut into that many near-equal
    /// buckets and runs as ONE fused bucketed program
    /// ([`crate::sched::bucket`]) in which bucket `i+1`'s reduce-scatter
    /// overlaps bucket `i`'s all-gather. `None` or `Some(1)` keeps the
    /// single-operation composed path. Explicitly-batched calls go through
    /// [`Communicator::all_reduce_batch`] regardless of this knob. Each
    /// bucket runs on its own channel set, so combining this with a
    /// pinned `channels > 1` is a loud error on the all-reduce path
    /// (striping buckets further is an open follow-up); primitive
    /// collectives on the same communicator still honor `channels`.
    pub buckets: Option<usize>,
    /// Record the unified [`crate::obs`] event timeline on every
    /// transport run (config key `trace`, CLI `--trace <path>`): each
    /// [`CollectiveReport`]'s `transport.trace` then carries the merged
    /// per-rank flight recordings, exportable with
    /// [`crate::obs::chrome_trace`]. Off by default — the disabled
    /// recorder costs one branch per event site.
    pub trace: bool,
    /// Append one [`crate::obs::calib::CalibRecord`] (tuner prediction vs
    /// transport wall time) to this JSONL history per collective call
    /// (config key `calib_history`, CLI `--calib-history <path>`). The
    /// drift trends over this file are what justify tightening the
    /// tuner's `*_CALIBRATION_TOLERANCE` constants. `None` records
    /// nothing.
    pub calib_history: Option<PathBuf>,
    /// Adversarial delivery policy for every transport run (config key
    /// `adversary` = `<preset>[:<seed>]`, e.g. `delay` or `reorder:7`):
    /// each collective executes under the named
    /// [`crate::adversary::PolicySpec`] delivery schedule instead of
    /// eager FIFO delivery — a chaos knob for soak tests, not for
    /// production. Results must still be bit-exact (the transport's
    /// ordering guard holds); see [`crate::adversary`]. `None` (the
    /// default) is eager delivery with zero overhead.
    pub adversary: Option<crate::adversary::PolicySpec>,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            nranks: 1,
            algorithm: None,
            buffer_slots: None,
            datapath: DataPathKind::Scalar,
            reduce_shards: None,
            artifacts_dir: None,
            validate: true,
            placement: None,
            leaders_per_node: None,
            inter_bw: None,
            channels: None,
            parallel_links: None,
            buckets: None,
            trace: false,
            calib_history: None,
            adversary: None,
        }
    }
}

/// Result metadata for one collective call.
#[derive(Debug, Clone)]
pub struct CollectiveReport {
    pub algorithm: Algorithm,
    /// Channels the program was split across (1 = unsplit).
    pub channels: usize,
    pub steps: usize,
    pub transport: TransportReport,
}

/// An NCCL-like communicator over `nranks` in-process ranks.
pub struct Communicator {
    cfg: CommConfig,
    datapath: DataPath,
    _service: Option<PjrtService>,
    tuner: Tuner,
    cache: Mutex<HashMap<(Collective, String, usize), Arc<Program>>>,
    /// Shared transport arena: every collective on this communicator
    /// leases the same page-aligned backing allocation, so steady-state
    /// calls run with zero datapath allocations.
    arena: ArenaCache,
}

impl Communicator {
    pub fn new(mut cfg: CommConfig) -> Result<Communicator> {
        if cfg.nranks == 0 {
            return Err(Error::Config("nranks must be >= 1".into()));
        }
        // Fold the leader count into the placement up front so every
        // consumer (tuner crossover, program cache, staging bound) sees
        // the same striped placement.
        if let Some(l) = cfg.leaders_per_node {
            if l == 0 {
                return Err(Error::Config("leaders_per_node must be >= 1".into()));
            }
            if let Some(pl) = cfg.placement.take() {
                cfg.placement = Some(pl.with_leaders(l)?);
            }
        }
        if let Some(alg) = cfg.algorithm {
            if !alg.supports(cfg.nranks) {
                return Err(Error::Config(format!(
                    "{alg} does not support nranks={}",
                    cfg.nranks
                )));
            }
        }
        if let Some(pl) = &cfg.placement {
            if pl.nranks() != cfg.nranks {
                return Err(Error::Config(format!(
                    "placement covers {} ranks but nranks={}",
                    pl.nranks(),
                    cfg.nranks
                )));
            }
        }
        if cfg.channels == Some(0) {
            return Err(Error::Config("channels must be >= 1".into()));
        }
        if cfg.parallel_links == Some(0) {
            return Err(Error::Config("parallel_links must be >= 1".into()));
        }
        if cfg.buckets == Some(0) {
            return Err(Error::Config("buckets must be >= 1".into()));
        }
        if cfg.reduce_shards == Some(0) {
            return Err(Error::Config("reduce_shards must be >= 1".into()));
        }
        let (datapath, service) = match cfg.datapath {
            DataPathKind::Scalar => (DataPath::Scalar, None),
            DataPathKind::Pjrt => {
                let dir = cfg
                    .artifacts_dir
                    .clone()
                    .unwrap_or_else(Registry::default_dir);
                let shards = cfg
                    .reduce_shards
                    .unwrap_or_else(|| default_reduce_shards(cfg.nranks));
                let (svc, handle) = PjrtService::spawn_sharded(dir, shards)?;
                (DataPath::Pjrt(handle), Some(svc))
            }
        };
        let tuner = Tuner {
            inter_bw: cfg.inter_bw,
            parallel_links: cfg.parallel_links.unwrap_or(1),
            ..Tuner::default()
        };
        Ok(Communicator {
            cfg,
            datapath,
            _service: service,
            tuner,
            cache: Mutex::new(HashMap::new()),
            arena: ArenaCache::new(),
        })
    }

    pub fn nranks(&self) -> usize {
        self.cfg.nranks
    }

    pub fn tuner(&self) -> &Tuner {
        &self.tuner
    }

    /// Resolve the algorithm for this call (pinned, or tuned from the
    /// message size, buffer budget, and — when configured — the rank
    /// placement). All-reduce resolves to a composition
    /// ([`Algorithm::Compose`]): the tuner sweeps phase pairs × segment
    /// counts, and a pinned non-composed algorithm is lifted to the
    /// sequential `alg+alg:1`.
    pub fn resolve(&self, coll: Collective, chunk_bytes: usize) -> Algorithm {
        let slots = self.cfg.buffer_slots.unwrap_or(usize::MAX / 2);
        if coll == Collective::AllReduce {
            return match self.cfg.algorithm {
                Some(Algorithm::PatAuto) | None => self
                    .tuner
                    .choose_allreduce(
                        self.cfg.nranks,
                        chunk_bytes,
                        slots,
                        self.cfg.placement.as_ref(),
                    )
                    .algorithm,
                Some(alg @ Algorithm::Compose { .. }) => alg,
                Some(alg) => PhaseAlg::from_algorithm(alg)
                    .map(|p| Algorithm::Compose { rs: p, ag: p, segments: 1 })
                    .unwrap_or(alg),
            };
        }
        match self.cfg.algorithm {
            Some(Algorithm::PatAuto) | None => {
                self.tuner
                    .choose_placed(
                        self.cfg.nranks,
                        chunk_bytes,
                        slots,
                        coll,
                        self.cfg.placement.as_ref(),
                    )
                    .algorithm
            }
            Some(alg) => alg,
        }
    }

    /// Resolve the channel count for this call: the pinned `channels`
    /// knob, or the tuner's channel crossover
    /// ([`Tuner::choose_channels`]) at the resolved algorithm's
    /// aggregation — which stays at one channel unless the configured
    /// `parallel_links` gives the extra channels links to recruit.
    pub fn resolve_channels(&self, alg: Algorithm, chunk_bytes: usize) -> usize {
        if let Some(c) = self.cfg.channels {
            return c.max(1);
        }
        let a = match alg {
            Algorithm::Pat { aggregation } | Algorithm::HierPat { aggregation } => aggregation,
            _ => usize::MAX,
        };
        self.tuner
            .choose_channels(self.cfg.nranks, a, chunk_bytes)
            .channels
    }

    /// The placement hierarchical programs are built from: the configured
    /// one, or contiguous default-sized nodes.
    fn effective_placement(&self) -> Result<Placement> {
        match &self.cfg.placement {
            Some(p) => Ok(p.clone()),
            None => {
                let pl = Placement::uniform(self.cfg.nranks, sched::DEFAULT_RANKS_PER_NODE)?;
                match self.cfg.leaders_per_node {
                    Some(l) => pl.with_leaders(l),
                    None => Ok(pl),
                }
            }
        }
    }

    fn program(&self, coll: Collective, alg: Algorithm, channels: usize) -> Result<Arc<Program>> {
        let key = (coll, alg.name(), channels);
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(&key) {
                return Ok(p.clone());
            }
        }
        let mut prog = if alg.uses_placement() {
            let pl = self.effective_placement()?;
            sched::generate_placed(alg, coll, &pl)?
        } else {
            sched::generate(alg, coll, self.cfg.nranks)?
        };
        if channels > 1 {
            prog = sched::channel::split(&prog, channels)?;
        }
        if self.cfg.validate {
            sched::verify::verify_program(&prog)?;
        }
        let prog = Arc::new(prog);
        self.cache
            .lock()
            .unwrap()
            .insert(key, prog.clone());
        Ok(prog)
    }

    /// Transport options for a program running on `channels` channels.
    /// `buffer_slots` is denominated in single-channel chunk slots; a
    /// C-channel program stripes chunks C× smaller, so the same byte
    /// budget holds C× the slots — without the scaling, a budget the
    /// tuner saturated at one channel would spuriously exhaust the pool
    /// the moment the collective is split.
    fn options(&self, channels: usize) -> TransportOptions {
        TransportOptions {
            datapath: self.datapath.clone(),
            slot_capacity: self
                .cfg
                .buffer_slots
                .map(|b| b.saturating_mul(channels.max(1))),
            staged: true,
            // programs are verified once at cache fill, not per call
            validate: false,
            trace: self.cfg.trace,
            arena: Some(self.arena.clone()),
            delivery: self
                .cfg
                .adversary
                .as_ref()
                .map(|spec| spec.transport_factory()),
            ..Default::default()
        }
    }

    /// Record one predicted-vs-measured calibration point into the
    /// configured drift history ([`crate::obs::calib`]). The prediction
    /// is recomputed from the tuner's closed form for the *resolved*
    /// algorithm — the same formula the crossover sweep ranked it by —
    /// so the residual measures model error, not selection error.
    /// Best-effort: an unwritable history warns on stderr rather than
    /// failing a collective that already produced correct output.
    fn record_calib(&self, coll: Collective, alg: Algorithm, chunk_bytes: usize, rep: &CollectiveReport) {
        if self.cfg.calib_history.is_none() {
            return;
        }
        let pl = self.cfg.placement.as_ref();
        let predicted_s = match (coll, alg) {
            (Collective::AllReduce, Algorithm::Compose { rs, ag, segments }) => {
                let seg_bytes = (chunk_bytes / segments.max(1)).max(1);
                self.tuner
                    .predict_allreduce(rs, ag, segments, self.cfg.nranks, seg_bytes, pl)
            }
            (_, alg) => match PhaseAlg::from_algorithm(alg) {
                Ok(ph) => self.tuner.predict_phase(ph, self.cfg.nranks, chunk_bytes, coll, pl),
                // No closed form for this spelling — nothing to compare.
                Err(_) => return,
            },
        };
        let bytes = match coll {
            Collective::AllGather => chunk_bytes,
            _ => chunk_bytes.saturating_mul(self.cfg.nranks),
        };
        self.append_calib(coll, alg.name(), bytes, predicted_s, rep);
    }

    fn append_calib(
        &self,
        coll: Collective,
        alg: String,
        bytes: usize,
        predicted_s: f64,
        rep: &CollectiveReport,
    ) {
        let Some(path) = &self.cfg.calib_history else { return };
        let rec = crate::obs::calib::CalibRecord {
            collective: match coll {
                Collective::AllGather => "allgather",
                Collective::ReduceScatter => "reduce_scatter",
                Collective::AllReduce => "allreduce",
            }
            .into(),
            alg,
            nranks: self.cfg.nranks,
            bytes,
            channels: rep.channels,
            predicted_us: predicted_s * 1e6,
            measured_us: rep.transport.wall.as_secs_f64() * 1e6,
        };
        if let Err(e) = crate::obs::calib::append(path, &rec) {
            eprintln!("[calib] cannot append to {}: {e}", path.display());
        }
    }

    /// All-gather: `inputs[r]` is rank r's contribution; every output is
    /// the concatenation of all contributions.
    pub fn all_gather(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.all_gather_report(inputs)?.0)
    }

    /// All-gather returning execution metadata. Multi-channel programs
    /// stripe each contribution across their channels; lengths that do not
    /// divide into the stripes are padded internally and the padding
    /// stripped on return.
    pub fn all_gather_report(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, CollectiveReport)> {
        let n = self.cfg.nranks;
        let len = inputs.first().map(Vec::len).unwrap_or(0);
        if inputs.iter().any(|v| v.len() != len) {
            return Err(Error::Config("ragged all-gather inputs".into()));
        }
        let chunk_bytes = len * 4;
        let alg = self.resolve(Collective::AllGather, chunk_bytes);
        let channels = self.resolve_channels(alg, chunk_bytes);
        let prog = self.program(Collective::AllGather, alg, channels)?;
        let stripes = (prog.chunk_space() / n.max(1)).max(1);
        let report = |rep| CollectiveReport {
            algorithm: alg,
            channels: prog.channels,
            steps: prog.steps,
            transport: rep,
        };
        if len % stripes == 0 {
            let (out, rep) = transport::run_allgather(&prog, inputs, &self.options(prog.channels))?;
            let cr = report(rep);
            self.record_calib(Collective::AllGather, alg, chunk_bytes, &cr);
            return Ok((out, cr));
        }
        let padded = len.div_ceil(stripes) * stripes;
        let padded_inputs: Vec<Vec<f32>> = inputs
            .iter()
            .map(|v| {
                let mut p = v.clone();
                p.resize(padded, 0.0);
                p
            })
            .collect();
        let (outs, rep) =
            transport::run_allgather(&prog, &padded_inputs, &self.options(prog.channels))?;
        let outs = outs
            .into_iter()
            .map(|o| {
                let mut trimmed = Vec::with_capacity(n * len);
                for s in 0..n {
                    trimmed.extend_from_slice(&o[s * padded..s * padded + len]);
                }
                trimmed
            })
            .collect();
        let cr = report(rep);
        self.record_calib(Collective::AllGather, alg, chunk_bytes, &cr);
        Ok((outs, cr))
    }

    /// Reduce-scatter: `inputs[r]` holds rank r's contribution to all `n`
    /// chunks; output `r` is the element-wise sum of chunk `r`.
    pub fn reduce_scatter(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.reduce_scatter_report(inputs)?.0)
    }

    /// All-reduce, composed the NCCL way from the paper's two collectives:
    /// one fused reduce-scatter ∘ all-gather program
    /// ([`crate::sched::compose`]), pipelined over payload segments so one
    /// segment's all-gather overlaps the next segment's reduce-scatter.
    /// The phase pair and segment count come from the pinned
    /// [`Algorithm::Compose`] (`rs+ag[:segments]`) or the tuner's
    /// pair × segment crossover sweep. Every rank returns the full
    /// element-wise sum.
    ///
    /// Input vectors may have any (uniform) length; they are padded to the
    /// composed chunk grid (`segments × nranks` chunks) internally and the
    /// padding is stripped on return.
    pub fn all_reduce(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.all_reduce_report(inputs)?.0)
    }

    /// All-reduce returning execution metadata.
    pub fn all_reduce_report(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, CollectiveReport)> {
        let n = self.cfg.nranks;
        if inputs.len() != n {
            return Err(Error::Config(format!(
                "expected {n} inputs, got {}",
                inputs.len()
            )));
        }
        let len = inputs.first().map(Vec::len).unwrap_or(0);
        if inputs.iter().any(|v| v.len() != len) {
            return Err(Error::Config("ragged all-reduce inputs".into()));
        }
        if let Some(nb) = self.cfg.buckets.filter(|&b| b > 1) {
            // Gradient bucketing: cut the payload into near-equal
            // contiguous buckets and run them as ONE fused bucketed
            // program (bucket i+1's reduce-scatter overlapping bucket
            // i's all-gather) instead of one monolithic composition.
            // The split is tuner::bucket_sizes (in element units), so
            // execution matches the shape choose_bucketed predicts.
            let sizes = crate::coordinator::tuner::bucket_sizes(len, nb, false);
            let mut buckets: Vec<Vec<Vec<f32>>> = Vec::with_capacity(nb);
            let mut pos = 0usize;
            for &l in &sizes {
                buckets.push(inputs.iter().map(|v| v[pos..pos + l].to_vec()).collect());
                pos += l;
            }
            let (bucket_outs, rep) = self.all_reduce_batch_report(&buckets)?;
            let outs = (0..n)
                .map(|r| {
                    let mut v = Vec::with_capacity(len);
                    for bo in &bucket_outs {
                        v.extend_from_slice(&bo[r]);
                    }
                    v
                })
                .collect();
            return Ok((outs, rep));
        }
        // Per-chunk payload at one segment — what the tuner's crossover
        // sweep expects.
        let chunk_bytes = len * 4 / n.max(1);
        let alg = self.resolve(Collective::AllReduce, chunk_bytes);
        let channels = self.resolve_channels(alg, chunk_bytes);
        let prog = self.program(Collective::AllReduce, alg, channels)?;
        let nchunks = prog.chunk_space();
        let chunk = len.div_ceil(nchunks).max(1);
        let padded = chunk * nchunks;
        let padded_inputs: Vec<Vec<f32>> = inputs
            .iter()
            .map(|v| {
                let mut p = v.clone();
                p.resize(padded, 0.0);
                p
            })
            .collect();
        let (outs, rep) =
            transport::run_allreduce(&prog, &padded_inputs, &self.options(prog.channels))?;
        let outs = outs
            .into_iter()
            .map(|mut v| {
                v.truncate(len);
                v
            })
            .collect();
        let cr = CollectiveReport {
            algorithm: alg,
            channels: prog.channels,
            steps: prog.steps,
            transport: rep,
        };
        self.record_calib(Collective::AllReduce, alg, chunk_bytes, &cr);
        Ok((outs, cr))
    }

    /// Bucketed all-reduce — the gradient-bucket entry point
    /// ([`crate::sched::bucket`]): `buckets[b]` holds bucket `b`'s `n`
    /// per-rank tensors (lengths may differ across buckets), and the whole
    /// batch executes as ONE fused multi-channel program in which bucket
    /// `i+1`'s reduce-scatter overlaps bucket `i`'s all-gather and every
    /// bucket runs on its own channels (parallel ECMP flows). Returns the
    /// per-bucket full sums in the same `[bucket][rank]` shape.
    pub fn all_reduce_batch(&self, buckets: &[Vec<Vec<f32>>]) -> Result<Vec<Vec<Vec<f32>>>> {
        Ok(self.all_reduce_batch_report(buckets)?.0)
    }

    /// Bucketed all-reduce returning execution metadata. Bucket payloads
    /// are padded to the fused chunk grid internally (bucket `b`'s
    /// `segments × stripes_b × n` chunks each carry
    /// `⌈len_b / (segments·stripes_b·n)⌉` elements) and the padding is
    /// stripped on return; one transport buffer pool bounds the staging
    /// footprint across all buckets. On a multi-rail fabric
    /// (`parallel_links > 1`) buckets at or above
    /// [`tuner::BUCKET_STRIPE_THRESHOLD_BYTES`] are channel-striped
    /// across the rails ([`sched::bucket::stripe_plan`]); smaller buckets
    /// stay single-channel.
    pub fn all_reduce_batch_report(
        &self,
        buckets: &[Vec<Vec<f32>>],
    ) -> Result<(Vec<Vec<Vec<f32>>>, CollectiveReport)> {
        let n = self.cfg.nranks;
        let nb = buckets.len();
        if nb == 0 {
            return Err(Error::Config(
                "all_reduce_batch needs at least one bucket".into(),
            ));
        }
        let mut lens = Vec::with_capacity(nb);
        for (b, bk) in buckets.iter().enumerate() {
            if bk.len() != n {
                return Err(Error::Config(format!(
                    "bucket {b}: expected {n} inputs, got {}",
                    bk.len()
                )));
            }
            let len = bk.first().map(Vec::len).unwrap_or(0);
            if bk.iter().any(|v| v.len() != len) {
                return Err(Error::Config(format!("bucket {b}: ragged inputs")));
            }
            lens.push(len);
        }
        // Buckets already run on one channel set each (parallel ECMP
        // flows per bucket); striping every bucket further across pinned
        // channels would need a stripe-major chunk grid — a ROADMAP
        // follow-up — so an explicit channels pin is a loud error here
        // rather than a silently dropped knob.
        if let Some(c) = self.cfg.channels.filter(|&c| c > 1) {
            return Err(Error::Config(format!(
                "channels={c} cannot be combined with bucketed all-reduce \
                 (each bucket already runs on its own channel set)"
            )));
        }
        let total: usize = lens.iter().sum();
        // Phase resolution sees the per-chunk payload of an average
        // bucket — the per-operation size the crossover sweep models.
        let chunk_bytes = (total * 4 / (n.max(1) * nb)).max(1);
        let (rs, ag, segments) = self.resolve_phases(chunk_bytes)?;
        // Cross-bucket channel striping: buckets big enough to be
        // bandwidth-bound get one channel set per fabric rail (their own
        // ECMP flows); small buckets stay single-channel and skip the
        // per-round channel tax. `parallel_links = 1` (the default)
        // stripes nothing.
        let bucket_bytes: Vec<usize> = lens.iter().map(|&l| l * 4).collect();
        let stripes = sched::bucket::stripe_plan(
            &bucket_bytes,
            tuner::BUCKET_STRIPE_THRESHOLD_BYTES,
            self.tuner.parallel_links,
        );
        let prog = self.bucketed_program(rs, ag, segments, nb, &stripes)?;
        // chunks per bucket (stripes multiply the grid; each striped
        // chunk carries 1/stripes of the bucket payload)
        let m: Vec<usize> = stripes.iter().map(|&st| segments * st * n).collect();
        let elems: Vec<usize> = lens.iter().zip(&m).map(|(&l, &mb)| l.div_ceil(mb)).collect();
        let mut chunk_elems = Vec::with_capacity(m.iter().sum());
        for (&mb, &e) in m.iter().zip(&elems) {
            chunk_elems.resize(chunk_elems.len() + mb, e);
        }
        let padded_total: usize = chunk_elems.iter().sum();
        let padded_inputs: Vec<Vec<f32>> = (0..n)
            .map(|r| {
                let mut v = Vec::with_capacity(padded_total);
                for (b, bk) in buckets.iter().enumerate() {
                    v.extend_from_slice(&bk[r]);
                    v.resize(v.len() + (m[b] * elems[b] - lens[b]), 0.0);
                }
                v
            })
            .collect();
        let (outs, rep) = transport::run_allreduce_batch(
            &prog,
            &chunk_elems,
            &padded_inputs,
            &self.options(prog.channels),
        )?;
        // Slice the per-bucket sums back out, dropping the padding.
        let mut result: Vec<Vec<Vec<f32>>> = vec![Vec::with_capacity(n); nb];
        for out in outs {
            let mut pos = 0usize;
            for (b, bucket_out) in result.iter_mut().enumerate() {
                bucket_out.push(out[pos..pos + lens[b]].to_vec());
                pos += m[b] * elems[b];
            }
        }
        let cr = CollectiveReport {
            algorithm: Algorithm::Compose { rs, ag, segments },
            channels: prog.channels,
            steps: prog.steps,
            transport: rep,
        };
        if self.cfg.calib_history.is_some() {
            let predicted_s = self.tuner.predict_bucketed(
                rs,
                ag,
                &bucket_bytes,
                segments,
                n,
                self.cfg.placement.as_ref(),
            );
            self.append_calib(
                Collective::AllReduce,
                format!("bkt{nb}:{}+{}:{segments}", rs.spec(), ag.spec()),
                total * 4,
                predicted_s,
                &cr,
            );
        }
        Ok((result, cr))
    }

    /// The (rs, ag, segments) phase triple an all-reduce call resolves to
    /// (pinned composition, lifted bare algorithm, or the tuner's sweep).
    fn resolve_phases(&self, chunk_bytes: usize) -> Result<(PhaseAlg, PhaseAlg, usize)> {
        match self.resolve(Collective::AllReduce, chunk_bytes) {
            Algorithm::Compose { rs, ag, segments } => Ok((rs, ag, segments)),
            other => {
                let ph = PhaseAlg::from_algorithm(other)?;
                Ok((ph, ph, 1))
            }
        }
    }

    /// Cached fused program for `nb` uniform buckets of `rs+ag:segments`,
    /// channel-striped per bucket by `stripes`
    /// ([`sched::bucket::fuse_striped`]).
    fn bucketed_program(
        &self,
        rs: PhaseAlg,
        ag: PhaseAlg,
        segments: usize,
        nb: usize,
        stripes: &[usize],
    ) -> Result<Arc<Program>> {
        let key = (
            Collective::AllReduce,
            format!("bkt{nb}:{}+{}:{segments}|st{stripes:?}", rs.spec(), ag.spec()),
            1usize,
        );
        {
            let cache = self.cache.lock().unwrap();
            if let Some(p) = cache.get(&key) {
                return Ok(p.clone());
            }
        }
        let build = |alg: Algorithm, coll: Collective| -> Result<Program> {
            if alg.uses_placement() {
                let pl = self.effective_placement()?;
                sched::generate_placed(alg, coll, &pl)
            } else {
                sched::generate(alg, coll, self.cfg.nranks)
            }
        };
        let rsp = build(rs.to_algorithm(), Collective::ReduceScatter)?;
        let agp = build(ag.to_algorithm(), Collective::AllGather)?;
        let prog = sched::bucket::fuse_striped(
            &sched::bucket::uniform(&rsp, &agp, nb, segments),
            stripes,
        )?;
        if self.cfg.validate {
            sched::verify::verify_program(&prog)?;
        }
        let prog = Arc::new(prog);
        self.cache.lock().unwrap().insert(key, prog.clone());
        Ok(prog)
    }

    /// Reduce-scatter returning execution metadata. Multi-channel
    /// programs stripe each output slot across their channels; slot
    /// lengths that do not divide into the stripes are padded internally
    /// and the padding stripped on return.
    pub fn reduce_scatter_report(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<(Vec<Vec<f32>>, CollectiveReport)> {
        let n = self.cfg.nranks;
        let total = inputs.first().map(Vec::len).unwrap_or(0);
        let chunk_bytes = total * 4 / n.max(1);
        let alg = self.resolve(Collective::ReduceScatter, chunk_bytes);
        let channels = self.resolve_channels(alg, chunk_bytes);
        let prog = self.program(Collective::ReduceScatter, alg, channels)?;
        let stripes = (prog.chunk_space() / n.max(1)).max(1);
        let report = |rep| CollectiveReport {
            algorithm: alg,
            channels: prog.channels,
            steps: prog.steps,
            transport: rep,
        };
        let slot = if n > 0 && total % n == 0 { total / n } else { 0 };
        if slot % stripes.max(1) == 0 {
            // (Also the error path: a `total` not divisible by nranks is
            // rejected by the transport with the pre-channel message.)
            let (out, rep) =
                transport::run_reduce_scatter(&prog, inputs, &self.options(prog.channels))?;
            let cr = report(rep);
            self.record_calib(Collective::ReduceScatter, alg, chunk_bytes, &cr);
            return Ok((out, cr));
        }
        if inputs.iter().any(|v| v.len() != total) {
            return Err(Error::Config("ragged reduce-scatter inputs".into()));
        }
        // Pad every per-rank output slot to a stripe multiple.
        let padl = slot.div_ceil(stripes) * stripes;
        let padded_inputs: Vec<Vec<f32>> = inputs
            .iter()
            .map(|v| {
                let mut p = vec![0f32; n * padl];
                for s in 0..n {
                    p[s * padl..s * padl + slot].copy_from_slice(&v[s * slot..(s + 1) * slot]);
                }
                p
            })
            .collect();
        let (outs, rep) =
            transport::run_reduce_scatter(&prog, &padded_inputs, &self.options(prog.channels))?;
        let outs = outs
            .into_iter()
            .map(|mut v| {
                v.truncate(slot);
                v
            })
            .collect();
        let cr = report(rep);
        self.record_calib(Collective::ReduceScatter, alg, chunk_bytes, &cr);
        Ok((outs, cr))
    }
}

impl std::fmt::Debug for Communicator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Communicator")
            .field("nranks", &self.cfg.nranks)
            .field("algorithm", &self.cfg.algorithm)
            .field("datapath", &self.datapath.name())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn comm(nranks: usize, alg: Option<Algorithm>) -> Communicator {
        Communicator::new(CommConfig { nranks, algorithm: alg, ..Default::default() }).unwrap()
    }

    #[test]
    fn allgather_end_to_end() {
        let n = 6;
        let c = comm(n, Some(Algorithm::Pat { aggregation: 2 }));
        let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 32]).collect();
        let (out, rep) = c.all_gather_report(&inputs).unwrap();
        assert_eq!(rep.algorithm, Algorithm::Pat { aggregation: 2 });
        for o in &out {
            assert_eq!(o.len(), n * 32);
            for r in 0..n {
                assert!(o[r * 32..(r + 1) * 32].iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn reduce_scatter_end_to_end() {
        let n = 5;
        let c = comm(n, None); // tuned
        let mut rng = Rng::new(4);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * 16).map(|_| rng.below(50) as f32).collect())
            .collect();
        let out = c.reduce_scatter(&inputs).unwrap();
        for r in 0..n {
            for i in 0..16 {
                let want: f32 = (0..n).map(|s| inputs[s][r * 16 + i]).sum();
                assert_eq!(out[r][i], want, "rank {r} idx {i}");
            }
        }
    }

    #[test]
    fn program_cache_reused() {
        let c = comm(4, Some(Algorithm::Ring));
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 8]).collect();
        c.all_gather(&inputs).unwrap();
        c.all_gather(&inputs).unwrap();
        assert_eq!(c.cache.lock().unwrap().len(), 1);
    }

    #[test]
    fn all_reduce_composed() {
        // length not divisible by nranks exercises the padding path
        let n = 6;
        let len = 50;
        let c = comm(n, Some(Algorithm::Pat { aggregation: 2 }));
        let mut rng = Rng::new(8);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.below(100) as f32).collect())
            .collect();
        let outs = c.all_reduce(&inputs).unwrap();
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out.len(), len, "rank {r}");
            for i in 0..len {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "rank {r} idx {i}");
            }
        }
    }

    /// A pinned `rs+ag:segments` composition drives the fused allreduce
    /// path end to end, including odd lengths (padding) and mixed phase
    /// generators.
    #[test]
    fn all_reduce_pinned_composition() {
        let n = 7;
        let len = 45; // not divisible by segments * n
        let alg = Algorithm::parse("pat:2+ring:3").unwrap();
        let c = comm(n, Some(alg));
        let mut rng = Rng::new(21);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (outs, rep) = c.all_reduce_report(&inputs).unwrap();
        assert_eq!(rep.algorithm, alg);
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out.len(), len, "rank {r}");
            for i in 0..len {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "rank {r} idx {i}");
            }
        }
        // repeated calls reuse the cached fused program
        c.all_reduce(&inputs).unwrap();
        assert_eq!(c.cache.lock().unwrap().len(), 1);
    }

    /// Tuned all-reduce resolves to a composition and still produces exact
    /// sums.
    #[test]
    fn all_reduce_tuned_resolves_to_composition() {
        let c = comm(6, None);
        let alg = c.resolve(Collective::AllReduce, 4 << 10);
        assert!(
            matches!(alg, Algorithm::Compose { .. }),
            "expected a composition, got {alg}"
        );
        let inputs: Vec<Vec<f32>> = (0..6).map(|r| vec![r as f32; 24]).collect();
        let outs = c.all_reduce(&inputs).unwrap();
        let want: f32 = (0..6).map(|r| r as f32).sum();
        for out in &outs {
            assert!(out.iter().all(|&v| v == want));
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Communicator::new(CommConfig { nranks: 0, ..Default::default() }).is_err());
        assert!(Communicator::new(CommConfig {
            nranks: 6,
            algorithm: Some(Algorithm::Recursive),
            ..Default::default()
        })
        .is_err());
        // placement / nranks mismatch
        assert!(Communicator::new(CommConfig {
            nranks: 6,
            placement: Some(crate::core::Placement::uniform(8, 4).unwrap()),
            ..Default::default()
        })
        .is_err());
        // zero reduction-service shards
        assert!(Communicator::new(CommConfig {
            nranks: 4,
            reduce_shards: Some(0),
            ..Default::default()
        })
        .is_err());
    }

    /// Hierarchical PAT end-to-end over the threaded transport, uneven
    /// nodes (13 ranks on nodes of 4), both collectives.
    #[test]
    fn hier_pat_end_to_end() {
        let n = 13;
        let c = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::HierPat { aggregation: 2 }),
            placement: Some(crate::core::Placement::uniform(n, 4).unwrap()),
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 16]).collect();
        let (out, rep) = c.all_gather_report(&inputs).unwrap();
        assert_eq!(rep.algorithm, Algorithm::HierPat { aggregation: 2 });
        for o in &out {
            for r in 0..n {
                assert!(o[r * 16..(r + 1) * 16].iter().all(|&v| v == r as f32));
            }
        }
        let mut rng = Rng::new(11);
        let rs_in: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * 8).map(|_| rng.below(100) as f32).collect())
            .collect();
        let rs_out = c.reduce_scatter(&rs_in).unwrap();
        for r in 0..n {
            for i in 0..8 {
                let want: f32 = (0..n).map(|s| rs_in[s][r * 8 + i]).sum();
                assert_eq!(rs_out[r][i], want, "rank {r} idx {i}");
            }
        }
    }

    /// `leaders_per_node` folds into the placement at construction: the
    /// striped schedule stays bit-exact with the single-leader one, the
    /// report shows the inter-node fan-out actually widened, and a zero
    /// leader count is a loud config error.
    #[test]
    fn leaders_per_node_knob() {
        let n = 16;
        let mk = |leaders: Option<usize>| {
            Communicator::new(CommConfig {
                nranks: n,
                algorithm: Some(Algorithm::HierPat { aggregation: usize::MAX }),
                placement: Some(crate::core::Placement::uniform(n, 4).unwrap()),
                leaders_per_node: leaders,
                ..Default::default()
            })
            .unwrap()
        };
        let mut rng = Rng::new(23);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..12).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (out1, _rep1) = mk(None).all_gather_report(&inputs).unwrap();
        let (out4, rep4) = mk(Some(4)).all_gather_report(&inputs).unwrap();
        assert_eq!(out1, out4);
        // Per-rank staging attribution covers every rank and agrees with
        // the scalar high-water mark.
        assert_eq!(rep4.transport.peak_slots_by_rank.len(), n);
        assert_eq!(
            rep4.transport.peak_slots_by_rank.iter().copied().max(),
            Some(rep4.transport.peak_slots)
        );
        assert!(Communicator::new(CommConfig {
            nranks: n,
            leaders_per_node: Some(0),
            ..Default::default()
        })
        .is_err());
    }

    /// Without an explicit placement, a pinned hierarchical algorithm runs
    /// on default 8-rank nodes.
    #[test]
    fn hier_pat_default_placement() {
        let n = 12;
        let c = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::HierPat { aggregation: usize::MAX }),
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 4]).collect();
        let out = c.all_gather(&inputs).unwrap();
        assert_eq!(out[0].len(), n * 4);
    }

    #[test]
    fn tuned_pick_small_message_is_logarithmic() {
        let c = comm(32, None);
        let alg = c.resolve(Collective::AllGather, 128);
        match alg {
            Algorithm::Pat { aggregation } => assert!(aggregation > 1),
            other => panic!("expected PAT for small messages, got {other}"),
        }
    }

    /// Pinned channels run end to end for all three collectives, including
    /// lengths that need the stripe padding, and the report says how many
    /// channels executed.
    #[test]
    fn channels_knob_end_to_end() {
        let n = 6;
        let c = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            channels: Some(4),
            ..Default::default()
        })
        .unwrap();
        // len 10 is not divisible by 4 stripes -> padding path
        let len = 10;
        let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32 + 1.0; len]).collect();
        let (out, rep) = c.all_gather_report(&inputs).unwrap();
        assert_eq!(rep.channels, 4);
        for o in &out {
            assert_eq!(o.len(), n * len);
            for r in 0..n {
                assert!(o[r * len..(r + 1) * len].iter().all(|&v| v == r as f32 + 1.0));
            }
        }

        let mut rng = Rng::new(5);
        let slot = 7; // not divisible by 4 -> padding path
        let rs_in: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * slot).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (rs_out, rep) = c.reduce_scatter_report(&rs_in).unwrap();
        assert_eq!(rep.channels, 4);
        for r in 0..n {
            assert_eq!(rs_out[r].len(), slot);
            for i in 0..slot {
                let want: f32 = (0..n).map(|s| rs_in[s][r * slot + i]).sum();
                assert_eq!(rs_out[r][i], want, "rank {r} idx {i}");
            }
        }

        let ar_in: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..13).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (ar_out, rep) = c.all_reduce_report(&ar_in).unwrap();
        assert!(rep.channels >= 4, "allreduce channels: {}", rep.channels);
        for (r, o) in ar_out.iter().enumerate() {
            assert_eq!(o.len(), 13, "rank {r}");
            for i in 0..13 {
                let want: f32 = (0..n).map(|s| ar_in[s][i]).sum();
                assert_eq!(o[i], want, "rank {r} idx {i}");
            }
        }
    }

    /// A buffer budget the tuner saturates at one channel still executes
    /// when the collective is split: the enforced capacity scales with the
    /// channel count (same bytes — C× the slots at 1/C the slot size).
    #[test]
    fn buffer_budget_scales_with_channels() {
        let n = 32;
        // RS law: a·log2(n/a) slots — a=4 needs 4·3 = 12 at n=32; give
        // exactly that so the single-channel budget is saturated.
        let slots = 12;
        for channels in [1usize, 2, 4] {
            let c = Communicator::new(CommConfig {
                nranks: n,
                buffer_slots: Some(slots),
                channels: Some(channels),
                ..Default::default()
            })
            .unwrap();
            let mut rng = Rng::new(channels as u64);
            let chunk = 8;
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(100) as f32).collect())
                .collect();
            let (outs, rep) = c.reduce_scatter_report(&inputs).unwrap();
            assert_eq!(rep.channels, channels);
            for r in 0..n {
                for i in 0..chunk {
                    let want: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                    assert_eq!(outs[r][i], want, "channels={channels} rank={r}");
                }
            }
        }
    }

    /// Bucketed all-reduce end to end: unequal bucket sizes (padding
    /// included), exact per-bucket sums, one cached fused program, and
    /// the report exposing the per-bucket channel count.
    #[test]
    fn all_reduce_batch_end_to_end() {
        let n = 6;
        let c = comm(n, Some(Algorithm::Pat { aggregation: 2 }));
        let mut rng = Rng::new(17);
        // three buckets of different (and awkward) lengths
        let lens = [10usize, 25, 7];
        let buckets: Vec<Vec<Vec<f32>>> = lens
            .iter()
            .map(|&l| {
                (0..n)
                    .map(|_| (0..l).map(|_| rng.below(100) as f32).collect())
                    .collect()
            })
            .collect();
        let (outs, rep) = c.all_reduce_batch_report(&buckets).unwrap();
        assert_eq!(outs.len(), lens.len());
        assert_eq!(rep.channels, lens.len());
        for (b, &l) in lens.iter().enumerate() {
            for (r, out) in outs[b].iter().enumerate() {
                assert_eq!(out.len(), l, "bucket {b} rank {r}");
                for i in 0..l {
                    let want: f32 = (0..n).map(|s| buckets[b][s][i]).sum();
                    assert_eq!(out[i], want, "bucket {b} rank {r} idx {i}");
                }
            }
        }
        // a second batch of the same shape reuses the cached program
        c.all_reduce_batch(&buckets).unwrap();
        assert_eq!(c.cache.lock().unwrap().len(), 1);
        // empty batches are rejected
        assert!(c.all_reduce_batch(&[]).is_err());
    }

    /// The `buckets` knob routes plain all_reduce through the fused
    /// bucketed program and still returns exact sums on every rank.
    #[test]
    fn buckets_knob_splits_all_reduce() {
        let n = 5;
        let len = 23; // not divisible by the bucket count
        let c = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            buckets: Some(4),
            ..Default::default()
        })
        .unwrap();
        let mut rng = Rng::new(29);
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.below(100) as f32).collect())
            .collect();
        let (outs, rep) = c.all_reduce_report(&inputs).unwrap();
        assert_eq!(rep.channels, 4, "one channel per bucket");
        for (r, out) in outs.iter().enumerate() {
            assert_eq!(out.len(), len, "rank {r}");
            for i in 0..len {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "rank {r} idx {i}");
            }
        }
        // buckets = 0 is rejected at construction
        assert!(Communicator::new(CommConfig {
            nranks: 4,
            buckets: Some(0),
            ..Default::default()
        })
        .is_err());
        // a pinned channel split cannot silently stack on bucketing: the
        // combination is a loud error on the all-reduce path (ag/rs calls
        // on the same communicator still honor the channels knob)
        let c = Communicator::new(CommConfig {
            nranks: 4,
            channels: Some(2),
            buckets: Some(2),
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<Vec<f32>> = (0..4).map(|r| vec![r as f32; 8]).collect();
        let err = c.all_reduce(&inputs).unwrap_err();
        assert!(err.to_string().contains("channel"), "{err}");
        assert!(c.all_gather(&inputs).is_ok());
    }

    /// With `calib_history` set, every collective call appends one
    /// predicted-vs-measured record; predictions are positive and keyed
    /// by the resolved algorithm.
    #[test]
    fn calib_history_records_every_collective() {
        let path = std::env::temp_dir().join(format!(
            "patcol_comm_calib_{}.jsonl",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let n = 8;
        let c = Communicator::new(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            calib_history: Some(path.clone()),
            ..Default::default()
        })
        .unwrap();
        let inputs: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; 16]).collect();
        c.all_gather(&inputs).unwrap();
        let rs_in: Vec<Vec<f32>> = (0..n).map(|r| vec![r as f32; n * 4]).collect();
        c.reduce_scatter(&rs_in).unwrap();
        c.all_reduce(&inputs).unwrap();
        let recs = crate::obs::calib::load(&path);
        assert_eq!(recs.len(), 3, "one record per collective call");
        let colls: Vec<&str> = recs.iter().map(|r| r.collective.as_str()).collect();
        assert_eq!(colls, ["allgather", "reduce_scatter", "allreduce"]);
        for r in &recs {
            assert_eq!(r.nranks, n);
            assert!(r.predicted_us > 0.0, "{:?}", r);
            assert!(r.measured_us > 0.0, "{:?}", r);
        }
        assert!(recs[0].alg.contains("pat"), "{}", recs[0].alg);
        std::fs::remove_file(&path).unwrap();
    }

    /// Channel auto-selection: single-link fabrics stay at one channel;
    /// a multi-rail fabric goes multi-channel at bandwidth-bound sizes.
    #[test]
    fn channels_resolved_by_tuner() {
        let flat = comm(16, Some(Algorithm::Pat { aggregation: 2 }));
        assert_eq!(flat.resolve_channels(Algorithm::Pat { aggregation: 2 }, 4 << 20), 1);
        let railed = Communicator::new(CommConfig {
            nranks: 16,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            parallel_links: Some(4),
            ..Default::default()
        })
        .unwrap();
        assert!(railed.resolve_channels(Algorithm::Pat { aggregation: 2 }, 4 << 20) > 1);
        assert_eq!(railed.resolve_channels(Algorithm::Pat { aggregation: 2 }, 16), 1);
        // pinned wins over the tuner
        let pinned = Communicator::new(CommConfig {
            nranks: 16,
            channels: Some(2),
            parallel_links: Some(4),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(pinned.resolve_channels(Algorithm::Ring, 4 << 20), 2);
        // zero knobs rejected
        assert!(Communicator::new(CommConfig {
            nranks: 4,
            channels: Some(0),
            ..Default::default()
        })
        .is_err());
    }

    /// Cross-bucket channel striping end to end: on a multi-rail fabric a
    /// bucket at the byte threshold is striped across the rails (extra
    /// channels in the fused program), small buckets stay single-channel,
    /// and the batched sums remain exact.
    #[test]
    fn bucketed_allreduce_stripes_big_buckets() {
        let n = 4usize;
        let big = crate::coordinator::tuner::BUCKET_STRIPE_THRESHOLD_BYTES / 4; // elems
        let lens = [64usize, big, 100];
        let mk = |cfg: CommConfig| Communicator::new(cfg).unwrap();
        let railed = mk(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            parallel_links: Some(4),
            ..Default::default()
        });
        let flat = mk(CommConfig {
            nranks: n,
            algorithm: Some(Algorithm::Pat { aggregation: 2 }),
            ..Default::default()
        });
        let buckets: Vec<Vec<Vec<f32>>> = lens
            .iter()
            .map(|&l| (0..n).map(|r| (0..l).map(|i| (r * l + i) as f32).collect()).collect())
            .collect();
        let (outs_railed, rep_railed) = railed.all_reduce_batch_report(&buckets).unwrap();
        let (outs_flat, rep_flat) = flat.all_reduce_batch_report(&buckets).unwrap();
        // the big middle bucket gains 3 extra channels; the others don't
        assert_eq!(rep_railed.channels, rep_flat.channels + 3);
        assert_eq!(outs_railed, outs_flat, "striping must not change the sums");
        for (b, &l) in lens.iter().enumerate() {
            let want: Vec<f32> = (0..l)
                .map(|i| (0..n).map(|r| (r * l + i) as f32).sum())
                .collect();
            for r in 0..n {
                assert_eq!(outs_railed[b][r], want, "bucket {b} rank {r}");
            }
        }
    }
}
