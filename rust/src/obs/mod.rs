//! obs — unified observability: one timeline schema for both executors.
//!
//! Every claim in the paper is a statement about *when* and *how much*:
//! logarithmic rounds (P1), minimized long-distance traffic (P2),
//! log-bounded buffers (P3). This module makes those quantities
//! recordable on both execution paths with a single schema, so the
//! simulator's predictions and the threaded transport's measurements are
//! directly comparable in the same viewer:
//!
//! * [`trace`] — the [`Event`] schema, per-(rank, channel) [`Counters`],
//!   and the unbounded [`TraceRecorder`] the simulator writes into.
//! * [`flight`] — the bounded, lock-free per-thread [`FlightRecorder`]
//!   the transport's rank threads write into (near-zero overhead when
//!   disabled; its tail is dumped by the watchdog on a recv timeout).
//! * [`chrome`] — Chrome trace-event JSON export
//!   ([`chrome_trace`], Perfetto-loadable), spans grouped rank → channel
//!   with segment/bucket/phase categories via [`ChannelTags`] — plus the
//!   inverse, [`import_chrome_trace`], which is what `patcol analyze`
//!   reads back.
//! * [`critpath`] — critical-path extraction over the op-span dependency
//!   graph: the timed longest chain, its wire/reduce/stall/wait
//!   decomposition, and the executor-invariant structural depth.
//! * [`metrics`] — aggregate [`MetricsReport`]: stall taxonomy per
//!   (rank, channel), pool/arena occupancy percentiles, per-link
//!   utilization and contention (via the simulator's `link_stats`).
//! * [`calib`] — append-only calibration-drift history: every tuned
//!   run's `predicted_us` vs `measured_us`, so the tuner's tolerance
//!   constants are trend lines, not folklore.
//! * [`baseline`] — the bench-baseline writer: with
//!   [`baseline::BASELINE_ENV`] set, every bench report is also stamped
//!   into one committed trajectory document (`BENCH_8.json`) that CI
//!   compares new runs against.
//!
//! # Event schema
//!
//! One flat record ([`Event`]) covers both executors. Fields:
//! `kind`, `rank`, `channel`, `step`, `peer`, `chunks`, `chunk0`,
//! `bytes`, `value`, `t_start`, `t_end` (seconds from the run origin).
//! Kinds ([`EventKind`]):
//!
//! | kind     | span                                        | emitted by    |
//! |----------|---------------------------------------------|---------------|
//! | `send`   | a `Send` op occupying its channel stream    | sim, transport|
//! | `recv`   | a `Recv` op: match + unpack (+ reduce)      | sim, transport|
//! | `wire`   | message in flight, src rank → `peer`        | sim, transport|
//! | `stall`  | channel blocked on an unmatched receive     | sim, transport|
//! | `reduce` | one reduction-kernel invocation             | sim, transport|
//! | `pool`   | buffer-pool occupancy sample (`value`=live) | transport     |
//! | `arena`  | arena occupancy sample (`value`=bytes), v3  | transport     |
//!
//! # Stability guarantee
//!
//! The schema is **append-only**: existing fields and kind names keep
//! their meaning across versions; new fields or kinds may appear, and
//! each addition bumps [`SCHEMA_VERSION`] (stamped into every exported
//! Chrome trace under `otherData.schema_version`, and into bench report
//! JSON). Consumers should ignore unknown fields/kinds and may key on
//! `schema_version` for anything stricter. Both executors are required
//! to emit the *same* schema — a test asserts the kind/field sets of a
//! simulator trace and a transport trace of the same program agree.
//!
//! ```
//! use patcol::obs::{ChannelTags, chrome_trace, Event, EventKind, TraceRecorder};
//! let mut rec = TraceRecorder::new();
//! rec.record(Event::span(EventKind::Wire, 0, 0, 0, 0.0, 1e-6).with_peer(1));
//! let trace = rec.finish();
//! let doc = chrome_trace(&trace, &ChannelTags::plain());
//! assert!(doc.to_string().contains("traceEvents"));
//! ```

pub mod baseline;
pub mod calib;
pub mod chrome;
pub mod critpath;
pub mod flight;
pub mod metrics;
pub mod trace;

pub use chrome::{chrome_trace, import_chrome_trace, ChannelTags};
pub use critpath::{critical_path, CritNode, CritPath, Decomposition};
pub use flight::{FlightRecorder, DEFAULT_FLIGHT_CAPACITY};
pub use metrics::{metrics, LevelLinkStat, LinkStat, MetricsReport, OccupancyStats, StallTaxonomy};
pub use trace::{Counters, Event, EventKind, Trace, TraceRecorder, SCHEMA_VERSION};
