//! One observability schema from both executors (the PR's acceptance
//! criterion): run the same 16-rank PAT all-reduce through the network
//! simulator and the threaded transport with tracing on, export both
//! timelines as Chrome trace-event JSON, re-parse them, and check the two
//! documents speak the same schema — same top-level shape, same
//! `schema_version`, identical field sets for every event kind they
//! share — and that the two executors account for the same traffic.

use std::collections::{BTreeMap, BTreeSet};

use patcol::core::{Algorithm, Collective};
use patcol::obs::{chrome_trace, ChannelTags, Trace, TraceRecorder, SCHEMA_VERSION};
use patcol::sched;
use patcol::sim::{self, CostModel, Topology};
use patcol::transport::{run_allreduce, TransportOptions};
use patcol::util::json::{self, Json};
use patcol::util::Rng;

const N: usize = 16;
const PER: usize = 32; // f32 elems per chunk

fn program() -> sched::Program {
    // Lifts to the fused pat+pat:1 composition — reduce-scatter phase then
    // all-gather phase through one program.
    sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::AllReduce,
        N,
    )
    .unwrap()
}

fn tags() -> ChannelTags {
    let alg = Algorithm::Pat { aggregation: usize::MAX };
    let rsp = sched::generate(alg, Collective::ReduceScatter, N).unwrap();
    let agp = sched::generate(alg, Collective::AllGather, N).unwrap();
    ChannelTags::composed(sched::compose::Layout::of(&rsp, &agp, 1))
}

fn sim_trace(p: &sched::Program) -> Trace {
    let topo = Topology::flat(N, CostModel::ib_hdr_nic_bw());
    let mut rec = TraceRecorder::new();
    sim::simulate_observed(p, &topo, &CostModel::ib_hdr(), PER * 4, &mut rec).unwrap();
    rec.finish()
}

fn transport_trace(p: &sched::Program) -> Trace {
    let total = p.chunk_space() * PER;
    let mut rng = Rng::new(11);
    let inputs: Vec<Vec<f32>> = (0..N)
        .map(|_| {
            let mut v = vec![0f32; total];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let opts = TransportOptions { trace: true, ..Default::default() };
    let (_, rep) = run_allreduce(p, &inputs, &opts).unwrap();
    rep.trace.expect("trace requested")
}

/// Export → pretty text → re-parse, i.e. exactly what a consumer reads.
fn exported(trace: &Trace) -> Json {
    json::parse(&chrome_trace(trace, &tags()).to_pretty()).unwrap()
}

/// Event schema of a Chrome trace document: for each `(ph, name)` kind,
/// the set of field keys it carries (args flattened as `args.*`).
/// Metadata (`ph == "M"`) records name processes/threads, not timeline
/// events, and are not part of the event schema.
fn schema_of(doc: &Json) -> BTreeMap<String, BTreeSet<String>> {
    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut schema: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for e in evs {
        let obj = e.as_obj().unwrap();
        let ph = obj.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue;
        }
        let name = obj.get("name").unwrap().as_str().unwrap();
        let keys = schema.entry(format!("{ph}:{name}")).or_default();
        for (k, v) in obj {
            if k == "args" {
                for ak in v.as_obj().unwrap().keys() {
                    keys.insert(format!("args.{ak}"));
                }
            } else {
                keys.insert(k.clone());
            }
        }
    }
    schema
}

#[test]
fn both_executors_emit_one_schema() {
    let p = program();
    let st = sim_trace(&p);
    let tt = transport_trace(&p);

    let sim_doc = exported(&st);
    let tp_doc = exported(&tt);

    // Top-level shape + stamped schema version, both documents.
    for doc in [&sim_doc, &tp_doc] {
        assert_eq!(
            doc.get("otherData")
                .and_then(|o| o.get("schema_version"))
                .and_then(|v| v.as_usize()),
            Some(SCHEMA_VERSION as usize)
        );
        assert!(doc.get("displayTimeUnit").is_some());
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }

    let ss = schema_of(&sim_doc);
    let ts = schema_of(&tp_doc);

    // The core timeline kinds come out of both executors.
    for kind in ["X:send", "X:recv", "X:wire", "X:reduce"] {
        assert!(ss.contains_key(kind), "sim missing {kind}: {:?}", ss.keys());
        assert!(ts.contains_key(kind), "transport missing {kind}: {:?}", ts.keys());
    }
    // Pool occupancy is transport-only (the simulator has no buffer pool).
    assert!(ts.contains_key("C:pool live slots"));
    assert!(!ss.contains_key("C:pool live slots"));

    // Every kind both executors emit carries identical field sets — the
    // "identical schema" acceptance criterion.
    for (kind, sim_keys) in &ss {
        if let Some(tp_keys) = ts.get(kind) {
            assert_eq!(
                sim_keys, tp_keys,
                "field sets diverge for event kind {kind}"
            );
        }
    }

    // Same program on both executors ⇒ the counters must account for the
    // same traffic, message for message and byte for byte.
    let (s_tot, t_tot) = (st.totals(), tt.totals());
    assert_eq!(s_tot.msgs_sent, t_tot.msgs_sent);
    assert_eq!(s_tot.msgs_recv, t_tot.msgs_recv);
    assert_eq!(s_tot.bytes_sent, t_tot.bytes_sent);
    assert_eq!(s_tot.bytes_recv, t_tot.bytes_recv);
    assert!(s_tot.reduce_calls > 0 && t_tot.reduce_calls > 0);
}

/// Arena steady state, observed: with a warm shared
/// [`patcol::transport::ArenaCache`], the second run of the same
/// reduce-scatter performs zero datapath allocations — the report says so,
/// and the v2 trace counters (`allocs`, `arena_hw_bytes`) record the same
/// story per (rank, channel).
#[test]
fn steady_state_records_zero_allocs() {
    use patcol::transport::{run_reduce_scatter, ArenaCache};

    let p = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::ReduceScatter,
        N,
    )
    .unwrap();
    let total = p.chunk_space() * PER;
    let mut rng = Rng::new(23);
    let inputs: Vec<Vec<f32>> = (0..N)
        .map(|_| {
            let mut v = vec![0f32; total];
            rng.fill_f32(&mut v);
            v
        })
        .collect();
    let opts = TransportOptions {
        trace: true,
        arena: Some(ArenaCache::new()),
        ..Default::default()
    };

    let (out1, rep1) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
    assert_eq!(rep1.arena_allocs, 1, "cold cache allocates exactly one arena");
    assert!(rep1.arena_bytes > 0);

    let (out2, rep2) = run_reduce_scatter(&p, &inputs, &opts).unwrap();
    assert_eq!(out1, out2, "warm run diverged");
    assert_eq!(rep2.arena_allocs, 0, "warm cache re-allocated the arena");
    assert_eq!(rep2.slots_allocated, 0, "steady state fell back to the heap");
    assert!(rep2.arena_hw_bytes > 0, "high-water mark not recorded");
    assert!(
        rep2.arena_hw_bytes <= rep2.arena_bytes,
        "high-water {} exceeds the arena footprint {}",
        rep2.arena_hw_bytes,
        rep2.arena_bytes
    );

    // The same facts flow through the trace counters (schema v2 fields).
    let trace = rep2.trace.expect("trace requested");
    let tot = trace.totals();
    assert_eq!(tot.allocs, 0, "trace counters saw steady-state allocations");
    assert!(tot.arena_hw_bytes > 0, "trace counters missing arena high-water");
}

/// Trace-analytics cross-executor criterion: the analyzer
/// ([`patcol::obs::critpath`] / [`patcol::obs::metrics`]) extracts the
/// same *structural* facts from a simulator trace and a transport trace
/// of the same program — identical critical-path step count (dependency
/// structure is program-determined; only timings differ) and an
/// identical stall-taxonomy key set.
#[test]
fn analyzer_agrees_across_executors() {
    use patcol::obs::{critical_path, import_chrome_trace, metrics};

    let p = program();
    let st = sim_trace(&p);
    let tt = transport_trace(&p);

    let scp = critical_path(&st).expect("sim critical path");
    let tcp = critical_path(&tt).expect("transport critical path");
    assert_eq!(
        scp.dag_depth, tcp.dag_depth,
        "structural critical-path depth must be executor-invariant"
    );

    // The decomposition is an exact accounting identity on both sides.
    for cp in [&scp, &tcp] {
        assert!(
            (cp.covered + cp.gap_sum - cp.elapsed).abs() <= 1e-9 * cp.elapsed.max(1e-9),
            "covered {} + gaps {} != elapsed {}",
            cp.covered,
            cp.gap_sum,
            cp.elapsed
        );
        assert!((cp.decomp.sum() - cp.elapsed).abs() <= 1e-9 * cp.elapsed.max(1e-9));
        assert!(cp.span_sum > 0.0);
    }

    // Same stall-taxonomy rows from both executors — the key set is a
    // property of the program, not of one run's timing — and both
    // classes are always present in the vocabulary.
    let sm = metrics(&st);
    let tm = metrics(&tt);
    let skeys: Vec<_> = sm.stalls.keys().copied().collect();
    let tkeys: Vec<_> = tm.stalls.keys().copied().collect();
    assert_eq!(skeys, tkeys, "stall taxonomy (rank, channel) key sets diverge");
    assert_eq!(patcol::obs::StallTaxonomy::CLASSES, ["warmup", "steady"]);

    // The transport side carries pool occupancy; the simulator cannot.
    assert!(tm.pool.is_some() && sm.pool.is_none());

    // Export → import (what `patcol analyze` reads) preserves the
    // structural depth.
    let back = import_chrome_trace(&exported(&st)).unwrap();
    assert_eq!(critical_path(&back).unwrap().dag_depth, scp.dag_depth);
}

/// The PR's 64-rank acceptance criterion, through the same path `patcol
/// analyze` takes (export → re-import): the critical path's span sum
/// covers ≥ 95 % of the measured elapsed time, a Träff optimality-gap
/// figure comes out, and the stall decomposition has a row per
/// (rank, channel).
#[test]
fn analyze_64_rank_pat_allreduce() {
    use patcol::coordinator::Tuner;
    use patcol::obs::{critical_path, import_chrome_trace, metrics};

    let n = 64usize;
    let p = sched::generate(
        Algorithm::Pat { aggregation: usize::MAX },
        Collective::AllReduce,
        n,
    )
    .unwrap();
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let mut rec = TraceRecorder::new();
    let rep = sim::simulate_observed(&p, &topo, &CostModel::ib_hdr(), PER * 4, &mut rec).unwrap();
    let doc = json::parse(&chrome_trace(&rec.finish(), &ChannelTags::plain()).to_pretty()).unwrap();
    let trace = import_chrome_trace(&doc).unwrap();

    let cp = critical_path(&trace).expect("64-rank trace has a critical path");
    assert!(
        cp.span_sum >= 0.95 * cp.elapsed,
        "chain spans sum to {} — less than 95% of elapsed {}",
        cp.span_sum,
        cp.elapsed
    );
    // The analyzer's elapsed is the simulator's modeled time (µs
    // round-trip through the Chrome document tolerated).
    assert!(
        (cp.elapsed - rep.total_time).abs() <= 1e-9 + 0.01 * rep.total_time,
        "elapsed {} vs modeled {}",
        cp.elapsed,
        rep.total_time
    );

    // Träff optimality gap: a finite, non-negative percentage.
    let total_bytes = p.chunk_space() * PER * 4;
    let bound = Tuner::default().allreduce_lower_bound(n, total_bytes);
    assert!(bound > 0.0);
    let gap_pct = 100.0 * (cp.elapsed - bound) / bound;
    assert!(
        gap_pct.is_finite() && gap_pct > -1e-6,
        "modeled time beat the lower bound: {gap_pct}%"
    );

    // Per-(rank, channel) stall decomposition: one row per stream the
    // counters know, and a 64-rank PAT run genuinely stalls somewhere.
    let m = metrics(&trace);
    assert_eq!(m.stalls.len(), trace.counters.len());
    assert!(m.stalls.keys().all(|&(r, _)| r < n));
    assert!(m.stall_total() > 0.0);
}

#[test]
fn spans_are_well_formed_and_grouped() {
    let p = program();
    for trace in [sim_trace(&p), transport_trace(&p)] {
        let doc = exported(&trace);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut process_names = 0usize;
        for e in evs {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            match ph {
                "M" => {
                    if e.get("name").unwrap().as_str() == Some("process_name") {
                        process_names += 1;
                    }
                }
                "X" => {
                    // Perfetto needs pid/tid/ts/dur; durations are
                    // non-negative microseconds.
                    let pid = e.get("pid").unwrap().as_usize().unwrap();
                    assert!(pid < N);
                    assert!(e.get("tid").unwrap().as_usize().is_some());
                    assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                    assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
                }
                "C" => {
                    assert!(e.get("args").unwrap().get("live").is_some());
                }
                other => panic!("unexpected phase {other:?}"),
            }
        }
        // One process-name record per rank: the rank → channel grouping.
        assert_eq!(process_names, N);
    }
}
