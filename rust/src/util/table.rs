//! Fixed-width text tables for CLI / bench output (the rows the paper's
//! evaluation would print).

/// A simple left-padded column table builder.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cells[i].len());
                // right-align numeric-looking cells, left-align text
                let numeric = cells[i]
                    .chars()
                    .next()
                    .map(|c| c.is_ascii_digit() || c == '-' || c == '+' || c == '.')
                    .unwrap_or(false);
                if numeric {
                    line.extend(std::iter::repeat(' ').take(pad));
                    line.push_str(&cells[i]);
                } else {
                    line.push_str(&cells[i]);
                    line.extend(std::iter::repeat(' ').take(pad));
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.extend(std::iter::repeat('-').take(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a byte count human-readably (power-of-two units, NCCL style).
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u + 1 < UNITS.len() {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else if x >= 100.0 {
        format!("{x:.0} {}", UNITS[u])
    } else {
        format!("{x:.1} {}", UNITS[u])
    }
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_time_s(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.1} ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2} us", t * 1e6)
    } else if t < 1.0 {
        format!("{:.3} ms", t * 1e3)
    } else {
        format!("{t:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["alg", "time"]);
        t.row(["ring", "1.5"]);
        t.row(["pat(a=2)", "12.25"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("alg"));
        assert!(lines[3].contains("12.25"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(1 << 20), "1.0 MiB");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time_s(0.5e-9 * 100.0), "50.0 ns");
        assert!(fmt_time_s(0.0025).contains("ms"));
    }
}
