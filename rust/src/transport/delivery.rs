//! The controllable delivery layer: a policy hook over the per-(src, dst,
//! channel) connection FIFOs of [`crate::transport::engine`].
//!
//! The threaded transport normally matches receives **eagerly**: the
//! moment a rank's channel scheduler polls a connection whose FIFO is
//! non-empty, the head descriptor is delivered. A [`DeliveryPolicy`]
//! interposes at exactly that point — each poll of a non-empty FIFO is a
//! **decision point**, and the policy may deliver the head, briefly defer
//! the match ([`Verdict::Hold`]), park outright waiting for deeper
//! arrivals ([`Verdict::HoldFirm`], replay only), or — when the
//! FIFO-ordering sentinel is armed — deliver a queued message *out of
//! order* ([`Verdict::Deliver`] with a non-zero index). This is what lets
//! the [`crate::adversary`] harness drive the *real* transport through
//! perturbed schedules and replay a recorded schedule bit-exactly.
//!
//! ## Deterministic virtual time
//!
//! Physical wall time is useless for replay, so every decision carries
//! two deterministic clocks maintained by the engine:
//!
//! * [`Decision::nth`] — how many messages this rank has already matched
//!   on this exact (src, channel) connection. The *n*-th match of a
//!   connection is a program-determined event (per-connection FIFO
//!   matching is part of the IR semantics), so `(rank, src, channel,
//!   nth)` names a decision point stably across runs **and across
//!   deviation-subset replays** — the key the shrinker relies on.
//! * [`Decision::vtime`] — total messages matched by the rank so far (a
//!   rank-local Lamport-style clock), useful for ordering a rank's
//!   decisions in logs.
//!
//! ## The bounded-hold rule (why policies cannot deadlock the transport)
//!
//! Cross-channel deferral is not free: blocking an arrived message while
//! other ranks block on *our* sends can manufacture deadlocks that the
//! verified program does not contain. The engine therefore enforces a
//! bounded hold: a [`Verdict::Hold`] only defers the match while the rank
//! has other progress to make or new traffic is arriving; once a full
//! scheduler pass makes no progress, the engine waits one short grace
//! interval for in-flight traffic (letting FIFOs deepen — the point of
//! holding) and then **force-releases** the head of a held connection,
//! notifying the policy via [`DeliveryPolicy::delivered`] with
//! `forced = true`. Only [`Verdict::HoldFirm`] may park the thread, and
//! it is reserved for pinned replay, where a recorded decision proves the
//! awaited messages are already causally en route (the watchdog still
//! backstops it).
//!
//! ## Mutation sentinels
//!
//! [`sentinel`] (compiled under `cfg(any(test, feature = "adversary"))`)
//! hosts two switches that each disable one protocol guard so the
//! adversary harness can prove it *finds* the resulting bugs: the
//! FIFO-ordering clamp in the delivery path, and one accumulator
//! slot-release on the reduce-scatter send path. Production builds
//! compile the guards unconditionally — [`fifo_reorder_allowed`] and
//! [`slot_release_skipped`] are constant `false` without the cfg.

use std::sync::Arc;

use crate::core::Rank;

/// One delivery decision point: rank `rank` polls connection
/// `(src, channel)` and finds `depth ≥ 1` arrived-but-unmatched
/// messages. See the module docs for the `nth`/`vtime` clocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    /// The receiving rank (the one running the policy instance).
    pub rank: Rank,
    /// Source rank of the polled connection.
    pub src: Rank,
    /// Channel of the polled connection.
    pub channel: usize,
    /// Arrived-but-unmatched messages on the connection FIFO right now.
    pub depth: usize,
    /// Messages already matched on this connection (stable decision key).
    pub nth: u64,
    /// Messages already matched by this rank across all connections.
    pub vtime: u64,
}

/// A policy's answer at a decision point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Match the message at this FIFO index (0 = head). Non-zero indices
    /// are clamped to 0 by the FIFO-ordering guard unless the
    /// [`sentinel::Sentinel::FifoGuardOff`] mutation is armed.
    Deliver(usize),
    /// Defer the match for now. Subject to the bounded-hold rule: the
    /// engine re-asks every pass and force-releases when nothing else
    /// progresses.
    Hold,
    /// Park-eligible hold: treat the connection as if nothing had
    /// arrived, letting the rank thread block on the shared receiver
    /// until more traffic lands. Used by pinned replay to wait for a
    /// recorded FIFO depth; guarded by the watchdog like any other park.
    HoldFirm,
}

/// A per-rank delivery schedule controller, instantiated once per rank
/// thread by the [`DeliveryFactory`] in
/// [`crate::transport::TransportOptions::delivery`].
///
/// Contract: `decide` may be called any number of times for the same
/// decision point (the scheduler re-polls every pass while a hold
/// stands); `delivered` is called exactly once per matched message, with
/// the index actually used and whether the bounded-hold rule overrode the
/// policy (`forced`).
pub trait DeliveryPolicy: Send {
    /// Choose what to do at a decision point.
    fn decide(&mut self, d: Decision) -> Verdict;

    /// A message was matched at `d` using FIFO index `idx`. `forced` is
    /// true when the engine force-released a held connection.
    fn delivered(&mut self, d: Decision, idx: usize, forced: bool) {
        let _ = (d, idx, forced);
    }

    /// Human-readable log of the perturbations applied so far — attached
    /// to the watchdog's blamed stall report when a deadlock fires under
    /// this policy. Empty = nothing to report.
    fn perturbation_log(&self) -> String {
        String::new()
    }
}

/// Builds one [`DeliveryPolicy`] per rank thread. `Arc` so
/// [`crate::transport::TransportOptions`] stays `Clone`.
pub type DeliveryFactory = Arc<dyn Fn(Rank) -> Box<dyn DeliveryPolicy> + Send + Sync>;

/// The always-eager policy: deliver every head immediately. Equivalent to
/// running with no policy at all; exists so explicit "clean" runs can go
/// through the same plumbing.
#[derive(Debug, Default, Clone, Copy)]
pub struct EagerDelivery;

impl DeliveryPolicy for EagerDelivery {
    fn decide(&mut self, _d: Decision) -> Verdict {
        Verdict::Deliver(0)
    }
}

/// Mutation sentinels: runtime switches that each disable one protocol
/// guard, so the adversary explorer can demonstrate it catches the
/// resulting bug (the harness's own regression tests). Compiled only for
/// tests and the `adversary` feature; arming serializes on a global lock
/// so concurrent tests cannot observe each other's mutations.
#[cfg(any(test, feature = "adversary"))]
pub mod sentinel {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Mutex, MutexGuard};

    use crate::core::{Error, Result};

    static FIFO_GUARD_OFF: AtomicBool = AtomicBool::new(false);
    static SLOT_RELEASE_OFF: AtomicBool = AtomicBool::new(false);
    static ARM_LOCK: Mutex<()> = Mutex::new(());

    /// Which guard to disable.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Sentinel {
        /// Disable the FIFO-ordering clamp in the delivery path: policies
        /// may then deliver non-head FIFO entries, reordering messages
        /// within one (src, dst, channel) connection.
        FifoGuardOff,
        /// Skip the accumulator slot-release on the reduce-scatter send
        /// path: every consumed accumulator leaks its pool slot.
        SlotReleaseOff,
    }

    impl Sentinel {
        /// Stable name used in replay-trace JSON.
        pub fn name(&self) -> &'static str {
            match self {
                Sentinel::FifoGuardOff => "fifo-guard-off",
                Sentinel::SlotReleaseOff => "slot-release-off",
            }
        }

        /// Parse [`Sentinel::name`] (and the short CLI spellings).
        pub fn parse(s: &str) -> Result<Sentinel> {
            match s {
                "fifo" | "fifo-guard-off" => Ok(Sentinel::FifoGuardOff),
                "slot" | "slot-release-off" => Ok(Sentinel::SlotReleaseOff),
                other => Err(Error::Config(format!(
                    "unknown sentinel {other:?} (want fifo|slot)"
                ))),
            }
        }
    }

    /// RAII arming: sets the switch, holds the global sentinel lock, and
    /// restores the healthy state on drop.
    pub struct Armed {
        which: Sentinel,
        _lock: MutexGuard<'static, ()>,
    }

    /// Arm one sentinel for the lifetime of the returned guard.
    pub fn arm(which: Sentinel) -> Armed {
        // A test that panicked while armed leaves the mutex poisoned but
        // the state restored (Drop ran during unwind) — recover the lock.
        let lock = ARM_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        flag(which).store(true, Ordering::SeqCst);
        Armed { which, _lock: lock }
    }

    impl Drop for Armed {
        fn drop(&mut self) {
            flag(self.which).store(false, Ordering::SeqCst);
        }
    }

    fn flag(which: Sentinel) -> &'static AtomicBool {
        match which {
            Sentinel::FifoGuardOff => &FIFO_GUARD_OFF,
            Sentinel::SlotReleaseOff => &SLOT_RELEASE_OFF,
        }
    }

    /// Hold the sentinel lock *without* arming anything: a test that
    /// must observe healthy guards while driving a delivery policy takes
    /// this to serialize against sentinel-armed tests in the same
    /// process (sentinels are process-global).
    pub fn exclusive() -> MutexGuard<'static, ()> {
        ARM_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The currently armed sentinel, if any (recorded into shrunk traces
    /// so replay can re-arm it).
    pub fn active() -> Option<Sentinel> {
        if FIFO_GUARD_OFF.load(Ordering::SeqCst) {
            Some(Sentinel::FifoGuardOff)
        } else if SLOT_RELEASE_OFF.load(Ordering::SeqCst) {
            Some(Sentinel::SlotReleaseOff)
        } else {
            None
        }
    }

    pub(crate) fn fifo_guard_off() -> bool {
        FIFO_GUARD_OFF.load(Ordering::Relaxed)
    }

    pub(crate) fn slot_release_off() -> bool {
        SLOT_RELEASE_OFF.load(Ordering::Relaxed)
    }
}

/// True when the FIFO-ordering guard is disabled (sentinel armed): the
/// delivery path then honors non-head [`Verdict::Deliver`] indices.
/// Constant `false` in production builds — the guard is unconditional.
#[inline]
pub fn fifo_reorder_allowed() -> bool {
    #[cfg(any(test, feature = "adversary"))]
    {
        sentinel::fifo_guard_off()
    }
    #[cfg(not(any(test, feature = "adversary")))]
    {
        false
    }
}

/// True when the reduce-scatter accumulator slot-release should be
/// skipped (sentinel armed). Constant `false` in production builds.
#[inline]
pub fn slot_release_skipped() -> bool {
    #[cfg(any(test, feature = "adversary"))]
    {
        sentinel::slot_release_off()
    }
    #[cfg(not(any(test, feature = "adversary")))]
    {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Sentinels are process-global, so every assertion about their state
    // happens while holding the sentinel lock (via `arm` or `exclusive`)
    // — a concurrently armed test must not be observable here.

    #[test]
    fn guards_default_healthy() {
        let _g = sentinel::exclusive();
        assert!(!fifo_reorder_allowed());
        assert!(!slot_release_skipped());
    }

    #[test]
    fn sentinel_arming_is_scoped() {
        {
            let _a = sentinel::arm(sentinel::Sentinel::FifoGuardOff);
            assert!(fifo_reorder_allowed());
            assert!(!slot_release_skipped());
            assert_eq!(
                sentinel::active(),
                Some(sentinel::Sentinel::FifoGuardOff)
            );
        }
        {
            let _g = sentinel::exclusive();
            assert!(!fifo_reorder_allowed());
            assert_eq!(sentinel::active(), None);
        }
        {
            let _b = sentinel::arm(sentinel::Sentinel::SlotReleaseOff);
            assert!(slot_release_skipped());
        }
        let _g = sentinel::exclusive();
        assert!(!slot_release_skipped());
    }

    #[test]
    fn sentinel_names_roundtrip() {
        use sentinel::Sentinel;
        for s in [Sentinel::FifoGuardOff, Sentinel::SlotReleaseOff] {
            assert_eq!(Sentinel::parse(s.name()).unwrap(), s);
        }
        assert!(Sentinel::parse("bogus").is_err());
    }

    #[test]
    fn eager_policy_always_delivers_head() {
        let mut p = EagerDelivery;
        let d = Decision { rank: 0, src: 1, channel: 0, depth: 3, nth: 0, vtime: 0 };
        assert_eq!(p.decide(d), Verdict::Deliver(0));
        assert!(p.perturbation_log().is_empty());
    }
}
