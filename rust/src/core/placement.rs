//! Rank placement: the rank → node mapping hierarchical schedules are built
//! from.
//!
//! A *node* models a set of ranks with cheap mutual communication (one
//! machine's NVLink domain, or one leaf switch of a fat-tree). Node sizes
//! may be uneven — 13 ranks on nodes of 4 places them as `[4, 4, 4, 1]` —
//! which is exactly the shape elastic / partially-drained training jobs
//! produce. The first rank of each node is its *leader*: the rank that
//! participates in the inter-node phase of a hierarchical schedule
//! ([`crate::sched::hier`]).
//!
//! ## Spelling (config / CLI grammar)
//!
//! * `uniform:<k>` — contiguous nodes of `k` ranks, last node takes the
//!   remainder (`uniform:4` over 13 ranks → `[4, 4, 4, 1]`).
//! * `<k>` — shorthand for `uniform:<k>`.
//! * `<k1>,<k2>,...` — explicit node sizes; must sum to the rank count
//!   (`4,4,5` over 13 ranks).

use crate::core::{Error, Rank, Result};

/// A rank → node mapping with (possibly uneven) contiguous nodes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `node_of[r]` is the node id of rank `r` (node ids are dense).
    node_of: Vec<usize>,
    /// `nodes[m]` is node `m`'s rank list, ascending; `nodes[m][0]` is the
    /// leader.
    nodes: Vec<Vec<Rank>>,
}

impl Placement {
    /// Build from explicit node sizes; ranks are assigned contiguously.
    pub fn from_node_sizes(sizes: &[usize]) -> Result<Placement> {
        if sizes.is_empty() {
            return Err(Error::Config("placement needs at least one node".into()));
        }
        if sizes.iter().any(|&s| s == 0) {
            return Err(Error::Config("placement node sizes must be >= 1".into()));
        }
        let nranks: usize = sizes.iter().sum();
        let mut node_of = Vec::with_capacity(nranks);
        let mut nodes = Vec::with_capacity(sizes.len());
        let mut next = 0usize;
        for (m, &s) in sizes.iter().enumerate() {
            nodes.push((next..next + s).collect());
            for _ in 0..s {
                node_of.push(m);
            }
            next += s;
        }
        Ok(Placement { node_of, nodes })
    }

    /// Contiguous nodes of `ranks_per_node`; when it does not divide
    /// `nranks` the last node takes the remainder (uneven tail), and
    /// `ranks_per_node > nranks` yields a single node — callers never need
    /// to pre-clamp.
    pub fn uniform(nranks: usize, ranks_per_node: usize) -> Result<Placement> {
        if nranks == 0 {
            return Err(Error::Config("placement needs at least one rank".into()));
        }
        if ranks_per_node == 0 {
            return Err(Error::Config("ranks_per_node must be >= 1".into()));
        }
        let full = nranks / ranks_per_node;
        let rem = nranks % ranks_per_node;
        let mut sizes = vec![ranks_per_node; full];
        if rem > 0 {
            sizes.push(rem);
        }
        Self::from_node_sizes(&sizes)
    }

    /// Every rank on its own node (degenerates hierarchical schedules to
    /// their flat inter-node algorithm).
    pub fn singletons(nranks: usize) -> Result<Placement> {
        Self::uniform(nranks, 1)
    }

    /// Parse the config/CLI grammar (see module docs) for `nranks` ranks.
    pub fn parse(spec: &str, nranks: usize) -> Result<Placement> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(Error::Config("empty placement spec".into()));
        }
        if let Some(rest) = spec.strip_prefix("uniform:") {
            let k: usize = rest
                .trim()
                .parse()
                .map_err(|_| Error::Config(format!("placement: bad node size {rest:?}")))?;
            return Self::uniform(nranks, k);
        }
        if spec.contains(',') {
            let sizes: Result<Vec<usize>> = spec
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| Error::Config(format!("placement: bad node size {t:?}")))
                })
                .collect();
            let sizes = sizes?;
            let total: usize = sizes.iter().sum();
            if total != nranks {
                return Err(Error::Config(format!(
                    "placement sizes sum to {total}, expected nranks={nranks}"
                )));
            }
            return Self::from_node_sizes(&sizes);
        }
        let k: usize = spec
            .parse()
            .map_err(|_| Error::Config(format!("placement: bad spec {spec:?}")))?;
        Self::uniform(nranks, k)
    }

    pub fn nranks(&self) -> usize {
        self.node_of.len()
    }

    pub fn nnodes(&self) -> usize {
        self.nodes.len()
    }

    /// Node id of `rank`.
    pub fn node_of(&self, rank: Rank) -> usize {
        self.node_of[rank]
    }

    /// Ranks of `node`, ascending (leader first).
    pub fn ranks_of(&self, node: usize) -> &[Rank] {
        &self.nodes[node]
    }

    /// The leader rank of `node` (its first rank).
    pub fn leader(&self, node: usize) -> Rank {
        self.nodes[node][0]
    }

    pub fn is_leader(&self, rank: Rank) -> bool {
        self.leader(self.node_of(rank)) == rank
    }

    pub fn node_sizes(&self) -> Vec<usize> {
        self.nodes.iter().map(Vec::len).collect()
    }

    pub fn max_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).max().unwrap_or(0)
    }

    pub fn min_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).min().unwrap_or(0)
    }

    /// `"nodes=4 sizes=[4, 4, 4, 1]"` — for reports and explain output.
    pub fn describe(&self) -> String {
        format!("nodes={} sizes={:?}", self.nnodes(), self.node_sizes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_uneven_tail() {
        let p = Placement::uniform(13, 4).unwrap();
        assert_eq!(p.nranks(), 13);
        assert_eq!(p.nnodes(), 4);
        assert_eq!(p.node_sizes(), vec![4, 4, 4, 1]);
        assert_eq!(p.leader(0), 0);
        assert_eq!(p.leader(3), 12);
        assert_eq!(p.node_of(7), 1);
        assert!(p.is_leader(8));
        assert!(!p.is_leader(9));
        assert_eq!(p.max_node_size(), 4);
        assert_eq!(p.min_node_size(), 1);
    }

    #[test]
    fn explicit_sizes() {
        let p = Placement::from_node_sizes(&[4, 4, 5]).unwrap();
        assert_eq!(p.nranks(), 13);
        assert_eq!(p.ranks_of(2), &[8, 9, 10, 11, 12]);
        assert_eq!(p.leader(2), 8);
    }

    #[test]
    fn singletons_degenerate() {
        let p = Placement::singletons(5).unwrap();
        assert_eq!(p.nnodes(), 5);
        assert!((0..5).all(|r| p.is_leader(r)));
    }

    #[test]
    fn parse_grammar() {
        assert_eq!(
            Placement::parse("uniform:4", 13).unwrap().node_sizes(),
            vec![4, 4, 4, 1]
        );
        assert_eq!(Placement::parse("4", 13).unwrap().node_sizes(), vec![4, 4, 4, 1]);
        assert_eq!(
            Placement::parse("4,4,5", 13).unwrap().node_sizes(),
            vec![4, 4, 5]
        );
        // oversized uniform clamps to one node
        assert_eq!(Placement::parse("99", 6).unwrap().nnodes(), 1);
        assert!(Placement::parse("4,4", 13).is_err()); // wrong sum
        assert!(Placement::parse("a,b", 2).is_err());
        assert!(Placement::parse("", 4).is_err());
        assert!(Placement::parse("0", 4).is_err());
    }

    #[test]
    fn invalid_rejected() {
        assert!(Placement::from_node_sizes(&[]).is_err());
        assert!(Placement::from_node_sizes(&[2, 0]).is_err());
        assert!(Placement::uniform(0, 4).is_err());
        assert!(Placement::uniform(8, 0).is_err());
    }
}
