//! Discrete-event execution of a schedule [`Program`] against a
//! [`Topology`] + [`CostModel`].
//!
//! Model (documented assumptions):
//!
//! * Each rank executes in-order streams (NCCL channels): ops retire in
//!   program order within a stream; `Recv` blocks its stream, `Send` posts
//!   and returns after the software gap `msg_gap` (NIC offload does
//!   serialization). Channels are explicit in the IR ([`Op::channel`]):
//!   every (rank, channel) is its own stream with its own connection
//!   (per-channel FIFO wires), so channel-split collectives and composed
//!   all-reduce segments overlap the way NCCL's multi-channel collectives
//!   do, while still contending for the same links. Single-channel
//!   programs reproduce the classic one-stream-per-rank model exactly.
//! * A message traverses its link path cut-through: every link on the path
//!   starts serializing at the same contended start time `t0 = max(ready,
//!   max link_free)` and is busy for `bytes / bw_link`; the message arrives
//!   at `t0 + bytes / min_bw + alpha_base + alpha_hop * hops`. Contention
//!   is first-come-first-served per link in event-time order.
//! * Static routing: the path for (src, dst, channel) is fixed for the
//!   whole run (ECMP hash, salt = channel), so colliding flows collide on
//!   *every* step — the paper's congestion mechanism. Distinct channels
//!   are distinct connections and hash independently, which is exactly how
//!   multi-channel execution recruits parallel fabric links.
//! * Non-contiguous payloads (more than one chunk per message) pay the
//!   local pack cost at the sender and unpack cost at the receiver
//!   (PAT's "linear part is purely local"). Reducing receives additionally
//!   pay `reduce_byte * bytes` (the RS datapath kernel).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::core::{Error, Rank, Result};
use crate::obs::{Event, EventKind, LevelLinkStat, LinkStat, TraceRecorder};
use crate::sched::program::{Op, Program};
use crate::sim::cost::CostModel;
use crate::sim::fault::FaultModel;
use crate::sim::topology::Topology;

/// Simulation result and traffic metrics.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Completion time of the slowest rank (seconds).
    pub total_time: f64,
    /// Total messages injected.
    pub messages: usize,
    /// Total bytes injected at NICs.
    pub bytes_sent: usize,
    /// Σ (message bytes × links traversed) — the "long-distance traffic"
    /// metric: schedules that send big payloads far score high.
    pub bytes_links: f64,
    /// Bytes crossing each fabric tier (index = distance level; level 0 =
    /// NIC/leaf-local, top = the tapered tier the paper worries about).
    pub bytes_by_level: Vec<usize>,
    /// Message counts per fabric tier (same indexing as `bytes_by_level`);
    /// `msgs_by_level[1..]` are the inter-node / cross-leaf transfers a
    /// placement-aware schedule is meant to minimize.
    pub msgs_by_level: Vec<usize>,
    /// Heaviest per-link byte count (hot-spot load).
    pub max_link_bytes: usize,
    /// Busy fraction of the busiest link (serialization time / total time).
    pub busiest_link_utilization: f64,
    /// Per-rank completion times.
    pub finish: Vec<f64>,
    /// Wall-clock window of each logical step: `(earliest serialization
    /// start, latest arrival)` over the step's messages, indexed by
    /// `Op::step`. Steps with no messages keep the `(+inf, -inf)`
    /// sentinel. This is what makes phase overlap *visible* for composed
    /// all-reduce schedules — feed it to
    /// [`crate::sched::compose::phase_windows`] to get per-(segment,
    /// phase) time windows.
    pub step_spans: Vec<(f64, f64)>,
    /// Wall-clock window of each channel's traffic: `(earliest
    /// serialization start, latest arrival)` over the channel's messages,
    /// indexed by `Op::channel`; silent channels keep the `(+inf, -inf)`
    /// sentinel. Bucketed all-reduce programs own a disjoint channel range
    /// per bucket, so feeding this to
    /// [`crate::sched::bucket::bucket_windows`] makes *inter-bucket*
    /// overlap (bucket `i+1` starting before bucket `i` ends) measurable.
    pub channel_spans: Vec<(f64, f64)>,
    /// Per-link traffic stats, indexed like the topology's link table:
    /// bytes serialized, busy seconds, contended seconds (how long this
    /// link's occupancy delayed messages wanting to start), and busy
    /// fraction of the run. Feed to
    /// [`crate::obs::MetricsReport::with_links`] for the analyzer's
    /// contention view.
    pub link_stats: Vec<LinkStat>,
    /// `link_stats` rolled up per fabric tier (indexed by the topology's
    /// `Link::level`: 0 = NIC, 1 = leaf↔spine, 2 = spine↔core). One row
    /// per tier makes the taper story auditable at a glance — a
    /// hierarchical schedule should show its byte mass at level 0 and
    /// only the striped leader flows above it.
    pub level_link_stats: Vec<LevelLinkStat>,
}

impl SimReport {
    /// Algorithm bandwidth: payload bytes per rank / total time (the
    /// `algbw` NCCL reports). For AG the payload is `(n-1) * chunk_bytes`
    /// received per rank; callers pass the per-rank payload.
    pub fn algbw(&self, payload_bytes_per_rank: usize) -> f64 {
        payload_bytes_per_rank as f64 / self.total_time
    }
}

/// Time-ordered f64 key for the event heap (all times finite).
#[derive(Debug, Clone, Copy, PartialEq)]
struct T(f64);
impl Eq for T {}
impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for T {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("non-finite sim time")
    }
}

/// One message's simulated lifetime (for `--trace` / timeline analysis).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub step: usize,
    pub src: Rank,
    pub dst: Rank,
    pub nchunks: usize,
    pub bytes: usize,
    /// Time serialization started (after link contention).
    pub t_start: f64,
    /// Time the message fully arrived at the destination NIC.
    pub t_arrival: f64,
}

/// Simulate `p` over `topo` with `cost`, `chunk_bytes` bytes per chunk.
pub fn simulate(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: usize,
) -> Result<SimReport> {
    let sizes = vec![chunk_bytes; p.chunk_space()];
    sim_inner(p, topo, cost, &sizes, None, None, None)
}

/// Like [`simulate`], but under a [`FaultModel`]: seeded per-message
/// serialization jitter plus link-flap down-windows (see
/// [`crate::sim::fault`]). A zero model (`jitter == 0`, no flaps)
/// reproduces [`simulate`] exactly.
pub fn simulate_faulted(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: usize,
    faults: &FaultModel,
) -> Result<SimReport> {
    let sizes = vec![chunk_bytes; p.chunk_space()];
    sim_inner(p, topo, cost, &sizes, None, None, Some(faults))
}

/// Like [`simulate`], but with a *per-chunk* byte size (`chunk_bytes[c]`
/// = bytes of chunk id `c`; the slice must cover the program's chunk
/// space). This is how bucketed all-reduce programs with unequal bucket
/// sizes are costed: each bucket's chunks carry that bucket's payload
/// share (see [`crate::sched::bucket::BucketLayout::chunk_elems`]).
pub fn simulate_sized(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: &[usize],
) -> Result<SimReport> {
    sim_inner(p, topo, cost, chunk_bytes, None, None, None)
}

/// Like [`simulate`], additionally returning the per-message timeline.
pub fn simulate_traced(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: usize,
) -> Result<(SimReport, Vec<TraceEvent>)> {
    let mut trace = Vec::new();
    let sizes = vec![chunk_bytes; p.chunk_space()];
    let rep = sim_inner(p, topo, cost, &sizes, Some(&mut trace), None, None)?;
    trace.sort_by(|a, b| a.t_start.partial_cmp(&b.t_start).unwrap());
    Ok((rep, trace))
}

/// Like [`simulate`], additionally recording the full unified event
/// timeline (op spans, wire transit, stalls, reductions — the same
/// [`crate::obs`] schema the transport emits) into `rec`. The report's
/// `step_spans` / `channel_spans` become derived views of the trace:
/// [`crate::obs::Trace::step_spans`] reproduces them exactly.
pub fn simulate_observed(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: usize,
    rec: &mut TraceRecorder,
) -> Result<SimReport> {
    let sizes = vec![chunk_bytes; p.chunk_space()];
    sim_inner(p, topo, cost, &sizes, None, Some(rec), None)
}

fn sim_inner(
    p: &Program,
    topo: &Topology,
    cost: &CostModel,
    chunk_bytes: &[usize],
    mut trace: Option<&mut Vec<TraceEvent>>,
    mut obs: Option<&mut TraceRecorder>,
    faults: Option<&FaultModel>,
) -> Result<SimReport> {
    if topo.nranks != p.nranks {
        return Err(Error::Sim(format!(
            "topology has {} ranks, program has {}",
            topo.nranks, p.nranks
        )));
    }
    if chunk_bytes.len() < p.chunk_space() {
        return Err(Error::Sim(format!(
            "per-chunk sizes cover {} chunks, program uses {}",
            chunk_bytes.len(),
            p.chunk_space()
        )));
    }
    let msg_bytes = |chunks: &[usize]| chunks.iter().map(|&c| chunk_bytes[c]).sum::<usize>();
    let n = p.nranks;
    // Channels are explicit in the IR (`Op::channel`): composed all-reduce
    // programs carry one channel per pipeline segment, channel-split
    // primitives one per stripe (see `sched::channel`). Each channel has
    // its own proxy stream and connection, so channels progress
    // independently while still contending on the links — no more
    // inferring channels from chunk-id conventions per collective.
    let channels = p.channels.max(1);
    // Per-rank per-channel in-order op streams.
    let streams = crate::sched::channel::per_channel_streams(p);
    let mut pc = vec![vec![0usize; channels]; n];
    let mut chan_time = vec![vec![0.0f64; channels]; n];
    let mut link_free = vec![0.0f64; topo.links.len()];
    let mut link_bytes = vec![0usize; topo.links.len()];
    let mut link_busy = vec![0.0f64; topo.links.len()];
    let mut link_contended = vec![0.0f64; topo.links.len()];
    // In-flight messages per (src, dst, channel): arrival times, FIFO.
    // Channels are separate connections, so FIFO holds per channel.
    let mut wires: HashMap<(Rank, Rank, usize), VecDeque<f64>> = HashMap::new();
    // Streams blocked on an empty wire, keyed by (src, dst, channel).
    let mut blocked: HashMap<(Rank, Rank, usize), (Rank, usize)> = HashMap::new();
    // Event heap: (ready time, rank, channel). A stream appears at most once.
    let mut heap: BinaryHeap<Reverse<(T, Rank, usize)>> = BinaryHeap::new();
    let mut queued = vec![vec![false; channels]; n];

    let mut report = SimReport {
        total_time: 0.0,
        messages: 0,
        bytes_sent: 0,
        bytes_links: 0.0,
        bytes_by_level: vec![0; topo.max_level() + 1],
        msgs_by_level: vec![0; topo.max_level() + 1],
        max_link_bytes: 0,
        busiest_link_utilization: 0.0,
        finish: vec![0.0; n],
        step_spans: vec![(f64::INFINITY, f64::NEG_INFINITY); p.steps],
        channel_spans: vec![(f64::INFINITY, f64::NEG_INFINITY); channels],
        link_stats: Vec::new(),
        level_link_stats: Vec::new(),
    };

    // Initial scheduling pass.
    for r in 0..n {
        for k in 0..channels {
            schedule_stream(
                r, k, &streams, &pc, &chan_time, &wires, &mut blocked, &mut heap,
                &mut queued,
            );
        }
    }

    let mut retired = 0usize;
    let total_ops = p.total_ops();

    while let Some(Reverse((T(t), r, k))) = heap.pop() {
        queued[r][k] = false;
        let op = streams[r][k][pc[r][k]];
        match op {
            Op::Send { peer, chunks, step, .. } => {
                let bytes = msg_bytes(chunks);
                // Local pack for non-contiguous aggregated payloads.
                let t_ready = t + cost.pack_cost(chunks.len(), bytes);
                // Per-channel connections are distinct flows: the static
                // ECMP hash is salted with the channel, so a multi-channel
                // collective spreads over parallel spines/cores.
                let path = topo.route(r, *peer, k as u64);
                // Contended start: after every link on the path is free.
                let mut t0 = t_ready;
                let mut min_bw = f64::INFINITY;
                for &l in &path {
                    t0 = t0.max(link_free[l]);
                    min_bw = min_bw.min(topo.links[l].bandwidth);
                    // How long this link's prior occupancy would make a
                    // ready message wait — per-link contention blame.
                    link_contended[l] += (link_free[l] - t_ready).max(0.0);
                }
                if let Some(fm) = faults {
                    // Link flap: a start inside a down-window on any link
                    // of the path waits for the window to close.
                    t0 = fm.hold_start(&path, t0);
                }
                for &l in &path {
                    let ser_l = bytes as f64 / topo.links[l].bandwidth;
                    link_free[l] = t0 + ser_l;
                    link_bytes[l] += bytes;
                    link_busy[l] += ser_l;
                }
                let ser = if path.is_empty() { 0.0 } else { bytes as f64 / min_bw };
                let hops = path.len().saturating_sub(1);
                let mut arrival = t0 + ser + cost.alpha_base + cost.alpha_hop * hops as f64;
                if let Some(fm) = faults {
                    // Seeded per-message serialization jitter; the message
                    // index (retire order is deterministic) keys the hash.
                    arrival += fm.jitter_extra(r, *peer, k, report.messages as u64, ser);
                }
                wires.entry((r, *peer, k)).or_default().push_back(arrival);
                // Sender stream available again after the posting gap.
                chan_time[r][k] = t_ready + cost.msg_gap;

                report.messages += 1;
                report.bytes_sent += bytes;
                report.bytes_links += (bytes * path.len()) as f64;
                let span = &mut report.step_spans[*step];
                span.0 = span.0.min(t0);
                span.1 = span.1.max(arrival);
                let cspan = &mut report.channel_spans[k];
                cspan.0 = cspan.0.min(t0);
                cspan.1 = cspan.1.max(arrival);
                let lvl = topo.distance_level(r, *peer);
                report.bytes_by_level[lvl] += bytes;
                report.msgs_by_level[lvl] += 1;
                if let Some(tr) = trace.as_deref_mut() {
                    tr.push(TraceEvent {
                        step: *step,
                        src: r,
                        dst: *peer,
                        nchunks: chunks.len(),
                        bytes,
                        t_start: t0,
                        t_arrival: arrival,
                    });
                }
                if let Some(o) = obs.as_deref_mut() {
                    // The op occupies its stream from wake to pack-done +
                    // posting gap; the wire span is contended start → arrival.
                    o.record(
                        Event::span(EventKind::SendOp, r, k, *step, t, t_ready + cost.msg_gap)
                            .with_peer(*peer)
                            .with_msg(chunks, bytes),
                    );
                    o.record(
                        Event::span(EventKind::Wire, r, k, *step, t0, arrival)
                            .with_peer(*peer)
                            .with_msg(chunks, bytes),
                    );
                }

                // Wake the peer stream if it is blocked on this wire.
                if let Some((d, dk)) = blocked.remove(&(r, *peer, k)) {
                    debug_assert_eq!(d, *peer);
                    if !queued[d][dk] {
                        let wake = chan_time[d][dk].max(arrival);
                        heap.push(Reverse((T(wake), d, dk)));
                        queued[d][dk] = true;
                    }
                }
            }
            Op::Recv { peer, chunks, reduce, step, .. } => {
                let bytes = msg_bytes(chunks);
                let ready = chan_time[r][k];
                let q = wires.entry((*peer, r, k)).or_default();
                let arrival = q.pop_front().ok_or_else(|| {
                    Error::Sim(format!("rank {r} woken with empty wire from {peer}"))
                })?;
                let mut tdone = t.max(arrival) + cost.pack_cost(chunks.len(), bytes);
                if *reduce {
                    tdone += cost.reduce_cost(bytes);
                }
                chan_time[r][k] = tdone;
                if let Some(o) = obs.as_deref_mut() {
                    // The stream was free at `ready` but could not retire
                    // this Recv until `t` — blocked on the wire.
                    if t > ready {
                        o.record(
                            Event::span(EventKind::Stall, r, k, *step, ready, t)
                                .with_peer(*peer),
                        );
                    }
                    o.record(
                        Event::span(EventKind::RecvOp, r, k, *step, t, tdone)
                            .with_peer(*peer)
                            .with_msg(chunks, bytes),
                    );
                    if *reduce {
                        o.record(
                            Event::span(
                                EventKind::Reduce,
                                r,
                                k,
                                *step,
                                tdone - cost.reduce_cost(bytes),
                                tdone,
                            )
                            .with_bytes(bytes),
                        );
                    }
                }
            }
        }
        pc[r][k] += 1;
        retired += 1;
        schedule_stream(
            r, k, &streams, &pc, &chan_time, &wires, &mut blocked, &mut heap,
            &mut queued,
        );
    }

    if retired != total_ops {
        return Err(Error::Sim(format!(
            "simulation stalled: retired {retired}/{total_ops} ops (unverified program?)"
        )));
    }

    for r in 0..n {
        report.finish[r] = chan_time[r].iter().cloned().fold(0.0, f64::max);
    }
    report.total_time = report.finish.iter().cloned().fold(0.0, f64::max);
    report.max_link_bytes = link_bytes.iter().copied().max().unwrap_or(0);
    if report.total_time > 0.0 {
        report.busiest_link_utilization = link_bytes
            .iter()
            .enumerate()
            .map(|(l, &b)| b as f64 / topo.links[l].bandwidth / report.total_time)
            .fold(0.0, f64::max);
    }
    report.link_stats = (0..topo.links.len())
        .map(|l| LinkStat {
            link: l,
            bytes: link_bytes[l],
            busy_s: link_busy[l],
            contended_s: link_contended[l],
            utilization: if report.total_time > 0.0 {
                link_busy[l] / report.total_time
            } else {
                0.0
            },
        })
        .collect();
    // Tier roll-up: every link carries its fabric level, so the per-tier
    // rows are a direct fold of the per-link table.
    let mut by_level = vec![LevelLinkStat::default(); topo.max_level() + 1];
    for (lvl, row) in by_level.iter_mut().enumerate() {
        row.level = lvl;
    }
    for s in &report.link_stats {
        let row = &mut by_level[topo.links[s.link].level];
        row.links += 1;
        row.bytes += s.bytes;
        row.busy_s += s.busy_s;
        row.contended_s += s.contended_s;
        row.max_utilization = row.max_utilization.max(s.utilization);
    }
    report.level_link_stats = by_level;
    Ok(report)
}

#[allow(clippy::too_many_arguments)]
fn schedule_stream(
    r: Rank,
    k: usize,
    streams: &[Vec<Vec<&Op>>],
    pc: &[Vec<usize>],
    chan_time: &[Vec<f64>],
    wires: &HashMap<(Rank, Rank, usize), VecDeque<f64>>,
    blocked: &mut HashMap<(Rank, Rank, usize), (Rank, usize)>,
    heap: &mut BinaryHeap<Reverse<(T, Rank, usize)>>,
    queued: &mut [Vec<bool>],
) {
    if pc[r][k] >= streams[r][k].len() || queued[r][k] {
        return;
    }
    match streams[r][k][pc[r][k]] {
        Op::Send { .. } => {
            heap.push(Reverse((T(chan_time[r][k]), r, k)));
            queued[r][k] = true;
        }
        Op::Recv { peer, .. } => {
            if let Some(q) = wires.get(&(*peer, r, k)) {
                if let Some(&arrival) = q.front() {
                    heap.push(Reverse((T(chan_time[r][k].max(arrival)), r, k)));
                    queued[r][k] = true;
                    return;
                }
            }
            blocked.insert((*peer, r, k), (r, k));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{pat, ring};
    use crate::sim::topology::Topology;

    fn flat(n: usize) -> Topology {
        Topology::flat(n, CostModel::ib_hdr_nic_bw())
    }

    #[test]
    fn ring_time_scales_linearly_in_ranks() {
        let cost = CostModel::ib_hdr();
        let t8 = simulate(&ring::allgather(8), &flat(8), &cost, 256).unwrap();
        let t32 = simulate(&ring::allgather(32), &flat(32), &cost, 256).unwrap();
        let ratio = t32.total_time / t8.total_time;
        assert!(
            (3.0..6.0).contains(&ratio),
            "ring should scale ~linearly: {ratio}"
        );
    }

    #[test]
    fn pat_time_scales_logarithmically_for_small_messages() {
        let cost = CostModel::ib_hdr();
        let t8 = simulate(&pat::allgather(8, usize::MAX), &flat(8), &cost, 256).unwrap();
        let t64 = simulate(&pat::allgather(64, usize::MAX), &flat(64), &cost, 256).unwrap();
        // 3 steps -> 6 steps: about 2x, certainly far below the 8x of ring.
        let ratio = t64.total_time / t8.total_time;
        assert!(ratio < 3.5, "pat should scale ~log: {ratio}");
    }

    #[test]
    fn pat_beats_ring_at_small_size_loses_nothing_at_large() {
        let cost = CostModel::ib_hdr();
        let n = 32;
        let small = 128; // bytes/chunk
        let pat_t = simulate(&pat::allgather(n, usize::MAX), &flat(n), &cost, small)
            .unwrap()
            .total_time;
        let ring_t = simulate(&ring::allgather(n), &flat(n), &cost, small)
            .unwrap()
            .total_time;
        assert!(
            pat_t < ring_t / 2.0,
            "small-size PAT {pat_t} should be well under ring {ring_t}"
        );
    }

    #[test]
    fn conservation_of_bytes() {
        let cost = CostModel::ideal();
        let n = 16;
        let chunk = 1024;
        let rep = simulate(&ring::allgather(n), &flat(n), &cost, chunk).unwrap();
        // ring AG: n*(n-1) messages of one chunk
        assert_eq!(rep.messages, n * (n - 1));
        assert_eq!(rep.bytes_sent, n * (n - 1) * chunk);
    }

    #[test]
    fn reduce_scatter_pays_reduction() {
        let mut cost = CostModel::ideal();
        cost.reduce_byte = 1.0; // 1 s/byte — dominates everything
        let n = 4;
        let ag = simulate(&ring::allgather(n), &flat(n), &cost, 64).unwrap();
        let rs = simulate(&ring::reduce_scatter(n), &flat(n), &cost, 64).unwrap();
        assert!(rs.total_time > ag.total_time * 10.0);
    }

    #[test]
    fn tapered_fabric_slows_cross_leaf_traffic() {
        let cost = CostModel::ideal();
        let n = 16;
        let full = Topology::leaf_spine(n, 4, 4, 25e9, 1.0).unwrap();
        let tapered = Topology::leaf_spine(n, 4, 1, 25e9, 0.25).unwrap();
        let p = crate::sched::bruck::allgather_near_first(n);
        let t_full = simulate(&p, &full, &cost, 1 << 20).unwrap().total_time;
        let t_tap = simulate(&p, &tapered, &cost, 1 << 20).unwrap().total_time;
        assert!(
            t_tap > 2.0 * t_full,
            "taper must hurt: full={t_full} tapered={t_tap}"
        );
    }

    #[test]
    fn level_accounting() {
        let cost = CostModel::ideal();
        let topo = Topology::leaf_spine(8, 4, 2, 25e9, 1.0).unwrap();
        let p = ring::allgather(8);
        let rep = simulate(&p, &topo, &cost, 100).unwrap();
        // ring neighbours: ranks 3->4 and 7->0 cross leaves each step.
        assert!(rep.bytes_by_level[1] > 0);
        assert!(rep.bytes_by_level[0] > rep.bytes_by_level[1]);
        assert_eq!(rep.bytes_by_level.iter().sum::<usize>(), rep.bytes_sent);
        assert_eq!(rep.msgs_by_level.iter().sum::<usize>(), rep.messages);
        // ring on 2 leaves of 4: exactly 2 of the 8 sends per step cross
        assert_eq!(rep.msgs_by_level[1], 2 * 7);
    }

    #[test]
    fn empty_program_zero_time() {
        let p = crate::sched::pat::allgather(1, 1);
        let rep = simulate(&p, &flat(1), &CostModel::ib_hdr(), 64).unwrap();
        assert_eq!(rep.total_time, 0.0);
        assert_eq!(rep.messages, 0);
    }

    #[test]
    fn trace_covers_every_message() {
        let p = ring::allgather(6);
        let topo = flat(6);
        let (rep, trace) = simulate_traced(&p, &topo, &CostModel::ib_hdr(), 512).unwrap();
        assert_eq!(trace.len(), rep.messages);
        for ev in &trace {
            assert!(ev.t_arrival > ev.t_start);
            assert!(ev.t_arrival <= rep.total_time + 1e-12);
            assert_eq!(ev.bytes, ev.nchunks * 512);
        }
        // sorted by start time
        for w in trace.windows(2) {
            assert!(w[0].t_start <= w[1].t_start);
        }
    }

    #[test]
    fn rank_count_mismatch_rejected() {
        let p = ring::allgather(4);
        assert!(simulate(&p, &flat(8), &CostModel::ib_hdr(), 64).is_err());
    }

    #[test]
    fn step_spans_cover_every_nonempty_step() {
        let p = pat::allgather(16, 2);
        let rep = simulate(&p, &flat(16), &CostModel::ib_hdr(), 1024).unwrap();
        assert_eq!(rep.step_spans.len(), p.steps);
        let nonempty: std::collections::HashSet<usize> =
            p.messages().iter().map(|m| m.step).collect();
        for (s, &(t0, t1)) in rep.step_spans.iter().enumerate() {
            if nonempty.contains(&s) {
                assert!(t0.is_finite() && t1 >= t0, "step {s}: ({t0}, {t1})");
                assert!(t1 <= rep.total_time + 1e-12);
            } else {
                assert!(!t0.is_finite(), "empty step {s} should keep the sentinel");
            }
        }
        // steps' start times are non-decreasing for a dependent chain
        for w in rep.step_spans.windows(2) {
            if w[0].0.is_finite() && w[1].0.is_finite() {
                assert!(w[0].0 <= w[1].0 + 1e-12);
            }
        }
    }

    /// A channel-split primitive collective runs through the simulator
    /// (per-channel streams + wires), with the same bytes in C× messages.
    #[test]
    fn channel_split_program_simulates() {
        use crate::sched::channel;
        let n = 16;
        let base = pat::allgather(n, 2);
        let topo = Topology::leaf_spine(n, 4, 4, 25e9, 0.5).unwrap();
        let cost = CostModel::ib_hdr();
        let chunk = 64 << 10;
        let rep1 = simulate(&base, &topo, &cost, chunk).unwrap();
        for c in [2usize, 4] {
            let split = channel::split(&base, c).unwrap();
            let rep = simulate(&split, &topo, &cost, chunk / c).unwrap();
            assert_eq!(rep.messages, c * rep1.messages, "c={c}");
            assert_eq!(rep.bytes_sent, rep1.bytes_sent, "c={c}");
            assert!(rep.total_time > 0.0);
        }
        // reduce-scatter side too
        let rs = channel::split(&base.mirror(), 2).unwrap();
        simulate(&rs, &topo, &cost, chunk / 2).unwrap();
    }

    /// Channels are distinct flows: with several spines, at least some
    /// (src, dst) pairs route differently on different channel salts —
    /// the mechanism that lets C > 1 recruit parallel links.
    #[test]
    fn channels_hash_to_distinct_paths() {
        let topo = Topology::leaf_spine(16, 4, 4, 25e9, 1.0).unwrap();
        let mut diverged = 0usize;
        for src in 0..16 {
            for dst in 0..16 {
                if src / 4 == dst / 4 || src == dst {
                    continue; // same leaf: fixed 2-link path
                }
                if topo.route(src, dst, 0) != topo.route(src, dst, 1) {
                    diverged += 1;
                }
            }
        }
        assert!(diverged > 0, "no (src, dst) pair diverged across channel salts");
    }

    /// `simulate_sized` with a uniform size vector reproduces `simulate`
    /// exactly, and per-chunk sizes change exactly the bytes accounting.
    #[test]
    fn sized_simulation_matches_uniform_and_scales_bytes() {
        let p = pat::allgather(8, 2);
        let topo = flat(8);
        let cost = CostModel::ib_hdr();
        let uniform = simulate(&p, &topo, &cost, 1024).unwrap();
        let sized = simulate_sized(&p, &topo, &cost, &vec![1024; p.chunk_space()]).unwrap();
        assert_eq!(uniform.total_time, sized.total_time);
        assert_eq!(uniform.bytes_sent, sized.bytes_sent);
        // doubling one chunk's size adds exactly its extra transfers
        let mut sizes = vec![1024usize; p.chunk_space()];
        sizes[0] = 2048;
        let bigger = simulate_sized(&p, &topo, &cost, &sizes).unwrap();
        // chunk 0 is sent to the other 7 ranks exactly once each
        assert_eq!(bigger.bytes_sent, uniform.bytes_sent + 7 * 1024);
        // undersized vectors are a loud error
        assert!(simulate_sized(&p, &topo, &cost, &[1024]).is_err());
    }

    /// Channel spans: every channel with traffic gets a finite window
    /// inside the run, and a bucketed program's windows genuinely overlap
    /// across adjacent buckets (the cross-operation pipelining).
    #[test]
    fn channel_spans_expose_bucket_overlap() {
        use crate::sched::bucket::{self, BucketLayout};
        let n = 32;
        let rs = pat::reduce_scatter(n, usize::MAX);
        let ag = pat::allgather(n, usize::MAX);
        let buckets = bucket::uniform(&rs, &ag, 3, 1);
        let p = bucket::fuse(&buckets).unwrap();
        let layout = BucketLayout::of(&buckets);
        let rep = simulate(&p, &flat(n), &CostModel::ib_hdr(), 32 << 10).unwrap();
        assert_eq!(rep.channel_spans.len(), p.channels);
        for (k, &(s, e)) in rep.channel_spans.iter().enumerate() {
            assert!(s.is_finite() && e >= s, "channel {k}: ({s}, {e})");
            assert!(e <= rep.total_time + 1e-12, "channel {k}");
        }
        let windows = bucket::bucket_windows(&layout, &rep.channel_spans);
        assert_eq!(windows.len(), 3);
        for w in windows.windows(2) {
            assert!(
                w[1].t_start < w[0].t_end && w[0].t_start < w[1].t_start,
                "buckets {} and {} do not overlap: ({}, {}) vs ({}, {})",
                w[0].bucket,
                w[1].bucket,
                w[0].t_start,
                w[0].t_end,
                w[1].t_start,
                w[1].t_end
            );
        }
    }

    /// The unified trace subsumes the report's step/channel spans: the
    /// derived views computed from wire events reproduce them exactly,
    /// and the per-(rank, channel) counters account for every message.
    #[test]
    fn observed_trace_subsumes_report_spans() {
        use crate::obs::{EventKind, TraceRecorder};
        use crate::sched::channel;
        let p = channel::split(&pat::allgather(16, 2), 2).unwrap();
        let topo = flat(16);
        let cost = CostModel::ib_hdr();
        let mut rec = TraceRecorder::new();
        let rep = simulate_observed(&p, &topo, &cost, 1024, &mut rec).unwrap();
        let baseline = simulate(&p, &topo, &cost, 1024).unwrap();
        assert_eq!(rep.total_time, baseline.total_time, "observing must not perturb");
        let trace = rec.finish();
        // derived views == report fields (including empty-step sentinels)
        let derived_steps = trace.step_spans(p.steps);
        for (s, (&a, &b)) in derived_steps.iter().zip(rep.step_spans.iter()).enumerate() {
            if a.0.is_finite() || b.0.is_finite() {
                assert_eq!(a, b, "step {s}");
            }
        }
        assert_eq!(trace.channel_spans(p.channels), rep.channel_spans);
        // counters: every simulated message was recorded once, both sides
        let totals = trace.totals();
        assert_eq!(totals.msgs_sent, rep.messages);
        assert_eq!(totals.msgs_recv, rep.messages);
        assert_eq!(totals.bytes_sent, rep.bytes_sent);
        let wires = trace.events.iter().filter(|e| e.kind == EventKind::Wire).count();
        assert_eq!(wires, rep.messages);
        // a 16-rank PAT run genuinely blocks on receives somewhere
        assert!(totals.stall_seconds > 0.0, "expected at least one stall");
    }

    /// Per-link stats agree with the aggregate counters: byte totals
    /// match `bytes_links`, and the peak utilization reproduces
    /// `busiest_link_utilization`.
    #[test]
    fn link_stats_account_for_traffic_and_contention() {
        let topo = Topology::leaf_spine(16, 4, 2, 25e9, 0.5).unwrap();
        let p = ring::allgather(16);
        let rep = simulate(&p, &topo, &CostModel::ib_hdr(), 64 << 10).unwrap();
        assert!(!rep.link_stats.is_empty());
        for (l, s) in rep.link_stats.iter().enumerate() {
            assert_eq!(s.link, l);
            assert!(s.busy_s >= 0.0 && s.contended_s >= 0.0);
            assert!(s.utilization <= 1.0 + 1e-9, "link {l} over unity");
        }
        let total: usize = rep.link_stats.iter().map(|s| s.bytes).sum();
        assert_eq!(total as f64, rep.bytes_links);
        let max_util =
            rep.link_stats.iter().map(|s| s.utilization).fold(0.0, f64::max);
        assert!((max_util - rep.busiest_link_utilization).abs() < 1e-9);
        // a ring over tapered leaf-spine genuinely contends somewhere
        assert!(rep.link_stats.iter().any(|s| s.contended_s > 0.0));
    }

    /// The per-tier roll-up partitions the per-link table: one row per
    /// fabric level, link/byte totals preserved, and the tier byte split
    /// consistent with `bytes_by_level`'s traffic attribution.
    #[test]
    fn level_link_stats_partition_the_link_table() {
        let topo = Topology::three_level(32, 4, 4, 2, 2, 25e9, 1.0, 0.25).unwrap();
        let p = pat::allgather(32, usize::MAX);
        let rep = simulate(&p, &topo, &CostModel::ib_hdr(), 16 << 10).unwrap();
        assert_eq!(rep.level_link_stats.len(), 3);
        for (lvl, row) in rep.level_link_stats.iter().enumerate() {
            assert_eq!(row.level, lvl);
            assert!(row.links > 0, "level {lvl} has no links");
        }
        assert_eq!(
            rep.level_link_stats.iter().map(|r| r.links).sum::<usize>(),
            rep.link_stats.len()
        );
        let bytes_total: usize = rep.level_link_stats.iter().map(|r| r.bytes).sum();
        assert_eq!(bytes_total as f64, rep.bytes_links);
        // a flat PAT at 32 ranks genuinely crosses the core tier
        assert!(rep.level_link_stats[2].bytes > 0);
        for row in &rep.level_link_stats {
            let max_in_tier = rep
                .link_stats
                .iter()
                .filter(|s| topo.links[s.link].level == row.level)
                .map(|s| s.utilization)
                .fold(0.0, f64::max);
            assert!((row.max_utilization - max_in_tier).abs() < 1e-12);
        }
    }

    /// Reducing receives emit reduce-kernel events in the unified trace.
    #[test]
    fn observed_trace_records_reductions() {
        use crate::obs::{EventKind, TraceRecorder};
        let p = pat::reduce_scatter(8, 2);
        let mut rec = TraceRecorder::new();
        simulate_observed(&p, &flat(8), &CostModel::ib_hdr(), 512, &mut rec).unwrap();
        let trace = rec.finish();
        let reduces = trace.events.iter().filter(|e| e.kind == EventKind::Reduce).count();
        assert!(reduces > 0);
        assert_eq!(trace.totals().reduce_calls, reduces);
    }

    /// A composed all-reduce program runs through the simulator without
    /// stalling, and its segment phases genuinely overlap in time.
    #[test]
    fn composed_allreduce_simulates_with_overlap() {
        use crate::sched::compose::{self, Layout, Phase};
        let n = 32;
        let rs = pat::reduce_scatter(n, usize::MAX);
        let ag = pat::allgather(n, usize::MAX);
        let p = compose::fuse(&rs, &ag, 4).unwrap();
        let layout = Layout::of(&rs, &ag, 4);
        let rep = simulate(&p, &flat(n), &CostModel::ib_hdr(), 64 << 10).unwrap();
        assert!(rep.total_time > 0.0);
        let windows = compose::phase_windows(&layout, &rep.step_spans);
        let find = |seg: usize, ph: Phase| {
            windows
                .iter()
                .find(|w| w.segment == seg && w.phase == ph)
                .unwrap_or_else(|| panic!("missing window seg={seg} {ph:?}"))
        };
        let ag0 = find(0, Phase::AllGather);
        let rs1 = find(1, Phase::ReduceScatter);
        // temporal overlap: each starts before the other ends
        assert!(
            ag0.t_start < rs1.t_end && rs1.t_start < ag0.t_end,
            "no overlap: ag0=({}, {}) rs1=({}, {})",
            ag0.t_start,
            ag0.t_end,
            rs1.t_start,
            rs1.t_end
        );
    }
}
