//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! Maps the unified [`Trace`] onto the trace-event format: **pid = rank**,
//! **tid = channel**, so the viewer groups spans rank → channel; metadata
//! events name each track. Span events (`"ph":"X"`) carry the event kind
//! as `name` and a `cat` string that appends the channel's segment/bucket
//! tag and — when a [`crate::sched::compose::Layout`] or
//! [`crate::sched::bucket::BucketLayout`] is supplied — the
//! reduce-scatter/all-gather phase the message belongs to, so Perfetto's
//! category coloring separates phases and buckets visually. Buffer-pool
//! samples export as counter tracks (`"ph":"C"`).
//!
//! Timestamps: trace seconds × 1e6 (the format wants microseconds).

use crate::obs::trace::{Event, EventKind, Trace, SCHEMA_VERSION};
use crate::sched::bucket::BucketLayout;
use crate::sched::compose::Layout;
use crate::util::json::Json;

/// How to label each channel track and classify events into
/// segment/bucket/phase categories.
#[derive(Debug, Clone)]
pub struct ChannelTags {
    tags: Vec<String>,
    mode: TagMode,
}

#[derive(Debug, Clone)]
enum TagMode {
    Plain,
    Composed(Layout),
    Bucketed(BucketLayout),
}

impl ChannelTags {
    /// No extra structure: channels are just channels.
    pub fn plain() -> ChannelTags {
        ChannelTags { tags: Vec::new(), mode: TagMode::Plain }
    }

    /// Composed all-reduce: channel `k` carries pipeline segment `k`;
    /// events additionally classify into rs/ag phases by (step, chunk).
    pub fn composed(layout: Layout) -> ChannelTags {
        let tags = (0..layout.segments).map(|s| format!("seg{s}")).collect();
        ChannelTags { tags, mode: TagMode::Composed(layout) }
    }

    /// Bucketed batch: channel `channel_base_b + s` carries bucket `b`'s
    /// segment `s`.
    pub fn bucketed(layout: BucketLayout) -> ChannelTags {
        let mut tags = Vec::with_capacity(layout.channels());
        for b in 0..layout.nbuckets() {
            let (lo, hi) = layout.channel_range(b);
            for k in lo..hi {
                tags.push(format!("bucket{b}/seg{}", k - lo));
            }
        }
        ChannelTags { tags, mode: TagMode::Bucketed(layout) }
    }

    /// Track label for channel `k` (`None` when untagged).
    pub fn tag(&self, channel: usize) -> Option<&str> {
        self.tags.get(channel).map(|s| s.as_str())
    }

    /// Phase ("reduce-scatter" / "all-gather") of a message event, when
    /// the tag mode carries a step grid to classify against.
    fn phase_of(&self, ev: &Event) -> Option<&'static str> {
        let chunk = ev.chunk0?;
        match &self.mode {
            TagMode::Plain => None,
            TagMode::Composed(layout) => {
                let (_, phase) = layout.classify(ev.step, chunk);
                Some(phase.as_str())
            }
            TagMode::Bucketed(layout) => {
                let b = layout.bucket_of_chunk(chunk);
                let local_step = ev.step.saturating_sub(layout.step_base[b]);
                let local_chunk = chunk - layout.chunk_base[b];
                let (_, phase) = layout.per_bucket[b].classify(local_step, local_chunk);
                Some(phase.as_str())
            }
        }
    }

    /// The `cat` string for an event: kind, channel tag, phase.
    fn cat(&self, ev: &Event) -> String {
        let mut cat = ev.kind.name().to_string();
        if let Some(tag) = self.tag(ev.channel) {
            cat.push(',');
            cat.push_str(tag);
        }
        if let Some(phase) = self.phase_of(ev) {
            cat.push(',');
            cat.push_str(phase);
        }
        cat
    }
}

fn usecs(t: f64) -> f64 {
    t * 1e6
}

/// Counter-track name for counter-sample kinds (`"ph":"C"`); span kinds
/// export as `"X"` events and return `None`.
fn counter_track(kind: EventKind) -> Option<&'static str> {
    match kind {
        EventKind::Pool => Some("pool live slots"),
        EventKind::Arena => Some("arena bytes"),
        _ => None,
    }
}

/// Inverse of [`counter_track`] plus the span kinds by their exported
/// `name` — `None` for names this version does not know (forward
/// compatibility: unknown kinds are skipped on import).
fn kind_of_name(name: &str) -> Option<EventKind> {
    match name {
        "send" => Some(EventKind::SendOp),
        "recv" => Some(EventKind::RecvOp),
        "wire" => Some(EventKind::Wire),
        "stall" => Some(EventKind::Stall),
        "reduce" => Some(EventKind::Reduce),
        "adversary" => Some(EventKind::Adversary),
        "pool live slots" => Some(EventKind::Pool),
        "arena bytes" => Some(EventKind::Arena),
        _ => None,
    }
}

/// Export a [`Trace`] as a Chrome trace-event JSON document (object form,
/// with `traceEvents` plus a `schema_version` stamp in `otherData`).
pub fn chrome_trace(trace: &Trace, tags: &ChannelTags) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(trace.events.len() + 2 * trace.counters.len());

    // Track-naming metadata: one process per rank, one thread per channel.
    let mut ranks: Vec<usize> = trace.counters.keys().map(|&(r, _)| r).collect();
    ranks.dedup();
    for &r in &ranks {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(r as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("rank {r}")))])),
        ]));
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_sort_index")),
            ("pid", Json::num(r as f64)),
            ("args", Json::obj(vec![("sort_index", Json::num(r as f64))])),
        ]));
    }
    for &(r, k) in trace.counters.keys() {
        let label = match tags.tag(k) {
            Some(t) => format!("ch{k} [{t}]"),
            None => format!("ch{k}"),
        };
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(r as f64)),
            ("tid", Json::num(k as f64)),
            ("args", Json::obj(vec![("name", Json::str(label))])),
        ]));
    }

    for ev in &trace.events {
        if let Some(track) = counter_track(ev.kind) {
            // Counter tracks: live pool slots / arena bytes over time, a
            // curve per rank in the timeline.
            events.push(Json::obj(vec![
                ("ph", Json::str("C")),
                ("name", Json::str(track)),
                ("pid", Json::num(ev.rank as f64)),
                ("tid", Json::num(ev.channel as f64)),
                ("ts", Json::num(usecs(ev.t_start))),
                ("args", Json::obj(vec![("live", Json::num(ev.value as f64))])),
            ]));
            continue;
        }
        let mut args = vec![("step", Json::num(ev.step as f64))];
        if let Some(p) = ev.peer {
            args.push(("peer", Json::num(p as f64)));
        }
        if ev.chunks > 0 {
            args.push(("chunks", Json::num(ev.chunks as f64)));
        }
        if let Some(c0) = ev.chunk0 {
            args.push(("chunk0", Json::num(c0 as f64)));
        }
        if ev.bytes > 0 {
            args.push(("bytes", Json::num(ev.bytes as f64)));
        }
        events.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(ev.kind.name())),
            ("cat", Json::str(tags.cat(ev))),
            ("pid", Json::num(ev.rank as f64)),
            ("tid", Json::num(ev.channel as f64)),
            ("ts", Json::num(usecs(ev.t_start))),
            ("dur", Json::num(usecs(ev.duration()))),
            ("args", Json::Obj(args.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(SCHEMA_VERSION as f64)),
                ("generator", Json::str("patcol")),
                ("dropped_events", Json::num(trace.dropped as f64)),
            ]),
        ),
    ])
}

/// Re-import a Chrome trace document exported by [`chrome_trace`] back
/// into a [`Trace`] — what `patcol analyze TRACE.json` consumes.
///
/// Tolerant across schema versions per the append-only guarantee in
/// [`crate::obs`]: metadata records (`"ph":"M"`) and unknown event names
/// are skipped, missing args default to their neutral values, so v2
/// documents (which predate the `arena bytes` track) load cleanly.
/// Counters are rebuilt by folding the imported events; join-time-only
/// counters that are not event-carried (`allocs`, and `arena_hw_bytes`
/// in v2 documents) come back as 0.
pub fn import_chrome_trace(doc: &Json) -> crate::core::Result<Trace> {
    use crate::core::Error;
    let evs = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| Error::Config("trace document has no traceEvents array".into()))?;
    let dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(|d| d.as_f64())
        .unwrap_or(0.0) as u64;
    let mut trace = Trace { dropped, ..Trace::default() };
    for e in evs {
        let ph = e.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph != "X" && ph != "C" {
            continue;
        }
        let Some(kind) = e.get("name").and_then(|n| n.as_str()).and_then(kind_of_name)
        else {
            continue; // future kind: skip, per the stability guarantee
        };
        let num = |key: &str| e.get(key).and_then(|v| v.as_f64());
        let arg = |key: &str| e.get("args").and_then(|a| a.get(key)).and_then(|v| v.as_f64());
        let (rank, channel) = match (num("pid"), num("tid")) {
            (Some(p), Some(t)) => (p as usize, t as usize),
            _ => {
                return Err(Error::Config(format!(
                    "event without pid/tid: {}",
                    e.to_string()
                )))
            }
        };
        let ts = num("ts")
            .ok_or_else(|| Error::Config(format!("event without ts: {}", e.to_string())))?
            / 1e6;
        let dur = num("dur").unwrap_or(0.0) / 1e6;
        let mut ev = Event::span(
            kind,
            rank,
            channel,
            arg("step").unwrap_or(0.0) as usize,
            ts,
            ts + dur,
        );
        ev.peer = arg("peer").map(|p| p as usize);
        ev.chunks = arg("chunks").unwrap_or(0.0) as usize;
        ev.chunk0 = arg("chunk0").map(|c| c as usize);
        ev.bytes = arg("bytes").unwrap_or(0.0) as usize;
        ev.value = arg("live").unwrap_or(0.0) as usize;
        trace
            .counters
            .entry((ev.rank, ev.channel))
            .or_default()
            .absorb(&ev);
        trace.events.push(ev);
    }
    trace.sort();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceRecorder;
    use crate::util::json;

    fn sample_trace() -> Trace {
        let mut rec = TraceRecorder::new();
        rec.record(
            Event::span(EventKind::SendOp, 0, 0, 0, 0.0, 1e-6)
                .with_peer(1)
                .with_msg(&[2], 8),
        );
        rec.record(
            Event::span(EventKind::Wire, 0, 0, 0, 0.0, 2e-6).with_peer(1).with_msg(&[2], 8),
        );
        rec.record(Event::span(EventKind::Pool, 1, 0, 0, 1e-6, 1e-6).with_value(2));
        rec.record(Event::span(EventKind::Arena, 1, 0, 0, 1e-6, 1e-6).with_value(4096));
        rec.finish()
    }

    #[test]
    fn export_roundtrips_through_parser() {
        let doc = chrome_trace(&sample_trace(), &ChannelTags::plain());
        let text = doc.to_string();
        let back = json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        assert_eq!(
            back.get("otherData").unwrap().get("schema_version").unwrap().as_usize(),
            Some(SCHEMA_VERSION as usize)
        );
        // span and counter phases both present
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("C")));
        // pid/tid grouping: the wire span sits on rank 0 / channel 0
        let wire = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("wire"))
            .unwrap();
        assert_eq!(wire.get("pid").unwrap().as_usize(), Some(0));
        assert_eq!(wire.get("args").unwrap().get("peer").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn arena_samples_export_as_counter_track() {
        let doc = chrome_trace(&sample_trace(), &ChannelTags::plain());
        let text = doc.to_pretty();
        let back = json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        let arena = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("arena bytes"))
            .expect("arena counter track missing");
        assert_eq!(arena.get("ph").unwrap().as_str(), Some("C"));
        assert_eq!(
            arena.get("args").unwrap().get("live").unwrap().as_usize(),
            Some(4096)
        );
    }

    #[test]
    fn import_inverts_export() {
        let trace = sample_trace();
        let doc = chrome_trace(&trace, &ChannelTags::plain());
        let back = import_chrome_trace(&json::parse(&doc.to_pretty()).unwrap()).unwrap();
        assert_eq!(back.events.len(), trace.events.len());
        for (a, b) in back.events.iter().zip(trace.events.iter()) {
            assert_eq!(a.kind, b.kind);
            assert_eq!((a.rank, a.channel, a.step), (b.rank, b.channel, b.step));
            assert_eq!(a.peer, b.peer);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(a.value, b.value);
            assert!((a.t_start - b.t_start).abs() < 1e-12);
            assert!((a.t_end - b.t_end).abs() < 1e-12);
        }
        // counters rebuilt from the imported events
        let (ct, co) = (back.totals(), trace.totals());
        assert_eq!(ct.msgs_sent, co.msgs_sent);
        assert_eq!(ct.bytes_sent, co.bytes_sent);
        assert_eq!(ct.pool_peak, co.pool_peak);
        assert_eq!(ct.arena_hw_bytes, co.arena_hw_bytes);
    }

    #[test]
    fn import_tolerates_older_and_newer_documents() {
        // A v2-era document: no arena track, plus an unknown future kind
        // that must be skipped rather than rejected.
        let text = r#"{
            "traceEvents": [
                {"ph": "M", "name": "process_name", "pid": 0,
                 "args": {"name": "rank 0"}},
                {"ph": "X", "name": "send", "cat": "send", "pid": 0, "tid": 0,
                 "ts": 1.0, "dur": 2.0, "args": {"step": 3, "peer": 1, "bytes": 64}},
                {"ph": "X", "name": "quantum_flux", "pid": 0, "tid": 0,
                 "ts": 0.0, "dur": 1.0, "args": {}},
                {"ph": "C", "name": "pool live slots", "pid": 0, "tid": 0,
                 "ts": 2.0, "args": {"live": 5}}
            ],
            "otherData": {"schema_version": 2, "dropped_events": 7}
        }"#;
        let back = import_chrome_trace(&json::parse(text).unwrap()).unwrap();
        assert_eq!(back.events.len(), 2, "metadata and unknown kinds skipped");
        assert_eq!(back.dropped, 7);
        let send = &back.events[0];
        assert_eq!(send.kind, EventKind::SendOp);
        assert_eq!(send.step, 3);
        assert_eq!(send.peer, Some(1));
        assert_eq!(send.bytes, 64);
        assert!((send.t_start - 1e-6).abs() < 1e-15);
        assert_eq!(back.totals().pool_peak, 5);
    }

    #[test]
    fn composed_tags_classify_phase() {
        let layout = Layout { nranks: 4, segments: 2, rs_steps: 2, ag_steps: 2 };
        let tags = ChannelTags::composed(layout);
        assert_eq!(tags.tag(1), Some("seg1"));
        // segment 0 (chunks 0..4): step 0 is rs, step 2 is ag
        let rs = Event::span(EventKind::Wire, 0, 0, 0, 0.0, 1.0).with_msg(&[1], 4);
        let ag = Event::span(EventKind::Wire, 0, 0, 2, 0.0, 1.0).with_msg(&[1], 4);
        assert_eq!(tags.cat(&rs), "wire,seg0,reduce-scatter");
        assert_eq!(tags.cat(&ag), "wire,seg0,all-gather");
    }
}
