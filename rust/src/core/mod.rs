//! Core types shared by every layer: ranks, chunks, collectives, algorithms,
//! element types and error handling.

pub mod error;
pub mod placement;

pub use error::{Error, Result};
pub use placement::Placement;

use std::fmt;

/// A rank id within a communicator, `0..nranks`.
pub type Rank = usize;

/// A chunk id. For all-gather, chunk `c` is the contribution of rank `c`
/// (and ends up in slot `c` of every receive buffer). For reduce-scatter,
/// chunk `c` is the slice of every rank's send buffer that reduces to rank
/// `c`'s output.
pub type ChunkId = usize;

/// The two collectives PAT implements (the paper's scope), plus the
/// workload NCCL composes them into: all-reduce as reduce-scatter followed
/// by all-gather (see [`crate::sched::compose`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    /// Every rank contributes one chunk; every rank ends with all `n` chunks.
    AllGather,
    /// Every rank contributes `n` chunks; rank `r` ends with the element-wise
    /// sum over ranks of chunk `r`.
    ReduceScatter,
    /// Every rank contributes all chunks; every rank ends with the full
    /// element-wise sum of every chunk. Programs for this collective are
    /// RS∘AG compositions: reducing receives until a chunk's owner holds
    /// the complete sum, plain receives while it is rebroadcast.
    AllReduce,
}

impl Collective {
    pub fn as_str(&self) -> &'static str {
        match self {
            Collective::AllGather => "all_gather",
            Collective::ReduceScatter => "reduce_scatter",
            Collective::AllReduce => "all_reduce",
        }
    }
}

impl fmt::Display for Collective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Algorithm selection for a collective operation.
///
/// `Ring` is NCCL's historical AG/RS algorithm (linear step count, full
/// bandwidth). `BruckNearFirst`/`BruckFarFirst` and `RecursiveDoubling` (AG) /
/// `RecursiveHalving` (RS) are the classic logarithmic baselines discussed in
/// the paper. `Pat` is the paper's contribution.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    Ring,
    /// Classic Bruck dimension order: nearest dimension first (paper Fig. 1).
    BruckNearFirst,
    /// Dimension-reversed Bruck: farthest dimension first (paper Fig. 3).
    BruckFarFirst,
    /// Recursive doubling (AG) / halving (RS); power-of-two ranks only.
    Recursive,
    /// Parallel Aggregated Trees with at most `aggregation` parallel trees
    /// (chunks aggregated per transfer). `aggregation` is clamped to a power
    /// of two in `[1, 2^(ceil(log2 n) - 1)]`.
    Pat { aggregation: usize },
    /// PAT with aggregation chosen from the intermediate-buffer budget and
    /// the operation size (what the tuner does in NCCL).
    PatAuto,
    /// Two-level hierarchical PAT over a rank [`Placement`]: an intra-node
    /// gather (near-first tree among co-located ranks), an inter-node PAT
    /// among per-node leaders with `aggregation` bounding how many *node*
    /// chunk sets one transfer carries, and an intra-node fan-out. The
    /// placement comes from the communicator/CLI configuration (see
    /// [`crate::sched::generate_placed`]); without one, contiguous nodes of
    /// 8 ranks are assumed.
    HierPat { aggregation: usize },
    /// All-reduce composition: a reduce-scatter phase run with `rs`, an
    /// all-gather phase run with `ag`, fused into one program with the
    /// payload split into `segments` pipeline segments — segment `i`'s
    /// all-gather overlaps segment `i+1`'s reduce-scatter (see
    /// [`crate::sched::compose`]). Spelled `rs+ag[:segments]`, e.g.
    /// `pat+ring:4`. Mixed pairs are allowed; only valid for
    /// [`Collective::AllReduce`].
    Compose { rs: PhaseAlg, ag: PhaseAlg, segments: usize },
}

/// A non-composed algorithm usable as one phase of [`Algorithm::Compose`].
///
/// Mirrors the flat/hierarchical variants of [`Algorithm`] (everything but
/// `PatAuto`, which the tuner must resolve first, and `Compose` itself).
/// Kept as a separate `Copy` enum so `Algorithm` stays `Copy` despite the
/// nesting.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PhaseAlg {
    Ring,
    BruckNearFirst,
    BruckFarFirst,
    Recursive,
    Pat { aggregation: usize },
    HierPat { aggregation: usize },
}

impl PhaseAlg {
    /// The equivalent stand-alone [`Algorithm`].
    pub fn to_algorithm(self) -> Algorithm {
        match self {
            PhaseAlg::Ring => Algorithm::Ring,
            PhaseAlg::BruckNearFirst => Algorithm::BruckNearFirst,
            PhaseAlg::BruckFarFirst => Algorithm::BruckFarFirst,
            PhaseAlg::Recursive => Algorithm::Recursive,
            PhaseAlg::Pat { aggregation } => Algorithm::Pat { aggregation },
            PhaseAlg::HierPat { aggregation } => Algorithm::HierPat { aggregation },
        }
    }

    /// Convert a stand-alone algorithm into a compose phase. `PatAuto` and
    /// nested `Compose` are rejected.
    pub fn from_algorithm(alg: Algorithm) -> Result<PhaseAlg> {
        match alg {
            Algorithm::Ring => Ok(PhaseAlg::Ring),
            Algorithm::BruckNearFirst => Ok(PhaseAlg::BruckNearFirst),
            Algorithm::BruckFarFirst => Ok(PhaseAlg::BruckFarFirst),
            Algorithm::Recursive => Ok(PhaseAlg::Recursive),
            Algorithm::Pat { aggregation } => Ok(PhaseAlg::Pat { aggregation }),
            Algorithm::HierPat { aggregation } => Ok(PhaseAlg::HierPat { aggregation }),
            Algorithm::PatAuto | Algorithm::Compose { .. } => Err(Error::Config(format!(
                "{alg} cannot be used as a compose phase"
            ))),
        }
    }

    /// Parse a phase spelling (same grammar as the flat algorithms).
    pub fn parse(s: &str) -> Result<PhaseAlg> {
        PhaseAlg::from_algorithm(Algorithm::parse(s)?)
    }

    /// Canonical config spelling (round-trips through [`PhaseAlg::parse`]).
    pub fn spec(&self) -> String {
        self.to_algorithm().spec()
    }

    /// Human-readable label (matches [`Algorithm::name`]).
    pub fn name(&self) -> String {
        self.to_algorithm().name()
    }

    pub fn supports(&self, nranks: usize) -> bool {
        self.to_algorithm().supports(nranks)
    }
}

impl Algorithm {
    /// Human-readable label (used in program names, tables, reports).
    /// For the canonical *parseable* spelling use [`Algorithm::spec`].
    pub fn name(&self) -> String {
        match self {
            Algorithm::Ring => "ring".into(),
            Algorithm::BruckNearFirst => "bruck_near".into(),
            Algorithm::BruckFarFirst => "bruck_far".into(),
            Algorithm::Recursive => "recursive".into(),
            Algorithm::Pat { aggregation } if *aggregation >= usize::MAX / 2 => {
                "pat(full)".into()
            }
            Algorithm::Pat { aggregation } => format!("pat(a={aggregation})"),
            Algorithm::PatAuto => "pat_auto".into(),
            Algorithm::HierPat { aggregation } if *aggregation >= usize::MAX / 2 => {
                "hier_pat(full)".into()
            }
            Algorithm::HierPat { aggregation } => format!("hier_pat(a={aggregation})"),
            Algorithm::Compose { rs, ag, segments } => {
                format!("{}+{}:{segments}", rs.name(), ag.name())
            }
        }
    }

    /// Canonical config/CLI spelling — guaranteed to round-trip through
    /// [`Algorithm::parse`] (`parse(a.spec()) == a`; aggregation factors at
    /// or above `usize::MAX / 2` normalize to the bare "full" spelling).
    /// `Display` uses this, so error messages and CLI output can be pasted
    /// back into `--alg` / config files verbatim.
    pub fn spec(&self) -> String {
        match self {
            Algorithm::Ring => "ring".into(),
            Algorithm::BruckNearFirst => "bruck_near".into(),
            Algorithm::BruckFarFirst => "bruck_far".into(),
            Algorithm::Recursive => "recursive".into(),
            Algorithm::Pat { aggregation } if *aggregation >= usize::MAX / 2 => "pat".into(),
            Algorithm::Pat { aggregation } => format!("pat:{aggregation}"),
            Algorithm::PatAuto => "pat_auto".into(),
            Algorithm::HierPat { aggregation } if *aggregation >= usize::MAX / 2 => {
                "hier_pat".into()
            }
            Algorithm::HierPat { aggregation } => format!("hier_pat:{aggregation}"),
            Algorithm::Compose { rs, ag, segments } => {
                format!("{}+{}:{segments}", rs.spec(), ag.spec())
            }
        }
    }

    /// Parse a CLI/config spelling: `ring`, `bruck_near`, `bruck_far`,
    /// `recursive`, `pat`, `pat:<agg>`, `pat_auto`, `hier_pat`,
    /// `hier_pat:<agg>`, or the all-reduce composition `rs+ag[:<segments>]`
    /// (e.g. `pat+ring:4`).
    ///
    /// ## Composition grammar
    ///
    /// The text left of `+` is the reduce-scatter phase, the text right of
    /// it the all-gather phase, and a trailing `:<int>` that leaves a valid
    /// phase spelling behind is the segment count (default 1). A trailing
    /// integer therefore always binds to *segments*: `pat+pat:4` is four
    /// segments of fully-aggregated PAT; to pin the all-gather aggregation
    /// instead, spell the segments explicitly (`pat+pat:4:1`).
    pub fn parse(s: &str) -> Result<Algorithm> {
        let s = s.trim();
        if let Some((left, right)) = s.split_once('+') {
            let rs = PhaseAlg::parse(left)?;
            let (ag_spec, segments) = match right.rsplit_once(':') {
                Some((pre, suf)) => match suf.trim().parse::<usize>() {
                    Ok(k) if PhaseAlg::parse(pre).is_ok() => (pre, k),
                    _ => (right, 1),
                },
                None => (right, 1),
            };
            if segments == 0 {
                return Err(Error::Config("compose segments must be >= 1".into()));
            }
            let ag = PhaseAlg::parse(ag_spec)?;
            return Ok(Algorithm::Compose { rs, ag, segments });
        }
        if let Some(rest) = s.strip_prefix("pat:") {
            let a: usize = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad pat aggregation: {rest:?}")))?;
            if a == 0 {
                return Err(Error::Config("pat aggregation must be >= 1".into()));
            }
            return Ok(Algorithm::Pat { aggregation: a });
        }
        if let Some(rest) = s.strip_prefix("hier_pat:") {
            let a: usize = rest
                .parse()
                .map_err(|_| Error::Config(format!("bad hier_pat aggregation: {rest:?}")))?;
            if a == 0 {
                return Err(Error::Config("hier_pat aggregation must be >= 1".into()));
            }
            return Ok(Algorithm::HierPat { aggregation: a });
        }
        match s {
            "ring" => Ok(Algorithm::Ring),
            "bruck_near" | "bruck" => Ok(Algorithm::BruckNearFirst),
            "bruck_far" => Ok(Algorithm::BruckFarFirst),
            "recursive" | "rd" | "rh" => Ok(Algorithm::Recursive),
            "pat" => Ok(Algorithm::Pat { aggregation: usize::MAX }),
            "pat_auto" => Ok(Algorithm::PatAuto),
            "hier_pat" | "hier" => Ok(Algorithm::HierPat { aggregation: usize::MAX }),
            other => Err(Error::Config(format!("unknown algorithm {other:?}"))),
        }
    }

    /// Does this algorithm support `nranks`?
    pub fn supports(&self, nranks: usize) -> bool {
        match self {
            Algorithm::Recursive => nranks.is_power_of_two(),
            Algorithm::Compose { rs, ag, .. } => rs.supports(nranks) && ag.supports(nranks),
            _ => nranks >= 1,
        }
    }

    /// Does generating this algorithm consume a rank [`Placement`]? True
    /// for [`Algorithm::HierPat`] and for compositions with a hierarchical
    /// phase; callers route these through
    /// [`crate::sched::generate_placed`].
    pub fn uses_placement(&self) -> bool {
        match self {
            Algorithm::HierPat { .. } => true,
            Algorithm::Compose { rs, ag, .. } => {
                matches!(rs, PhaseAlg::HierPat { .. }) || matches!(ag, PhaseAlg::HierPat { .. })
            }
            _ => false,
        }
    }
}

impl fmt::Display for Algorithm {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec())
    }
}

/// A full schedule spec: an algorithm plus the number of NCCL-style
/// channels its program is split across. This is what the CLI `--alg` /
/// config `algorithm` keys actually speak, and the one place the whole
/// grammar is documented:
///
/// ```text
/// spec     := alg [ "*" channels ]
/// alg      := phase                      (a primitive collective)
///           | phase "+" phase [ ":" segments ]   (all-reduce: RS phase + AG phase)
/// phase    := "ring" | "bruck_near" | "bruck_far" | "recursive"
///           | "pat" [ ":" agg ] | "pat_auto"
///           | "hier_pat" [ ":" agg ]
/// segments := integer >= 1   (compose pipeline segments, default 1)
/// channels := integer >= 1   (chunk-striped channel split, default 1)
/// ```
///
/// Reading `pat+ring:2*4`: a fused all-reduce whose reduce-scatter phase
/// is fully-aggregated PAT and whose all-gather phase is Ring, split into
/// 2 pipeline segments, each striped over 4 channels (8 channels total).
/// A trailing `:<int>` after a composition binds to *segments*, so
/// `pat+pat:4` is four segments of fully-aggregated PAT; pin the
/// all-gather aggregation by spelling segments explicitly
/// (`pat+pat:4:1`). One channel prints bare; an explicit `*1` still
/// *pins* single-channel against the tuner (see
/// [`AlgSpec::parse_pinned`]).
///
/// Parsing and display round-trip exactly — `parse(spec.to_string()) ==
/// spec` for every value, so any spelling the tool prints can be pasted
/// back into `--alg` or a config file:
///
/// ```
/// use patcol::core::AlgSpec;
///
/// for s in ["ring", "pat:2", "pat_auto", "hier_pat:4", "pat*4",
///           "pat+ring:2*4", "hier_pat:2+ring:1", "pat+pat:4:1"] {
///     let spec = AlgSpec::parse(s).unwrap();
///     assert_eq!(spec.to_string(), s, "canonical spellings round-trip");
///     assert_eq!(AlgSpec::parse(&spec.to_string()).unwrap(), spec);
/// }
///
/// // one channel prints bare; `*1` parses back to the bare spelling
/// let pinned = AlgSpec::parse("pat*1").unwrap();
/// assert_eq!(pinned.channels, 1);
/// assert_eq!(pinned.to_string(), "pat");
///
/// // the composed example from the grammar above
/// let spec = AlgSpec::parse("pat+ring:2*4").unwrap();
/// assert_eq!(spec.channels, 4);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AlgSpec {
    pub alg: Algorithm,
    pub channels: usize,
}

impl AlgSpec {
    /// The single-channel spec of `alg`.
    pub fn single(alg: Algorithm) -> AlgSpec {
        AlgSpec { alg, channels: 1 }
    }

    /// Parse `<alg>[*<channels>]`. Everything after the last `*` must be
    /// the channel count; the rest is the [`Algorithm`] grammar.
    pub fn parse(s: &str) -> Result<AlgSpec> {
        let s = s.trim();
        match s.rsplit_once('*') {
            Some((alg, chans)) => {
                let channels: usize = chans.trim().parse().map_err(|_| {
                    Error::Config(format!("bad channel count {:?} in {s:?}", chans.trim()))
                })?;
                if channels == 0 {
                    return Err(Error::Config("channels must be >= 1".into()));
                }
                Ok(AlgSpec { alg: Algorithm::parse(alg)?, channels })
            }
            None => Ok(AlgSpec::single(Algorithm::parse(s)?)),
        }
    }

    /// Parse a spelling, reporting whether the channel count was explicit:
    /// `None` when there was no `*` suffix (callers let the tuner decide),
    /// `Some(c)` — including `Some(1)` — when there was (the count is
    /// pinned; `pat*1` must keep the tuner from going multi-channel). This
    /// is the single place that knows the suffix grammar; the config and
    /// CLI front-ends both go through it.
    pub fn parse_pinned(s: &str) -> Result<(Algorithm, Option<usize>)> {
        let spec = AlgSpec::parse(s)?;
        Ok((spec.alg, s.contains('*').then_some(spec.channels)))
    }

    /// Canonical spelling — round-trips through [`AlgSpec::parse`]
    /// (`parse(a.spec()) == a`; one channel prints bare).
    pub fn spec(&self) -> String {
        if self.channels == 1 {
            self.alg.spec()
        } else {
            format!("{}*{}", self.alg.spec(), self.channels)
        }
    }
}

impl fmt::Display for AlgSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.spec())
    }
}

/// Element types supported on the datapath. The wire format is always raw
/// little-endian bytes; reduction kernels exist for each.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
}

impl DType {
    pub fn size_bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::F64 => "f64",
            DType::I32 => "i32",
        }
    }
}

/// Ceiling log2 for schedule dimensioning. `ceil_log2(1) == 0`.
pub fn ceil_log2(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - (n - 1).leading_zeros()
}

/// Floor log2. `floor_log2(1) == 0`.
pub fn floor_log2(n: usize) -> u32 {
    debug_assert!(n >= 1);
    usize::BITS - 1 - n.leading_zeros()
}

/// Ideal (perfectly packed) step count of the PAT schedule for `nranks`
/// with aggregation `a`: `Σ_d ceil(|O_d| / a)` where `|O_d|` counts offsets
/// `o ≡ 0 (mod 2^{d+1})` with `o + 2^d < nranks`.
///
/// The implemented schedule achieves this exactly for power-of-two rank
/// counts (and for `a = 1` / full aggregation on any count); for awkward
/// counts the lockstep depth-first linear phase may leave partially-empty
/// rounds and use up to `n-1` steps (see `sched::pat`).
pub fn pat_step_count(nranks: usize, a: usize) -> usize {
    debug_assert!(a >= 1);
    if nranks <= 1 {
        return 0;
    }
    let dmax = floor_log2(nranks - 1); // highest dim with any transfer
    let mut steps = 0usize;
    for d in 0..=dmax {
        let stride = 1usize << (d + 1);
        let span = nranks - (1usize << d); // o in [0, span), o % stride == 0
        let od = (span + stride - 1) / stride;
        steps += (od + a - 1) / a;
    }
    steps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log2_helpers() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(floor_log2(1), 0);
        assert_eq!(floor_log2(8), 3);
        assert_eq!(floor_log2(9), 3);
    }

    #[test]
    fn step_counts_match_paper_figures() {
        // N=8: full Bruck 3 steps; agg 2 -> 4 (Figs 5-6); agg 1 -> 7 (Fig 10).
        assert_eq!(pat_step_count(8, 4), 3);
        assert_eq!(pat_step_count(8, 2), 4);
        assert_eq!(pat_step_count(8, 1), 7);
        // N=16: 8 trees -> 4 (Fig 7); 4 trees -> 5 (Fig 8); 2 trees -> 8 (Fig 9).
        assert_eq!(pat_step_count(16, 8), 4);
        assert_eq!(pat_step_count(16, 4), 5);
        assert_eq!(pat_step_count(16, 2), 8);
        assert_eq!(pat_step_count(16, 1), 15);
    }

    #[test]
    fn step_count_fully_linear_is_nminus1() {
        for n in 2..70 {
            assert_eq!(pat_step_count(n, 1), n - 1, "n={n}");
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        assert_eq!(Algorithm::parse("ring").unwrap(), Algorithm::Ring);
        assert_eq!(Algorithm::parse("pat:4").unwrap(), Algorithm::Pat { aggregation: 4 });
        assert_eq!(Algorithm::parse("bruck_far").unwrap(), Algorithm::BruckFarFirst);
        assert_eq!(
            Algorithm::parse("hier_pat:2").unwrap(),
            Algorithm::HierPat { aggregation: 2 }
        );
        assert_eq!(
            Algorithm::parse("hier_pat").unwrap(),
            Algorithm::HierPat { aggregation: usize::MAX }
        );
        assert_eq!(Algorithm::parse("hier_pat").unwrap().name(), "hier_pat(full)");
        assert_eq!(
            Algorithm::HierPat { aggregation: 2 }.name(),
            "hier_pat(a=2)"
        );
        assert!(Algorithm::parse("nope").is_err());
        assert!(Algorithm::parse("pat:0").is_err());
        assert!(Algorithm::parse("hier_pat:0").is_err());
    }

    #[test]
    fn recursive_requires_pow2() {
        assert!(Algorithm::Recursive.supports(8));
        assert!(!Algorithm::Recursive.supports(7));
        assert!(Algorithm::Pat { aggregation: 1 }.supports(7));
        // compose inherits both phases' constraints
        let c = Algorithm::Compose {
            rs: PhaseAlg::Recursive,
            ag: PhaseAlg::Ring,
            segments: 2,
        };
        assert!(c.supports(8));
        assert!(!c.supports(7));
    }

    #[test]
    fn compose_grammar() {
        assert_eq!(
            Algorithm::parse("pat+ring:4").unwrap(),
            Algorithm::Compose {
                rs: PhaseAlg::Pat { aggregation: usize::MAX },
                ag: PhaseAlg::Ring,
                segments: 4
            }
        );
        // a trailing integer binds to segments, not the AG aggregation...
        assert_eq!(
            Algorithm::parse("pat+pat:4").unwrap(),
            Algorithm::Compose {
                rs: PhaseAlg::Pat { aggregation: usize::MAX },
                ag: PhaseAlg::Pat { aggregation: usize::MAX },
                segments: 4
            }
        );
        // ...so the AG aggregation is pinned by spelling segments explicitly
        assert_eq!(
            Algorithm::parse("pat+pat:4:1").unwrap(),
            Algorithm::Compose {
                rs: PhaseAlg::Pat { aggregation: usize::MAX },
                ag: PhaseAlg::Pat { aggregation: 4 },
                segments: 1
            }
        );
        // default segment count is 1
        assert_eq!(
            Algorithm::parse("hier_pat:2+ring").unwrap(),
            Algorithm::Compose {
                rs: PhaseAlg::HierPat { aggregation: 2 },
                ag: PhaseAlg::Ring,
                segments: 1
            }
        );
        assert!(Algorithm::parse("pat+ring:0").is_err());
        assert!(Algorithm::parse("pat_auto+ring").is_err());
        assert!(Algorithm::parse("pat+nope").is_err());
        assert!(Algorithm::parse("+ring").is_err());
    }

    /// The satellite round-trip guarantee: `parse(display(a)) == a` for
    /// every variant, including the nested `rs+ag[:segments]` grammar.
    /// (This is what flushed out `Display` printing the human label
    /// `pat(a=2)` instead of the parseable spelling `pat:2` — `Display`
    /// now delegates to [`Algorithm::spec`].)
    #[test]
    fn display_parse_roundtrip_fuzz() {
        // aggregation factors at/above usize::MAX/2 normalize to the bare
        // "full" spelling, which parses back to usize::MAX — so the fuzz
        // universe uses small factors plus the canonical MAX.
        let aggs = [1usize, 2, 3, 4, 7, 8, 64, usize::MAX];
        let mut flat = vec![
            Algorithm::Ring,
            Algorithm::BruckNearFirst,
            Algorithm::BruckFarFirst,
            Algorithm::Recursive,
            Algorithm::PatAuto,
        ];
        for &a in &aggs {
            flat.push(Algorithm::Pat { aggregation: a });
            flat.push(Algorithm::HierPat { aggregation: a });
        }
        let mut all = flat.clone();
        let phases: Vec<PhaseAlg> = flat
            .iter()
            .filter_map(|&a| PhaseAlg::from_algorithm(a).ok())
            .collect();
        for &rs in &phases {
            for &ag in &phases {
                for segments in [1usize, 2, 3, 4, 8, 17] {
                    all.push(Algorithm::Compose { rs, ag, segments });
                }
            }
        }
        for a in all {
            let shown = format!("{a}");
            assert_eq!(shown, a.spec(), "{a:?}");
            let back = Algorithm::parse(&shown)
                .unwrap_or_else(|e| panic!("{a:?} displayed as {shown:?}: {e}"));
            assert_eq!(back, a, "round-trip through {shown:?}");
        }
    }

    /// The channels extension of the grammar: `parse(display(a)) == a`
    /// over every algorithm × channel count, including composed specs like
    /// `pat+ring:2*4`. One channel displays bare and parses back to 1.
    #[test]
    fn algspec_display_parse_roundtrip_fuzz() {
        let mut algs = vec![
            Algorithm::Ring,
            Algorithm::BruckNearFirst,
            Algorithm::BruckFarFirst,
            Algorithm::Recursive,
            Algorithm::PatAuto,
            Algorithm::Pat { aggregation: 2 },
            Algorithm::Pat { aggregation: usize::MAX },
            Algorithm::HierPat { aggregation: 4 },
        ];
        let phases = [
            PhaseAlg::Pat { aggregation: usize::MAX },
            PhaseAlg::Pat { aggregation: 2 },
            PhaseAlg::Ring,
            PhaseAlg::HierPat { aggregation: 2 },
        ];
        for &rs in &phases {
            for &ag in &phases {
                for segments in [1usize, 2, 4, 17] {
                    algs.push(Algorithm::Compose { rs, ag, segments });
                }
            }
        }
        for alg in algs {
            for channels in [1usize, 2, 3, 4, 8, 64] {
                let spec = AlgSpec { alg, channels };
                let shown = format!("{spec}");
                assert_eq!(shown, spec.spec(), "{spec:?}");
                let back = AlgSpec::parse(&shown)
                    .unwrap_or_else(|e| panic!("{spec:?} displayed as {shown:?}: {e}"));
                assert_eq!(back, spec, "round-trip through {shown:?}");
            }
        }
        // headline spellings from the issue
        assert_eq!(
            AlgSpec::parse("pat*4").unwrap(),
            AlgSpec { alg: Algorithm::Pat { aggregation: usize::MAX }, channels: 4 }
        );
        assert_eq!(
            AlgSpec::parse("pat+ring:2*4").unwrap(),
            AlgSpec {
                alg: Algorithm::Compose {
                    rs: PhaseAlg::Pat { aggregation: usize::MAX },
                    ag: PhaseAlg::Ring,
                    segments: 2,
                },
                channels: 4,
            }
        );
        // bare algorithms parse as one channel
        assert_eq!(AlgSpec::parse("ring").unwrap(), AlgSpec::single(Algorithm::Ring));
        // pin reporting: a `*` suffix pins (even `*1`); bare spellings don't
        assert_eq!(AlgSpec::parse_pinned("pat*1").unwrap().1, Some(1));
        assert_eq!(AlgSpec::parse_pinned("pat*4").unwrap().1, Some(4));
        assert_eq!(AlgSpec::parse_pinned("pat").unwrap().1, None);
        // rejects
        assert!(AlgSpec::parse("pat*0").is_err());
        assert!(AlgSpec::parse("pat*x").is_err());
        assert!(AlgSpec::parse("*4").is_err());
    }
}
