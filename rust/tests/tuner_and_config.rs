//! Tuner sanity (DESIGN invariant 6), the hierarchical-prediction
//! calibration, and config/CLI plumbing.

use patcol::coordinator::config::{parse_bytes, ConfigMap};
use patcol::coordinator::tuner::{CHANNEL_CALIBRATION_TOLERANCE, HIER_CALIBRATION_TOLERANCE};
use patcol::coordinator::{CommConfig, Communicator, Tuner};
use patcol::core::{Algorithm, Collective, Placement};
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};

/// Invariant 6: on a grid of (ranks, sizes), the tuner's pick simulates
/// within 25% of the best fixed candidate on the ideal fabric. (The tuner
/// uses a closed-form model, the reference is the event simulator, so we
/// allow model error but no gross misprediction.)
#[test]
fn tuner_never_grossly_wrong() {
    let tuner = Tuner::default();
    let cost = CostModel::ib_hdr();
    for &n in &[8usize, 32, 128] {
        let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
        for &size in &[256usize, 16 << 10, 1 << 20] {
            let sim_t = |alg: Algorithm| {
                let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
                simulate(&prog, &topo, &cost, size).unwrap().total_time
            };
            let candidates = [
                Algorithm::Ring,
                Algorithm::Pat { aggregation: usize::MAX },
                Algorithm::Pat { aggregation: 8 },
                Algorithm::Pat { aggregation: 1 },
            ];
            let best = candidates
                .iter()
                .map(|&a| sim_t(a))
                .fold(f64::INFINITY, f64::min);
            let picked = tuner.choose(n, size, 1 << 30, Collective::AllGather).algorithm;
            let picked_t = sim_t(picked);
            assert!(
                picked_t <= best * 1.25,
                "n={n} size={size}: picked {picked} at {picked_t}, best {best}"
            );
        }
    }
}

/// Tuner calibration (ROADMAP follow-up): `predict_hier` tracks the event
/// simulator on a tapered three-level fabric within the documented
/// constant [`HIER_CALIBRATION_TOLERANCE`] (both directions), across
/// aggregations and the latency→bandwidth size band. The fabric: 64 ranks
/// as 8-rank nodes = 8-rank leaves, 2 pods × 4 leaves, core tier tapered
/// ×0.25; the tuner's `inter_bw` is set to the core-tapered uplink the
/// closed form folds all contention into.
#[test]
fn predict_hier_tracks_simulator_on_tapered_fabric() {
    let n = 64usize;
    let k = 8usize;
    let nic = CostModel::ib_hdr_nic_bw();
    let topo = Topology::three_level(n, k, 4, 4, 2, nic, 1.0, 0.25).unwrap();
    let pl = Placement::uniform(n, k).unwrap();
    topo.check_placement(&pl).unwrap();
    let cost = CostModel::ib_hdr();
    let tuner = Tuner { inter_bw: Some(nic * 0.25), ..Tuner::default() };
    for &a in &[2usize, usize::MAX] {
        for &chunk in &[4usize << 10, 64 << 10, 256 << 10] {
            let prog = sched::generate_placed(
                Algorithm::HierPat { aggregation: a },
                Collective::AllGather,
                &pl,
            )
            .unwrap();
            let sim_t = simulate(&prog, &topo, &cost, chunk).unwrap().total_time;
            let pred = tuner.predict_hier(&pl, a, chunk);
            let ratio = pred / sim_t;
            assert!(
                (1.0 / HIER_CALIBRATION_TOLERANCE..=HIER_CALIBRATION_TOLERANCE)
                    .contains(&ratio),
                "a={a} chunk={chunk}: predicted {pred:.6}s vs simulated {sim_t:.6}s \
                 (ratio {ratio:.2} outside ×/÷{HIER_CALIBRATION_TOLERANCE})"
            );
        }
    }
}

/// Tuner calibration (the open ROADMAP item): `predict_channels` tracks
/// the event simulator on a multi-rail leaf-spine fabric within the
/// documented constant [`CHANNEL_CALIBRATION_TOLERANCE`] (both
/// directions), across the latency→bandwidth band and channel counts.
/// The fabric: 64 ranks on 8-rank leaves with 4 untapered spines; the
/// tuner's `parallel_links` is set to the spine count — the rails the
/// closed form lets extra channels recruit. The residual gaps the
/// constant absorbs (serial channel tax at small sizes, un-modeled ECMP
/// collision variance at large) are documented on the constant itself.
#[test]
fn predict_channels_tracks_simulator_on_multirail_fabric() {
    let n = 64usize;
    let spines = 4usize;
    let nic = CostModel::ib_hdr_nic_bw();
    let topo = Topology::leaf_spine(n, 8, spines, nic, 1.0).unwrap();
    let cost = CostModel::ib_hdr();
    let tuner = Tuner { parallel_links: spines, ..Tuner::default() };
    let a = usize::MAX; // fully-aggregated PAT, the multi-channel workhorse
    let base = sched::generate(Algorithm::Pat { aggregation: a }, Collective::AllGather, n)
        .unwrap();
    for &chunk in &[4usize << 10, 64 << 10, 1 << 20] {
        for &c in &[1usize, 2, 4] {
            let split = sched::channel::split(&base, c).unwrap();
            let sim_t = simulate(&split, &topo, &cost, chunk / c).unwrap().total_time;
            let pred = tuner.predict_channels(n, a, chunk, c);
            let ratio = pred / sim_t;
            assert!(
                (1.0 / CHANNEL_CALIBRATION_TOLERANCE..=CHANNEL_CALIBRATION_TOLERANCE)
                    .contains(&ratio),
                "chunk={chunk} channels={c}: predicted {pred:.6}s vs simulated \
                 {sim_t:.6}s (ratio {ratio:.2} outside ×/÷{CHANNEL_CALIBRATION_TOLERANCE})"
            );
        }
    }
}

/// The tuner respects the buffer budget end-to-end through the
/// communicator: with 2 slots, the resolved PAT aggregation is 1 for RS on
/// 32 ranks (law: a·log2(n/a) ≤ slots).
#[test]
fn buffer_budget_respected_via_communicator() {
    let comm = Communicator::new(CommConfig {
        nranks: 32,
        buffer_slots: Some(2),
        ..Default::default()
    })
    .unwrap();
    match comm.resolve(Collective::ReduceScatter, 64) {
        Algorithm::Pat { aggregation } => assert_eq!(aggregation, 1),
        Algorithm::Ring => {} // also buffer-safe
        other => panic!("unexpected {other}"),
    }
}

#[test]
fn config_file_to_communicator() {
    let cfg = ConfigMap::parse(
        "nranks = 6\nalgorithm = pat:2\nbuffer_slots = 16\ndatapath = scalar\n",
    )
    .unwrap();
    let cc = cfg.to_comm_config().unwrap();
    let comm = Communicator::new(cc).unwrap();
    assert_eq!(comm.nranks(), 6);
    let inputs: Vec<Vec<f32>> = (0..6).map(|r| vec![r as f32; 10]).collect();
    let (_, rep) = comm.all_gather_report(&inputs).unwrap();
    assert_eq!(rep.algorithm, Algorithm::Pat { aggregation: 2 });
}

#[test]
fn size_strings() {
    assert_eq!(parse_bytes("512").unwrap(), 512);
    assert_eq!(parse_bytes("8MiB").unwrap(), 8 << 20);
}

/// CLI binary smoke: selftest + explain + tune + sweep run clean.
#[test]
fn cli_binary_smoke() {
    let bin = env!("CARGO_BIN_EXE_patcol");
    for argv in [
        vec!["selftest", "--max-ranks", "9"],
        vec!["explain", "--ranks", "8", "--agg", "2"],
        vec!["tune", "--ranks", "64", "--size", "4KiB", "--buffer-slots", "16"],
        vec!["sweep", "--ranks", "16", "--sizes", "1KiB,64KiB"],
        vec![
            "simulate", "--ranks", "32", "--size", "64KiB", "--alg", "ring",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8",
        ],
        vec!["run", "--ranks", "4", "--size", "4KiB", "--alg", "pat:2",
             "--collective", "rs"],
        vec!["explain", "--ranks", "13", "--alg", "hier_pat:2",
             "--ranks-per-node", "4"],
        vec![
            "simulate", "--ranks", "32", "--size", "64KiB", "--alg", "hier_pat",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8",
            "--ranks-per-node", "8",
        ],
        vec!["run", "--ranks", "13", "--size", "4KiB", "--alg", "hier_pat:2",
             "--placement", "4,4,5", "--collective", "rs"],
        vec!["tune", "--ranks", "64", "--size", "1MiB", "--buffer-slots", "1024",
             "--ranks-per-node", "8", "--inter-gbps", "25"],
        vec!["run", "--ranks", "6", "--size", "4KiB", "--alg", "pat:2+ring:2"],
        vec!["explain", "--ranks", "8", "--alg", "pat+pat:2"],
        vec![
            "simulate", "--ranks", "32", "--size", "16KiB", "--alg", "pat+ring:4",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8", "--intra-gbps", "200",
            "--ranks-per-node", "8",
        ],
        vec!["tune", "--ranks", "64", "--size", "64KiB", "--buffer-slots", "256",
             "--collective", "ar"],
        vec!["run", "--ranks", "5", "--size", "2KiB", "--collective", "ar"],
        vec!["explain", "--ranks", "8", "--alg", "pat*4"],
        vec!["run", "--ranks", "4", "--size", "4KiB", "--alg", "pat:2",
             "--channels", "2", "--collective", "rs"],
        vec![
            "simulate", "--ranks", "32", "--size", "256KiB", "--alg", "pat*4",
            "--topo", "leaf_spine", "--ranks-per-leaf", "8", "--taper", "0.5",
        ],
        vec!["tune", "--ranks", "64", "--size", "4MiB", "--buffer-slots", "1024",
             "--parallel-links", "4"],
        vec!["run", "--ranks", "5", "--size", "8KiB", "--collective", "ar",
             "--buckets", "4"],
        vec!["run", "--ranks", "4", "--size", "16KiB", "--collective", "ar",
             "--alg", "pat:2", "--bucket-bytes", "4KiB"],
        vec!["tune", "--ranks", "64", "--size", "4MiB", "--buffer-slots", "256",
             "--collective", "ar"],
    ] {
        let out = std::process::Command::new(bin)
            .args(&argv)
            .output()
            .expect("spawn patcol");
        assert!(
            out.status.success(),
            "patcol {argv:?}: {}\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }
}
