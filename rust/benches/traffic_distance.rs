//! P2 / Figs. 1–3 — long-distance traffic and static-routing congestion.
//!
//! The paper's core argument against classic Bruck / recursive doubling:
//! "their last steps consist in every rank sending a lot of data to very
//! distant ranks, often crossing many levels of network switches … the
//! last step frequently runs many times slower than the theory due to
//! static routing, or due to higher levels of the fabric being tapered."
//!
//! This bench runs all algorithms on a 3-level fat-tree with a tapered top
//! tier and static ECMP, reporting (a) bytes crossing each fabric level,
//! (b) the bytes×links long-haul metric, and (c) simulated completion
//! time. PAT should move the least data across the top tier and win
//! end-to-end.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = 128usize;
    // 4 pods x 4 leaves x 8 ranks; top tier tapered to 1/4.
    let topo = Topology::three_level(n, 8, 4, 4, 2, CostModel::ib_hdr_nic_bw(), 1.0, 0.25)
        .unwrap();
    let cost = CostModel::ib_hdr();
    let chunk = if smoke { 4 << 10 } else { 256 << 10 }; // bandwidth-relevant size
    let algs: &[Algorithm] = if smoke {
        &[Algorithm::BruckNearFirst, Algorithm::Pat { aggregation: 4 }]
    } else {
        &[
            Algorithm::Ring,
            Algorithm::BruckNearFirst,
            Algorithm::Recursive,
            Algorithm::BruckFarFirst,
            Algorithm::Pat { aggregation: 4 },
            Algorithm::Pat { aggregation: 1 },
        ]
    };

    let mut report = Report::new("traffic_distance");
    report.param("nranks", Json::num(n as f64));
    report.param("topology", Json::str(topo.name.clone()));
    report.param("chunk_bytes", Json::num(chunk as f64));

    println!(
        "\nall-gather on {} ({} per rank), tapered top tier (x0.25), static ECMP:",
        topo.name,
        fmt_bytes(chunk)
    );
    let mut t = Table::new([
        "algorithm",
        "leaf-local",
        "pod level",
        "top level",
        "bytes*links",
        "time",
    ]);
    for alg in algs {
        let prog = sched::generate(*alg, Collective::AllGather, n).unwrap();
        let rep = simulate(&prog, &topo, &cost, chunk).unwrap();
        t.row([
            alg.name(),
            fmt_bytes(rep.bytes_by_level[0]),
            fmt_bytes(rep.bytes_by_level[1]),
            fmt_bytes(rep.bytes_by_level[2]),
            format!("{:.2e}", rep.bytes_links),
            fmt_time_s(rep.total_time),
        ]);
        report.rows.push(Json::obj(vec![
            ("algorithm", Json::str(alg.name())),
            ("bytes_leaf", Json::num(rep.bytes_by_level[0] as f64)),
            ("bytes_pod", Json::num(rep.bytes_by_level[1] as f64)),
            ("bytes_top", Json::num(rep.bytes_by_level[2] as f64)),
            ("bytes_links", Json::num(rep.bytes_links)),
            ("time", Json::num(rep.total_time)),
            ("max_link_bytes", Json::num(rep.max_link_bytes as f64)),
        ]));
    }
    print!("{}", t.render());

    // Headline assertion: classic Bruck pushes far more bytes over the top
    // tier than PAT, and loses end-to-end on the tapered fabric.
    let get = |alg: Algorithm| {
        let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
        simulate(&prog, &topo, &cost, chunk).unwrap()
    };
    let bruck = get(Algorithm::BruckNearFirst);
    let patr = get(Algorithm::Pat { aggregation: 4 });
    println!(
        "\ntop-tier bytes: bruck_near {} vs pat {} ({:.1}x less long-haul)",
        fmt_bytes(bruck.bytes_by_level[2]),
        fmt_bytes(patr.bytes_by_level[2]),
        bruck.bytes_by_level[2] as f64 / patr.bytes_by_level[2].max(1) as f64
    );
    println!(
        "completion: bruck_near {} vs pat {} ({:.1}x faster on the tapered fabric)",
        fmt_time_s(bruck.total_time),
        fmt_time_s(patr.total_time),
        bruck.total_time / patr.total_time
    );
    report.param(
        "bruck_over_pat_top_bytes",
        Json::num(bruck.bytes_by_level[2] as f64 / patr.bytes_by_level[2].max(1) as f64),
    );
    report.param(
        "bruck_over_pat_time",
        Json::num(bruck.total_time / patr.total_time),
    );
    report.save().unwrap();
}
