//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment has no crates.io access and no XLA shared library,
//! so this crate provides the exact API surface `patcol::runtime` consumes
//! while reporting the backend as unavailable: constructors that require the
//! real runtime return [`Error`], and [`backend_available`] returns `false`
//! so integration tests can skip cleanly instead of failing.
//!
//! To enable the real PJRT datapath, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual xla-rs bindings; no `patcol` source
//! changes are required.

use std::fmt;

/// Error type mirroring `xla::Error` (only the Debug/Display surface is
/// consumed by patcol).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `true` when a real PJRT backend is linked in. Always `false` in the stub.
pub fn backend_available() -> bool {
    false
}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error(format!(
        "{what}: PJRT/XLA backend unavailable (patcol was built with the \
         offline `xla` stub; point the `xla` path dependency at the real \
         bindings to enable it)"
    )))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled executable (stub: never constructible via a real compile).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal. Constructors succeed (they hold no real storage in the
/// stub); every conversion that would need the backend fails.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(!backend_available());
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("unavailable"));
    }
}
