//! Counterexample minimisation: greedy delta-debugging over the
//! deviation list of a failing episode, with pinned replay as the
//! reproduction oracle.
//!
//! A failing episode's [`Failure`] carries the full list of
//! [`Deviation`]s the exploration policy applied — often dozens, of
//! which only one or two matter. [`shrink`] removes chunks of the list
//! (halving chunk sizes down to singletons, ddmin-style) and replays
//! each candidate subset through [`replay_pinned`]; a candidate
//! *reproduces* iff its blamed `(rank, channel, step, kind)` equals the
//! original blame exactly. Subsets are replayable in the first place
//! because deviations key on the per-connection match index `nth`,
//! which is program-determined and therefore stable when other
//! perturbations are removed (see [`crate::transport::delivery`]).
//!
//! Watchdog-timeout failures are never shrunk (the caller filters
//! them): a timeout reproduces or not depending on machine load, which
//! would make minimisation chase noise.

use std::sync::Arc;

use crate::core::Result;
use crate::obs::{Event, EventKind, TraceRecorder};

use super::explore::{episode_options, Failure, Workload};
use super::policy::{drain_log, new_log, Deviation, PinnedPolicy};

/// Outcome of one shrink run.
#[derive(Debug, Clone)]
pub struct ShrinkResult {
    /// Minimal deviation list that still reproduces the blame (may be
    /// empty: sentinel-induced failures need no delivery perturbation).
    pub deviations: Vec<Deviation>,
    /// The blame every surviving candidate reproduced.
    pub blame: super::Blame,
    /// Deviation count before shrinking.
    pub initial: usize,
    /// Replay trials spent.
    pub trials: usize,
}

/// Cap on replay trials per shrink: each trial is a full transport run,
/// and greedy ddmin on a pathological list could otherwise thrash. When
/// the budget runs out the current (partially shrunk) list is returned
/// — still a valid counterexample, just not minimal.
pub const MAX_TRIALS: usize = 400;

/// Replay a pinned deviation list against the workload and return the
/// failure it produces, if any. Deterministic: every deviation is
/// applied at its recorded `(rank, src, channel, nth)` coordinate and
/// no new perturbations are introduced (see
/// [`PinnedPolicy`]).
pub fn replay_pinned(w: &Workload, devs: &[Deviation]) -> Result<Option<Failure>> {
    let (p, cap) = w.build()?;
    let inputs = w.inputs();
    let expected = w.expected(&inputs);
    let sink = new_log();
    let opts = episode_options(cap, PinnedPolicy::factory(Arc::new(devs.to_vec()), sink.clone()));
    let run = w.run(&p, &inputs, &opts);
    let log = drain_log(&sink);
    Ok(match run {
        Ok((outputs, _rep)) => w.compare(&outputs, &expected).map(|blame| Failure {
            blame,
            error: None,
            deviations: log.deviations,
        }),
        Err(e) => {
            let text = e.to_string();
            Some(Failure {
                blame: super::parse_blame(&text),
                error: Some(text),
                deviations: log.deviations,
            })
        }
    })
}

/// Greedily minimise `failure.deviations` while preserving its exact
/// blame. Trials are recorded into `obs` as [`EventKind::Adversary`]
/// events on channel 1 (`step` = trial index, `value` = candidate size,
/// `bytes` = 1 iff the candidate reproduced).
pub fn shrink(
    w: &Workload,
    failure: &Failure,
    mut obs: Option<&mut TraceRecorder>,
) -> Result<ShrinkResult> {
    let target = failure.blame.clone();
    let initial = failure.deviations.len();
    let mut trials = 0usize;

    let mut try_candidate = |cand: &[Deviation],
                             trials: &mut usize,
                             obs: &mut Option<&mut TraceRecorder>|
     -> Result<bool> {
        *trials += 1;
        let repro = replay_pinned(w, cand)?
            .map(|f| f.blame == target)
            .unwrap_or(false);
        if let Some(rec) = obs.as_mut() {
            let t = *trials as f64;
            rec.record(
                Event::span(EventKind::Adversary, 0, 1, *trials, t, t + 1.0)
                    .with_value(cand.len())
                    .with_bytes(usize::from(repro)),
            );
        }
        Ok(repro)
    };

    // Sentinel-induced failures often need no deviation at all: test the
    // empty list first so they shrink in one trial.
    if try_candidate(&[], &mut trials, &mut obs)? {
        return Ok(ShrinkResult { deviations: Vec::new(), blame: target, initial, trials });
    }

    let mut devs = failure.deviations.clone();
    let mut chunk = devs.len().div_ceil(2).max(1);
    loop {
        let mut i = 0;
        while i < devs.len() && devs.len() > 1 && trials < MAX_TRIALS {
            let end = (i + chunk).min(devs.len());
            let mut cand = devs.clone();
            cand.drain(i..end);
            if try_candidate(&cand, &mut trials, &mut obs)? {
                devs = cand;
                // Keep `i`: the next chunk slid into this position.
            } else {
                i = end;
            }
        }
        if chunk == 1 || trials >= MAX_TRIALS {
            break;
        }
        chunk = (chunk / 2).max(1);
    }
    Ok(ShrinkResult { deviations: devs, blame: target, initial, trials })
}
