//! Crate-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug, Error)]
pub enum Error {
    #[error("configuration error: {0}")]
    Config(String),

    #[error("schedule error: {0}")]
    Schedule(String),

    #[error("transport error: {0}")]
    Transport(String),

    #[error("verification failed: {0}")]
    Verify(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("simulation error: {0}")]
    Sim(String),

    #[error("unsupported: {0}")]
    Unsupported(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}
