//! P1 — "logarithmic number of network transfers for small size
//! operations": simulated all-gather latency vs message size, PAT vs Ring
//! vs Bruck vs recursive doubling, on the ideal flat fabric.
//!
//! Expected shape (the paper's motivating comparison):
//! * tiny messages: PAT/Bruck/RD ≈ α·log2(n) vs Ring ≈ α·(n-1) — PAT wins
//!   by ~(n-1)/log2(n);
//! * huge messages: all algorithms converge to the bandwidth bound; PAT's
//!   full-buffer linear schedule matches Ring.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let n = 64usize;
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let cost = CostModel::ib_hdr();
    let algs = [
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
        Algorithm::Recursive,
        Algorithm::Pat { aggregation: usize::MAX },
        Algorithm::Pat { aggregation: 4 },
        Algorithm::Pat { aggregation: 1 },
    ];
    let ks: Vec<usize> = if smoke {
        vec![6, 16]
    } else {
        (6..=24).step_by(2).collect()
    };
    let sizes: Vec<usize> = ks.into_iter().map(|k| 1usize << k).collect();

    let mut report = Report::new("latency_vs_size");
    report.param("nranks", Json::num(n as f64));
    report.param("topology", Json::str(topo.name.clone()));
    report.param("collective", Json::str("all_gather"));

    let header: Vec<String> = std::iter::once("size/rank".to_string())
        .chain(algs.iter().map(|a| a.name()))
        .chain(std::iter::once("ring/pat".to_string()))
        .collect();
    let mut table = Table::new(header);

    for &size in &sizes {
        let mut row = vec![fmt_bytes(size)];
        let mut times = Vec::new();
        for alg in &algs {
            let prog = sched::generate(*alg, Collective::AllGather, n).unwrap();
            let t = simulate(&prog, &topo, &cost, size).unwrap().total_time;
            times.push(t);
            row.push(fmt_time_s(t));
        }
        let speedup = times[0] / times[3]; // ring / pat(full)
        row.push(format!("{speedup:.1}x"));
        table.row(row);
        let mut jrow = vec![("size", Json::num(size as f64))];
        let names: Vec<String> = algs.iter().map(|a| a.name()).collect();
        for (name, t) in names.iter().zip(&times) {
            jrow.push((name.as_str(), Json::num(*t)));
        }
        report.rows.push(Json::obj(jrow));
    }

    println!("\nall-gather latency vs size, {n} ranks, {}:", topo.name);
    print!("{}", table.render());

    // Small-size speedup check: ring/pat should approach (n-1)/ceil_log2(n).
    let small = sizes[0];
    let ring = simulate(
        &sched::generate(Algorithm::Ring, Collective::AllGather, n).unwrap(),
        &topo,
        &cost,
        small,
    )
    .unwrap()
    .total_time;
    let pat = simulate(
        &sched::generate(Algorithm::Pat { aggregation: usize::MAX }, Collective::AllGather, n)
            .unwrap(),
        &topo,
        &cost,
        small,
    )
    .unwrap()
    .total_time;
    let ideal = (n - 1) as f64 / patcol::core::ceil_log2(n) as f64;
    println!(
        "small-size speedup ring/pat = {:.1}x (step-count ideal {:.1}x)",
        ring / pat,
        ideal
    );
    report.param("small_speedup", Json::num(ring / pat));
    report.param("ideal_speedup", Json::num(ideal));

    // Large-size bandwidth parity: pat(a=1)'s full-buffer schedule within
    // 1.3x of ring.
    let big = *sizes.last().unwrap();
    let ring_b = simulate(
        &sched::generate(Algorithm::Ring, Collective::AllGather, n).unwrap(),
        &topo,
        &cost,
        big,
    )
    .unwrap()
    .total_time;
    let pat1_b = simulate(
        &sched::generate(Algorithm::Pat { aggregation: 1 }, Collective::AllGather, n).unwrap(),
        &topo,
        &cost,
        big,
    )
    .unwrap()
    .total_time;
    println!(
        "large-size parity pat(a=1)/ring = {:.2} (→ 1.0 means full bandwidth)",
        pat1_b / ring_b
    );
    report.param("large_parity", Json::num(pat1_b / ring_b));

    // Träff optimality gaps (deterministic, simulator-derived): PAT's
    // modeled time over the single-phase all-gather lower bound —
    // max(⌈log2 n⌉ rounds, (n−1)/n of the payload through one NIC) — at
    // the latency-bound and bandwidth-bound ends of the sweep. Param
    // names end in `_gap_pct` so the bench-baseline harness
    // (obs::baseline::optimality_gaps) picks them up and CI gates their
    // growth against the committed BENCH_8.json.
    let tuner = patcol::coordinator::Tuner::default();
    let ag_bound = |size: usize| {
        let total_bytes = n * size;
        let rounds = patcol::core::ceil_log2(n) as f64 * tuner.cost.alpha_base;
        let volume = (n - 1) as f64 / n as f64 * total_bytes as f64 / tuner.nic_bw;
        rounds.max(volume)
    };
    let gap = |t: f64, bound: f64| 100.0 * (t - bound) / bound.max(1e-30);
    let small_gap = gap(pat, ag_bound(small));
    let large_gap = gap(pat1_b, ag_bound(big));
    println!(
        "Träff gap: pat(full) @ {} = {small_gap:.1}%, pat(a=1) @ {} = {large_gap:.1}%",
        fmt_bytes(small),
        fmt_bytes(big)
    );
    report.param("pat_small_gap_pct", Json::num(small_gap));
    report.param("pat_large_gap_pct", Json::num(large_gap));
    report.save().unwrap();
}
