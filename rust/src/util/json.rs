//! Minimal JSON value model, writer, and parser.
//!
//! Used for `artifacts/manifest.json` (written by the python AOT pipeline,
//! read by the rust runtime) and for machine-readable bench results. The
//! offline environment lacks `serde`, so this is a small, strict-enough
//! implementation covering the JSON we produce and consume.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::core::{Error, Result};

/// A JSON value. Object keys are kept sorted (BTreeMap) so emission is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    for _ in 0..(indent + 2) {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    for _ in 0..(indent + 2) {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    // JSON has no inf/NaN literals; `{x}` would emit `inf`/`NaN` and
    // corrupt the document (e.g. the `(+inf, -inf)` sentinels of empty
    // step spans). Emit null, which every consumer already handles.
    if !x.is_finite() {
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(Error::Config(format!(
            "trailing JSON content at byte {}",
            p.pos
        )));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("JSON parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected literal {s}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let v = Json::obj(vec![
            ("name", Json::str("reduce_f32_4096")),
            ("n", Json::num(4096.0)),
            ("inputs", Json::arr(vec![Json::num(2.0)])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2.5, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("arr", Json::arr((0..3).map(|i| Json::num(i as f64)))),
            ("obj", Json::obj(vec![("k", Json::str("v"))])),
        ]);
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn nonfinite_serializes_as_null_and_roundtrips() {
        // the empty-step sentinel shape from SimReport::step_spans
        let v = Json::arr(vec![
            Json::num(f64::INFINITY),
            Json::num(f64::NEG_INFINITY),
            Json::num(f64::NAN),
            Json::num(1.5),
        ]);
        let s = v.to_string();
        assert_eq!(s, "[null,null,null,1.5]");
        let back = parse(&s).unwrap();
        assert_eq!(
            back,
            Json::arr(vec![Json::Null, Json::Null, Json::Null, Json::num(1.5)])
        );
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }
}
