//! P5 — the log→linear crossover.
//!
//! "There is always a scale at which the linear part will become
//! predominant over the logarithmic part. The performance factor over the
//! ring algorithm will be dependent on how much faster the linear part is,
//! compared to the linear part of the ring."
//!
//! This bench sweeps size at fixed rank count and rank count at fixed
//! size, locating where Ring catches up with PAT, and verifying that
//! PAT's full-buffer linear schedule sustains ring-level bandwidth.

use patcol::core::{Algorithm, Collective};
use patcol::report::Report;
use patcol::sched;
use patcol::sim::{simulate, CostModel, Topology};
use patcol::util::json::Json;
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};

fn sim_t(alg: Algorithm, n: usize, chunk: usize) -> f64 {
    let topo = Topology::flat(n, CostModel::ib_hdr_nic_bw());
    let cost = CostModel::ib_hdr();
    let prog = sched::generate(alg, Collective::AllGather, n).unwrap();
    simulate(&prog, &topo, &cost, chunk).unwrap().total_time
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut report = Report::new("crossover");
    let n = 64usize;

    println!("\nPAT-vs-Ring crossover in size ({n} ranks):");
    let mut t = Table::new(["size/rank", "pat(auto-best)", "ring", "ratio"]);
    let mut crossover_size: Option<usize> = None;
    let ks: Vec<usize> = if smoke {
        vec![6, 16]
    } else {
        (6..=26).step_by(2).collect()
    };
    for k in ks {
        let size = 1usize << k;
        // best PAT over aggregation choices — what the tuner would do
        let pat_best = [usize::MAX, 8, 2, 1]
            .iter()
            .map(|&a| sim_t(Algorithm::Pat { aggregation: a }, n, size))
            .fold(f64::INFINITY, f64::min);
        let ring = sim_t(Algorithm::Ring, n, size);
        let ratio = ring / pat_best;
        if ratio < 1.05 && crossover_size.is_none() {
            crossover_size = Some(size);
        }
        t.row([
            fmt_bytes(size),
            fmt_time_s(pat_best),
            fmt_time_s(ring),
            format!("{ratio:.2}x"),
        ]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("size_sweep")),
            ("size", Json::num(size as f64)),
            ("pat_best", Json::num(pat_best)),
            ("ring", Json::num(ring)),
        ]));
    }
    print!("{}", t.render());
    match crossover_size {
        Some(s) => println!("ring reaches parity (≤1.05x) at ~{} per rank", fmt_bytes(s)),
        None => println!("ring never reaches parity in this sweep"),
    }

    // Crossover in scale: at a fixed mid size, the PAT advantage grows
    // with rank count (the "at scale" in the paper's title).
    println!("\nPAT advantage vs rank count (64 KiB per rank):");
    let mut t = Table::new(["ranks", "pat(full)", "ring", "speedup"]);
    let rank_sweep: &[usize] = if smoke {
        &[8, 32]
    } else {
        &[8, 32, 128, 512, 2048]
    };
    for &n in rank_sweep {
        let pat = sim_t(Algorithm::Pat { aggregation: usize::MAX }, n, 64 << 10);
        let ring = sim_t(Algorithm::Ring, n, 64 << 10);
        t.row([
            format!("{n}"),
            fmt_time_s(pat),
            fmt_time_s(ring),
            format!("{:.1}x", ring / pat),
        ]);
        report.rows.push(Json::obj(vec![
            ("kind", Json::str("rank_sweep")),
            ("ranks", Json::num(n as f64)),
            ("pat", Json::num(pat)),
            ("ring", Json::num(ring)),
        ]));
    }
    print!("{}", t.render());

    // Bandwidth parity of the fully linear schedule at large size.
    let big = 16 << 20;
    let pat1 = sim_t(Algorithm::Pat { aggregation: 1 }, n, big);
    let ring = sim_t(Algorithm::Ring, n, big);
    println!(
        "\nfully-linear PAT at {} per rank: {:.2}x ring time (1.0 = full bandwidth)",
        fmt_bytes(big),
        pat1 / ring
    );
    report.param("linear_parity", Json::num(pat1 / ring));
    report.save().unwrap();
}
