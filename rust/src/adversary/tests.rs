//! In-crate adversary tests.
//!
//! Everything here needs the mutation sentinels, which exist only under
//! `cfg(test)` / `--features adversary` *of the library crate* —
//! integration-test binaries link the library without either, so the
//! sentinel-armed scenarios (and the golden replay, which re-arms a
//! recorded sentinel) must live in-crate.
//!
//! Sentinels are process-global: tests that arm one hold the sentinel
//! lock for their whole scenario, and tests that need healthy guards
//! while driving a delivery policy serialize through
//! [`sentinel::exclusive`].

use crate::core::{AlgSpec, Collective};
use crate::obs::{EventKind, TraceRecorder};
use crate::transport::delivery::sentinel::{self, Sentinel};

use super::explore::{explore, run_episode, Workload};
use super::policy::{DevKind, PolicySpec, Preset};
use super::{replay, ReplayTrace};

fn workload(coll: Collective, alg: &str, n: usize, elems: usize, seed: u64) -> Workload {
    Workload::new(coll, AlgSpec::parse(alg).unwrap(), n, elems, seed)
}

/// Satellite: with the FIFO-ordering guard disabled, the explorer's
/// reorder policy corrupts an all-gather, and the failure shrinks to a
/// small replayable deviation list that reproduces the same blame.
#[test]
fn explorer_finds_and_shrinks_fifo_reorder_bug() {
    let w = workload(Collective::AllGather, "ring", 4, 8, 7);
    let pol = PolicySpec { preset: Preset::Reorder, seed: 3 };
    let ce = {
        let _armed = sentinel::arm(Sentinel::FifoGuardOff);
        let report = explore(&w, &pol, 64, None).unwrap();
        report
            .counterexample
            .expect("reorder exploration must corrupt an unguarded FIFO within 64 episodes")
    };
    assert_eq!(ce.sentinel.as_deref(), Some("fifo-guard-off"));
    assert!(
        !ce.deviations.is_empty(),
        "an in-order run cannot corrupt an all-gather; the counterexample needs a deviation"
    );
    assert!(
        ce.deviations.iter().all(|d| matches!(d.kind, DevKind::Skip { .. })),
        "only reorders corrupt data — holds must shrink away: {:?}",
        ce.deviations
    );
    assert!(ce.blame.kind.starts_with("wrong-result"), "{:?}", ce.blame);
    assert!(ce.shrink_trials > 0);
    // Replay re-arms the recorded sentinel (the explore guard is dropped)
    // and must reproduce the blame bit-exactly.
    let got = replay(&ce).unwrap().expect("shrunk trace must still fail on replay");
    assert_eq!(got.blame, ce.blame);
}

/// Satellite: with one reduce-scatter slot release disabled, every rank
/// leaks accumulator slots and the enforced sound capacity trips. The
/// failure needs no delivery perturbation at all, so the shrinker must
/// reach the empty deviation list.
#[test]
fn explorer_finds_slot_release_leak() {
    let w = workload(Collective::ReduceScatter, "ring", 8, 8, 5);
    let pol = PolicySpec { preset: Preset::Delay, seed: 1 };
    let ce = {
        let _armed = sentinel::arm(Sentinel::SlotReleaseOff);
        let report = explore(&w, &pol, 4, None).unwrap();
        report
            .counterexample
            .expect("a leaked slot per forwarded chunk must exhaust the sound capacity")
    };
    assert_eq!(ce.sentinel.as_deref(), Some("slot-release-off"));
    assert_eq!(ce.blame.kind, "pool-exhausted", "{:?}", ce.blame);
    assert!(
        ce.deviations.is_empty(),
        "the leak fires under eager delivery too — shrink must reach the empty list: {:?}",
        ce.deviations
    );
    let got = replay(&ce).unwrap().expect("replay must still exhaust the pool");
    assert_eq!(got.blame, ce.blame);
}

/// Satellite: the committed golden counterexample replays bit-exactly —
/// same blamed (rank, channel, step) and failure kind on every machine.
/// The trace pins one reordered delivery on the rank-0→rank-1 connection
/// of a 4-rank ring all-gather: rank 1's first match takes the chunk-3
/// payload instead of chunk 0, so rank 1 (and everyone downstream of its
/// forwards) ends up with a misplaced chunk while rank 0 stays clean.
#[test]
fn golden_trace_replays_bit_exactly() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/adversary_golden.json");
    let trace = ReplayTrace::load(&path).unwrap();
    assert_eq!(trace.sentinel.as_deref(), Some("fifo-guard-off"));
    assert_eq!(trace.deviations.len(), 1);
    let got = replay(&trace).unwrap().expect("golden trace must fail");
    assert_eq!(got.blame, trace.blame, "replay must blame the recorded coordinates");
    assert_eq!(got.blame.rank, 1);
    assert_eq!(got.blame.channel, 0);
    assert_eq!(got.blame.step, 0);
    assert_eq!(got.blame.kind, "wrong-result chunk 0");
}

/// A shrunk trace round-trips through its JSON wire format.
#[test]
fn replay_trace_roundtrips_through_json() {
    let w = workload(Collective::ReduceScatter, "pat:2*2", 8, 16, 11);
    let trace = ReplayTrace {
        workload: w,
        policy: "mix:9".into(),
        episode: 17,
        sentinel: Some("fifo-guard-off".into()),
        deviations: vec![
            super::Deviation {
                rank: 3,
                src: 1,
                channel: 1,
                nth: 4,
                kind: DevKind::Hold { cycles: 2 },
            },
            super::Deviation { rank: 0, src: 7, channel: 0, nth: 0, kind: DevKind::Skip { depth: 2 } },
        ],
        blame: super::Blame { rank: 3, channel: 1, step: 2, kind: "pool-exhausted".into() },
        initial_deviations: 40,
        shrink_trials: 12,
    };
    let doc = trace.to_json();
    let back = ReplayTrace::from_json(&crate::util::json::parse(&doc.to_string()).unwrap()).unwrap();
    assert_eq!(back, trace);
}

/// With healthy guards, adversarial exploration finds nothing: holds are
/// force-released, reorder attempts are clamped to FIFO order, and every
/// episode's result stays bit-exact. Episode outcomes land in the obs
/// timeline as [`EventKind::Adversary`] events.
#[test]
fn healthy_transport_survives_exploration() {
    // Serialize against sentinel-armed tests without arming anything.
    let _guard = sentinel::exclusive();
    let mut rec = TraceRecorder::new();
    for (coll, alg) in [
        (Collective::AllGather, "pat:2"),
        (Collective::ReduceScatter, "ring*2"),
    ] {
        let w = workload(coll, alg, 8, 16, 13);
        let pol = PolicySpec { preset: Preset::Mix, seed: 2 };
        let report = explore(&w, &pol, 6, Some(&mut rec)).unwrap();
        assert_eq!(report.episodes_run, 6);
        assert!(
            report.counterexample.is_none(),
            "healthy transport must survive {alg}: {:?}",
            report.counterexample
        );
        assert_eq!(report.failures, 0, "{alg}");
        assert!(report.total_decisions > 0, "policies must actually be consulted ({alg})");
    }
    let trace = rec.finish();
    let episodes = trace
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Adversary && e.channel == 0)
        .count();
    assert_eq!(episodes, 12, "one outcome event per episode");
}

/// Episodes are reproducible: the same (workload, policy, episode) runs
/// twice with identical deviation counts and outcomes — the property the
/// whole find-shrink-replay chain rests on.
#[test]
fn episodes_are_deterministic_in_their_seed() {
    let _guard = sentinel::exclusive();
    let w = workload(Collective::AllGather, "ring", 4, 8, 9);
    let pol = PolicySpec { preset: Preset::Dpor, seed: 0 };
    for episode in [0u64, 5, 21] {
        let a = run_episode(&w, &pol, episode).unwrap();
        let b = run_episode(&w, &pol, episode).unwrap();
        assert_eq!(a.deviations, b.deviations, "episode {episode}");
        assert_eq!(a.decisions, b.decisions, "episode {episode}");
        assert_eq!(a.failure.is_some(), b.failure.is_some(), "episode {episode}");
        assert!(a.failure.is_none(), "dpor holds cannot corrupt a guarded transport");
    }
}
