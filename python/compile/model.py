"""L2 JAX compute graphs, AOT-lowered to HLO text for the rust runtime.

Two families:

1. **Collective datapath graphs** — thin jax functions around the L1 Pallas
   kernels (`kernels.reduce`, `kernels.update`). These are what the rust
   transport executes on the reduce-scatter hot path.

2. **Workload model** — a small decoder-only transformer LM with flat
   parameter handling, used by the end-to-end ZeRO-style data-parallel
   training example (`examples/zero_train.rs`): per-rank grads are computed
   by the `train_step` artifact, reduce-scattered with PAT over real bytes,
   applied with the `scale_add` artifact, and all-gathered back with PAT.

Parameters travel as a single flat f32 vector (ravel_pytree) so the rust
side can shard them with ordinary chunk arithmetic.
"""

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from compile.kernels import reduce as kred
from compile.kernels import update as kupd
from compile.kernels.ref import ref_softmax_xent


# ---------------------------------------------------------------------------
# 1. Collective datapath graphs (call the Pallas kernels).
# ---------------------------------------------------------------------------

def reduce2_graph(n: int):
    """(a[n], b[n]) -> (a+b,) via the Pallas reduce kernel."""

    def fn(a, b):
        return (kred.reduce2(a, b),)

    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return fn, (spec, spec)


def reduce_k_graph(n: int, k: int):
    """(acc[n], x0[n], .., x{k-1}[n]) -> (acc + Σ xi,) fused."""

    def fn(acc, *xs):
        return (kred.reduce_k(acc, *xs),)

    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    return fn, tuple([spec] * (k + 1))


def scale_add_graph(n: int):
    """(p[n], g[n], lr[1]) -> (p - lr*g,) via the Pallas update kernel."""

    def fn(p, g, lr):
        return (kupd.scale_add(p, g, lr),)

    spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    lr_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
    return fn, (spec, spec, lr_spec)


# ---------------------------------------------------------------------------
# 2. Transformer LM workload.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    seq: int = 64
    batch: int = 4  # per-rank batch


def init_params(cfg: ModelConfig, seed: int = 0):
    """Initialize the parameter pytree."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4 + 8 * cfg.n_layers)
    it = iter(ks)
    d, v, f = cfg.d_model, cfg.vocab, cfg.d_ff
    scale = d ** -0.5
    params = {
        "embed": jax.random.normal(next(it), (v, d)) * 0.02,
        "pos": jax.random.normal(next(it), (cfg.seq, d)) * 0.02,
        "unembed": jax.random.normal(next(it), (d, v)) * scale,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append(
            {
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wq": jax.random.normal(next(it), (d, d)) * scale,
                "wk": jax.random.normal(next(it), (d, d)) * scale,
                "wv": jax.random.normal(next(it), (d, d)) * scale,
                "wo": jax.random.normal(next(it), (d, d)) * scale,
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "w1": jax.random.normal(next(it), (d, f)) * scale,
                "w2": jax.random.normal(next(it), (f, d)) * (f ** -0.5),
            }
        )
    return params


def init_flat(cfg: ModelConfig, seed: int = 0):
    """Flat parameter vector + unravel closure."""
    params = init_params(cfg, seed)
    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(x, layer, cfg: ModelConfig):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h

    def split(w):
        return (x @ w).reshape(b, s, h, hd).transpose(0, 2, 1, 3)

    q, k, v = split(layer["wq"]), split(layer["wk"]), split(layer["wv"])
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ layer["wo"]


def forward_loss(params, tokens, cfg: ModelConfig):
    """Causal LM loss. `tokens` int32 [batch, seq+1]."""
    inp = tokens[:, :-1]
    tgt = tokens[:, 1:]
    x = params["embed"][inp] + params["pos"][None, : inp.shape[1]]
    for layer in params["layers"]:
        x = x + _attention(_layernorm(x, layer["ln1"]["g"], layer["ln1"]["b"]), layer, cfg)
        hdn = _layernorm(x, layer["ln2"]["g"], layer["ln2"]["b"])
        x = x + jax.nn.gelu(hdn @ layer["w1"]) @ layer["w2"]
    x = _layernorm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    logits = x @ params["unembed"]
    return ref_softmax_xent(logits, tgt)


def train_step_graph(cfg: ModelConfig, seed: int = 0):
    """(params_flat[P], tokens[B, S+1]) -> (loss, grads_flat[P])."""
    flat0, unravel = init_flat(cfg, seed)
    nparams = flat0.shape[0]

    def fn(flat, tokens):
        loss, grads = jax.value_and_grad(
            lambda f: forward_loss(unravel(f), tokens, cfg)
        )(flat)
        gflat, _ = ravel_pytree(grads)
        return (loss, gflat.astype(jnp.float32))

    specs = (
        jax.ShapeDtypeStruct((nparams,), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32),
    )
    return fn, specs, nparams, flat0


@partial(jax.jit, static_argnames=("cfg",))
def jit_forward_loss_flat(flat, tokens, cfg: ModelConfig):
    """Convenience for python-side tests."""
    _, unravel = init_flat(cfg, 0)
    return forward_loss(unravel(flat), tokens, cfg)
