//! Configuration: environment variables (`PATCOL_*`) and simple
//! `key = value` config files, merged into a [`CommConfig`] and cost-model
//! overrides. (No serde in this environment — the parser is a small
//! line-oriented reader with `#` comments.)
//!
//! Recognized keys (file and env, env wins; env names are upper-cased with
//! the `PATCOL_` prefix):
//!
//! | key | meaning |
//! |-----|---------|
//! | `nranks` | world size |
//! | `algorithm` | `ring`, `bruck_near`, `bruck_far`, `recursive`, `pat`, `pat:<a>`, `pat_auto`, `hier_pat`, `hier_pat:<a>`, or the all-reduce composition `rs+ag[:<segments>]` (e.g. `pat+ring:4`); any spelling takes a `*<channels>` suffix (e.g. `pat*4`) |
//! | `segments` | all-reduce pipeline segment count; wraps a non-composed `algorithm` into `alg+alg:<segments>` |
//! | `channels` | NCCL-style channel count every collective is split across (overrides an `algorithm = alg*C` suffix) |
//! | `parallel_links` | parallel fabric links per rank for the tuner's channel-count crossover (default 1 = auto stays single-channel) |
//! | `buckets` | gradient-bucket count: all-reduce payloads split into that many buckets fused into one pipelined program (CLI `--buckets` / `--bucket-bytes`) |
//! | `buffer_slots` | intermediate-buffer budget in chunk slots |
//! | `datapath` | `scalar` or `pjrt` |
//! | `reduce_shards` | PJRT reduction-service shard count (worker threads, each owning a client); default = `min(cores, nranks)` |
//! | `artifacts` | artifact directory |
//! | `validate` | `true`/`false` |
//! | `trace` | `true`/`false` — capture an observability trace ([`crate::obs`]) |
//! | `calib_history` | JSONL file appended with one predicted-vs-measured [`crate::obs::calib::CalibRecord`] per collective call |
//! | `placement` | rank → node placement (grammar below) |
//! | `ranks_per_node` | shorthand for `placement = uniform:<k>` |
//! | `leaders_per_node` | stripe leaders per node for hierarchical algorithms: each leader owns an interleaved chunk stripe and its own inter-node channel (clamped to the smallest node size) |
//! | `inter_gbps` | per-node uplink bandwidth for the tuner's flat-vs-hier crossover |
//! | `alpha_base_us`, `alpha_hop_ns`, `gamma_chunk_ns`, `nic_gbps` | cost-model overrides |
//!
//! ## Placement grammar
//!
//! `placement` accepts (see [`Placement::parse`]):
//!
//! * `uniform:<k>` — contiguous nodes of `k` ranks; when `k` does not
//!   divide `nranks` the last node takes the remainder
//!   (`uniform:4` over 13 ranks → nodes of `[4, 4, 4, 1]`);
//! * `<k>` — shorthand for `uniform:<k>`;
//! * `<k1>,<k2>,...` — explicit node sizes, which must sum to `nranks`
//!   (e.g. `4,4,5` over 13 ranks);
//! * `<k>x<m>` — three-level: uniform nodes of `k` ranks grouped into
//!   pods of `m` nodes (`8x4` over 256 ranks → 8 pods of 4 nodes);
//! * `<sizes>;<sizes>;...` — three-level with explicit pods of
//!   comma-separated node sizes (e.g. `4,4;4,5` over 17 ranks).
//!
//! `nranks` must be set (in the same file or by env overlay) for the
//! placement to be resolved; `ranks_per_node` is ignored when an explicit
//! `placement` is present.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::core::{AlgSpec, Algorithm, Error, PhaseAlg, Placement, Result};
use crate::coordinator::communicator::{CommConfig, DataPathKind};
use crate::sim::CostModel;

/// A flat key→value config layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ConfigMap {
    pub values: BTreeMap<String, String>,
}

impl ConfigMap {
    /// Parse `key = value` lines; `#` starts a comment; blank lines are
    /// skipped. Keys are lower-cased.
    pub fn parse(text: &str) -> Result<ConfigMap> {
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("config line {}: expected key = value", lineno + 1))
            })?;
            values.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
        Ok(ConfigMap { values })
    }

    pub fn from_file(path: &Path) -> Result<ConfigMap> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Read `PATCOL_<KEY>` environment overrides for the given keys.
    pub fn env_overlay(mut self, keys: &[&str]) -> ConfigMap {
        for k in keys {
            let env_key = format!("PATCOL_{}", k.to_uppercase());
            if let Ok(v) = std::env::var(&env_key) {
                self.values.insert(k.to_string(), v);
            }
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| Error::Config(format!("{key}: bad integer {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| Error::Config(format!("{key}: bad float {v:?}"))),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>> {
        match self.get(key) {
            None => Ok(None),
            Some("true" | "1" | "yes") => Ok(Some(true)),
            Some("false" | "0" | "no") => Ok(Some(false)),
            Some(v) => Err(Error::Config(format!("{key}: bad bool {v:?}"))),
        }
    }

    /// Build a [`CommConfig`] from this map.
    pub fn to_comm_config(&self) -> Result<CommConfig> {
        let mut cfg = CommConfig::default();
        if let Some(n) = self.get_usize("nranks")? {
            cfg.nranks = n;
        }
        if let Some(a) = self.get("algorithm") {
            let (alg, pinned) = AlgSpec::parse_pinned(a)?;
            cfg.algorithm = Some(alg);
            if let Some(c) = pinned {
                cfg.channels = Some(c);
            }
        }
        if let Some(s) = self.get_usize("segments")? {
            if s == 0 {
                return Err(Error::Config("segments must be >= 1".into()));
            }
            cfg.algorithm = Some(match cfg.algorithm {
                Some(Algorithm::Compose { rs, ag, .. }) => {
                    Algorithm::Compose { rs, ag, segments: s }
                }
                Some(alg) => {
                    let ph = PhaseAlg::from_algorithm(alg)?;
                    Algorithm::Compose { rs: ph, ag: ph, segments: s }
                }
                None => {
                    return Err(Error::Config(
                        "segments requires an algorithm to compose".into(),
                    ))
                }
            });
        }
        if let Some(c) = self.get_usize("channels")? {
            if c == 0 {
                return Err(Error::Config("channels must be >= 1".into()));
            }
            cfg.channels = Some(c);
        }
        if let Some(l) = self.get_usize("parallel_links")? {
            if l == 0 {
                return Err(Error::Config("parallel_links must be >= 1".into()));
            }
            cfg.parallel_links = Some(l);
        }
        if let Some(b) = self.get_usize("buckets")? {
            if b == 0 {
                return Err(Error::Config("buckets must be >= 1".into()));
            }
            cfg.buckets = Some(b);
        }
        cfg.buffer_slots = self.get_usize("buffer_slots")?;
        if let Some(s) = self.get_usize("reduce_shards")? {
            if s == 0 {
                return Err(Error::Config("reduce_shards must be >= 1".into()));
            }
            cfg.reduce_shards = Some(s);
        }
        match self.get("datapath") {
            Some("pjrt") => cfg.datapath = DataPathKind::Pjrt,
            Some("scalar") | None => {}
            Some(other) => {
                return Err(Error::Config(format!("datapath: unknown {other:?}")))
            }
        }
        if let Some(dir) = self.get("artifacts") {
            cfg.artifacts_dir = Some(PathBuf::from(dir));
        }
        if let Some(v) = self.get_bool("validate")? {
            cfg.validate = v;
        }
        if let Some(v) = self.get_bool("trace")? {
            cfg.trace = v;
        }
        if let Some(p) = self.get("calib_history") {
            cfg.calib_history = Some(PathBuf::from(p));
        }
        if let Some(spec) = self.get("adversary") {
            cfg.adversary = Some(crate::adversary::PolicySpec::parse(spec)?);
        }
        if let Some(spec) = self.get("placement") {
            cfg.placement = Some(Placement::parse(spec, cfg.nranks)?);
        } else if let Some(k) = self.get_usize("ranks_per_node")? {
            cfg.placement = Some(Placement::uniform(cfg.nranks, k)?);
        }
        if let Some(l) = self.get_usize("leaders_per_node")? {
            if l == 0 {
                return Err(Error::Config("leaders_per_node must be >= 1".into()));
            }
            cfg.leaders_per_node = Some(l);
        }
        if let Some(v) = self.get_f64("inter_gbps")? {
            cfg.inter_bw = Some(v * 1e9);
        }
        Ok(cfg)
    }

    /// Apply cost-model overrides, returning `(model, nic_bw)`.
    pub fn to_cost_model(&self) -> Result<(CostModel, f64)> {
        let mut cost = CostModel::ib_hdr();
        let mut nic = CostModel::ib_hdr_nic_bw();
        if let Some(v) = self.get_f64("alpha_base_us")? {
            cost.alpha_base = v * 1e-6;
        }
        if let Some(v) = self.get_f64("alpha_hop_ns")? {
            cost.alpha_hop = v * 1e-9;
        }
        if let Some(v) = self.get_f64("gamma_chunk_ns")? {
            cost.gamma_chunk = v * 1e-9;
        }
        if let Some(v) = self.get_f64("nic_gbps")? {
            nic = v * 1e9;
        }
        Ok((cost, nic))
    }
}

/// Parse a human size like `64`, `4KiB`, `1MiB`, `2GiB` (also `KB`/`MB`/
/// `GB` as power-of-two for CLI convenience).
pub fn parse_bytes(s: &str) -> Result<usize> {
    let s = s.trim();
    let split = s
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(s.len());
    let (num, unit) = s.split_at(split);
    let n: usize = num
        .parse()
        .map_err(|_| Error::Config(format!("bad size {s:?}")))?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        other => return Err(Error::Config(format!("bad size unit {other:?}"))),
    };
    Ok(n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_file_and_build() {
        let cfg = ConfigMap::parse(
            "# comment\nnranks = 16\nalgorithm = pat:4\nbuffer_slots = 32\nvalidate = false\n",
        )
        .unwrap();
        let cc = cfg.to_comm_config().unwrap();
        assert_eq!(cc.nranks, 16);
        assert_eq!(cc.algorithm, Some(Algorithm::Pat { aggregation: 4 }));
        assert_eq!(cc.buffer_slots, Some(32));
        assert!(!cc.validate);
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(ConfigMap::parse("nonsense line").is_err());
        let cfg = ConfigMap::parse("nranks = abc").unwrap();
        assert!(cfg.to_comm_config().is_err());
    }

    #[test]
    fn placement_keys() {
        let cfg = ConfigMap::parse("nranks = 13\nplacement = 4,4,5\ninter_gbps = 12.5\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        let pl = cfg.placement.unwrap();
        assert_eq!(pl.node_sizes(), vec![4, 4, 5]);
        assert_eq!(cfg.inter_bw, Some(12.5e9));

        let cfg = ConfigMap::parse("nranks = 13\nranks_per_node = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.placement.unwrap().node_sizes(), vec![4, 4, 4, 1]);

        // explicit placement wins over ranks_per_node
        let cfg = ConfigMap::parse("nranks = 8\nplacement = uniform:2\nranks_per_node = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.placement.unwrap().nnodes(), 4);

        // sizes that do not sum to nranks are rejected
        assert!(ConfigMap::parse("nranks = 8\nplacement = 4,4,4\n")
            .unwrap()
            .to_comm_config()
            .is_err());
        assert!(ConfigMap::parse("nranks = 8\nranks_per_node = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn three_level_and_leader_keys() {
        // `<k>x<m>` — uniform nodes grouped into pods
        let cfg = ConfigMap::parse("nranks = 32\nplacement = 4x4\nleaders_per_node = 2\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        let pl = cfg.placement.unwrap();
        assert!(pl.is_three_level());
        assert_eq!(pl.npods(), 2);
        assert_eq!(cfg.leaders_per_node, Some(2));

        // explicit pods of node sizes
        let cfg = ConfigMap::parse("nranks = 17\nplacement = 4,4;4,5\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        let pl = cfg.placement.unwrap();
        assert_eq!(pl.npods(), 2);
        assert_eq!(pl.node_sizes(), vec![4, 4, 4, 5]);

        // leaders_per_node stands alone (applied to the default placement
        // by the communicator) and rejects zero
        let cfg = ConfigMap::parse("nranks = 16\nleaders_per_node = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.leaders_per_node, Some(4));
        assert!(ConfigMap::parse("nranks = 16\nleaders_per_node = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn segments_key_composes() {
        use crate::core::PhaseAlg;
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat:2\nsegments = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(
            cfg.algorithm,
            Some(Algorithm::Compose {
                rs: PhaseAlg::Pat { aggregation: 2 },
                ag: PhaseAlg::Pat { aggregation: 2 },
                segments: 4
            })
        );
        // overrides the segment count of an explicit composition
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat+ring:2\nsegments = 8\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        match cfg.algorithm {
            Some(Algorithm::Compose { segments, .. }) => assert_eq!(segments, 8),
            other => panic!("{other:?}"),
        }
        assert!(ConfigMap::parse("nranks = 8\nsegments = 2\n")
            .unwrap()
            .to_comm_config()
            .is_err());
        assert!(ConfigMap::parse("nranks = 8\nalgorithm = pat\nsegments = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn channels_keys() {
        // channel suffix on the algorithm spelling
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat:2*4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.algorithm, Some(Algorithm::Pat { aggregation: 2 }));
        assert_eq!(cfg.channels, Some(4));
        // explicit channels key overrides the suffix
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat*4\nchannels = 2\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.channels, Some(2));
        // an explicit *1 suffix pins single-channel (the tuner must not
        // override it), while a bare spelling leaves the tuner free
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat*1\nparallel_links = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.channels, Some(1));
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.channels, None);
        // parallel_links for the tuner crossover
        let cfg = ConfigMap::parse("nranks = 8\nparallel_links = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.parallel_links, Some(4));
        // composed spelling with channels
        let cfg = ConfigMap::parse("nranks = 8\nalgorithm = pat+ring:2*4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        match cfg.algorithm {
            Some(Algorithm::Compose { segments, .. }) => assert_eq!(segments, 2),
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.channels, Some(4));
        // zero rejected
        assert!(ConfigMap::parse("nranks = 8\nchannels = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
        assert!(ConfigMap::parse("nranks = 8\nparallel_links = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn buckets_key() {
        let cfg = ConfigMap::parse("nranks = 8\nbuckets = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.buckets, Some(4));
        let cfg = ConfigMap::parse("nranks = 8\n").unwrap().to_comm_config().unwrap();
        assert_eq!(cfg.buckets, None);
        assert!(ConfigMap::parse("nranks = 8\nbuckets = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn calib_history_key() {
        let cfg = ConfigMap::parse("nranks = 8\ncalib_history = runs/calib.jsonl\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.calib_history, Some(PathBuf::from("runs/calib.jsonl")));
        let cfg = ConfigMap::parse("nranks = 8\n").unwrap().to_comm_config().unwrap();
        assert_eq!(cfg.calib_history, None);
    }

    #[test]
    fn reduce_shards_key() {
        let cfg = ConfigMap::parse("nranks = 8\nreduce_shards = 4\n")
            .unwrap()
            .to_comm_config()
            .unwrap();
        assert_eq!(cfg.reduce_shards, Some(4));
        let cfg = ConfigMap::parse("nranks = 8\n").unwrap().to_comm_config().unwrap();
        assert_eq!(cfg.reduce_shards, None);
        assert!(ConfigMap::parse("nranks = 8\nreduce_shards = 0\n")
            .unwrap()
            .to_comm_config()
            .is_err());
    }

    #[test]
    fn cost_overrides() {
        let cfg = ConfigMap::parse("alpha_base_us = 5\nnic_gbps = 100\n").unwrap();
        let (cost, nic) = cfg.to_cost_model().unwrap();
        assert!((cost.alpha_base - 5e-6).abs() < 1e-12);
        assert_eq!(nic, 100e9);
    }

    #[test]
    fn size_parsing() {
        assert_eq!(parse_bytes("64").unwrap(), 64);
        assert_eq!(parse_bytes("4KiB").unwrap(), 4096);
        assert_eq!(parse_bytes("1M").unwrap(), 1 << 20);
        assert_eq!(parse_bytes("2GiB").unwrap(), 2 << 30);
        assert!(parse_bytes("x").is_err());
        assert!(parse_bytes("4XB").is_err());
    }

    #[test]
    fn env_overlay_wins() {
        std::env::set_var("PATCOL_NRANKS", "99");
        let cfg = ConfigMap::parse("nranks = 4").unwrap().env_overlay(&["nranks"]);
        assert_eq!(cfg.get_usize("nranks").unwrap(), Some(99));
        std::env::remove_var("PATCOL_NRANKS");
    }
}
