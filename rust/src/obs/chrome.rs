//! Chrome trace-event JSON export (Perfetto / `chrome://tracing`).
//!
//! Maps the unified [`Trace`] onto the trace-event format: **pid = rank**,
//! **tid = channel**, so the viewer groups spans rank → channel; metadata
//! events name each track. Span events (`"ph":"X"`) carry the event kind
//! as `name` and a `cat` string that appends the channel's segment/bucket
//! tag and — when a [`crate::sched::compose::Layout`] or
//! [`crate::sched::bucket::BucketLayout`] is supplied — the
//! reduce-scatter/all-gather phase the message belongs to, so Perfetto's
//! category coloring separates phases and buckets visually. Buffer-pool
//! samples export as counter tracks (`"ph":"C"`).
//!
//! Timestamps: trace seconds × 1e6 (the format wants microseconds).

use crate::obs::trace::{Event, EventKind, Trace, SCHEMA_VERSION};
use crate::sched::bucket::BucketLayout;
use crate::sched::compose::Layout;
use crate::util::json::Json;

/// How to label each channel track and classify events into
/// segment/bucket/phase categories.
#[derive(Debug, Clone)]
pub struct ChannelTags {
    tags: Vec<String>,
    mode: TagMode,
}

#[derive(Debug, Clone)]
enum TagMode {
    Plain,
    Composed(Layout),
    Bucketed(BucketLayout),
}

impl ChannelTags {
    /// No extra structure: channels are just channels.
    pub fn plain() -> ChannelTags {
        ChannelTags { tags: Vec::new(), mode: TagMode::Plain }
    }

    /// Composed all-reduce: channel `k` carries pipeline segment `k`;
    /// events additionally classify into rs/ag phases by (step, chunk).
    pub fn composed(layout: Layout) -> ChannelTags {
        let tags = (0..layout.segments).map(|s| format!("seg{s}")).collect();
        ChannelTags { tags, mode: TagMode::Composed(layout) }
    }

    /// Bucketed batch: channel `channel_base_b + s` carries bucket `b`'s
    /// segment `s`.
    pub fn bucketed(layout: BucketLayout) -> ChannelTags {
        let mut tags = Vec::with_capacity(layout.channels());
        for b in 0..layout.nbuckets() {
            let (lo, hi) = layout.channel_range(b);
            for k in lo..hi {
                tags.push(format!("bucket{b}/seg{}", k - lo));
            }
        }
        ChannelTags { tags, mode: TagMode::Bucketed(layout) }
    }

    /// Track label for channel `k` (`None` when untagged).
    pub fn tag(&self, channel: usize) -> Option<&str> {
        self.tags.get(channel).map(|s| s.as_str())
    }

    /// Phase ("reduce-scatter" / "all-gather") of a message event, when
    /// the tag mode carries a step grid to classify against.
    fn phase_of(&self, ev: &Event) -> Option<&'static str> {
        let chunk = ev.chunk0?;
        match &self.mode {
            TagMode::Plain => None,
            TagMode::Composed(layout) => {
                let (_, phase) = layout.classify(ev.step, chunk);
                Some(phase.as_str())
            }
            TagMode::Bucketed(layout) => {
                let b = layout.bucket_of_chunk(chunk);
                let local_step = ev.step.saturating_sub(layout.step_base[b]);
                let local_chunk = chunk - layout.chunk_base[b];
                let (_, phase) = layout.per_bucket[b].classify(local_step, local_chunk);
                Some(phase.as_str())
            }
        }
    }

    /// The `cat` string for an event: kind, channel tag, phase.
    fn cat(&self, ev: &Event) -> String {
        let mut cat = ev.kind.name().to_string();
        if let Some(tag) = self.tag(ev.channel) {
            cat.push(',');
            cat.push_str(tag);
        }
        if let Some(phase) = self.phase_of(ev) {
            cat.push(',');
            cat.push_str(phase);
        }
        cat
    }
}

fn usecs(t: f64) -> f64 {
    t * 1e6
}

/// Export a [`Trace`] as a Chrome trace-event JSON document (object form,
/// with `traceEvents` plus a `schema_version` stamp in `otherData`).
pub fn chrome_trace(trace: &Trace, tags: &ChannelTags) -> Json {
    let mut events: Vec<Json> = Vec::with_capacity(trace.events.len() + 2 * trace.counters.len());

    // Track-naming metadata: one process per rank, one thread per channel.
    let mut ranks: Vec<usize> = trace.counters.keys().map(|&(r, _)| r).collect();
    ranks.dedup();
    for &r in &ranks {
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_name")),
            ("pid", Json::num(r as f64)),
            ("args", Json::obj(vec![("name", Json::str(format!("rank {r}")))])),
        ]));
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("process_sort_index")),
            ("pid", Json::num(r as f64)),
            ("args", Json::obj(vec![("sort_index", Json::num(r as f64))])),
        ]));
    }
    for &(r, k) in trace.counters.keys() {
        let label = match tags.tag(k) {
            Some(t) => format!("ch{k} [{t}]"),
            None => format!("ch{k}"),
        };
        events.push(Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(r as f64)),
            ("tid", Json::num(k as f64)),
            ("args", Json::obj(vec![("name", Json::str(label))])),
        ]));
    }

    for ev in &trace.events {
        if ev.kind == EventKind::Pool {
            // Counter track: live buffer-pool slots over time.
            events.push(Json::obj(vec![
                ("ph", Json::str("C")),
                ("name", Json::str("pool live slots")),
                ("pid", Json::num(ev.rank as f64)),
                ("tid", Json::num(ev.channel as f64)),
                ("ts", Json::num(usecs(ev.t_start))),
                ("args", Json::obj(vec![("live", Json::num(ev.value as f64))])),
            ]));
            continue;
        }
        let mut args = vec![("step", Json::num(ev.step as f64))];
        if let Some(p) = ev.peer {
            args.push(("peer", Json::num(p as f64)));
        }
        if ev.chunks > 0 {
            args.push(("chunks", Json::num(ev.chunks as f64)));
        }
        if let Some(c0) = ev.chunk0 {
            args.push(("chunk0", Json::num(c0 as f64)));
        }
        if ev.bytes > 0 {
            args.push(("bytes", Json::num(ev.bytes as f64)));
        }
        events.push(Json::obj(vec![
            ("ph", Json::str("X")),
            ("name", Json::str(ev.kind.name())),
            ("cat", Json::str(tags.cat(ev))),
            ("pid", Json::num(ev.rank as f64)),
            ("tid", Json::num(ev.channel as f64)),
            ("ts", Json::num(usecs(ev.t_start))),
            ("dur", Json::num(usecs(ev.duration()))),
            ("args", Json::Obj(args.into_iter().map(|(k, v)| (k.to_string(), v)).collect())),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
        (
            "otherData",
            Json::obj(vec![
                ("schema_version", Json::num(SCHEMA_VERSION as f64)),
                ("generator", Json::str("patcol")),
                ("dropped_events", Json::num(trace.dropped as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceRecorder;
    use crate::util::json;

    fn sample_trace() -> Trace {
        let mut rec = TraceRecorder::new();
        rec.record(
            Event::span(EventKind::SendOp, 0, 0, 0, 0.0, 1e-6)
                .with_peer(1)
                .with_msg(&[2], 8),
        );
        rec.record(
            Event::span(EventKind::Wire, 0, 0, 0, 0.0, 2e-6).with_peer(1).with_msg(&[2], 8),
        );
        rec.record(Event::span(EventKind::Pool, 1, 0, 0, 1e-6, 1e-6).with_value(2));
        rec.finish()
    }

    #[test]
    fn export_roundtrips_through_parser() {
        let doc = chrome_trace(&sample_trace(), &ChannelTags::plain());
        let text = doc.to_string();
        let back = json::parse(&text).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        assert_eq!(
            back.get("otherData").unwrap().get("schema_version").unwrap().as_usize(),
            Some(SCHEMA_VERSION as usize)
        );
        // span and counter phases both present
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("C")));
        // pid/tid grouping: the wire span sits on rank 0 / channel 0
        let wire = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("wire"))
            .unwrap();
        assert_eq!(wire.get("pid").unwrap().as_usize(), Some(0));
        assert_eq!(wire.get("args").unwrap().get("peer").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn composed_tags_classify_phase() {
        let layout = Layout { nranks: 4, segments: 2, rs_steps: 2, ag_steps: 2 };
        let tags = ChannelTags::composed(layout);
        assert_eq!(tags.tag(1), Some("seg1"));
        // segment 0 (chunks 0..4): step 0 is rs, step 2 is ag
        let rs = Event::span(EventKind::Wire, 0, 0, 0, 0.0, 1.0).with_msg(&[1], 4);
        let ag = Event::span(EventKind::Wire, 0, 0, 2, 0.0, 1.0).with_msg(&[1], 4);
        assert_eq!(tags.cat(&rs), "wire,seg0,reduce-scatter");
        assert_eq!(tags.cat(&ag), "wire,seg0,all-gather");
    }
}
