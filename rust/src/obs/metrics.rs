//! Aggregate trace metrics: stall taxonomy, occupancy percentiles, and
//! per-link utilization/contention.
//!
//! Where [`crate::obs::critpath`] explains *the* slowest chain, this
//! module aggregates over *all* of the trace:
//!
//! * **Stall taxonomy** — per (rank, channel), blocked-on-receive time
//!   split into `warmup` (stalls resolved before that stream completed
//!   its first receive: the pipeline fill, expected and benign) and
//!   `steady` (stalls after the pipeline was primed: skew or
//!   contention, the thing ROADMAP's arrival-skew work needs blamed
//!   per rank). Every (rank, channel) the trace knows gets a row with
//!   *both* classes, zero-valued when unseen, so the key set is a
//!   schema property of the program rather than of one run's timing —
//!   the cross-executor test depends on this.
//! * **Occupancy percentiles** — p50/p90/p99/max over the buffer-pool
//!   slot samples and the arena byte samples (transport side; `None`
//!   when the trace has no such counter samples, e.g. simulator runs).
//! * **Link stats** ([`LinkStat`]) — per-link bytes, busy seconds,
//!   contended seconds (serialization delayed behind earlier flows),
//!   and utilization. Produced by the simulator (the transport has no
//!   fabric model); attach with [`MetricsReport::with_links`].

use std::collections::BTreeMap;

use crate::core::Rank;
use crate::obs::trace::{EventKind, Trace};
use crate::util::json::Json;

/// Per-link traffic accounting (simulator side; see
/// `SimReport::link_stats`).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkStat {
    /// Link index in the topology's link table.
    pub link: usize,
    /// Total bytes serialized onto the link.
    pub bytes: usize,
    /// Seconds the link spent serializing.
    pub busy_s: f64,
    /// Seconds messages waited for this link to free up before starting
    /// to serialize — the fabric-contention signal.
    pub contended_s: f64,
    /// `busy_s` / run elapsed.
    pub utilization: f64,
}

impl LinkStat {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("link", Json::num(self.link as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("busy_s", Json::num(self.busy_s)),
            ("contended_s", Json::num(self.contended_s)),
            ("utilization", Json::num(self.utilization)),
        ])
    }
}

/// Per-fabric-tier roll-up of [`LinkStat`] (simulator side; see
/// `SimReport::level_link_stats`). Level 0 = NIC links, 1 = leaf↔spine,
/// 2 = spine↔core — the tier axis on which taper bites, so a three-level
/// schedule's claim ("traffic stays low in the tree") is checkable as
/// one row per tier instead of hundreds of per-link rows.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LevelLinkStat {
    /// Fabric tier (the topology's `Link::level`).
    pub level: usize,
    /// Links in this tier.
    pub links: usize,
    /// Total bytes serialized across the tier.
    pub bytes: usize,
    /// Total busy seconds across the tier's links.
    pub busy_s: f64,
    /// Total contended seconds across the tier's links.
    pub contended_s: f64,
    /// Busiest link's utilization within the tier.
    pub max_utilization: f64,
}

impl LevelLinkStat {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("level", Json::num(self.level as f64)),
            ("links", Json::num(self.links as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("busy_s", Json::num(self.busy_s)),
            ("contended_s", Json::num(self.contended_s)),
            ("max_utilization", Json::num(self.max_utilization)),
        ])
    }
}

/// Blocked-on-receive seconds for one (rank, channel), by class. Both
/// classes are always present (see module docs).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallTaxonomy {
    /// Stalls resolved before the stream's first receive completed.
    pub warmup_s: f64,
    /// Stalls after the pipeline was primed.
    pub steady_s: f64,
}

impl StallTaxonomy {
    /// The fixed class vocabulary, in reporting order.
    pub const CLASSES: [&'static str; 2] = ["warmup", "steady"];

    pub fn total(&self) -> f64 {
        self.warmup_s + self.steady_s
    }
}

/// Occupancy percentiles over counter samples ([`EventKind::Pool`] /
/// [`EventKind::Arena`]).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OccupancyStats {
    pub samples: usize,
    pub p50: usize,
    pub p90: usize,
    pub p99: usize,
    pub max: usize,
}

impl OccupancyStats {
    fn from_samples(mut vals: Vec<usize>) -> Option<OccupancyStats> {
        if vals.is_empty() {
            return None;
        }
        vals.sort_unstable();
        // Nearest-rank percentile: smallest value with at least p% of the
        // samples at or below it.
        let pct = |p: f64| {
            let idx = (p / 100.0 * vals.len() as f64).ceil() as usize;
            vals[idx.saturating_sub(1).min(vals.len() - 1)]
        };
        Some(OccupancyStats {
            samples: vals.len(),
            p50: pct(50.0),
            p90: pct(90.0),
            p99: pct(99.0),
            max: *vals.last().unwrap(),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("samples", Json::num(self.samples as f64)),
            ("p50", Json::num(self.p50 as f64)),
            ("p90", Json::num(self.p90 as f64)),
            ("p99", Json::num(self.p99 as f64)),
            ("max", Json::num(self.max as f64)),
        ])
    }
}

/// The aggregate metrics of one trace (see module docs).
#[derive(Debug, Clone, Default)]
pub struct MetricsReport {
    /// Stall taxonomy per (rank, channel) — a row for every (rank,
    /// channel) the trace's counters know, both classes always present.
    pub stalls: BTreeMap<(Rank, usize), StallTaxonomy>,
    /// Buffer-pool occupancy percentiles (slots); `None` without
    /// pool samples (simulator traces).
    pub pool: Option<OccupancyStats>,
    /// Arena occupancy percentiles (bytes); `None` without arena
    /// samples (simulator and pre-v3 traces).
    pub arena: Option<OccupancyStats>,
    /// Per-link stats, when a simulator report supplied them.
    pub links: Vec<LinkStat>,
}

impl MetricsReport {
    /// Attach the simulator's per-link stats.
    pub fn with_links(mut self, links: &[LinkStat]) -> MetricsReport {
        self.links = links.to_vec();
        self
    }

    /// Total stall seconds across all (rank, channel) rows.
    pub fn stall_total(&self) -> f64 {
        self.stalls.values().map(|s| s.total()).sum()
    }

    pub fn to_json(&self) -> Json {
        let stalls: Vec<Json> = self
            .stalls
            .iter()
            .map(|(&(r, k), s)| {
                Json::obj(vec![
                    ("rank", Json::num(r as f64)),
                    ("channel", Json::num(k as f64)),
                    ("warmup_s", Json::num(s.warmup_s)),
                    ("steady_s", Json::num(s.steady_s)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("stalls", Json::Arr(stalls)),
            (
                "pool_occupancy",
                self.pool.map(|o| o.to_json()).unwrap_or(Json::Null),
            ),
            (
                "arena_occupancy",
                self.arena.map(|o| o.to_json()).unwrap_or(Json::Null),
            ),
            (
                "links",
                Json::Arr(self.links.iter().map(|l| l.to_json()).collect()),
            ),
        ])
    }
}

/// Aggregate `trace` into a [`MetricsReport`] (link stats, if any, are
/// attached separately with [`MetricsReport::with_links`]).
pub fn metrics(trace: &Trace) -> MetricsReport {
    // First completed receive per (rank, channel): the warmup boundary.
    let mut first_recv_end: BTreeMap<(Rank, usize), f64> = BTreeMap::new();
    for e in &trace.events {
        if e.kind == EventKind::RecvOp {
            let v = first_recv_end.entry((e.rank, e.channel)).or_insert(f64::INFINITY);
            *v = v.min(e.t_end);
        }
    }

    // Every (rank, channel) the counters know gets a taxonomy row.
    let mut stalls: BTreeMap<(Rank, usize), StallTaxonomy> = trace
        .counters
        .keys()
        .map(|&k| (k, StallTaxonomy::default()))
        .collect();
    let mut pool_samples = Vec::new();
    let mut arena_samples = Vec::new();
    for e in &trace.events {
        match e.kind {
            EventKind::Stall => {
                let boundary = first_recv_end
                    .get(&(e.rank, e.channel))
                    .copied()
                    .unwrap_or(f64::INFINITY);
                let row = stalls.entry((e.rank, e.channel)).or_default();
                if e.t_end <= boundary {
                    row.warmup_s += e.duration();
                } else {
                    row.steady_s += e.duration();
                }
            }
            EventKind::Pool => pool_samples.push(e.value),
            EventKind::Arena => arena_samples.push(e.value),
            _ => {}
        }
    }

    MetricsReport {
        stalls,
        pool: OccupancyStats::from_samples(pool_samples),
        arena: OccupancyStats::from_samples(arena_samples),
        links: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{Event, TraceRecorder};

    #[test]
    fn stalls_classify_warmup_vs_steady() {
        let mut rec = TraceRecorder::new();
        use EventKind::*;
        // first recv on (0,0) completes at t=2; the stall before it is
        // warmup, the one after is steady.
        rec.record(Event::span(Stall, 0, 0, 0, 0.0, 1.0));
        rec.record(Event::span(RecvOp, 0, 0, 0, 1.0, 2.0).with_peer(1).with_bytes(8));
        rec.record(Event::span(Stall, 0, 0, 1, 3.0, 3.25));
        rec.record(Event::span(RecvOp, 0, 0, 1, 3.25, 4.0).with_peer(1).with_bytes(8));
        // (1,0) emits traffic but never stalls: zero-valued row expected.
        rec.record(Event::span(SendOp, 1, 0, 0, 0.0, 0.5).with_peer(0).with_bytes(8));
        let m = metrics(&rec.finish());
        let s00 = m.stalls[&(0, 0)];
        assert!((s00.warmup_s - 1.0).abs() < 1e-12);
        assert!((s00.steady_s - 0.25).abs() < 1e-12);
        let s10 = m.stalls[&(1, 0)];
        assert_eq!(s10, StallTaxonomy::default(), "stall-free row still present");
        assert!((m.stall_total() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn occupancy_percentiles_over_counter_samples() {
        let mut rec = TraceRecorder::new();
        for (i, live) in (1..=100).enumerate() {
            let t = i as f64;
            rec.record(Event::span(EventKind::Pool, 0, 0, i, t, t).with_value(live));
            rec.record(
                Event::span(EventKind::Arena, 0, 0, i, t, t).with_value(live * 1024),
            );
        }
        let m = metrics(&rec.finish());
        let pool = m.pool.expect("pool samples");
        assert_eq!(pool.samples, 100);
        assert_eq!(pool.p50, 50);
        assert_eq!(pool.p90, 90);
        assert_eq!(pool.p99, 99);
        assert_eq!(pool.max, 100);
        let arena = m.arena.expect("arena samples");
        assert_eq!(arena.max, 100 * 1024);
        assert_eq!(arena.p50, 50 * 1024);
    }

    #[test]
    fn counterless_trace_has_no_occupancy() {
        let mut rec = TraceRecorder::new();
        rec.record(Event::span(EventKind::SendOp, 0, 0, 0, 0.0, 1.0).with_bytes(8));
        let m = metrics(&rec.finish());
        assert!(m.pool.is_none());
        assert!(m.arena.is_none());
        assert!(m.links.is_empty());
        let j = m.to_json();
        assert_eq!(j.get("pool_occupancy"), Some(&Json::Null));
    }

    #[test]
    fn json_carries_link_stats() {
        let m = metrics(&Trace::default()).with_links(&[LinkStat {
            link: 3,
            bytes: 4096,
            busy_s: 0.5,
            contended_s: 0.1,
            utilization: 0.25,
        }]);
        let j = m.to_json();
        let links = j.get("links").unwrap().as_arr().unwrap();
        assert_eq!(links.len(), 1);
        assert_eq!(links[0].get("link").unwrap().as_usize(), Some(3));
        assert_eq!(links[0].get("bytes").unwrap().as_usize(), Some(4096));
    }
}
