//! Rank placement: the rank → node (→ pod) mapping hierarchical schedules
//! are built from.
//!
//! A *node* models a set of ranks with cheap mutual communication (one
//! machine's NVLink domain, or one leaf switch of a fat-tree). Node sizes
//! may be uneven — 13 ranks on nodes of 4 places them as `[4, 4, 4, 1]` —
//! which is exactly the shape elastic / partially-drained training jobs
//! produce. The first rank of each node is its *leader*: the rank that
//! participates in the inter-node phase of a hierarchical schedule
//! ([`crate::sched::hier`]).
//!
//! Two extensions generalize the two-level picture:
//!
//! * **Multiple leaders per node** ([`Placement::with_leaders`]): the
//!   inter-node phase is striped across the first `L` ranks of every node,
//!   each stripe leader owning a chunk stripe and its own channel (ECMP
//!   salt). `L` is clamped to the smallest node size at use
//!   ([`Placement::effective_leaders`]).
//! * **Pods** ([`Placement::with_pods`], [`Placement::from_pod_sizes`]):
//!   contiguous groups of nodes forming a third hierarchy level
//!   (leaf/pod/fabric); hierarchical schedules then recurse — intra-node,
//!   intra-pod PAT, inter-pod PAT.
//!
//! ## Spelling (config / CLI grammar)
//!
//! * `uniform:<k>` — contiguous nodes of `k` ranks, last node takes the
//!   remainder (`uniform:4` over 13 ranks → `[4, 4, 4, 1]`).
//! * `<k>` — shorthand for `uniform:<k>`.
//! * `<k1>,<k2>,...` — explicit node sizes; must sum to the rank count
//!   (`4,4,5` over 13 ranks).
//! * `<k>x<m>` / `uniform:<k>x<m>` — three-level: uniform nodes of `k`
//!   ranks grouped into pods of `m` nodes (last pod takes the remainder).
//! * `<sizes>;<sizes>;...` — three-level with explicit pods: each `;`
//!   group is one pod's comma-separated node sizes (`4,4;4,1` = two pods).

use crate::core::{Error, Rank, Result};

/// A rank → node mapping with (possibly uneven) contiguous nodes, an
/// optional pod grouping (third level), and a leaders-per-node stripe
/// count for the inter-node phase.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `node_of[r]` is the node id of rank `r` (node ids are dense).
    node_of: Vec<usize>,
    /// `nodes[m]` is node `m`'s rank list, ascending; `nodes[m][0]` is the
    /// leader.
    nodes: Vec<Vec<Rank>>,
    /// Requested inter-node stripe leaders per node (>= 1). Clamped to the
    /// smallest node size when schedules are built; see
    /// [`Placement::effective_leaders`].
    leaders: usize,
    /// `pods[p]` is pod `p`'s node-id list (contiguous, covering every
    /// node). Empty means two-level (no pod grouping).
    pods: Vec<Vec<usize>>,
}

impl Placement {
    /// Build from explicit node sizes; ranks are assigned contiguously.
    pub fn from_node_sizes(sizes: &[usize]) -> Result<Placement> {
        if sizes.is_empty() {
            return Err(Error::Config("placement needs at least one node".into()));
        }
        if sizes.iter().any(|&s| s == 0) {
            return Err(Error::Config("placement node sizes must be >= 1".into()));
        }
        let nranks: usize = sizes.iter().sum();
        let mut node_of = Vec::with_capacity(nranks);
        let mut nodes = Vec::with_capacity(sizes.len());
        let mut next = 0usize;
        for (m, &s) in sizes.iter().enumerate() {
            nodes.push((next..next + s).collect());
            for _ in 0..s {
                node_of.push(m);
            }
            next += s;
        }
        Ok(Placement { node_of, nodes, leaders: 1, pods: Vec::new() })
    }

    /// Build a three-level placement from explicit per-pod node sizes:
    /// `pod_sizes[p]` lists pod `p`'s node sizes (`[[4,4],[4,1]]` = two
    /// pods, the second with an uneven tail node).
    pub fn from_pod_sizes(pod_sizes: &[Vec<usize>]) -> Result<Placement> {
        if pod_sizes.is_empty() || pod_sizes.iter().any(Vec::is_empty) {
            return Err(Error::Config("placement pods need at least one node each".into()));
        }
        let flat: Vec<usize> = pod_sizes.iter().flatten().copied().collect();
        let pl = Self::from_node_sizes(&flat)?;
        pl.with_pods_grouped(&pod_sizes.iter().map(Vec::len).collect::<Vec<_>>())
    }

    /// Contiguous nodes of `ranks_per_node`; when it does not divide
    /// `nranks` the last node takes the remainder (uneven tail), and
    /// `ranks_per_node > nranks` yields a single node — callers never need
    /// to pre-clamp.
    pub fn uniform(nranks: usize, ranks_per_node: usize) -> Result<Placement> {
        if nranks == 0 {
            return Err(Error::Config("placement needs at least one rank".into()));
        }
        if ranks_per_node == 0 {
            return Err(Error::Config("ranks_per_node must be >= 1".into()));
        }
        let full = nranks / ranks_per_node;
        let rem = nranks % ranks_per_node;
        let mut sizes = vec![ranks_per_node; full];
        if rem > 0 {
            sizes.push(rem);
        }
        Self::from_node_sizes(&sizes)
    }

    /// Every rank on its own node (degenerates hierarchical schedules to
    /// their flat inter-node algorithm).
    pub fn singletons(nranks: usize) -> Result<Placement> {
        Self::uniform(nranks, 1)
    }

    /// Set the requested inter-node stripe leader count (>= 1). The value
    /// is stored as requested; schedules clamp it to the smallest node
    /// size via [`Placement::effective_leaders`].
    pub fn with_leaders(mut self, leaders: usize) -> Result<Placement> {
        if leaders == 0 {
            return Err(Error::Config("leaders_per_node must be >= 1".into()));
        }
        self.leaders = leaders;
        Ok(self)
    }

    /// Group nodes into contiguous pods of `nodes_per_pod` nodes each (the
    /// last pod takes the remainder), turning a two-level placement into a
    /// three-level one.
    pub fn with_pods(self, nodes_per_pod: usize) -> Result<Placement> {
        if nodes_per_pod == 0 {
            return Err(Error::Config("nodes_per_pod must be >= 1".into()));
        }
        let nn = self.nnodes();
        let mut groups = Vec::new();
        let mut m = 0;
        while m < nn {
            groups.push(nodes_per_pod.min(nn - m));
            m += nodes_per_pod;
        }
        self.with_pods_grouped(&groups)
    }

    /// Group nodes into contiguous pods with explicit node counts; the
    /// counts must sum to the node count.
    pub fn with_pods_grouped(mut self, nodes_per_pod: &[usize]) -> Result<Placement> {
        let total: usize = nodes_per_pod.iter().sum();
        if nodes_per_pod.is_empty() || nodes_per_pod.iter().any(|&g| g == 0) {
            return Err(Error::Config("placement pods need at least one node each".into()));
        }
        if total != self.nnodes() {
            return Err(Error::Config(format!(
                "placement pod node counts sum to {total}, expected nnodes={}",
                self.nnodes()
            )));
        }
        let mut pods = Vec::with_capacity(nodes_per_pod.len());
        let mut next = 0usize;
        for &g in nodes_per_pod {
            pods.push((next..next + g).collect());
            next += g;
        }
        self.pods = pods;
        Ok(self)
    }

    /// Parse the config/CLI grammar (see module docs) for `nranks` ranks.
    pub fn parse(spec: &str, nranks: usize) -> Result<Placement> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Err(Error::Config("empty placement spec".into()));
        }
        // `<sizes>;<sizes>;...` — explicit pods of comma-separated node
        // sizes.
        if spec.contains(';') {
            let pods: Result<Vec<Vec<usize>>> = spec
                .split(';')
                .map(|group| {
                    group
                        .split(',')
                        .map(|t| {
                            t.trim().parse::<usize>().map_err(|_| {
                                Error::Config(format!("placement: bad node size {t:?}"))
                            })
                        })
                        .collect()
                })
                .collect();
            let pods = pods?;
            let total: usize = pods.iter().flatten().sum();
            if total != nranks {
                return Err(Error::Config(format!(
                    "placement sizes sum to {total}, expected nranks={nranks}"
                )));
            }
            return Self::from_pod_sizes(&pods);
        }
        let parse_k = |rest: &str| -> Result<(usize, Option<usize>)> {
            // `<k>` or `<k>x<m>` (m = nodes per pod).
            let (k, m) = match rest.split_once('x') {
                None => (rest.trim(), None),
                Some((k, m)) => (k.trim(), Some(m.trim())),
            };
            let k: usize = k
                .parse()
                .map_err(|_| Error::Config(format!("placement: bad node size {k:?}")))?;
            let m = match m {
                None => None,
                Some(m) => Some(m.parse::<usize>().map_err(|_| {
                    Error::Config(format!("placement: bad nodes-per-pod {m:?}"))
                })?),
            };
            Ok((k, m))
        };
        if let Some(rest) = spec.strip_prefix("uniform:") {
            let (k, m) = parse_k(rest)?;
            let pl = Self::uniform(nranks, k)?;
            return match m {
                None => Ok(pl),
                Some(m) => pl.with_pods(m),
            };
        }
        if spec.contains(',') {
            let sizes: Result<Vec<usize>> = spec
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| Error::Config(format!("placement: bad node size {t:?}")))
                })
                .collect();
            let sizes = sizes?;
            let total: usize = sizes.iter().sum();
            if total != nranks {
                return Err(Error::Config(format!(
                    "placement sizes sum to {total}, expected nranks={nranks}"
                )));
            }
            return Self::from_node_sizes(&sizes);
        }
        let (k, m) = parse_k(spec)?;
        let pl = Self::uniform(nranks, k)?;
        match m {
            None => Ok(pl),
            Some(m) => pl.with_pods(m),
        }
    }

    pub fn nranks(&self) -> usize {
        self.node_of.len()
    }

    pub fn nnodes(&self) -> usize {
        self.nodes.len()
    }

    /// Node id of `rank`.
    pub fn node_of(&self, rank: Rank) -> usize {
        self.node_of[rank]
    }

    /// Ranks of `node`, ascending (leader first).
    pub fn ranks_of(&self, node: usize) -> &[Rank] {
        &self.nodes[node]
    }

    /// The leader rank of `node` (its first rank).
    pub fn leader(&self, node: usize) -> Rank {
        self.nodes[node][0]
    }

    pub fn is_leader(&self, rank: Rank) -> bool {
        self.leader(self.node_of(rank)) == rank
    }

    /// Requested stripe leaders per node (as configured, unclamped).
    pub fn leaders_per_node(&self) -> usize {
        self.leaders
    }

    /// Stripe leaders actually usable: the requested count clamped to the
    /// smallest node size (every node must field a leader for each
    /// stripe).
    pub fn effective_leaders(&self) -> usize {
        self.leaders.min(self.min_node_size()).max(1)
    }

    /// The stripe leaders of `node`: its first `effective_leaders()`
    /// ranks.
    pub fn leaders_of(&self, node: usize) -> &[Rank] {
        &self.nodes[node][..self.effective_leaders()]
    }

    /// Whether `rank` is one of its node's stripe leaders (offset within
    /// the node below `effective_leaders()`).
    pub fn is_stripe_leader(&self, rank: Rank) -> bool {
        self.leaders_of(self.node_of(rank)).contains(&rank)
    }

    /// Whether a pod grouping is present (three-level hierarchy).
    pub fn is_three_level(&self) -> bool {
        !self.pods.is_empty()
    }

    /// Pod count (0 when two-level).
    pub fn npods(&self) -> usize {
        self.pods.len()
    }

    /// Node ids of pod `p`, ascending.
    pub fn pod_nodes(&self, pod: usize) -> &[usize] {
        &self.pods[pod]
    }

    /// Pod id of `node` (panics when two-level).
    pub fn pod_of_node(&self, node: usize) -> usize {
        self.pods
            .iter()
            .position(|p| p.contains(&node))
            .expect("node id out of range for pod lookup")
    }

    /// Total rank count of pod `p`.
    pub fn pod_rank_count(&self, pod: usize) -> usize {
        self.pods[pod].iter().map(|&m| self.nodes[m].len()).sum()
    }

    pub fn node_sizes(&self) -> Vec<usize> {
        self.nodes.iter().map(Vec::len).collect()
    }

    pub fn max_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).max().unwrap_or(0)
    }

    pub fn min_node_size(&self) -> usize {
        self.nodes.iter().map(Vec::len).min().unwrap_or(0)
    }

    /// `"nodes=4 sizes=[4, 4, 4, 1]"` — for reports and explain output;
    /// pods and extra leaders are appended when present.
    pub fn describe(&self) -> String {
        let mut s = format!("nodes={} sizes={:?}", self.nnodes(), self.node_sizes());
        if self.is_three_level() {
            s.push_str(&format!(
                " pods={:?}",
                self.pods.iter().map(Vec::len).collect::<Vec<_>>()
            ));
        }
        if self.leaders > 1 {
            s.push_str(&format!(" leaders={}", self.leaders));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_uneven_tail() {
        let p = Placement::uniform(13, 4).unwrap();
        assert_eq!(p.nranks(), 13);
        assert_eq!(p.nnodes(), 4);
        assert_eq!(p.node_sizes(), vec![4, 4, 4, 1]);
        assert_eq!(p.leader(0), 0);
        assert_eq!(p.leader(3), 12);
        assert_eq!(p.node_of(7), 1);
        assert!(p.is_leader(8));
        assert!(!p.is_leader(9));
        assert_eq!(p.max_node_size(), 4);
        assert_eq!(p.min_node_size(), 1);
    }

    #[test]
    fn explicit_sizes() {
        let p = Placement::from_node_sizes(&[4, 4, 5]).unwrap();
        assert_eq!(p.nranks(), 13);
        assert_eq!(p.ranks_of(2), &[8, 9, 10, 11, 12]);
        assert_eq!(p.leader(2), 8);
    }

    #[test]
    fn singletons_degenerate() {
        let p = Placement::singletons(5).unwrap();
        assert_eq!(p.nnodes(), 5);
        assert!((0..5).all(|r| p.is_leader(r)));
    }

    #[test]
    fn parse_grammar() {
        assert_eq!(
            Placement::parse("uniform:4", 13).unwrap().node_sizes(),
            vec![4, 4, 4, 1]
        );
        assert_eq!(Placement::parse("4", 13).unwrap().node_sizes(), vec![4, 4, 4, 1]);
        assert_eq!(
            Placement::parse("4,4,5", 13).unwrap().node_sizes(),
            vec![4, 4, 5]
        );
        // oversized uniform clamps to one node
        assert_eq!(Placement::parse("99", 6).unwrap().nnodes(), 1);
        assert!(Placement::parse("4,4", 13).is_err()); // wrong sum
        assert!(Placement::parse("a,b", 2).is_err());
        assert!(Placement::parse("", 4).is_err());
        assert!(Placement::parse("0", 4).is_err());
    }

    #[test]
    fn parse_three_level_grammar() {
        // uniform nodes grouped into pods: 16 ranks, nodes of 4, pods of 2
        // nodes (uneven last pod absorbed by the `x` grammar's remainder).
        let p = Placement::parse("4x2", 16).unwrap();
        assert!(p.is_three_level());
        assert_eq!(p.npods(), 2);
        assert_eq!(p.pod_nodes(0), &[0, 1]);
        assert_eq!(p.pod_nodes(1), &[2, 3]);
        assert_eq!(p.pod_rank_count(1), 8);
        assert_eq!(p.pod_of_node(3), 1);
        let p = Placement::parse("uniform:4x3", 20).unwrap();
        assert_eq!(p.npods(), 2); // 5 nodes -> pods of [3, 2]
        assert_eq!(p.pod_nodes(1), &[3, 4]);
        // explicit pods with uneven nodes
        let p = Placement::parse("4,4;4,1", 13).unwrap();
        assert_eq!(p.npods(), 2);
        assert_eq!(p.node_sizes(), vec![4, 4, 4, 1]);
        assert_eq!(p.pod_nodes(1), &[2, 3]);
        assert!(Placement::parse("4,4;4", 13).is_err()); // wrong sum
        assert!(Placement::parse("4x0", 16).is_err());
    }

    #[test]
    fn leaders_clamped_to_min_node() {
        let p = Placement::uniform(13, 4).unwrap().with_leaders(2).unwrap();
        assert_eq!(p.leaders_per_node(), 2);
        // min node size is 1 (the tail node) so only one stripe survives
        assert_eq!(p.effective_leaders(), 1);
        let p = Placement::uniform(16, 4).unwrap().with_leaders(2).unwrap();
        assert_eq!(p.effective_leaders(), 2);
        assert_eq!(p.leaders_of(1), &[4, 5]);
        assert!(p.is_stripe_leader(5));
        assert!(!p.is_stripe_leader(6));
        assert!(Placement::uniform(8, 4).unwrap().with_leaders(0).is_err());
        // requesting more leaders than ranks per node clamps
        let p = Placement::uniform(8, 4).unwrap().with_leaders(99).unwrap();
        assert_eq!(p.effective_leaders(), 4);
    }

    #[test]
    fn describe_mentions_pods_and_leaders() {
        let p = Placement::parse("4x2", 16).unwrap().with_leaders(2).unwrap();
        let d = p.describe();
        assert!(d.contains("pods=[2, 2]"), "{d}");
        assert!(d.contains("leaders=2"), "{d}");
        let d = Placement::uniform(8, 4).unwrap().describe();
        assert!(!d.contains("pods"), "{d}");
        assert!(!d.contains("leaders"), "{d}");
    }

    #[test]
    fn invalid_rejected() {
        assert!(Placement::from_node_sizes(&[]).is_err());
        assert!(Placement::from_node_sizes(&[2, 0]).is_err());
        assert!(Placement::uniform(0, 4).is_err());
        assert!(Placement::uniform(8, 0).is_err());
        assert!(Placement::from_pod_sizes(&[]).is_err());
        assert!(Placement::uniform(8, 4).unwrap().with_pods_grouped(&[1]).is_err());
    }
}
