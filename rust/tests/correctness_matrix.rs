//! P4 + global correctness matrix: every algorithm × collective × rank
//! count in its domain, through the reference executor AND the real
//! threaded transport, including primes and other awkward counts (paper
//! Fig. 4 / the "any number of ranks" claim) — plus the hierarchical axis
//! (HierPat × collectives × rank counts × node sizes, uneven included).

use patcol::core::{Algorithm, Collective, PhaseAlg, Placement};
use patcol::sched::bucket::{self, BucketLayout, BucketPhases};
use patcol::sched::{self, verify::verify_program};
use patcol::sim::{simulate, CostModel, SimReport, Topology};
use patcol::transport::{
    run_allgather, run_allreduce, run_allreduce_batch, run_reduce_scatter, TransportOptions,
};
use patcol::util::Rng;

fn algorithms() -> Vec<Algorithm> {
    vec![
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
        Algorithm::BruckFarFirst,
        Algorithm::Recursive,
        Algorithm::Pat { aggregation: 1 },
        Algorithm::Pat { aggregation: 2 },
        Algorithm::Pat { aggregation: 3 },
        Algorithm::Pat { aggregation: 5 },
        Algorithm::Pat { aggregation: 8 },
        Algorithm::Pat { aggregation: usize::MAX },
    ]
}

/// Reference-executor matrix over all n in [1, 64].
#[test]
fn verifier_matrix_to_64() {
    for n in 1..=64usize {
        for alg in algorithms() {
            if !alg.supports(n) {
                continue;
            }
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let p = sched::generate(alg, coll, n).unwrap();
                verify_program(&p)
                    .unwrap_or_else(|e| panic!("{alg} {coll} n={n}: {e}"));
            }
        }
    }
}

/// Real-byte transport on a spread of counts including primes.
#[test]
fn transport_matrix_primes_and_powers() {
    let opts = TransportOptions::default();
    for n in [2usize, 3, 5, 7, 8, 11, 13, 16, 17, 19, 23] {
        let chunk = 24;
        let mut rng = Rng::new(n as u64 * 31);
        for alg in algorithms() {
            if !alg.supports(n) {
                continue;
            }
            // all-gather
            let ag = sched::generate(alg, Collective::AllGather, n).unwrap();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let mut want = Vec::new();
            for i in &inputs {
                want.extend_from_slice(i);
            }
            let (outs, _) = run_allgather(&ag, &inputs, &opts)
                .unwrap_or_else(|e| panic!("{alg} ag n={n}: {e}"));
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &want, "{alg} ag n={n} rank={r}");
            }
            // reduce-scatter
            let rs = sched::generate(alg, Collective::ReduceScatter, n).unwrap();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (outs, _) = run_reduce_scatter(&rs, &inputs, &opts)
                .unwrap_or_else(|e| panic!("{alg} rs n={n}: {e}"));
            for r in 0..n {
                for i in 0..chunk {
                    let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                    assert_eq!(outs[r][i], w, "{alg} rs n={n} rank={r} idx={i}");
                }
            }
        }
    }
}

/// Property-style randomized sweep: random (n, aggregation, chunk) triples,
/// deterministic seed, both collectives, exact results.
#[test]
fn randomized_pat_cases() {
    let mut rng = Rng::new(0xFADE);
    let opts = TransportOptions::default();
    for case in 0..60 {
        let n = rng.range(1, 40);
        let a = match rng.below(4) {
            0 => 1,
            1 => rng.range(1, n.max(2)),
            2 => rng.range(1, 8),
            _ => usize::MAX,
        };
        let chunk = [1usize, 3, 8, 17][rng.below(4)];
        let ag = patcol::sched::pat::allgather(n, a);
        verify_program(&ag).unwrap_or_else(|e| panic!("case {case} n={n} a={a}: {e}"));
        let rs = patcol::sched::pat::reduce_scatter(n, a);
        verify_program(&rs).unwrap_or_else(|e| panic!("case {case} rs n={n} a={a}: {e}"));

        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..n * chunk).map(|_| rng.below(256) as f32).collect())
            .collect();
        let (outs, _) = run_reduce_scatter(&rs, &inputs, &opts).unwrap();
        for r in 0..n {
            for i in 0..chunk {
                let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                assert_eq!(outs[r][i], w, "case {case} n={n} a={a} rank={r}");
            }
        }
    }
}

/// Degenerate shapes: 1 rank (no-op), 2 ranks, empty chunks.
#[test]
fn degenerate_cases() {
    let opts = TransportOptions::default();
    // one rank: identity
    let p = patcol::sched::pat::allgather(1, 1);
    let (outs, rep) = run_allgather(&p, &[vec![5.0, 6.0]], &opts).unwrap();
    assert_eq!(outs[0], vec![5.0, 6.0]);
    assert_eq!(rep.messages, 0);

    let p = patcol::sched::pat::reduce_scatter(1, 1);
    let (outs, _) = run_reduce_scatter(&p, &[vec![7.0]], &opts).unwrap();
    assert_eq!(outs[0], vec![7.0]);

    // zero-length chunks move no bytes but complete
    let p = patcol::sched::pat::allgather(4, 2);
    let inputs: Vec<Vec<f32>> = (0..4).map(|_| vec![]).collect();
    let (outs, rep) = run_allgather(&p, &inputs, &opts).unwrap();
    assert!(outs.iter().all(|o| o.is_empty()));
    assert_eq!(rep.bytes_moved, 0);
}

/// The generation front-end rejects unsupported combinations cleanly.
#[test]
fn unsupported_combinations() {
    assert!(sched::generate(Algorithm::Recursive, Collective::AllGather, 12).is_err());
    assert!(sched::generate(Algorithm::PatAuto, Collective::AllGather, 8).is_err());
    assert!(sched::generate(Algorithm::Ring, Collective::AllGather, 0).is_err());
}

/// Hierarchical axis of the matrix: HierPat × {AG, RS} × every rank count
/// in [2, 64] × node sizes {1, 2, 4, 5, 8} (uneven tails included, e.g.
/// 13 ranks on nodes of 4), verified through the reference executor with
/// buffer-occupancy bounds: any valid AG delivers each foreign chunk
/// exactly once (n(n-1) chunk transfers); hierarchical staging peaks at
/// the leader, which relays everything for its node — at most n-1 staged
/// chunks for AG (its own chunk is never staged) and at most n live
/// accumulators for RS (it briefly holds a partial sum for every chunk
/// between the fan-in and inter-node phases).
#[test]
fn hier_matrix_to_64() {
    for n in 2..=64usize {
        for &k in &[1usize, 2, 4, 5, 8] {
            let pl = Placement::uniform(n, k.min(n)).unwrap();
            for &a in &[2usize, usize::MAX] {
                for coll in [Collective::AllGather, Collective::ReduceScatter] {
                    let p = sched::generate_placed(
                        Algorithm::HierPat { aggregation: a },
                        coll,
                        &pl,
                    )
                    .unwrap();
                    let occ = verify_program(&p)
                        .unwrap_or_else(|e| panic!("hier {coll} n={n} k={k} a={a}: {e}"));
                    let bound = match coll {
                        Collective::AllGather => n - 1,
                        _ => n,
                    };
                    assert!(
                        occ.peak_slots <= bound,
                        "hier {coll} n={n} k={k} a={a}: peak {} > {bound}",
                        occ.peak_slots
                    );
                    assert_eq!(
                        p.stats().chunk_transfers,
                        n * (n - 1),
                        "hier {coll} n={n} k={k} a={a}"
                    );
                }
            }
        }
    }
}

/// Hierarchical schedules through the real threaded transport: exact
/// results for both collectives on uneven placements.
#[test]
fn hier_transport_end_to_end() {
    let opts = TransportOptions::default();
    for (n, k) in [(8usize, 4usize), (13, 4), (16, 5), (9, 3), (12, 8)] {
        let pl = Placement::uniform(n, k).unwrap();
        let chunk = 16;
        let mut rng = Rng::new((n * 100 + k) as u64);
        for a in [1usize, 2, usize::MAX] {
            let alg = Algorithm::HierPat { aggregation: a };
            let ag = sched::generate_placed(alg, Collective::AllGather, &pl).unwrap();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let mut want = Vec::new();
            for i in &inputs {
                want.extend_from_slice(i);
            }
            let (outs, _) = run_allgather(&ag, &inputs, &opts)
                .unwrap_or_else(|e| panic!("hier ag n={n} k={k} a={a}: {e}"));
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &want, "hier ag n={n} k={k} a={a} rank={r}");
            }

            let rs = sched::generate_placed(alg, Collective::ReduceScatter, &pl).unwrap();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (outs, _) = run_reduce_scatter(&rs, &inputs, &opts)
                .unwrap_or_else(|e| panic!("hier rs n={n} k={k} a={a}: {e}"));
            for r in 0..n {
                for i in 0..chunk {
                    let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                    assert_eq!(outs[r][i], w, "hier rs n={n} k={k} a={a} rank={r} idx={i}");
                }
            }
        }
    }
}

/// Mirror involution and verifier agreement across every generator:
/// `mirror` is its own inverse (`mirror∘mirror == id`, field-for-field),
/// and both orientations of every program pass the reference executor.
#[test]
fn mirror_involution_across_generators() {
    let pl = Placement::uniform(13, 4).unwrap();
    let pl9 = Placement::from_node_sizes(&[4, 1, 4]).unwrap();
    let programs = vec![
        patcol::sched::ring::allgather(6),
        patcol::sched::bruck::allgather_near_first(9),
        patcol::sched::bruck::allgather_far_first(8),
        patcol::sched::recursive::allgather(8),
        patcol::sched::pat::allgather(12, 2),
        patcol::sched::pat::allgather(16, usize::MAX),
        patcol::sched::pat::allgather(7, 1),
        patcol::sched::hier::allgather(&pl, 2),
        patcol::sched::hier::allgather(&pl9, usize::MAX),
    ];
    for p in programs {
        let rs = p.mirror();
        assert_eq!(rs.collective, Collective::ReduceScatter, "{}", p.algorithm);
        let back = rs.mirror();
        assert_eq!(back, p, "mirror∘mirror != id for {}", p.algorithm);
        verify_program(&p).unwrap_or_else(|e| panic!("{} ag: {e}", p.algorithm));
        verify_program(&rs).unwrap_or_else(|e| panic!("{} rs: {e}", p.algorithm));
    }
}

/// Phase pairs for the all-reduce composition axis (mixed generators on
/// purpose — the composer is generator-agnostic).
fn phase_pairs() -> Vec<(PhaseAlg, PhaseAlg)> {
    vec![
        (
            PhaseAlg::Pat { aggregation: usize::MAX },
            PhaseAlg::Pat { aggregation: usize::MAX },
        ),
        (PhaseAlg::Pat { aggregation: 2 }, PhaseAlg::Ring),
        (PhaseAlg::Ring, PhaseAlg::Pat { aggregation: 4 }),
        (PhaseAlg::Ring, PhaseAlg::Ring),
        (PhaseAlg::BruckFarFirst, PhaseAlg::BruckNearFirst),
        (PhaseAlg::Recursive, PhaseAlg::Recursive),
        (
            PhaseAlg::HierPat { aggregation: 2 },
            PhaseAlg::Pat { aggregation: 2 },
        ),
    ]
}

/// All-reduce axis, reference executor: every phase pair × ranks 2..=64 ×
/// segments {1, 2, 4} verifies, and moves exactly 2·S·n·(n-1) chunk
/// transfers (each phase delivers each foreign chunk exactly once per
/// segment).
#[test]
fn allreduce_verifier_matrix_to_64() {
    for n in 2..=64usize {
        for &(rs, ag) in &phase_pairs() {
            if !rs.supports(n) || !ag.supports(n) {
                continue;
            }
            for segments in [1usize, 2, 4] {
                let alg = Algorithm::Compose { rs, ag, segments };
                let p = sched::generate(alg, Collective::AllReduce, n).unwrap();
                verify_program(&p)
                    .unwrap_or_else(|e| panic!("{alg} n={n} s={segments}: {e}"));
                assert_eq!(
                    p.stats().chunk_transfers,
                    2 * segments * n * (n - 1),
                    "{alg} n={n} s={segments}"
                );
            }
        }
    }
}

/// All-reduce axis, real threaded transport: ranks 2..=64 × segments
/// {1, 2, 4} over representative pairs. The transport-executed result must
/// equal the reference sum on every rank, under an *enforced* staging-slot
/// capacity. Segment channels progress independently in the transport, so
/// the sound capacity is segments × the single-segment peak (reference
/// executor) plus one in-flight message of aggregation — all channels
/// simultaneously at their own worst point.
#[test]
fn allreduce_transport_matrix_to_64() {
    let pairs = [
        (
            PhaseAlg::Pat { aggregation: usize::MAX },
            PhaseAlg::Pat { aggregation: usize::MAX },
        ),
        (PhaseAlg::Pat { aggregation: 2 }, PhaseAlg::Ring),
        (PhaseAlg::Ring, PhaseAlg::Pat { aggregation: 4 }),
        (
            PhaseAlg::HierPat { aggregation: 2 },
            PhaseAlg::Pat { aggregation: 2 },
        ),
    ];
    let chunk = 4usize;
    for n in 2..=64usize {
        let mut rng = Rng::new(n as u64 * 131);
        for &(rs, ag) in &pairs {
            let per_segment = {
                let one = Algorithm::Compose { rs, ag, segments: 1 };
                let p1 = sched::generate(one, Collective::AllReduce, n).unwrap();
                verify_program(&p1)
                    .unwrap_or_else(|e| panic!("{one} n={n}: {e}"))
                    .peak_slots
            };
            for segments in [1usize, 2, 4] {
                let alg = Algorithm::Compose { rs, ag, segments };
                let p = sched::generate(alg, Collective::AllReduce, n).unwrap();
                verify_program(&p)
                    .unwrap_or_else(|e| panic!("{alg} n={n} s={segments}: {e}"));
                let cap = segments * per_segment + p.stats().max_aggregation + 1;
                let opts = TransportOptions {
                    slot_capacity: Some(cap),
                    validate: false,
                    ..Default::default()
                };
                let nchunks = p.chunk_space();
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..nchunks * chunk).map(|_| rng.below(997) as f32).collect())
                    .collect();
                let (outs, rep) = run_allreduce(&p, &inputs, &opts)
                    .unwrap_or_else(|e| panic!("{alg} n={n} s={segments}: {e}"));
                for (r, out) in outs.iter().enumerate() {
                    for i in 0..nchunks * chunk {
                        let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                        assert_eq!(
                            out[i], want,
                            "{alg} n={n} s={segments} rank={r} idx={i}"
                        );
                    }
                }
                assert!(
                    rep.peak_slots <= cap,
                    "{alg} n={n} s={segments}: transport peak {} > bound {cap}",
                    rep.peak_slots
                );
            }
        }
    }
}

/// Channel axis, reference executor: pat and ring × {AG, RS} × every rank
/// count in [2, 64] × channels {1, 2, 4}. Every split program verifies;
/// chunk transfers multiply by the channel count (each stripe moves its
/// own full n(n-1) grid of 1/C-sized chunks); and the measured occupancy
/// never exceeds C × the single-channel peak (each stripe's staging is an
/// independent copy sharing the rank's buffer).
#[test]
fn channel_verifier_matrix_to_64() {
    for n in 2..=64usize {
        for alg in [Algorithm::Pat { aggregation: 2 }, Algorithm::Ring] {
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let base = sched::generate(alg, coll, n).unwrap();
                let base_occ = verify_program(&base).unwrap();
                for c in [1usize, 2, 4] {
                    let p = sched::channel::split(&base, c).unwrap();
                    let occ = verify_program(&p)
                        .unwrap_or_else(|e| panic!("{alg}*{c} {coll} n={n}: {e}"));
                    assert_eq!(
                        p.stats().chunk_transfers,
                        c * n * (n - 1),
                        "{alg}*{c} {coll} n={n}"
                    );
                    assert!(
                        occ.peak_slots <= c * base_occ.peak_slots,
                        "{alg}*{c} {coll} n={n}: peak {} > {} × {}",
                        occ.peak_slots,
                        c,
                        base_occ.peak_slots
                    );
                }
            }
        }
    }
}

/// Channel axis, real threaded transport: ranks 2..=64 × channels
/// {1, 2, 4} × {ag, rs} for pat and ring, under an *enforced* staging-slot
/// capacity. Channels progress independently, so the sound capacity is
/// C × the single-channel peak (reference executor) plus one in-flight
/// message of aggregation. Results must be exact.
#[test]
fn channel_transport_matrix_to_64() {
    let chunk = 8usize; // divisible by every stripe count in the axis
    for n in 2..=64usize {
        let mut rng = Rng::new(n as u64 * 977);
        for alg in [Algorithm::Pat { aggregation: 2 }, Algorithm::Ring] {
            for c in [1usize, 2, 4] {
                // all-gather
                let base = sched::generate(alg, Collective::AllGather, n).unwrap();
                let base_peak = verify_program(&base).unwrap().peak_slots;
                let p = sched::channel::split(&base, c).unwrap();
                verify_program(&p).unwrap();
                let cap = c * base_peak + p.stats().max_aggregation + 1;
                let opts = TransportOptions {
                    slot_capacity: Some(cap),
                    validate: false,
                    ..Default::default()
                };
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                    .collect();
                let mut want = Vec::new();
                for i in &inputs {
                    want.extend_from_slice(i);
                }
                let (outs, rep) = run_allgather(&p, &inputs, &opts)
                    .unwrap_or_else(|e| panic!("{alg}*{c} ag n={n}: {e}"));
                for (r, o) in outs.iter().enumerate() {
                    assert_eq!(o, &want, "{alg}*{c} ag n={n} rank={r}");
                }
                assert!(
                    rep.peak_slots <= cap,
                    "{alg}*{c} ag n={n}: peak {} > cap {cap}",
                    rep.peak_slots
                );

                // reduce-scatter
                let base_rs = base.mirror();
                let base_peak = verify_program(&base_rs).unwrap().peak_slots;
                let prs = sched::channel::split(&base_rs, c).unwrap();
                verify_program(&prs).unwrap();
                let cap = c * base_peak + prs.stats().max_aggregation + 1;
                let opts = TransportOptions {
                    slot_capacity: Some(cap),
                    validate: false,
                    ..Default::default()
                };
                let inputs: Vec<Vec<f32>> = (0..n)
                    .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                    .collect();
                let (outs, rep) = run_reduce_scatter(&prs, &inputs, &opts)
                    .unwrap_or_else(|e| panic!("{alg}*{c} rs n={n}: {e}"));
                for r in 0..n {
                    for i in 0..chunk {
                        let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                        assert_eq!(outs[r][i], w, "{alg}*{c} rs n={n} rank={r} idx={i}");
                    }
                }
                assert!(
                    rep.peak_slots <= cap,
                    "{alg}*{c} rs n={n}: peak {} > cap {cap}",
                    rep.peak_slots
                );
            }
        }
    }
}

/// Bucketed axis, reference executor: every rank count in [2, 64] ×
/// bucket counts {1, 2, 4}. Uniform batches verify and move exactly
/// `2·B·n·(n−1)` chunk transfers (each bucket is a full RS∘AG over its
/// own chunk space); a mixed batch (different per-bucket segment counts
/// and phase generators) verifies through the same concatenated chunk
/// space — per-bucket reduction correctness is what the all-reduce
/// reference executor checks chunk by chunk.
#[test]
fn bucketed_verifier_matrix_to_64() {
    for n in 2..=64usize {
        let rsp = sched::generate(
            Algorithm::Pat { aggregation: 2 },
            Collective::ReduceScatter,
            n,
        )
        .unwrap();
        let agp =
            sched::generate(Algorithm::Pat { aggregation: 2 }, Collective::AllGather, n).unwrap();
        for nb in [1usize, 2, 4] {
            let p = bucket::fuse(&bucket::uniform(&rsp, &agp, nb, 1)).unwrap();
            verify_program(&p).unwrap_or_else(|e| panic!("bkt{nb} n={n}: {e}"));
            assert_eq!(p.channels, nb, "bkt{nb} n={n}");
            assert_eq!(p.chunk_space(), nb * n, "bkt{nb} n={n}");
            assert_eq!(
                p.stats().chunk_transfers,
                2 * nb * n * (n - 1),
                "bkt{nb} n={n}"
            );
        }
        // mixed batch: 2-segment pat bucket + single-segment ring bucket
        let mixed = vec![
            BucketPhases { rs: rsp.clone(), ag: agp.clone(), segments: 2 },
            BucketPhases {
                rs: sched::ring::reduce_scatter(n),
                ag: sched::ring::allgather(n),
                segments: 1,
            },
        ];
        let p = bucket::fuse(&mixed).unwrap();
        verify_program(&p).unwrap_or_else(|e| panic!("mixed bkt n={n}: {e}"));
        assert_eq!(p.channels, 3, "mixed bkt n={n}");
    }
}

/// Bucketed axis, real threaded transport: ranks 2..=64 × buckets
/// {1, 2, 4} with *unequal* bucket payloads, under an *enforced*
/// staging-slot capacity. Bucket channels progress independently, so the
/// sound shared-pool capacity is buckets × the single-composition peak
/// (reference executor) plus one in-flight message's aggregation — every
/// bucket simultaneously at its own worst point. Results must be exact.
#[test]
fn bucketed_transport_matrix_to_64() {
    for n in 2..=64usize {
        let mut rng = Rng::new(n as u64 * 271);
        let rsp = sched::generate(
            Algorithm::Pat { aggregation: 2 },
            Collective::ReduceScatter,
            n,
        )
        .unwrap();
        let agp =
            sched::generate(Algorithm::Pat { aggregation: 2 }, Collective::AllGather, n).unwrap();
        let per_single = {
            let one = sched::compose::fuse(&rsp, &agp, 1).unwrap();
            verify_program(&one)
                .unwrap_or_else(|e| panic!("single composition n={n}: {e}"))
                .peak_slots
        };
        for nb in [1usize, 2, 4] {
            let buckets = bucket::uniform(&rsp, &agp, nb, 1);
            let p = bucket::fuse(&buckets).unwrap();
            verify_program(&p).unwrap_or_else(|e| panic!("bkt{nb} n={n}: {e}"));
            let layout = BucketLayout::of(&buckets);
            let cap = nb * per_single + p.stats().max_aggregation + 1;
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                ..Default::default()
            };
            // ramp-flavoured unequal payloads: bucket b carries 2·(b+1)
            // elements per chunk
            let elems: Vec<usize> = (0..nb).map(|b| 2 * (b + 1)).collect();
            let chunk_elems = layout.chunk_elems(&elems);
            let total: usize = chunk_elems.iter().sum();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..total).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (outs, rep) = run_allreduce_batch(&p, &chunk_elems, &inputs, &opts)
                .unwrap_or_else(|e| panic!("bkt{nb} n={n}: {e}"));
            for (r, out) in outs.iter().enumerate() {
                for i in 0..total {
                    let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                    assert_eq!(out[i], want, "bkt{nb} n={n} rank={r} idx={i}");
                }
            }
            assert!(
                rep.peak_slots <= cap,
                "bkt{nb} n={n}: transport peak {} > bound {cap}",
                rep.peak_slots
            );
        }
    }
}

/// The headline hierarchy claim: on a tapered three-level fat-tree
/// (taper 0.25 ≤ 0.5, 256 ranks, small messages), HierPat crosses the
/// fabric strictly less than flat PAT at equal aggregation — fewer
/// cross-leaf messages AND fewer cross-leaf bytes — and stays valid.
#[test]
fn hier_fewer_cross_leaf_transfers_than_flat_pat() {
    let n = 256usize;
    let ranks_per_leaf = 8usize;
    // 8 pods × 4 leaves × 8 ranks; top tier tapered to 0.25.
    let topo = Topology::three_level(n, ranks_per_leaf, 4, 4, 2, 25e9, 1.0, 0.25).unwrap();
    let pl = Placement::uniform(n, ranks_per_leaf).unwrap();
    topo.check_placement(&pl).unwrap();
    let cost = CostModel::ib_hdr();
    let chunk = 512; // small-message regime
    let a = 4;

    let flat = sched::generate(Algorithm::Pat { aggregation: a }, Collective::AllGather, n)
        .unwrap();
    let hier = sched::generate_placed(
        Algorithm::HierPat { aggregation: a },
        Collective::AllGather,
        &pl,
    )
    .unwrap();
    verify_program(&hier).unwrap();

    let rep_flat = simulate(&flat, &topo, &cost, chunk).unwrap();
    let rep_hier = simulate(&hier, &topo, &cost, chunk).unwrap();

    let cross_msgs = |r: &SimReport| r.msgs_by_level[1..].iter().sum::<usize>();
    let cross_bytes = |r: &SimReport| r.bytes_by_level[1..].iter().sum::<usize>();
    assert!(
        cross_msgs(&rep_hier) < cross_msgs(&rep_flat),
        "cross-leaf msgs: hier {} !< flat {}",
        cross_msgs(&rep_hier),
        cross_msgs(&rep_flat)
    );
    assert!(
        cross_bytes(&rep_hier) < cross_bytes(&rep_flat),
        "cross-leaf bytes: hier {} !< flat {}",
        cross_bytes(&rep_hier),
        cross_bytes(&rep_flat)
    );
    // Sanity: the hierarchy keeps a substantial share of traffic leaf-local.
    assert!(rep_hier.msgs_by_level[0] > 0);
}

/// Arena-datapath axis: the zero-copy transport (one shared
/// [`patcol::transport::ArenaCache`] leased across the WHOLE sweep, so
/// later runs hit the warm path) over pat(a=2) × ranks 2..=64 × channels
/// {1, 2, 4} × {ag, rs}, under the same enforced staging caps as the
/// heap-era matrix. Results must be bit-identical to the reference sums,
/// no run may fall back to heap-allocated slots, and the recorded arena
/// high-water mark must stay within the leased footprint on the
/// reduce-scatter path (where pool occupancy is physical slots, not
/// reserve accounting). All-reduce and bucketed programs join the axis at
/// a rank subset.
#[test]
fn arena_transport_matrix_to_64() {
    let cache = patcol::transport::ArenaCache::new();
    let chunk = 8usize; // divisible by every stripe count in the axis
    let alg = Algorithm::Pat { aggregation: 2 };
    for n in 2..=64usize {
        let mut rng = Rng::new(n as u64 * 389);
        for c in [1usize, 2, 4] {
            // all-gather
            let base = sched::generate(alg, Collective::AllGather, n).unwrap();
            let base_peak = verify_program(&base).unwrap().peak_slots;
            let p = sched::channel::split(&base, c).unwrap();
            let cap = c * base_peak + p.stats().max_aggregation + 1;
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                arena: Some(cache.clone()),
                ..Default::default()
            };
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let mut want = Vec::new();
            for i in &inputs {
                want.extend_from_slice(i);
            }
            let (outs, rep) = run_allgather(&p, &inputs, &opts)
                .unwrap_or_else(|e| panic!("arena {alg}*{c} ag n={n}: {e}"));
            for (r, o) in outs.iter().enumerate() {
                assert_eq!(o, &want, "arena {alg}*{c} ag n={n} rank={r}");
            }
            assert!(
                rep.peak_slots <= cap,
                "arena {alg}*{c} ag n={n}: peak {} > cap {cap}",
                rep.peak_slots
            );
            assert_eq!(
                rep.slots_allocated, 0,
                "arena {alg}*{c} ag n={n}: fell back to the heap"
            );

            // reduce-scatter
            let base_rs = base.mirror();
            let base_peak = verify_program(&base_rs).unwrap().peak_slots;
            let prs = sched::channel::split(&base_rs, c).unwrap();
            let cap = c * base_peak + prs.stats().max_aggregation + 1;
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                arena: Some(cache.clone()),
                ..Default::default()
            };
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (outs, rep) = run_reduce_scatter(&prs, &inputs, &opts)
                .unwrap_or_else(|e| panic!("arena {alg}*{c} rs n={n}: {e}"));
            for r in 0..n {
                for i in 0..chunk {
                    let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                    assert_eq!(outs[r][i], w, "arena {alg}*{c} rs n={n} rank={r} idx={i}");
                }
            }
            assert!(
                rep.peak_slots <= cap,
                "arena {alg}*{c} rs n={n}: peak {} > cap {cap}",
                rep.peak_slots
            );
            assert_eq!(
                rep.slots_allocated, 0,
                "arena {alg}*{c} rs n={n}: fell back to the heap"
            );
            assert!(
                rep.arena_hw_bytes <= rep.arena_bytes,
                "arena {alg}*{c} rs n={n}: high-water {} > footprint {}",
                rep.arena_hw_bytes,
                rep.arena_bytes
            );
        }
    }

    // All-reduce and bucketed programs on the same shared cache.
    for n in [2usize, 3, 5, 8, 13, 16, 32, 64] {
        let mut rng = Rng::new(n as u64 * 523);
        let chunk = 4usize;
        let rs_ph = PhaseAlg::Pat { aggregation: 2 };
        let ag_ph = PhaseAlg::Pat { aggregation: 2 };
        let per_segment = {
            let one = Algorithm::Compose { rs: rs_ph, ag: ag_ph, segments: 1 };
            let p1 = sched::generate(one, Collective::AllReduce, n).unwrap();
            verify_program(&p1).unwrap().peak_slots
        };
        let segments = 2usize;
        let alg = Algorithm::Compose { rs: rs_ph, ag: ag_ph, segments };
        let p = sched::generate(alg, Collective::AllReduce, n).unwrap();
        let cap = segments * per_segment + p.stats().max_aggregation + 1;
        let opts = TransportOptions {
            slot_capacity: Some(cap),
            validate: false,
            arena: Some(cache.clone()),
            ..Default::default()
        };
        let nchunks = p.chunk_space();
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..nchunks * chunk).map(|_| rng.below(997) as f32).collect())
            .collect();
        let (outs, rep) = run_allreduce(&p, &inputs, &opts)
            .unwrap_or_else(|e| panic!("arena {alg} n={n}: {e}"));
        for (r, out) in outs.iter().enumerate() {
            for i in 0..nchunks * chunk {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "arena {alg} n={n} rank={r} idx={i}");
            }
        }
        assert!(rep.peak_slots <= cap, "arena {alg} n={n}: peak > cap");
        assert_eq!(rep.slots_allocated, 0, "arena {alg} n={n}: heap fallback");

        // bucketed
        let rsp = sched::generate(
            Algorithm::Pat { aggregation: 2 },
            Collective::ReduceScatter,
            n,
        )
        .unwrap();
        let agp =
            sched::generate(Algorithm::Pat { aggregation: 2 }, Collective::AllGather, n).unwrap();
        let per_single = {
            let one = sched::compose::fuse(&rsp, &agp, 1).unwrap();
            verify_program(&one).unwrap().peak_slots
        };
        let nb = 2usize;
        let buckets = bucket::uniform(&rsp, &agp, nb, 1);
        let pb = bucket::fuse(&buckets).unwrap();
        let layout = BucketLayout::of(&buckets);
        let cap = nb * per_single + pb.stats().max_aggregation + 1;
        let opts = TransportOptions {
            slot_capacity: Some(cap),
            validate: false,
            arena: Some(cache.clone()),
            ..Default::default()
        };
        let elems: Vec<usize> = (0..nb).map(|b| 2 * (b + 1)).collect();
        let chunk_elems = layout.chunk_elems(&elems);
        let total: usize = chunk_elems.iter().sum();
        let inputs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..total).map(|_| rng.below(997) as f32).collect())
            .collect();
        let (outs, rep) = run_allreduce_batch(&pb, &chunk_elems, &inputs, &opts)
            .unwrap_or_else(|e| panic!("arena bkt{nb} n={n}: {e}"));
        for (r, out) in outs.iter().enumerate() {
            for i in 0..total {
                let want: f32 = (0..n).map(|s| inputs[s][i]).sum();
                assert_eq!(out[i], want, "arena bkt{nb} n={n} rank={r} idx={i}");
            }
        }
        assert!(rep.peak_slots <= cap, "arena bkt{nb} n={n}: peak > cap");
        assert_eq!(rep.slots_allocated, 0, "arena bkt{nb} n={n}: heap fallback");
    }
}

/// Three-level axis, reference executor: placements with pods — uniform
/// (`<k>x<m>`) and uneven (explicit pod grammar, trailing fat node,
/// single-node pods) — × {AG, RS} × aggregations. Every program verifies,
/// delivers each foreign chunk exactly once, and keeps its measured
/// occupancy within the leader staging-budget law
/// ([`sched::hier::staging_bound`]).
#[test]
fn three_level_matrix() {
    let placements = vec![
        Placement::parse("4x2", 24).unwrap(),          // 3 pods × 2 nodes × 4
        Placement::parse("8x4", 64).unwrap(),          // 2 pods × 4 nodes × 8
        Placement::parse("2,3;4;3,2,3", 17).unwrap(),  // ragged pods AND nodes
        Placement::from_node_sizes(&[4, 4, 4, 5])
            .unwrap()
            .with_pods_grouped(&[1, 3])
            .unwrap(),                                 // lone-node first pod
    ];
    for pl in &placements {
        assert!(pl.is_three_level());
        let n = pl.nranks();
        for &a in &[1usize, 2, usize::MAX] {
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let p = sched::generate_placed(Algorithm::HierPat { aggregation: a }, coll, pl)
                    .unwrap_or_else(|e| panic!("3lvl {coll} n={n} a={a}: {e}"));
                let occ = verify_program(&p)
                    .unwrap_or_else(|e| panic!("3lvl {coll} n={n} a={a}: {e}"));
                assert_eq!(
                    p.stats().chunk_transfers,
                    n * (n - 1),
                    "3lvl {coll} n={n} a={a}"
                );
                let bound = sched::hier::staging_bound(pl, a, coll);
                assert!(
                    occ.peak_slots <= bound,
                    "3lvl {coll} n={n} a={a}: peak {} > bound {bound}",
                    occ.peak_slots
                );
            }
        }
    }
}

/// Multi-leader axis through the real threaded transport: leaders-per-node
/// {1, 2, 4} × {ag, rs, allreduce} on two-level and three-level
/// placements. Striped schedules must be bit-exact with the flat PAT
/// result (integer-valued payloads make float sums order-independent, so
/// equality is exact).
#[test]
fn multi_leader_transport_matrix() {
    let opts = TransportOptions::default();
    let chunk = 8usize;
    let placements = vec![
        Placement::uniform(24, 4).unwrap(),
        Placement::parse("4x2", 24).unwrap(),
    ];
    for base_pl in &placements {
        let n = base_pl.nranks();
        let mut rng = Rng::new(n as u64 * 709);
        for &l in &[1usize, 2, 4] {
            let pl = base_pl.clone().with_leaders(l).unwrap();
            let a = usize::MAX;
            let hier = Algorithm::HierPat { aggregation: a };

            // all-gather: striped hier == flat pat, element for element
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let flat = sched::generate(
                Algorithm::Pat { aggregation: a },
                Collective::AllGather,
                n,
            )
            .unwrap();
            let (want, _) = run_allgather(&flat, &inputs, &opts).unwrap();
            let hag = sched::generate_placed(hier, Collective::AllGather, &pl).unwrap();
            let (outs, _) = run_allgather(&hag, &inputs, &opts)
                .unwrap_or_else(|e| panic!("L={l} ag n={n}: {e}"));
            assert_eq!(outs, want, "L={l} ag n={n}");

            // reduce-scatter
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let hrs = sched::generate_placed(hier, Collective::ReduceScatter, &pl).unwrap();
            let (outs, _) = run_reduce_scatter(&hrs, &inputs, &opts)
                .unwrap_or_else(|e| panic!("L={l} rs n={n}: {e}"));
            for r in 0..n {
                for i in 0..chunk {
                    let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                    assert_eq!(outs[r][i], w, "L={l} rs n={n} rank={r} idx={i}");
                }
            }

            // all-reduce (bare hier lifted to a Compose of itself)
            let har = sched::generate_placed(hier, Collective::AllReduce, &pl).unwrap();
            let nchunks = har.chunk_space();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..nchunks * 2).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (outs, _) = run_allreduce(&har, &inputs, &opts)
                .unwrap_or_else(|e| panic!("L={l} ar n={n}: {e}"));
            for (r, out) in outs.iter().enumerate() {
                for i in 0..nchunks * 2 {
                    let w: f32 = (0..n).map(|s| inputs[s][i]).sum();
                    assert_eq!(out[i], w, "L={l} ar n={n} rank={r} idx={i}");
                }
            }
        }
    }
}

/// The pipelined fan-out under an *enforced* staging cap: the transport
/// runs with `slot_capacity` set from the analytic
/// [`sched::hier::staging_bound`] law (plus the usual one-in-flight
/// message allowance the sibling matrices use), and the per-rank peak
/// attribution ([`patcol::transport::TransportReport::peak_slots_by_rank`])
/// must cover every rank and stay within the cap — the sublinear bound is
/// a hard budget, not a trend.
#[test]
fn pipelined_fanout_respects_enforced_staging_caps() {
    let chunk = 8usize;
    for (n, k, l) in [(32usize, 8usize, 1usize), (32, 8, 2), (64, 8, 2), (64, 8, 4)] {
        let pl = Placement::uniform(n, k).unwrap().with_leaders(l).unwrap();
        let mut rng = Rng::new((n * 10 + l) as u64);
        for coll in [Collective::AllGather, Collective::ReduceScatter] {
            let a = 2usize;
            let p = sched::generate_placed(Algorithm::HierPat { aggregation: a }, coll, &pl)
                .unwrap();
            let occ = verify_program(&p).unwrap();
            let bound = sched::hier::staging_bound(&pl, a, coll);
            assert!(
                occ.peak_slots <= bound,
                "L={l} {coll} n={n}: verifier peak {} > bound {bound}",
                occ.peak_slots
            );
            let cap = bound + p.stats().max_aggregation + 1;
            let opts = TransportOptions {
                slot_capacity: Some(cap),
                validate: false,
                ..Default::default()
            };
            let rep = match coll {
                Collective::AllGather => {
                    let inputs: Vec<Vec<f32>> = (0..n)
                        .map(|_| (0..chunk).map(|_| rng.below(997) as f32).collect())
                        .collect();
                    let mut want = Vec::new();
                    for i in &inputs {
                        want.extend_from_slice(i);
                    }
                    let (outs, rep) = run_allgather(&p, &inputs, &opts)
                        .unwrap_or_else(|e| panic!("capped L={l} ag n={n}: {e}"));
                    for (r, o) in outs.iter().enumerate() {
                        assert_eq!(o, &want, "capped L={l} ag n={n} rank={r}");
                    }
                    rep
                }
                _ => {
                    let inputs: Vec<Vec<f32>> = (0..n)
                        .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                        .collect();
                    let (outs, rep) = run_reduce_scatter(&p, &inputs, &opts)
                        .unwrap_or_else(|e| panic!("capped L={l} rs n={n}: {e}"));
                    for r in 0..n {
                        for i in 0..chunk {
                            let w: f32 = (0..n).map(|s| inputs[s][r * chunk + i]).sum();
                            assert_eq!(outs[r][i], w, "capped L={l} rs n={n} rank={r}");
                        }
                    }
                    rep
                }
            };
            assert_eq!(rep.peak_slots_by_rank.len(), n, "L={l} {coll} n={n}");
            assert_eq!(
                rep.peak_slots_by_rank.iter().copied().max(),
                Some(rep.peak_slots),
                "L={l} {coll} n={n}"
            );
            for (r, &pk) in rep.peak_slots_by_rank.iter().enumerate() {
                assert!(
                    pk <= cap,
                    "L={l} {coll} n={n} rank={r}: peak {pk} > cap {cap}"
                );
            }
        }
    }
}

/// Claim P3 through the observability layer: the pool high-water counters
/// sampled at every buffer-pool transition on the real transport stay
/// within the reference verifier's measured occupancy bound — the traced
/// numbers are the enforced numbers, not an approximation. Counters are
/// keyed by (rank, channel) but sample rank-wide occupancy (channels on a
/// rank share one pool), so this sweeps single-channel programs where the
/// two coincide.
#[test]
fn traced_pool_high_water_within_verifier_bound() {
    let opts = TransportOptions { trace: true, ..Default::default() };
    for n in [4usize, 7, 8, 13, 16] {
        let chunk = 12;
        let mut rng = Rng::new(n as u64 * 67);
        for a in [1usize, 2, 4, usize::MAX] {
            let alg = Algorithm::Pat { aggregation: a };
            if !alg.supports(n) {
                continue;
            }
            let rs = sched::generate(alg, Collective::ReduceScatter, n).unwrap();
            let occ = verify_program(&rs).unwrap();
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..n * chunk).map(|_| rng.below(997) as f32).collect())
                .collect();
            let (_, rep) = run_reduce_scatter(&rs, &inputs, &opts).unwrap();
            let trace = rep.trace.as_ref().expect("trace requested");
            let sampled = trace.counters.values().map(|c| c.pool_peak).max().unwrap_or(0);
            assert_eq!(
                sampled, rep.peak_slots,
                "pat(a={a}) rs n={n}: sampled high water {sampled} != enforced peak {}",
                rep.peak_slots
            );
            assert!(
                sampled <= occ.peak_slots,
                "pat(a={a}) rs n={n}: traced pool high water {sampled} exceeds verifier \
                 occupancy bound {}",
                occ.peak_slots
            );
        }
    }
}

/// Adversarial-delivery axis: seeded hostile delivery schedules (random
/// holds, plus reorder *attempts* that the transport's FIFO-ordering
/// guard must clamp) across ranks × algorithms × channels. Every episode
/// must stay bit-exact against the reference result and within the
/// sound pool capacity (enforced by the episode runner, re-asserted
/// here) — delivery order is invisible to results on a healthy
/// transport.
#[test]
fn adversarial_delivery_matrix_stays_bit_exact() {
    use patcol::adversary::{run_episode, PolicySpec, Preset, Workload};
    use patcol::core::AlgSpec;
    for n in [4usize, 8, 16] {
        for alg in ["pat:2", "ring", "hier_pat:2"] {
            for channels in [1usize, 2] {
                let spec = AlgSpec::parse(&format!("{alg}*{channels}")).unwrap();
                for (preset, seed) in [(Preset::Delay, 5u64), (Preset::Reorder, 11)] {
                    let pol = PolicySpec { preset, seed: seed + n as u64 };
                    for coll in [Collective::AllGather, Collective::ReduceScatter] {
                        let w = Workload::new(coll, spec, n, 24, 3 + n as u64);
                        let (_, cap) = w.build().unwrap();
                        for episode in 0..2u64 {
                            let out = run_episode(&w, &pol, episode).unwrap();
                            assert!(
                                out.failure.is_none(),
                                "{alg}*{channels} {coll} n={n} {preset:?} ep{episode}: {:?}",
                                out.failure
                            );
                            assert!(
                                out.peak_slots <= cap,
                                "{alg}*{channels} {coll} n={n}: peak {} > sound capacity {cap}",
                                out.peak_slots
                            );
                            assert!(
                                out.decisions > 0,
                                "{alg}*{channels} {coll} n={n}: the policy was never consulted"
                            );
                        }
                    }
                }
            }
        }
    }
}
