//! `patcol` — the command-line launcher.
//!
//! Subcommands:
//!
//! * `explain`  — print a schedule step-by-step + the PAT tree (regenerates
//!   the paper's figures as text).
//! * `run`      — execute a collective on the in-process transport with
//!   real bytes (optionally through the PJRT Pallas datapath).
//! * `simulate` — run a schedule through the network simulator at scale.
//! * `sweep`    — compare algorithms across sizes on the simulator.
//! * `tune`     — show the tuner's decision for a configuration.
//! * `selftest` — quick correctness matrix across algorithms and rank
//!   counts.

use patcol::cli::Args;
use patcol::coordinator::config::parse_bytes;
use patcol::coordinator::{CommConfig, Communicator, DataPathKind, Tuner};
use patcol::core::{Algorithm, Collective, Result};
use patcol::sched::{self, explain, pat};
use patcol::sim::{self, CostModel, Topology};
use patcol::util::table::{fmt_bytes, fmt_time_s, Table};
use patcol::util::Rng;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_usage();
        return;
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            print_usage();
            std::process::exit(2);
        }
    };
    let res = match args.command.as_str() {
        "explain" => cmd_explain(&args),
        "run" => cmd_run(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "tune" => cmd_tune(&args),
        "selftest" => cmd_selftest(&args),
        other => {
            eprintln!("unknown command {other:?}");
            print_usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = res {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "patcol — PAT collective communication (all-gather / reduce-scatter)

USAGE: patcol <command> [--options]

COMMANDS
  explain   --ranks N [--agg A] [--alg ALG] [--collective ag|rs] [--trees]
  run       --ranks N --size BYTES [--alg ALG] [--collective ag|rs]
            [--datapath scalar|pjrt] [--buffer-slots S]
  simulate  --ranks N --size BYTES [--alg ALG] [--collective ag|rs]
            [--topo flat|leaf_spine|three_level|dragonfly] [--taper F]
  sweep     --ranks N [--sizes LIST] [--collective ag|rs] [--topo ...]
  tune      --ranks N --size BYTES [--buffer-slots S] [--collective ag|rs]
  selftest  [--max-ranks N]

ALG: ring | bruck_near | bruck_far | recursive | pat | pat:<agg> | pat_auto
SIZES: e.g. 1KiB,64KiB,1MiB (per-rank chunk size)"
    );
}

fn collective(args: &Args) -> Result<Collective> {
    match args.str("collective", "ag").as_str() {
        "ag" | "allgather" | "all_gather" => Ok(Collective::AllGather),
        "rs" | "reducescatter" | "reduce_scatter" => Ok(Collective::ReduceScatter),
        other => Err(patcol::core::Error::Config(format!(
            "unknown collective {other:?}"
        ))),
    }
}

fn topology(args: &Args, nranks: usize) -> Result<Topology> {
    let nic = CostModel::ib_hdr_nic_bw();
    let taper = args.f64("taper", 1.0)?;
    match args.str("topo", "flat").as_str() {
        "flat" => Ok(Topology::flat(nranks, nic)),
        "leaf_spine" => {
            let g = args.usize("ranks-per-leaf", 8.min(nranks))?;
            let s = args.usize("spines", (g).max(1))?;
            Topology::leaf_spine(nranks, g, s, nic, taper)
        }
        "three_level" => {
            let g = args.usize("ranks-per-leaf", 8.min(nranks))?;
            let lp = args.usize("leaves-per-pod", 4)?;
            let sp = args.usize("spines-per-pod", g)?;
            let c = args.usize("cores", sp)?;
            Topology::three_level(nranks, g, lp, sp, c, nic, 1.0, taper)
        }
        "dragonfly" => {
            let g = args.usize("ranks-per-group", 8.min(nranks))?;
            Topology::dragonfly(nranks, g, nic, nic * taper)
        }
        other => Err(patcol::core::Error::Config(format!(
            "unknown topology {other:?}"
        ))),
    }
}

fn cmd_explain(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 8)?;
    let agg = args.usize("agg", usize::MAX)?;
    let coll = collective(args)?;
    let alg = match args.opt_str("alg") {
        Some(s) => Algorithm::parse(&s)?,
        None => Algorithm::Pat { aggregation: agg },
    };
    let prog = sched::generate(alg, coll, n)?;
    println!("{}", explain::render_steps(&prog));
    if let Algorithm::Pat { .. } = alg {
        println!("{}", explain::render_pat_tree(n, agg));
    }
    if args.flag("trees") {
        println!("{}", explain::render_root_trees(&prog));
    }
    let occ = sched::verify::verify_program(&prog)?;
    let s = prog.stats();
    println!(
        "steps={} messages={} chunk_transfers={} max_aggregation={} peak_buffer_slots={}",
        s.steps, s.messages, s.chunk_transfers, s.max_aggregation, occ.peak_slots
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 8)?;
    let size = args.bytes("size", 64 * 1024)?;
    let coll = collective(args)?;
    let alg = match args.opt_str("alg") {
        Some(s) => Some(Algorithm::parse(&s)?),
        None => None,
    };
    let datapath = match args.str("datapath", "scalar").as_str() {
        "pjrt" => DataPathKind::Pjrt,
        _ => DataPathKind::Scalar,
    };
    let comm = Communicator::new(CommConfig {
        nranks: n,
        algorithm: alg,
        buffer_slots: args.opt_str("buffer-slots").map(|s| parse_bytes(&s)).transpose()?,
        datapath,
        ..Default::default()
    })?;
    let chunk = (size / 4).max(1);
    let mut rng = Rng::new(7);
    let (rep, payload) = match coll {
        Collective::AllGather => {
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0f32; chunk];
                    rng.fill_f32(&mut v);
                    v
                })
                .collect();
            let (_, rep) = comm.all_gather_report(&inputs)?;
            (rep, (n - 1) * chunk * 4)
        }
        Collective::ReduceScatter => {
            let inputs: Vec<Vec<f32>> = (0..n)
                .map(|_| {
                    let mut v = vec![0f32; n * chunk];
                    rng.fill_f32(&mut v);
                    v
                })
                .collect();
            let (_, rep) = comm.reduce_scatter_report(&inputs)?;
            (rep, (n - 1) * chunk * 4)
        }
    };
    let wall = rep.transport.wall.as_secs_f64();
    println!(
        "{} {} ranks={} chunk={} steps={} msgs={} bytes={} peak_slots={} wall={} algbw={}/s",
        rep.algorithm,
        coll,
        n,
        fmt_bytes(size),
        rep.steps,
        rep.transport.messages,
        fmt_bytes(rep.transport.bytes_moved),
        rep.transport.peak_slots,
        fmt_time_s(wall),
        fmt_bytes((payload as f64 / wall.max(1e-9)) as usize),
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let size = args.bytes("size", 64 * 1024)?;
    let coll = collective(args)?;
    let alg = Algorithm::parse(&args.str("alg", "pat"))?;
    let topo = topology(args, n)?;
    let cost = CostModel::ib_hdr();
    let prog = sched::generate(alg, coll, n)?;
    let rep = if let Some(trace_path) = args.opt_str("trace") {
        use patcol::util::json::Json;
        let (rep, trace) = sim::simulate_traced(&prog, &topo, &cost, size)?;
        let rows: Vec<Json> = trace
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("step", Json::num(e.step as f64)),
                    ("src", Json::num(e.src as f64)),
                    ("dst", Json::num(e.dst as f64)),
                    ("bytes", Json::num(e.bytes as f64)),
                    ("t_start", Json::num(e.t_start)),
                    ("t_arrival", Json::num(e.t_arrival)),
                ])
            })
            .collect();
        std::fs::write(&trace_path, Json::Arr(rows).to_pretty())?;
        println!("trace ({} messages) -> {trace_path}", trace.len());
        rep
    } else {
        sim::simulate(&prog, &topo, &cost, size)?
    };
    println!(
        "{} {} ranks={} chunk={} topo={}",
        alg, coll, n, fmt_bytes(size), topo.name
    );
    println!(
        "  time={}  algbw={}/s  msgs={}  bytes={}  bytes_links={:.2e}",
        fmt_time_s(rep.total_time),
        fmt_bytes(rep.algbw((n - 1) * size) as usize),
        rep.messages,
        fmt_bytes(rep.bytes_sent),
        rep.bytes_links,
    );
    for (lvl, b) in rep.bytes_by_level.iter().enumerate() {
        println!("  level {lvl}: {}", fmt_bytes(*b));
    }
    println!(
        "  busiest link: {} ({:.0}% busy)",
        fmt_bytes(rep.max_link_bytes),
        rep.busiest_link_utilization * 100.0
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let sizes = args.bytes_list(
        "sizes",
        &[256, 4 << 10, 64 << 10, 1 << 20, 16 << 20],
    )?;
    let coll = collective(args)?;
    let topo = topology(args, n)?;
    let cost = CostModel::ib_hdr();
    let algs: Vec<Algorithm> = vec![
        Algorithm::Ring,
        Algorithm::BruckNearFirst,
        Algorithm::Pat { aggregation: usize::MAX },
        Algorithm::Pat { aggregation: 4 },
        Algorithm::Pat { aggregation: 1 },
    ];
    let header: Vec<String> = std::iter::once("size".to_string())
        .chain(algs.iter().map(|a| a.name()))
        .collect();
    let mut t = Table::new(header);
    for size in sizes {
        let mut row = vec![fmt_bytes(size)];
        for alg in &algs {
            let prog = sched::generate(*alg, coll, n)?;
            let rep = sim::simulate(&prog, &topo, &cost, size)?;
            row.push(fmt_time_s(rep.total_time));
        }
        t.row(row);
    }
    println!("{} on {} ({} ranks):", coll, topo.name, n);
    print!("{}", t.render());
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let n = args.usize("ranks", 64)?;
    let size = args.bytes("size", 64 * 1024)?;
    let slots = args.usize("buffer-slots", 64)?;
    let coll = collective(args)?;
    let tuner = Tuner::default();
    let choice = tuner.choose(n, size, slots, coll);
    println!(
        "tune: ranks={n} chunk={} buffer_slots={slots} {coll}",
        fmt_bytes(size)
    );
    let mut t = Table::new(["algorithm", "predicted"]);
    for (alg, cost) in &choice.candidates {
        t.row([alg.name(), fmt_time_s(*cost)]);
    }
    print!("{}", t.render());
    println!("chosen: {}", choice.algorithm);
    Ok(())
}

fn cmd_selftest(args: &Args) -> Result<()> {
    let max = args.usize("max-ranks", 33)?;
    let mut count = 0usize;
    for n in 1..=max {
        for alg in [
            Algorithm::Ring,
            Algorithm::BruckNearFirst,
            Algorithm::BruckFarFirst,
            Algorithm::Recursive,
            Algorithm::Pat { aggregation: 1 },
            Algorithm::Pat { aggregation: 2 },
            Algorithm::Pat { aggregation: 7 },
            Algorithm::Pat { aggregation: usize::MAX },
        ] {
            if !alg.supports(n) {
                continue;
            }
            for coll in [Collective::AllGather, Collective::ReduceScatter] {
                let prog = sched::generate(alg, coll, n)?;
                sched::verify::verify_program(&prog).map_err(|e| {
                    patcol::core::Error::Verify(format!("{alg} {coll} n={n}: {e}"))
                })?;
                count += 1;
            }
        }
    }
    // Spot-check PAT tree phases against the paper's figures.
    assert_eq!(pat::phase_counts(8, 2), (1, 3));
    assert_eq!(pat::phase_counts(16, 2), (1, 7));
    println!("selftest OK: {count} (algorithm, collective, nranks) cases verified");
    Ok(())
}
