//! Algorithm auto-selection (what NCCL's tuning model does for PAT vs
//! Ring): a closed-form α-β-γ cost estimate over the candidate schedules,
//! constrained by the intermediate-buffer budget.
//!
//! The PAT aggregation factor is derived from the buffer budget using the
//! measured accumulator law (see `sched::pat`): a reduce-scatter with
//! aggregation `a` needs `a · log2(n/a)` persistent chunk slots, an
//! all-gather needs `a` transient slots per transfer. The tuner picks the
//! largest feasible `a`, then compares PAT(a), Ring, and (log-shaped but
//! congestion-prone) far-first Bruck under the cost model and returns the
//! cheapest.
//!
//! ## Placement-aware crossover
//!
//! When the caller supplies a rank [`Placement`] (ranks grouped onto
//! nodes, optionally onto pods) the tuner also evaluates the hierarchical
//! schedule ([`crate::sched::hier`]). The fabric asymmetry is modelled by
//! [`Tuner::inter_bw`]: the aggregate uplink bandwidth one node has to the
//! rest of the fabric (`None` = non-blocking). Per-schedule traffic shape
//! matters: the dimension-hopping schedules (PAT/Bruck) have every rank
//! talking cross-node on most rounds, so a node's `k` ranks share the
//! uplink `k` ways (`inter_bw / k` each); a *contiguous* ring crosses
//! each node boundary exactly once per step, so its pipeline is
//! bottlenecked by `min(nic, inter_bw)` — rings stay bandwidth-strong on
//! tapered fabrics, exactly why NCCL keeps them for huge payloads. The
//! hierarchical schedule keeps the non-leader ranks off the fabric and
//! stripes the inter-node phase across `L =
//! Placement::effective_leaders()` leader NICs, so its serialization rate
//! is `min(inter_bw, L·nic)` — with `L > 1` the single-leader NIC
//! bottleneck lifts, which is exactly the multi-leader win
//! [`Tuner::predict_hier`] models. The closed form mirrors the pipelined
//! construction: per inter-node round, the round's exchange overlaps the
//! *previous* round's intra-node distribution wave, and a three-level
//! placement recurses (intra-pod rounds, then inter-pod rounds with a
//! pod-wave relay). Hierarchical candidates are gated on the leader
//! staging-budget law [`crate::sched::hier::staging_bound`] instead of a
//! flat `n`-slot requirement. The resulting crossover
//! ([`Tuner::choose_placed`]): flat PAT at latency-bound sizes, HierPat
//! in the tapered mid-size band, Ring at the bandwidth extreme.

use crate::core::{ceil_log2, Algorithm, Collective, PhaseAlg, Placement};
use crate::sched::{hier, pat};
use crate::sim::CostModel;

/// Calibration constant for [`Tuner::predict_hier`] against the event
/// simulator on tapered three-level fabrics: across the calibrated sweep
/// (64 ranks on 8-rank nodes, 4 KiB – 256 KiB chunks, core taper 0.25,
/// `inter_bw` set to the core-tapered uplink), the closed form stays
/// within a factor of [`HIER_CALIBRATION_TOLERANCE`] of the simulated
/// time in both directions. Tightened from the original ×/÷6 by modeling
/// what the simulator actually overlaps: (a) the intra-node gather now
/// charges α on the *tree depth* (`⌈log2 s⌉` levels) instead of once per
/// member — sibling subtrees arrive concurrently and only serialize on
/// the leader NIC; (b) each inter-node round's exchange overlaps the
/// previous round's intra-node wave (the pipelined fan-out), so the
/// model takes the max of the two instead of their sum. The residual the
/// constant still absorbs is inter-node link contention — static-ECMP
/// collisions can stack several leader flows on one tapered core link,
/// which the closed form folds into the single `inter_bw` rate.
/// Asserted by `tests/tuner_and_config.rs`, which also appends every
/// sweep point to a [`crate::obs::calib`] drift history and checks the
/// recorded per-key residuals against this constant — run with
/// `--calib-history FILE` to accumulate the same trend lines across real
/// runs.
pub const HIER_CALIBRATION_TOLERANCE: f64 = 4.0;

/// Calibration constant for [`Tuner::predict_allreduce`] against the
/// event simulator on a tapered leaf-spine fabric (64 ranks on 8-rank
/// leaves, 4 spines at taper 0.25, `inter_bw` set to the aggregate
/// uplink, 4 KiB – 1 MiB per-rank payloads, 1–4 pipeline segments): the
/// closed-form two-stage pipeline bound stays within a factor of
/// [`ALLREDUCE_CALIBRATION_TOLERANCE`] of the simulated time in both
/// directions. The bound is structurally *optimistic* at bandwidth-bound
/// sizes — it assumes the reduce-scatter and all-gather phases of
/// adjacent segments overlap on disjoint resources, while in the fabric
/// they share the same NICs and (ECMP-collided) uplinks — and
/// *pessimistic* at latency-bound sizes, where it serializes per-round α
/// that the simulator's independent per-channel streams overlap.
/// Asserted by `tests/tuner_and_config.rs`; tightening it means modeling
/// shared-resource contention between pipelined phases, the residual the
/// constant documents.
pub const ALLREDUCE_CALIBRATION_TOLERANCE: f64 = 6.0;

/// Calibration constant for [`Tuner::predict_channels`] against the event
/// simulator on a multi-rail leaf-spine fabric (64 ranks, 8-rank leaves,
/// 4 spines, `parallel_links = 4`, 4 KiB – 1 MiB per-rank payloads,
/// C ∈ {1, 2, 4}): the closed form stays within a factor of
/// [`CHANNEL_CALIBRATION_TOLERANCE`] of the simulated time in both
/// directions. The two modeled-vs-simulated gaps it absorbs are exactly
/// the ones the ROADMAP calibration item names: (a) the closed form
/// charges the per-round channel tax `C × (α + gap)` serially, while the
/// simulator's per-(rank, channel) streams post those sends concurrently
/// (model pessimistic at small sizes, by up to ~C); (b) the closed form
/// models rail *count* (`min(C, parallel_links)`), while the simulator's
/// win comes from static-ECMP collision variance — colliding flows can
/// serialize several-fold on one spine (model optimistic at
/// bandwidth-bound sizes — on an unlucky deterministic hash several
/// flows of one leaf can stack on one spine uplink, stretching the
/// simulated time a further few-fold). Asserted by
/// `tests/tuner_and_config.rs`; tightening this constant means modeling
/// collision probability, not just rail count, in the closed form. Like
/// the hierarchy tolerance, drift is now recordable: the
/// [`crate::obs::calib`] history keys on channel count, so per-C
/// residual trends fall out of `drift_summary`.
pub const CHANNEL_CALIBRATION_TOLERANCE: f64 = 10.0;

/// Payload bytes at which one bucket of a batched all-reduce is worth
/// striping across extra channels ([`crate::sched::bucket::stripe_plan`]).
/// Below this a bucket is latency-bound: each extra channel adds a full
/// per-round message tax (the `C × (α + gap)` term of
/// [`Tuner::predict_channels`]) for no serialization win. Above it the
/// per-round payload dominates and extra per-bucket ECMP flows recruit
/// parallel rails, exactly as the channel crossover does for a single
/// collective — 256 KiB sits past the crossover's C > 1 flip for every
/// fabric the calibration sweeps.
pub const BUCKET_STRIPE_THRESHOLD_BYTES: usize = 256 << 10;

/// A tuner decision with its predicted cost.
#[derive(Debug, Clone)]
pub struct TunerChoice {
    pub algorithm: Algorithm,
    pub predicted_seconds: f64,
    /// All evaluated candidates (algorithm, predicted seconds), best first.
    pub candidates: Vec<(Algorithm, f64)>,
}

/// A channel-count decision ([`Tuner::choose_channels`]).
#[derive(Debug, Clone)]
pub struct ChannelChoice {
    pub channels: usize,
    pub predicted_seconds: f64,
    /// All evaluated candidates (channels, predicted seconds), best first.
    pub candidates: Vec<(usize, f64)>,
}

/// A gradient-bucketing decision ([`Tuner::choose_bucketed`]).
#[derive(Debug, Clone)]
pub struct BucketChoice {
    /// Per-bucket payload bytes per rank (sums to the requested total).
    pub bucket_bytes: Vec<usize>,
    /// Phase pair every bucket runs.
    pub rs: PhaseAlg,
    pub ag: PhaseAlg,
    pub predicted_seconds: f64,
    /// All evaluated candidates `(bucket count, ramp-shaped first bucket,
    /// predicted seconds)`, best first.
    pub candidates: Vec<(usize, bool, f64)>,
}

/// Split `total_bytes` into `nbuckets` bucket sizes. `ramp_first` shapes
/// the split so the first bucket is *half* the steady size — the classic
/// pipeline-ramp answer to the composer's open unequal-segment-sizes
/// item: the pipeline's first stage is the only one nothing overlaps, so
/// making it small fills the overlap window sooner, and the bucket fuser
/// takes arbitrary per-bucket sizes structurally. Rounding remainders go
/// to the last bucket; the sizes always sum to `total_bytes`.
pub fn bucket_sizes(total_bytes: usize, nbuckets: usize, ramp_first: bool) -> Vec<usize> {
    let b = nbuckets.max(1);
    if b == 1 || !ramp_first {
        let base = total_bytes / b;
        let mut v = vec![base; b];
        v[b - 1] += total_bytes - base * b;
        return v;
    }
    // first = steady / 2, so steady = 2·total / (2B − 1).
    let steady = 2 * total_bytes / (2 * b - 1);
    let mut v = vec![steady; b];
    v[0] = steady / 2;
    let sum: usize = v.iter().sum();
    v[b - 1] += total_bytes - sum; // floor rounding guarantees sum <= total
    v
}

/// Closed-form schedule cost estimator.
#[derive(Debug, Clone)]
pub struct Tuner {
    pub cost: CostModel,
    /// NIC bandwidth (bytes/s) used for serialization estimates.
    pub nic_bw: f64,
    /// Aggregate uplink bandwidth of one node toward the rest of the
    /// fabric (bytes/s); `None` models a non-blocking fabric. Only
    /// consulted by the placement-aware prediction paths.
    pub inter_bw: Option<f64>,
    /// Parallel fabric links one rank's traffic can recruit (rails /
    /// spine-ECMP width). Multi-channel execution scales bandwidth by
    /// `min(channels, parallel_links)` — with 1 (the default), extra
    /// channels only add latency, so [`Tuner::choose_channels`] stays at
    /// one channel, the pre-channel behaviour.
    pub parallel_links: usize,
}

impl Default for Tuner {
    fn default() -> Self {
        Tuner {
            cost: CostModel::ib_hdr(),
            nic_bw: CostModel::ib_hdr_nic_bw(),
            inter_bw: None,
            parallel_links: 1,
        }
    }
}

impl Tuner {
    /// Largest PAT aggregation whose buffer need fits `buffer_slots` chunk
    /// slots for this collective.
    pub fn max_aggregation(
        &self,
        nranks: usize,
        buffer_slots: usize,
        coll: Collective,
    ) -> usize {
        let buffer_slots = buffer_slots.max(1);
        let full = pat::clamp_aggregation(nranks, usize::MAX);
        let mut best = 1;
        let mut a = 1;
        while a <= full {
            let need = match coll {
                Collective::AllGather => a,
                // All-reduce staging is bounded by its reduce-scatter
                // phase (the accumulator law), so both use the RS law.
                Collective::ReduceScatter | Collective::AllReduce => {
                    let levels = (ceil_log2(nranks.max(2)) as usize)
                        .saturating_sub(a.trailing_zeros() as usize)
                        .max(1);
                    a * levels
                }
            };
            if need <= buffer_slots {
                best = a;
            }
            if a >= full {
                break;
            }
            a = (a * 2).min(full);
            if a == best {
                break;
            }
        }
        best
    }

    /// Per-rank serialization rate of a *flat* (placement-oblivious)
    /// schedule: on a tapered fabric, a node's `k` ranks share its uplink.
    fn flat_rate(&self, placement: Option<&Placement>) -> f64 {
        match (placement, self.inter_bw) {
            (Some(pl), Some(bw)) if pl.nnodes() > 1 => {
                (bw / pl.max_node_size() as f64).min(self.nic_bw)
            }
            _ => self.nic_bw,
        }
    }

    /// Serialization rate of a hierarchical leader: the whole node uplink,
    /// capped by its own NIC.
    fn leader_rate(&self) -> f64 {
        match self.inter_bw {
            Some(bw) => bw.min(self.nic_bw),
            None => self.nic_bw,
        }
    }

    /// Aggregate inter-node serialization rate of a node striped across
    /// `L` stripe leaders: the node uplink, capped by the `L` leader NICs
    /// it can actually recruit. `L = 1` reduces to [`Tuner::leader_rate`];
    /// on a tapered fabric extra leaders claim more of the uplink (the
    /// multi-leader striping win), until `L·nic` saturates it.
    fn striped_rate(&self, pl: &Placement) -> f64 {
        let lanes = pl.effective_leaders() as f64 * self.nic_bw;
        match self.inter_bw {
            Some(bw) => bw.min(lanes),
            None => lanes,
        }
    }

    fn predict_pat_at(&self, nranks: usize, a: usize, chunk_bytes: usize, rate: f64) -> f64 {
        let c = &self.cost;
        let mut t = 0.0;
        for round in pat::rounds(nranks, a) {
            let k = round.offsets.len();
            let bytes = k * chunk_bytes;
            t += c.alpha_base + bytes as f64 / rate + c.pack_cost(k, bytes) + c.msg_gap;
        }
        t
    }

    fn predict_ring_at(&self, nranks: usize, chunk_bytes: usize, rate: f64) -> f64 {
        if nranks <= 1 {
            return 0.0;
        }
        let c = &self.cost;
        let steps = (nranks - 1) as f64;
        steps * (c.alpha_base + c.msg_gap + chunk_bytes as f64 / rate)
    }

    /// Predicted wall time of a PAT schedule: per round, message overhead +
    /// serialization + local pack cost.
    pub fn predict_pat(&self, nranks: usize, a: usize, chunk_bytes: usize) -> f64 {
        self.predict_pat_at(nranks, a, chunk_bytes, self.nic_bw)
    }

    /// Predicted wall time of the ring schedule: n-1 back-to-back single
    /// chunk transfers; the pipeline overlaps serialization, so latency is
    /// (n-1)·(α + gap) + serialization of the payload.
    pub fn predict_ring(&self, nranks: usize, chunk_bytes: usize) -> f64 {
        self.predict_ring_at(nranks, chunk_bytes, self.nic_bw)
    }

    /// Predicted wall time of far-first Bruck (fully aggregated): log
    /// rounds of doubling payload, plus pack costs.
    pub fn predict_bruck(&self, nranks: usize, chunk_bytes: usize) -> f64 {
        self.predict_pat(nranks, usize::MAX, chunk_bytes)
    }

    /// Predicted wall time of a PAT(a) schedule split across `channels`
    /// NCCL-style channels ([`crate::sched::channel::split`]): every round
    /// posts one message per channel (latency and message-gap cost ×
    /// channels — the channel tax at small sizes), while serialization of
    /// the round's payload runs concurrently over `min(channels,
    /// parallel_links)` fabric links (the bandwidth win at large sizes).
    /// Pack cost covers the full payload either way. `channels = 1`
    /// reduces exactly to [`Tuner::predict_pat`].
    pub fn predict_channels(
        &self,
        nranks: usize,
        a: usize,
        chunk_bytes: usize,
        channels: usize,
    ) -> f64 {
        let ch = channels.max(1);
        let lanes = ch.min(self.parallel_links.max(1)) as f64;
        let c = &self.cost;
        let mut t = 0.0;
        for round in pat::rounds(nranks, a) {
            let k = round.offsets.len();
            let bytes = k * chunk_bytes;
            t += ch as f64 * (c.alpha_base + c.msg_gap)
                + bytes as f64 / (self.nic_bw * lanes)
                + c.pack_cost(k, bytes);
        }
        t
    }

    /// Channel-count crossover: sweep C ∈ {1, 2, 4, 8} for a PAT(a)
    /// schedule and return the cheapest. Latency-bound sizes pay the
    /// per-round channel tax and stay at C = 1; bandwidth-bound sizes on a
    /// multi-rail fabric (`parallel_links > 1`) amortize it and move to
    /// C ≈ `parallel_links` — more channels than links only add latency.
    pub fn choose_channels(
        &self,
        nranks: usize,
        a: usize,
        chunk_bytes: usize,
    ) -> ChannelChoice {
        let mut candidates: Vec<(usize, f64)> = [1usize, 2, 4, 8]
            .iter()
            .map(|&ch| (ch, self.predict_channels(nranks, a, chunk_bytes, ch)))
            .collect();
        candidates.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap().then(x.0.cmp(&y.0)));
        ChannelChoice {
            channels: candidates[0].0,
            predicted_seconds: candidates[0].1,
            candidates,
        }
    }

    /// Predicted wall time of the hierarchical schedule
    /// ([`crate::sched::hier`]), mirroring its pipelined, striped
    /// construction:
    ///
    /// * **Intra-node gather** — each stripe's near-first tree over its
    ///   `s = ⌈kmax/L⌉` members is `⌈log2 s⌉` levels deep; sibling
    ///   subtrees arrive concurrently (the overlap the simulator shows),
    ///   so α is charged on the depth while the stripe leader's NIC still
    ///   serializes all `s−1` arriving chunks.
    /// * **Wave 0** — the local broadcast of the node's own stripe set:
    ///   the leader feeds `⌈log2 kmax⌉` subtree children one stripe set
    ///   each off its NIC.
    /// * **Inter-node rounds, pipelined** — round `j`'s exchange (at the
    ///   striped rate `min(inter_bw, L·nic)`, all `L` stripes in flight)
    ///   overlaps round `j−1`'s intra-node wave (leader egress
    ///   `⌈log2 kmax⌉ ×` the round payload at its NIC's share), so each
    ///   round costs the *max* of the two, and only the last wave is paid
    ///   in full.
    /// * **Three-level recursion** — a podded placement runs the pipeline
    ///   twice: intra-pod rounds over the largest pod's nodes (node-set
    ///   payloads), then inter-pod rounds over pod leaders (pod-set
    ///   payloads), each inter-pod round relayed by a leader-to-leader
    ///   pod wave across the fabric before the node waves.
    pub fn predict_hier(&self, pl: &Placement, a: usize, chunk_bytes: usize) -> f64 {
        let c = &self.cost;
        let n = pl.nranks();
        if n <= 1 {
            return 0.0;
        }
        let kmax = pl.max_node_size();
        let l = pl.effective_leaders();
        let lf = l as f64;
        let s = kmax.div_ceil(l);
        let cb = chunk_bytes as f64;
        let nic = self.nic_bw;
        let mut t = 0.0;
        if s > 1 {
            let d = ceil_log2(s) as f64;
            t += d * (c.alpha_base + c.msg_gap) + (s - 1) as f64 * cb / nic;
        }
        let wd = if kmax > 1 { ceil_log2(kmax) as f64 } else { 0.0 };
        if kmax > 1 {
            t += wd * (c.alpha_base + c.msg_gap) + wd * s as f64 * cb / nic;
        }
        let rate = self.striped_rate(pl);
        // One pipelined PAT level: `set_chunks` chunks per virtual rank,
        // `pod_depth` > 0 adds the inter-pod leader-to-leader relay wave
        // (rides the fabric at the striped rate, like the exchange).
        let pipeline = |rounds: &[pat::PatRound], set_chunks: usize, pod_depth: f64| -> f64 {
            let mut tt = 0.0;
            let mut prev_wave = 0.0f64;
            for round in rounds {
                let k = round.offsets.len();
                let chunks = k * set_chunks;
                let bytes = chunks as f64 * cb;
                let exch = bytes / rate + c.pack_cost(chunks, chunks * chunk_bytes);
                tt += c.alpha_base + c.msg_gap + exch.max(prev_wave);
                prev_wave = pod_depth * (c.alpha_base + c.msg_gap)
                    + pod_depth * bytes / rate
                    + wd * (c.alpha_base + c.msg_gap)
                    + wd * bytes / (lf * nic);
            }
            tt + prev_wave
        };
        if pl.is_three_level() && pl.npods() > 1 {
            let np = pl.npods();
            let m = (0..np).map(|q| pl.pod_nodes(q).len()).max().unwrap_or(1);
            if m > 1 {
                let ac = pat::clamp_aggregation(m, a);
                t += pipeline(&pat::rounds(m, ac), kmax, 0.0);
            }
            let pod_set = (0..np).map(|q| pl.pod_rank_count(q)).max().unwrap_or(kmax);
            let pwd = if m > 1 { ceil_log2(m) as f64 } else { 0.0 };
            let ac = pat::clamp_aggregation(np, a);
            t += pipeline(&pat::rounds(np, ac), pod_set, pwd);
        } else if pl.nnodes() > 1 {
            let nn = pl.nnodes();
            let ac = pat::clamp_aggregation(nn, a);
            t += pipeline(&pat::rounds(nn, ac), kmax, 0.0);
        }
        t
    }

    /// Predicted wall time of one compose phase ([`PhaseAlg`]) moving
    /// `chunk_bytes` per chunk. The Bruck/recursive baselines share the
    /// fully-aggregated PAT shape; hierarchical phases need the placement
    /// (flat PAT is the fallback without one). Reduce-scatter phases are
    /// costed like their all-gather mirror plus the reduction datapath
    /// over the received payload.
    pub fn predict_phase(
        &self,
        alg: PhaseAlg,
        nranks: usize,
        chunk_bytes: usize,
        coll: Collective,
        placement: Option<&Placement>,
    ) -> f64 {
        let rate = self.flat_rate(placement);
        let mut t = match alg {
            PhaseAlg::Ring => {
                let ring_rate = if placement.is_some_and(|pl| pl.nnodes() > 1) {
                    self.leader_rate()
                } else {
                    self.nic_bw
                };
                self.predict_ring_at(nranks, chunk_bytes, ring_rate)
            }
            PhaseAlg::Pat { aggregation } => {
                self.predict_pat_at(nranks, aggregation, chunk_bytes, rate)
            }
            PhaseAlg::BruckNearFirst | PhaseAlg::BruckFarFirst | PhaseAlg::Recursive => {
                self.predict_pat_at(nranks, usize::MAX, chunk_bytes, rate)
            }
            PhaseAlg::HierPat { aggregation } => match placement {
                Some(pl) => self.predict_hier(pl, aggregation, chunk_bytes),
                None => self.predict_pat_at(nranks, aggregation, chunk_bytes, rate),
            },
        };
        if coll == Collective::ReduceScatter && nranks > 1 {
            t += self.cost.reduce_cost((nranks - 1) * chunk_bytes);
        }
        t
    }

    /// Predicted wall time of the pipelined composition
    /// `rs+ag:segments` ([`Algorithm::Compose`]): `chunk_bytes` is the
    /// per-chunk payload of ONE segment (i.e. total bytes / (nranks ×
    /// segments)). Classic two-stage pipeline bound: the first segment
    /// pays both phases, every further segment hides behind the slower
    /// phase.
    ///
    /// Known bias: the bound assumes the two phases overlap on disjoint
    /// resources and ignores per-channel ECMP path spreading (segments
    /// are channels with their own flows since the channel refactor), so
    /// it misestimates bandwidth-bound sizes on strongly tapered fabrics
    /// — the measured sweep (`benches/allreduce_compose.rs`) peaks
    /// mid-band. The form is calibrated against the event simulator to
    /// within [`ALLREDUCE_CALIBRATION_TOLERANCE`] (see
    /// `tests/tuner_and_config.rs`).
    pub fn predict_allreduce(
        &self,
        rs: PhaseAlg,
        ag: PhaseAlg,
        segments: usize,
        nranks: usize,
        chunk_bytes: usize,
        placement: Option<&Placement>,
    ) -> f64 {
        let segments = segments.max(1);
        let t_rs = self.predict_phase(rs, nranks, chunk_bytes, Collective::ReduceScatter, placement);
        let t_ag = self.predict_phase(ag, nranks, chunk_bytes, Collective::AllGather, placement);
        t_rs + t_ag + (segments - 1) as f64 * t_rs.max(t_ag)
    }

    /// All-reduce crossover: sweep algorithm pairs × segment counts and
    /// return the cheapest [`Algorithm::Compose`]. `chunk_bytes` is the
    /// single-segment per-chunk payload (total bytes per rank / nranks);
    /// each candidate with `S` segments is costed at `chunk_bytes / S`.
    /// The buffer budget bounds the PAT aggregation exactly as for the
    /// standalone collectives (the reduce-scatter law is the binding
    /// one); hierarchical pairs are offered under the same leader-staging
    /// gate as [`Tuner::choose_placed`].
    pub fn choose_allreduce(
        &self,
        nranks: usize,
        chunk_bytes: usize,
        buffer_slots: usize,
        placement: Option<&Placement>,
    ) -> TunerChoice {
        // Pipelining keeps up to two segments' buffer footprints live at
        // once (segment i's staged finals + segment i+1's accumulators),
        // so the aggregation is sized against half the budget.
        let a = self.max_aggregation(
            nranks,
            (buffer_slots / 2).max(1),
            Collective::ReduceScatter,
        );
        let mut phases = vec![
            PhaseAlg::Pat { aggregation: a },
            PhaseAlg::Pat { aggregation: 1 },
            PhaseAlg::Ring,
        ];
        // A clamped budget makes the first two coincide; don't cost the
        // same pair twice.
        phases.dedup();
        if let Some(pl) = placement {
            let ah = pat::clamp_aggregation(pl.nnodes(), usize::MAX);
            // The pipelined fan-out's staging law (RS is the binding
            // phase of an all-reduce, as for `max_aggregation`).
            if pl.nnodes() > 1
                && pl.nnodes() < nranks
                && hier::staging_bound(pl, ah, Collective::ReduceScatter) <= buffer_slots
            {
                phases.push(PhaseAlg::HierPat { aggregation: ah });
            }
        }
        let mut candidates = Vec::new();
        for &rs in &phases {
            for &ag in &phases {
                for segments in [1usize, 2, 4, 8] {
                    let seg_bytes = (chunk_bytes / segments).max(1);
                    candidates.push((
                        Algorithm::Compose { rs, ag, segments },
                        self.predict_allreduce(rs, ag, segments, nranks, seg_bytes, placement),
                    ));
                }
            }
        }
        candidates.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
        TunerChoice {
            algorithm: candidates[0].0,
            predicted_seconds: candidates[0].1,
            candidates,
        }
    }

    /// Non-pipelined all-reduce lower bounds (Träff, arXiv:2410.14234)
    /// for `total_bytes` per rank over `nranks`: any reduce-scatter ∘
    /// all-gather realization needs at least `2·⌈log2 n⌉` communication
    /// rounds and must move `2·(n−1)/n` of the payload through every
    /// rank's NIC. Each bound is individually necessary, so their max
    /// floors every fused-schedule prediction — a closed form that
    /// drifted below it would be promising more than the network admits.
    pub fn allreduce_lower_bound(&self, nranks: usize, total_bytes: usize) -> f64 {
        if nranks <= 1 {
            return 0.0;
        }
        let rounds = 2.0 * ceil_log2(nranks) as f64 * self.cost.alpha_base;
        let volume = 2.0 * (nranks - 1) as f64 / nranks as f64 * total_bytes as f64 / self.nic_bw;
        rounds.max(volume)
    }

    /// Predicted wall time of a *bucketed* all-reduce
    /// ([`crate::sched::bucket`]): `bucket_bytes[b]` is bucket `b`'s
    /// payload per rank, each bucket split into `segments` internal
    /// segments. The (bucket, segment) units form one two-stage pipeline —
    /// unit `i+1`'s reduce-scatter overlaps unit `i`'s all-gather — so the
    /// generalized unequal-stage pipeline bound applies: the first unit
    /// pays its reduce-scatter, every later unit hides behind
    /// `max(rs_i, ag_{i−1})`, and the last unit pays its all-gather.
    /// With equal buckets this collapses to
    /// [`Tuner::predict_allreduce`]'s formula; the result is floored at
    /// [`Tuner::allreduce_lower_bound`].
    pub fn predict_bucketed(
        &self,
        rs: PhaseAlg,
        ag: PhaseAlg,
        bucket_bytes: &[usize],
        segments: usize,
        nranks: usize,
        placement: Option<&Placement>,
    ) -> f64 {
        let segments = segments.max(1);
        let mut stages: Vec<(f64, f64)> = Vec::with_capacity(bucket_bytes.len() * segments);
        let mut total = 0usize;
        for &bytes in bucket_bytes {
            total += bytes;
            let per_chunk = (bytes / (nranks.max(1) * segments)).max(1);
            let t_rs =
                self.predict_phase(rs, nranks, per_chunk, Collective::ReduceScatter, placement);
            let t_ag = self.predict_phase(ag, nranks, per_chunk, Collective::AllGather, placement);
            for _ in 0..segments {
                stages.push((t_rs, t_ag));
            }
        }
        let Some(&(first_rs, _)) = stages.first() else {
            return 0.0;
        };
        let mut t = first_rs;
        for i in 1..stages.len() {
            t += stages[i].0.max(stages[i - 1].1);
        }
        t += stages.last().unwrap().1;
        t.max(self.allreduce_lower_bound(nranks, total))
    }

    /// Gradient-bucketing crossover: split `total_bytes` per rank into
    /// B ∈ {1, 2, 4, 8} buckets, equal or ramp-shaped
    /// ([`bucket_sizes`]), and return the cheapest under
    /// [`Tuner::predict_bucketed`]. The phase pair is PAT at the budget's
    /// aggregation (the reduce-scatter law against *half* the budget —
    /// pipelining keeps two buckets' footprints live at once, exactly as
    /// for [`Tuner::choose_allreduce`]'s segments). Latency-bound totals
    /// stay at one bucket (every extra bucket adds a serialized stage);
    /// bandwidth-bound totals pipeline, and the ramp shape wins when the
    /// first stage is long enough to be worth halving.
    pub fn choose_bucketed(
        &self,
        nranks: usize,
        total_bytes: usize,
        buffer_slots: usize,
        placement: Option<&Placement>,
    ) -> BucketChoice {
        let a = self.max_aggregation(
            nranks,
            (buffer_slots / 2).max(1),
            Collective::ReduceScatter,
        );
        let rs = PhaseAlg::Pat { aggregation: a };
        let ag = rs;
        let mut candidates: Vec<(usize, bool, f64)> = Vec::new();
        for &b in &[1usize, 2, 4, 8] {
            for ramp in [false, true] {
                if b == 1 && ramp {
                    continue;
                }
                let sizes = bucket_sizes(total_bytes, b, ramp);
                let t = self.predict_bucketed(rs, ag, &sizes, 1, nranks, placement);
                candidates.push((b, ramp, t));
            }
        }
        candidates.sort_by(|x, y| {
            x.2.partial_cmp(&y.2).unwrap().then(x.0.cmp(&y.0)).then(x.1.cmp(&y.1))
        });
        let (b, ramp, t) = candidates[0];
        BucketChoice {
            bucket_bytes: bucket_sizes(total_bytes, b, ramp),
            rs,
            ag,
            predicted_seconds: t,
            candidates,
        }
    }

    /// Choose an algorithm for `nranks`, `chunk_bytes` per rank, and a
    /// `buffer_slots`-chunk intermediate buffer.
    pub fn choose(
        &self,
        nranks: usize,
        chunk_bytes: usize,
        buffer_slots: usize,
        coll: Collective,
    ) -> TunerChoice {
        self.choose_placed(nranks, chunk_bytes, buffer_slots, coll, None)
    }

    /// Placement-aware choice: like [`Tuner::choose`], additionally
    /// evaluating hierarchical PAT candidates when the placement spans
    /// multiple multi-rank nodes. Each hierarchical candidate is offered
    /// only when the buffer budget covers its leader staging need under
    /// the pipelined fan-out ([`crate::sched::hier::staging_bound`] —
    /// logarithmic in the node count, not the old Θ(n) bulk-fan-out
    /// requirement).
    pub fn choose_placed(
        &self,
        nranks: usize,
        chunk_bytes: usize,
        buffer_slots: usize,
        coll: Collective,
        placement: Option<&Placement>,
    ) -> TunerChoice {
        let a = self.max_aggregation(nranks, buffer_slots, coll);
        let rate = self.flat_rate(placement);
        // A contiguous ring crosses each node boundary once per step (one
        // flow per uplink), so it runs at min(nic, inter_bw), not the
        // k-way shared rate the dimension-hopping schedules pay.
        let ring_rate = if placement.is_some_and(|pl| pl.nnodes() > 1) {
            self.leader_rate()
        } else {
            self.nic_bw
        };
        let mut candidates = vec![
            (
                Algorithm::Pat { aggregation: a },
                self.predict_pat_at(nranks, a, chunk_bytes, rate),
            ),
            (
                Algorithm::Ring,
                self.predict_ring_at(nranks, chunk_bytes, ring_rate),
            ),
        ];
        // Also consider intermediate aggregations (a smaller a can win when
        // pack cost dominates).
        let mut sub = a;
        while sub > 1 {
            sub /= 2;
            candidates.push((
                Algorithm::Pat { aggregation: sub },
                self.predict_pat_at(nranks, sub, chunk_bytes, rate),
            ));
        }
        if let Some(pl) = placement {
            if pl.nnodes() > 1 && pl.nnodes() < nranks {
                let mut ah = pat::clamp_aggregation(pl.nnodes(), usize::MAX);
                loop {
                    // Gate each aggregation on the pipelined fan-out's
                    // leader staging law, not a flat `n`-slot requirement
                    // — the law is logarithmic in the node count, so
                    // modest budgets admit hierarchy at scale.
                    if hier::staging_bound(pl, ah, coll) <= buffer_slots {
                        candidates.push((
                            Algorithm::HierPat { aggregation: ah },
                            self.predict_hier(pl, ah, chunk_bytes),
                        ));
                    }
                    if ah <= 1 {
                        break;
                    }
                    ah /= 2;
                }
            }
        }
        candidates.sort_by(|x, y| x.1.partial_cmp(&y.1).unwrap());
        TunerChoice {
            algorithm: candidates[0].0,
            predicted_seconds: candidates[0].1,
            candidates,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_pick_pat_large_pick_ring_or_pat1() {
        let t = Tuner::default();
        let small = t.choose(64, 256, 1 << 20, Collective::AllGather);
        assert!(
            matches!(small.algorithm, Algorithm::Pat { aggregation } if aggregation > 1),
            "{:?}",
            small.algorithm
        );
        // At huge sizes the per-chunk pack cost and serialization dominate:
        // ring (contiguous, pipelined) or pat(a=1) (also contiguous) win.
        let large = t.choose(64, 64 << 20, 1 << 20, Collective::AllGather);
        match large.algorithm {
            Algorithm::Ring | Algorithm::Pat { aggregation: 1 } => {}
            other => panic!("large message picked {other:?}"),
        }
    }

    #[test]
    fn buffer_budget_caps_aggregation() {
        let t = Tuner::default();
        // RS on 64 ranks: a=8 needs 8*log2(64/8)=24 slots.
        assert_eq!(t.max_aggregation(64, 24, Collective::ReduceScatter), 8);
        assert_eq!(t.max_aggregation(64, 23, Collective::ReduceScatter), 4);
        assert_eq!(t.max_aggregation(64, 1, Collective::ReduceScatter), 1);
        // AG is bounded by the transfer itself.
        assert_eq!(t.max_aggregation(64, 8, Collective::AllGather), 8);
    }

    #[test]
    fn predictions_monotone_in_ranks() {
        let t = Tuner::default();
        assert!(t.predict_ring(128, 1024) > t.predict_ring(16, 1024));
        assert!(t.predict_pat(128, 8, 1024) > t.predict_pat(16, 8, 1024));
        let small = Placement::uniform(16, 8).unwrap();
        let big = Placement::uniform(128, 8).unwrap();
        assert!(t.predict_hier(&big, 4, 1024) > t.predict_hier(&small, 4, 1024));
    }

    /// The tuner's pick must be within 5% of the best candidate it saw
    /// (trivially true) and PAT must beat ring by ~(n-1)/log2(n) at tiny
    /// sizes.
    #[test]
    fn pat_speedup_at_small_sizes() {
        let t = Tuner::default();
        let n = 128;
        let pat_t = t.predict_pat(n, 64, 64);
        let ring_t = t.predict_ring(n, 64);
        let speedup = ring_t / pat_t;
        let ideal = (n - 1) as f64 / (ceil_log2(n) as f64);
        assert!(
            speedup > ideal * 0.5,
            "speedup {speedup:.1} vs ideal {ideal:.1}"
        );
    }

    /// The flat-vs-hierarchical crossover on a tapered fabric (a node's 8
    /// ranks share one NIC-worth of uplink): tiny messages stay with flat
    /// PAT (fewest serial phases), the mid-size band goes hierarchical
    /// (flat PAT pays the k-way uplink share, ring pays (n-1)·α), and the
    /// bandwidth extreme goes to Ring (one boundary flow per uplink, full
    /// pipeline) — NCCL's actual regime split. On a non-blocking fabric
    /// the flat schedules win everywhere.
    #[test]
    fn hier_crossover_tracks_fabric_taper() {
        let pl = Placement::uniform(64, 8).unwrap();
        let slots = usize::MAX / 2;
        // 8 ranks share one NIC-worth of uplink
        let tapered = Tuner {
            inter_bw: Some(CostModel::ib_hdr_nic_bw()),
            ..Tuner::default()
        };
        let tiny = tapered.choose_placed(64, 64, slots, Collective::AllGather, Some(&pl));
        assert!(
            matches!(tiny.algorithm, Algorithm::Pat { .. }),
            "tapered tiny-message pick: {:?}",
            tiny.algorithm
        );
        let mid = tapered.choose_placed(64, 4 << 10, slots, Collective::AllGather, Some(&pl));
        assert!(
            matches!(mid.algorithm, Algorithm::HierPat { .. }),
            "tapered mid-size pick: {:?}",
            mid.algorithm
        );
        let big = tapered.choose_placed(64, 1 << 20, slots, Collective::AllGather, Some(&pl));
        assert!(
            matches!(big.algorithm, Algorithm::Ring),
            "tapered big-message pick: {:?}",
            big.algorithm
        );
        let flat = Tuner::default();
        for chunk in [64usize, 4 << 10, 1 << 20] {
            let pick = flat.choose_placed(64, chunk, slots, Collective::AllGather, Some(&pl));
            assert!(
                !matches!(pick.algorithm, Algorithm::HierPat { .. }),
                "non-blocking fabric pick at {chunk}: {:?}",
                pick.algorithm
            );
        }
    }

    /// Channel crossover: one channel at latency-bound sizes (the
    /// per-round channel tax), `parallel_links` channels at
    /// bandwidth-bound sizes on a multi-rail fabric, and never more
    /// channels than links. A single-link fabric stays single-channel at
    /// every size.
    #[test]
    fn channel_crossover_tracks_parallel_links() {
        let quad = Tuner { parallel_links: 4, ..Tuner::default() };
        let tiny = quad.choose_channels(64, usize::MAX, 64);
        assert_eq!(tiny.channels, 1, "{:?}", tiny.candidates);
        let big = quad.choose_channels(64, usize::MAX, 4 << 20);
        assert!(big.channels > 1, "{:?}", big.candidates);
        assert!(big.channels <= 4, "{:?}", big.candidates);

        let single = Tuner::default(); // parallel_links = 1
        for chunk in [64usize, 64 << 10, 4 << 20] {
            let pick = single.choose_channels(64, usize::MAX, chunk);
            assert_eq!(pick.channels, 1, "chunk={chunk}: {:?}", pick.candidates);
        }
        // C = 1 prediction coincides with the flat PAT prediction
        let a = 4;
        let p1 = quad.predict_channels(32, a, 1024, 1);
        let flat = quad.predict_pat(32, a, 1024);
        assert!((p1 - flat).abs() < 1e-12, "{p1} vs {flat}");
    }

    /// The pipeline formula: one segment pays both phases; S segments at
    /// tiny sizes only add serialized stages (S=1 wins), while at
    /// bandwidth-bound sizes splitting shrinks every stage (S>1 wins) —
    /// the segment-count crossover.
    #[test]
    fn allreduce_segment_crossover() {
        let t = Tuner::default();
        let n = 64;
        let rs = PhaseAlg::Pat { aggregation: usize::MAX };
        let s1 = t.predict_allreduce(rs, rs, 1, n, 1024, None);
        let t_rs = t.predict_phase(rs, n, 1024, Collective::ReduceScatter, None);
        let t_ag = t.predict_phase(rs, n, 1024, Collective::AllGather, None);
        assert!((s1 - (t_rs + t_ag)).abs() < 1e-12);

        let tiny = t.choose_allreduce(n, 64, 1 << 30, None);
        match tiny.algorithm {
            Algorithm::Compose { segments, .. } => assert_eq!(segments, 1, "{:?}", tiny.algorithm),
            other => panic!("expected a composition, got {other:?}"),
        }
        let big = t.choose_allreduce(n, 4 << 20, 1 << 30, None);
        match big.algorithm {
            Algorithm::Compose { segments, .. } => {
                assert!(segments > 1, "{:?}", big.algorithm)
            }
            other => panic!("expected a composition, got {other:?}"),
        }
    }

    /// `bucket_sizes` always sums to the total; the ramp shape halves the
    /// first bucket against the steady size.
    #[test]
    fn bucket_sizes_sum_and_ramp_shape() {
        for total in [0usize, 1, 1 << 10, (1 << 20) + 7] {
            for b in [1usize, 2, 4, 8] {
                for ramp in [false, true] {
                    let v = bucket_sizes(total, b, ramp);
                    assert_eq!(v.len(), b);
                    assert_eq!(v.iter().sum::<usize>(), total, "total={total} b={b} ramp={ramp}");
                }
            }
        }
        let v = bucket_sizes(9 << 20, 4, true);
        // steady = 2·9M/7; first ≈ steady/2 (integer division slack ≤ 1)
        assert!(v[0] <= v[1] / 2 + 1, "{v:?}");
        assert!(v[0] > 0);
        let eq = bucket_sizes(1 << 20, 4, false);
        assert!(eq.windows(2).all(|w| w[0] == w[1]), "{eq:?}");
    }

    /// Equal buckets collapse to the segment-pipeline formula (floored at
    /// the lower bound), and the empty batch predicts zero.
    #[test]
    fn predict_bucketed_matches_segment_pipeline_on_equal_buckets() {
        let t = Tuner::default();
        let n = 64;
        let rs = PhaseAlg::Pat { aggregation: usize::MAX };
        for total in [64usize << 10, 4 << 20] {
            for b in [1usize, 2, 4] {
                let sizes = bucket_sizes(total, b, false);
                if sizes.windows(2).any(|w| w[0] != w[1]) {
                    continue; // only the exactly-equal case collapses
                }
                let bucketed = t.predict_bucketed(rs, rs, &sizes, 1, n, None);
                let composed =
                    t.predict_allreduce(rs, rs, b, n, (total / (n * b)).max(1), None);
                let floored = composed.max(t.allreduce_lower_bound(n, total));
                assert!(
                    (bucketed - floored).abs() < 1e-12,
                    "total={total} b={b}: {bucketed} vs {floored}"
                );
            }
        }
        assert_eq!(t.predict_bucketed(rs, rs, &[], 1, n, None), 0.0);
    }

    /// The bucket-count crossover: tiny totals stay at one bucket (each
    /// extra bucket is a serialized stage), large totals pipeline across
    /// buckets. Predictions never fall below the non-pipelined lower
    /// bound.
    #[test]
    fn bucketed_crossover_and_lower_bound() {
        let t = Tuner::default();
        let n = 64;
        let slots = 1 << 30;
        let tiny = t.choose_bucketed(n, 2 << 10, slots, None);
        assert_eq!(tiny.bucket_bytes.len(), 1, "{:?}", tiny.candidates);
        let big = t.choose_bucketed(n, 16 << 20, slots, None);
        assert!(big.bucket_bytes.len() > 1, "{:?}", big.candidates);
        for &(b, ramp, pred) in &big.candidates {
            let lb = t.allreduce_lower_bound(n, 16 << 20);
            assert!(
                pred >= lb - 1e-15,
                "B={b} ramp={ramp}: prediction {pred} below lower bound {lb}"
            );
        }
        // the lower bound itself behaves: zero for one rank, monotone in
        // bytes, and below the serialized two-phase prediction
        assert_eq!(t.allreduce_lower_bound(1, 1 << 20), 0.0);
        assert!(t.allreduce_lower_bound(64, 2 << 20) > t.allreduce_lower_bound(64, 1 << 20));
        let rs = PhaseAlg::Pat { aggregation: usize::MAX };
        let serial = t.predict_allreduce(rs, rs, 1, 64, (1 << 20) / 64, None);
        assert!(t.allreduce_lower_bound(64, 1 << 20) <= serial);
    }

    /// Hierarchical pairs obey the same leader-staging budget gate as the
    /// standalone hierarchical candidates.
    #[test]
    fn allreduce_hier_pairs_gated_on_budget() {
        let pl = Placement::uniform(64, 8).unwrap();
        let t = Tuner {
            inter_bw: Some(CostModel::ib_hdr_nic_bw()),
            ..Tuner::default()
        };
        let tight = t.choose_allreduce(64, 1 << 20, 16, Some(&pl));
        assert!(
            tight.candidates.iter().all(|(alg, _)| match alg {
                Algorithm::Compose { rs, ag, .. } => {
                    !matches!(rs, PhaseAlg::HierPat { .. })
                        && !matches!(ag, PhaseAlg::HierPat { .. })
                }
                _ => true,
            }),
            "{:?}",
            tight.candidates
        );
        let roomy = t.choose_allreduce(64, 1 << 20, usize::MAX / 2, Some(&pl));
        assert!(
            roomy.candidates.iter().any(|(alg, _)| matches!(
                alg,
                Algorithm::Compose { rs: PhaseAlg::HierPat { .. }, .. }
            )),
            "hier pairs should be on offer with a roomy budget"
        );
    }

    /// Hierarchical candidates need the leader staging budget
    /// ([`hier::staging_bound`]); with a tight buffer the tuner must not
    /// offer them.
    #[test]
    fn hier_gated_on_buffer_budget() {
        let pl = Placement::uniform(64, 8).unwrap();
        let t = Tuner {
            inter_bw: Some(CostModel::ib_hdr_nic_bw()),
            ..Tuner::default()
        };
        let choice = t.choose_placed(64, 1 << 20, 16, Collective::AllGather, Some(&pl));
        assert!(
            choice
                .candidates
                .iter()
                .all(|(alg, _)| !matches!(alg, Algorithm::HierPat { .. })),
            "{:?}",
            choice.candidates
        );
    }

    /// The pipelined fan-out's staging law is logarithmic in the node
    /// count, so a budget well under `n` slots still admits hierarchy at
    /// scale — the old flat `buffer_slots >= nranks` gate would have
    /// refused every hierarchical candidate here.
    #[test]
    fn staging_law_admits_hier_under_modest_budget() {
        let pl = Placement::uniform(256, 8).unwrap();
        let t = Tuner {
            inter_bw: Some(CostModel::ib_hdr_nic_bw()),
            ..Tuner::default()
        };
        let slots = 128; // < nranks = 256
        let choice = t.choose_placed(256, 4 << 10, slots, Collective::AllGather, Some(&pl));
        assert!(
            choice
                .candidates
                .iter()
                .any(|(alg, _)| matches!(alg, Algorithm::HierPat { .. })),
            "no hierarchical candidate under the staging law: {:?}",
            choice.candidates
        );
        // every offered hierarchical aggregation actually fits the law
        for (alg, _) in &choice.candidates {
            if let Algorithm::HierPat { aggregation } = alg {
                assert!(
                    hier::staging_bound(&pl, *aggregation, Collective::AllGather) <= slots,
                    "a={aggregation} offered beyond the staging law"
                );
            }
        }
    }

    /// Multi-leader striping lifts the single-leader NIC bottleneck in
    /// the closed form: on a fabric whose node uplink is wider than one
    /// NIC, L = 4 leaders predict strictly faster than L = 1 at
    /// bandwidth-bound sizes, and never slower at any swept size.
    #[test]
    fn striping_lifts_leader_nic_bottleneck() {
        let nic = CostModel::ib_hdr_nic_bw();
        let pl1 = Placement::uniform(64, 8).unwrap();
        let pl4 = Placement::uniform(64, 8).unwrap().with_leaders(4).unwrap();
        // rail-optimized node: aggregate uplink = 4 NICs' worth
        let t = Tuner { inter_bw: Some(4.0 * nic), ..Tuner::default() };
        let big1 = t.predict_hier(&pl1, 4, 256 << 10);
        let big4 = t.predict_hier(&pl4, 4, 256 << 10);
        assert!(
            big4 < big1 * 0.75,
            "L=4 ({big4:.6}s) should beat L=1 ({big1:.6}s) at 256 KiB"
        );
        for chunk in [64usize, 4 << 10, 64 << 10] {
            let p1 = t.predict_hier(&pl1, 4, chunk);
            let p4 = t.predict_hier(&pl4, 4, chunk);
            assert!(p4 <= p1 * 1.001, "chunk={chunk}: L=4 {p4} vs L=1 {p1}");
        }
    }

    /// Three-level recursion predicts: a podded placement costs more than
    /// its two-level flattening of the same nodes would at the pod tier
    /// alone, stays finite and monotone in chunk size.
    #[test]
    fn three_level_prediction_sane() {
        let pl = Placement::parse("8x4", 256).unwrap();
        assert!(pl.is_three_level());
        let t = Tuner {
            inter_bw: Some(CostModel::ib_hdr_nic_bw()),
            ..Tuner::default()
        };
        let small = t.predict_hier(&pl, 4, 4 << 10);
        let big = t.predict_hier(&pl, 4, 1 << 20);
        assert!(small > 0.0 && big > small, "small={small} big={big}");
        // the two-level view of the same nodes runs more inter-node
        // rounds over 32 leaders; the podded recursion must not predict
        // slower than ~the flat-leader schedule at latency-bound sizes
        let flat = Placement::uniform(256, 8).unwrap();
        let tl = t.predict_hier(&pl, 4, 64);
        let two = t.predict_hier(&flat, 4, 64);
        assert!(tl.is_finite() && two.is_finite());
    }
}
