//! The α-β-γ cost model.
//!
//! * `alpha_base` — per-message software/NIC initiation overhead (the α in
//!   α-β models; a few microseconds for IB verbs + NCCL proxies).
//! * `alpha_hop` — per-switch-hop propagation/forwarding latency.
//! * link bandwidth — per-link serialization (β) lives on the
//!   [`crate::sim::topology::Link`], so tapered tiers serialize slower.
//! * `gamma_chunk` / `gamma_byte` — *local* per-chunk and per-byte handling
//!   cost for non-contiguous aggregation (pack/unpack). This is PAT's
//!   "linear part [that] is purely local" (paper §Performance).
//! * `msg_gap` — minimum spacing between messages injected by one NIC
//!   (inverse message rate). This is Ring's linear part: "more related to
//!   the message rate of the network than its latency".
//! * `reduce_byte` — per-byte cost of the reduction on the RS datapath.

/// Cost model parameters. All times in seconds, bandwidth in bytes/s.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    pub alpha_base: f64,
    pub alpha_hop: f64,
    pub gamma_chunk: f64,
    pub gamma_byte: f64,
    pub msg_gap: f64,
    pub reduce_byte: f64,
}

impl CostModel {
    /// An HDR-InfiniBand-like profile: 25 GB/s NICs (set on the topology),
    /// ~2 µs message overhead, 150 ns per hop, ~200M msg/s NIC message
    /// rate, 50 ns per-chunk local handling (GPU copy-engine descriptor
    /// cost), ~200 GB/s local pack/reduce bandwidth. The per-chunk constant
    /// is the knob the paper's §Performance discusses ("depending on the
    /// amount of optimization we can achieve on those linear parts … the
    /// algorithm may look linear or logarithmic"); the ablation bench
    /// sweeps it.
    pub fn ib_hdr() -> CostModel {
        CostModel {
            alpha_base: 2.0e-6,
            alpha_hop: 150e-9,
            gamma_chunk: 50e-9,
            gamma_byte: 1.0 / 200e9,
            msg_gap: 5e-9,
            reduce_byte: 1.0 / 200e9,
        }
    }

    /// NIC bandwidth matching the ib_hdr profile (bytes/s).
    pub fn ib_hdr_nic_bw() -> f64 {
        25e9
    }

    /// A latency-dominated profile (slow software stack, e.g. TCP):
    /// stresses the logarithmic-vs-linear step-count difference.
    pub fn tcp_like() -> CostModel {
        CostModel {
            alpha_base: 30e-6,
            alpha_hop: 1e-6,
            gamma_chunk: 1e-6,
            gamma_byte: 1.0 / 20e9,
            msg_gap: 2e-6,
            reduce_byte: 1.0 / 20e9,
        }
    }

    /// Zero-overhead model: pure link serialization. Useful in tests to
    /// isolate bandwidth effects.
    pub fn ideal() -> CostModel {
        CostModel {
            alpha_base: 0.0,
            alpha_hop: 0.0,
            gamma_chunk: 0.0,
            gamma_byte: 0.0,
            msg_gap: 0.0,
            reduce_byte: 0.0,
        }
    }

    /// Local pack/unpack cost for a message of `chunks` pieces totalling
    /// `bytes` (zero when the payload is a single contiguous chunk).
    pub fn pack_cost(&self, chunks: usize, bytes: usize) -> f64 {
        if chunks <= 1 {
            0.0
        } else {
            self.gamma_chunk * chunks as f64 + self.gamma_byte * bytes as f64
        }
    }

    /// Reduction cost for folding `bytes` into an accumulator.
    pub fn reduce_cost(&self, bytes: usize) -> f64 {
        self.reduce_byte * bytes as f64
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::ib_hdr()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_free_for_contiguous() {
        let c = CostModel::ib_hdr();
        assert_eq!(c.pack_cost(1, 1 << 20), 0.0);
        assert!(c.pack_cost(4, 1 << 20) > 0.0);
    }

    #[test]
    fn profiles_ordered() {
        assert!(CostModel::tcp_like().alpha_base > CostModel::ib_hdr().alpha_base);
        assert_eq!(CostModel::ideal().alpha_base, 0.0);
    }
}
