//! Episode runner: execute one workload through the real threaded
//! transport under an adversarial delivery policy, compare against the
//! reference result, and blame failures.
//!
//! An **episode** is one seeded run of a [`Workload`] (collective ×
//! algorithm × ranks × channels point) with a fresh per-rank policy
//! family from a [`PolicySpec`]. Episodes are independent and
//! deterministic in `(workload, policy, episode index)` up to OS thread
//! scheduling — the perturbations a policy *applies* are recorded as
//! [`Deviation`]s, which is what makes a failing episode replayable (see
//! [`crate::adversary::shrink`]).
//!
//! Every episode runs with the **sound slot capacity** enforced: `C ×
//! max(verifier occupancy, max aggregation)` of the unsplit program
//! (channels progress independently and share the rank's pool, so the
//! per-channel bound multiplies by the channel count — see
//! [`crate::transport::TransportOptions::slot_capacity`]). A healthy
//! schedule under any delivery order must stay within it; exceeding it
//! is a failure the episode reports, not an artifact.

use std::time::Duration;

use crate::core::{AlgSpec, Algorithm, Collective, Error, Placement, Rank, Result};
use crate::obs::{Event, EventKind, TraceRecorder};
use crate::sched;
use crate::sched::program::Program;
use crate::sched::verify::verify_program;
use crate::transport::{run_allgather, run_reduce_scatter, TransportOptions, TransportReport};

use super::policy::{drain_log, new_log, Deviation, PolicySpec};
use super::shrink::{self, ShrinkResult};
use super::ReplayTrace;

/// One collective execution point the adversary drives.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Workload {
    pub collective: Collective,
    /// Algorithm plus channel count ([`AlgSpec`] grammar, e.g. `pat:2*2`).
    pub spec: AlgSpec,
    pub nranks: usize,
    /// Per-rank slot payload in elements (padded up to a multiple of the
    /// channel count by [`Workload::new`]).
    pub elems: usize,
    /// Input-data seed (also the base for episode seeds).
    pub seed: u64,
}

impl Workload {
    /// Build a workload, padding `elems` to the channel stripe count the
    /// way the communicator pads odd payloads.
    pub fn new(
        collective: Collective,
        spec: AlgSpec,
        nranks: usize,
        elems: usize,
        seed: u64,
    ) -> Workload {
        let c = spec.channels.max(1);
        let elems = elems.max(1).div_ceil(c) * c;
        Workload { collective, spec, nranks, elems, seed }
    }

    /// One-line label for logs and reports.
    pub fn describe(&self) -> String {
        format!(
            "{} {} n={} elems={} seed={}",
            self.collective.as_str(),
            self.spec.spec(),
            self.nranks,
            self.elems,
            self.seed
        )
    }

    /// Generate the (channel-split) program plus the sound slot capacity
    /// (see the module docs).
    pub fn build(&self) -> Result<(Program, usize)> {
        let n = self.nranks;
        let base = match self.spec.alg {
            Algorithm::HierPat { .. } => {
                let node = if n >= 8 && n % 4 == 0 {
                    4
                } else if n >= 4 && n % 2 == 0 {
                    2
                } else {
                    return Err(Error::Config(format!(
                        "hier workload needs an even rank count >= 4, got {n}"
                    )));
                };
                sched::generate_placed(self.spec.alg, self.collective, &Placement::uniform(n, node)?)?
            }
            _ => sched::generate(self.spec.alg, self.collective, n)?,
        };
        let occ = verify_program(&base)?;
        let per_channel = occ.peak_slots.max(base.stats().max_aggregation).max(1);
        let cap = per_channel * self.spec.channels.max(1);
        let p = sched::channel::split(&base, self.spec.channels.max(1))?;
        Ok((p, cap))
    }

    /// Deterministic integer-valued inputs, pairwise distinct across
    /// (rank, element) so any misplaced chunk is visible at element 0 of
    /// the damage. Values stay far below 2^24, keeping every f32 sum
    /// exact — adversarial runs must be bit-identical to clean ones.
    pub fn inputs(&self) -> Vec<Vec<f32>> {
        let n = self.nranks;
        let per = match self.collective {
            Collective::AllGather => self.elems,
            _ => self.elems * n,
        };
        let base = 1 + (self.seed % 5) as usize;
        (0..n)
            .map(|r| (0..per).map(|i| (base + r * per + i) as f32).collect())
            .collect()
    }

    /// The reference result (exact, computed directly from the inputs).
    pub fn expected(&self, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let n = self.nranks;
        match self.collective {
            Collective::AllGather => {
                let mut all = Vec::with_capacity(n * self.elems);
                for inp in inputs {
                    all.extend_from_slice(inp);
                }
                vec![all; n]
            }
            _ => {
                let l = self.elems;
                (0..n)
                    .map(|r| {
                        let mut out = vec![0f32; l];
                        for inp in inputs {
                            for (o, x) in out.iter_mut().zip(&inp[r * l..(r + 1) * l]) {
                                *o += x;
                            }
                        }
                        out
                    })
                    .collect()
            }
        }
    }

    /// Execute the workload on the threaded transport.
    pub fn run(
        &self,
        p: &Program,
        inputs: &[Vec<f32>],
        opts: &TransportOptions,
    ) -> Result<(Vec<Vec<f32>>, TransportReport)> {
        match self.collective {
            Collective::AllGather => run_allgather(p, inputs, opts),
            Collective::ReduceScatter => run_reduce_scatter(p, inputs, opts),
            Collective::AllReduce => Err(Error::Unsupported(
                "adversary workloads cover ag and rs (allreduce = rs∘ag composition)".into(),
            )),
        }
    }

    /// First output mismatch vs the reference, as a blame: the damaged
    /// chunk id names the (rank, channel) coordinates (`step` is 0 —
    /// result damage is observed after the schedule finishes, not at a
    /// step). Scans ranks then elements in order, so the blame is
    /// deterministic for a deterministic data flow.
    pub fn compare(&self, outputs: &[Vec<f32>], expected: &[Vec<f32>]) -> Option<Blame> {
        let n = self.nranks;
        let c = self.spec.channels.max(1);
        let sub = self.elems / c;
        for (r, (out, want)) in outputs.iter().zip(expected).enumerate() {
            if let Some(i) = out.iter().zip(want).position(|(a, b)| a != b) {
                let (slot, o) = match self.collective {
                    Collective::AllGather => (i / self.elems, i % self.elems),
                    _ => (r, i),
                };
                let stripe = if sub == 0 { 0 } else { o / sub };
                let chunk = stripe * n + slot;
                return Some(Blame {
                    rank: r,
                    channel: stripe,
                    step: 0,
                    kind: format!("wrong-result chunk {chunk}"),
                });
            }
        }
        None
    }
}

/// Where and what failed, in stable coordinates: equality of blames is
/// the shrinker's reproduction criterion.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Blame {
    pub rank: Rank,
    pub channel: usize,
    pub step: usize,
    /// Coarse failure category (stable across runs; counts and live
    /// totals are stripped).
    pub kind: String,
}

impl Blame {
    /// Whether this blame is a watchdog timeout — excluded from shrink
    /// reproduction so counterexamples never converge onto
    /// timing-dependent artifacts.
    pub fn is_timeout(&self) -> bool {
        self.kind == "watchdog-timeout"
    }

    pub fn describe(&self) -> String {
        format!(
            "rank {} channel {} step {}: {}",
            self.rank, self.channel, self.step, self.kind
        )
    }
}

/// Extract a blame from a transport error message. The transport's
/// errors carry their coordinates in text ("rank 3", "channel 0",
/// "step 2" — see `blame_timeout` and the pool's annotated exhaustion
/// errors); this parses the first occurrence of each and buckets the
/// message into a stable category.
pub fn parse_blame(err: &str) -> Blame {
    let kind = if err.contains("timed out") {
        "watchdog-timeout".to_string()
    } else if err.contains("buffer pool exhausted") {
        "pool-exhausted".to_string()
    } else if err.contains("elems, want") {
        "length-mismatch".to_string()
    } else {
        let first = err.lines().next().unwrap_or("");
        first.chars().take(60).collect()
    };
    Blame {
        rank: coord_after(err, "rank ").unwrap_or(0),
        channel: coord_after(err, "channel ").unwrap_or(0),
        step: coord_after(err, "step ").unwrap_or(0),
        kind,
    }
}

/// First unsigned integer following the first occurrence of `label`.
fn coord_after(text: &str, label: &str) -> Option<usize> {
    let at = text.find(label)? + label.len();
    let rest = &text[at..];
    let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

/// A failing episode: the blame plus everything needed to replay it.
#[derive(Debug, Clone)]
pub struct Failure {
    pub blame: Blame,
    /// The raw transport error, when the failure was an error rather
    /// than silent result damage.
    pub error: Option<String>,
    /// The perturbations the policy actually applied this episode.
    pub deviations: Vec<Deviation>,
}

/// Outcome of one episode.
#[derive(Debug, Clone)]
pub struct EpisodeOutcome {
    pub episode: u64,
    /// Deviations the policy applied (0 = the run was effectively clean).
    pub deviations: usize,
    /// Force-released holds (bounded-hold rule firings).
    pub forced: usize,
    /// Decision points seen across all ranks.
    pub decisions: u64,
    /// Peak pool slots (0 when the run failed before reporting).
    pub peak_slots: usize,
    pub failure: Option<Failure>,
}

/// Watchdog for adversarial runs: long enough for held schedules on a
/// loaded CI box, short enough that failing episodes and deliberate
/// deadlock trials resolve quickly.
pub const EPISODE_TIMEOUT: Duration = Duration::from_secs(3);

/// Transport options for one adversarial run.
pub(crate) fn episode_options(
    cap: usize,
    delivery: crate::transport::DeliveryFactory,
) -> TransportOptions {
    TransportOptions {
        slot_capacity: Some(cap),
        recv_timeout: EPISODE_TIMEOUT,
        delivery: Some(delivery),
        ..TransportOptions::default()
    }
}

/// Run episode `episode` of `w` under `policy`. Harness-level problems
/// (program generation, verification) return `Err`; transport failures
/// and wrong results land in [`EpisodeOutcome::failure`].
pub fn run_episode(w: &Workload, policy: &PolicySpec, episode: u64) -> Result<EpisodeOutcome> {
    let (p, cap) = w.build()?;
    let inputs = w.inputs();
    let expected = w.expected(&inputs);
    let sink = new_log();
    let opts = episode_options(cap, policy.factory(episode, sink.clone()));
    let run = w.run(&p, &inputs, &opts);
    let log = drain_log(&sink);
    let mut outcome = EpisodeOutcome {
        episode,
        deviations: log.deviations.len(),
        forced: log.forced,
        decisions: log.decisions,
        peak_slots: 0,
        failure: None,
    };
    match run {
        Ok((outputs, rep)) => {
            outcome.peak_slots = rep.peak_slots;
            if let Some(blame) = w.compare(&outputs, &expected) {
                outcome.failure =
                    Some(Failure { blame, error: None, deviations: log.deviations });
            }
        }
        Err(e) => {
            let text = e.to_string();
            outcome.failure = Some(Failure {
                blame: parse_blame(&text),
                error: Some(text),
                deviations: log.deviations,
            });
        }
    }
    Ok(outcome)
}

/// What an exploration run found.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    pub workload: Workload,
    pub policy: PolicySpec,
    /// Episodes actually run (stops early at the first shrinkable
    /// failure).
    pub episodes_run: u64,
    /// Failing episodes seen (including the counterexample's).
    pub failures: usize,
    /// Watchdog-timeout failures skipped as shrink candidates.
    pub timeouts_skipped: usize,
    pub total_deviations: u64,
    pub total_decisions: u64,
    /// Shrunk, replayable counterexample from the first deterministic
    /// failure.
    pub counterexample: Option<ReplayTrace>,
    /// Shrink statistics when a counterexample was produced.
    pub shrink: Option<ShrinkResult>,
}

/// Run up to `episodes` seeded episodes; on the first non-timeout
/// failure, shrink its deviation list to a minimal replayable trace and
/// stop. Episode outcomes (and shrink trials) are recorded into `obs`
/// as [`EventKind::Adversary`] events on a synthetic per-index timeline.
pub fn explore(
    w: &Workload,
    policy: &PolicySpec,
    episodes: u64,
    mut obs: Option<&mut TraceRecorder>,
) -> Result<ExploreReport> {
    let mut report = ExploreReport {
        workload: w.clone(),
        policy: *policy,
        episodes_run: 0,
        failures: 0,
        timeouts_skipped: 0,
        total_deviations: 0,
        total_decisions: 0,
        counterexample: None,
        shrink: None,
    };
    for episode in 0..episodes {
        let outcome = run_episode(w, policy, episode)?;
        report.episodes_run += 1;
        report.total_deviations += outcome.deviations as u64;
        report.total_decisions += outcome.decisions;
        let failed = outcome.failure.is_some();
        if let Some(rec) = obs.as_mut() {
            let t = episode as f64;
            rec.record(
                Event::span(EventKind::Adversary, 0, 0, episode as usize, t, t + 1.0)
                    .with_value(outcome.deviations)
                    .with_bytes(usize::from(failed)),
            );
        }
        if let Some(failure) = outcome.failure {
            report.failures += 1;
            if failure.blame.is_timeout() {
                // Timing artifact, not a deterministic counterexample:
                // keep exploring (the deadlock is still reported if the
                // whole sweep finds nothing better — the caller sees
                // `failures > 0`).
                report.timeouts_skipped += 1;
                continue;
            }
            let shrunk = shrink::shrink(w, &failure, obs.as_mut().map(|r| &mut **r))?;
            report.counterexample = Some(ReplayTrace::new(w, policy, episode, &shrunk));
            report.shrink = Some(shrunk);
            break;
        }
    }
    Ok(report)
}
