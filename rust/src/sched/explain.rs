//! Human-readable schedule rendering — regenerates the paper's figures as
//! text: per-step transfer lists (Figs. 1, 3, 5), per-root broadcast trees
//! (Figs. 2, 4, 6–10), and the reduce-scatter mirror (Fig. 11).

use std::fmt::Write as _;

use crate::core::{Collective, Placement};
use crate::sched::pat::{self, StepPhase};
use crate::sched::program::Program;
use crate::sched::tree::FarFirstTree;
use crate::sched::hier;

/// Render the global step-by-step transfer table of a program, one line per
/// message, grouped by step — the "what does each rank send when" view of
/// Figs. 1/3/5. Multi-channel programs gain a channel column (the
/// connection each message rides); single-channel output is unchanged.
pub fn render_steps(p: &Program) -> String {
    let mut out = String::new();
    if p.channels > 1 {
        let _ = writeln!(
            out,
            "{} / {} on {} ranks — {} steps, {} channels",
            p.algorithm, p.collective, p.nranks, p.steps, p.channels
        );
    } else {
        let _ = writeln!(
            out,
            "{} / {} on {} ranks — {} steps",
            p.algorithm, p.collective, p.nranks, p.steps
        );
    }
    for (step, msgs) in p.rounds() {
        let _ = writeln!(out, "step {step}:");
        for m in msgs {
            let dist = ring_distance(m.src, m.dst, p.nranks);
            if p.channels > 1 {
                let _ = writeln!(
                    out,
                    "  {:>3} -> {:<3} ch {:>2}  dist {:>3}  chunks {:?}",
                    m.src, m.dst, m.channel, dist, m.chunks
                );
            } else {
                let _ = writeln!(
                    out,
                    "  {:>3} -> {:<3} dist {:>3}  chunks {:?}",
                    m.src, m.dst, dist, m.chunks
                );
            }
        }
    }
    out
}

/// Render one rank's program (op-by-op), the per-rank view used to inspect
/// FIFO order and buffer behaviour. Multi-channel ops carry a `/c<k>`
/// channel tag.
pub fn render_rank(p: &Program, rank: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "rank {rank} program ({}):", p.algorithm);
    let multi = p.channels > 1;
    for op in &p.ranks[rank] {
        let tag = if multi {
            format!("s{}/c{}", op.step(), op.channel())
        } else {
            format!("s{}", op.step())
        };
        match op {
            crate::sched::program::Op::Send { peer, chunks, .. } => {
                let _ = writeln!(out, "  [{tag}] send -> {peer}: {chunks:?}");
            }
            crate::sched::program::Op::Recv { peer, chunks, reduce, .. } => {
                let verb = if *reduce { "recv+reduce" } else { "recv" };
                let _ = writeln!(out, "  [{tag}] {verb} <- {peer}: {chunks:?}");
            }
        }
    }
    out
}

/// Render the PAT broadcast tree for root offset 0 with the step at which
/// each edge executes — the single-tree view of Figs. 6–10.
pub fn render_pat_tree(n: usize, a: usize) -> String {
    let mut out = String::new();
    let a = pat::clamp_aggregation(n, a);
    let rounds = pat::rounds(n, a);
    let (log_steps, lin_steps) = pat::phase_counts(n, a);
    let _ = writeln!(
        out,
        "PAT tree, {n} ranks, aggregation {a}: {} steps ({log_steps} logarithmic + {lin_steps} linear)",
        rounds.len()
    );
    // step at which each offset receives its data (edge from parent).
    let mut recv_step = vec![usize::MAX; n];
    for (s, r) in rounds.iter().enumerate() {
        for &o in &r.offsets {
            let to = o + (1usize << r.dim);
            if to < n {
                recv_step[to] = s;
            }
        }
    }
    let t = FarFirstTree::new(n);
    // Depth-first print.
    fn dfs(
        t: &FarFirstTree,
        o: usize,
        depth: usize,
        recv_step: &[usize],
        rounds: &[pat::PatRound],
        out: &mut String,
    ) {
        let indent = "  ".repeat(depth);
        if o == 0 {
            let _ = writeln!(out, "{indent}offset 0 (root)");
        } else {
            let s = recv_step[o];
            let phase = match rounds[s].phase {
                StepPhase::Logarithmic => "log",
                StepPhase::Linear => "lin",
            };
            let _ = writeln!(
                out,
                "{indent}offset {o:<3} <- {:<3} dim {} step {s} [{phase}]",
                t.parent(o),
                t.edge_dim(o)
            );
        }
        for c in t.children(o) {
            dfs(t, c, depth + 1, recv_step, rounds, out);
        }
    }
    dfs(&t, 0, 0, &recv_step, &rounds, &mut out);
    out
}

/// Human name of a hierarchical phase slug, per collective orientation
/// (the mirror reverses direction: gathers become scatters and
/// broadcasts become reductions).
fn hier_phase_name(slug: &str, coll: Collective) -> &'static str {
    let forward = matches!(coll, Collective::AllGather | Collective::AllReduce);
    match (slug, forward) {
        ("intra_gather", true) => "intra-node gather",
        ("intra_gather", false) => "intra-node scatter",
        ("intra_bcast", true) => "intra-node bcast",
        ("intra_bcast", false) => "intra-node fan-in",
        ("inter_pipeline", true) => "inter-node PAT + fan-out",
        ("inter_pipeline", false) => "fan-in + inter-node PAT reduce",
        ("pod_pipeline", true) => "intra-pod PAT + fan-out",
        ("pod_pipeline", false) => "fan-in + intra-pod PAT reduce",
        ("fabric_pipeline", true) => "inter-pod PAT + fan-out",
        ("fabric_pipeline", false) => "fan-in + inter-pod PAT reduce",
        _ => "phase",
    }
}

/// Render the phase structure of a hierarchical program: the step span,
/// message count and chunk traffic of each phase in
/// [`hier::phase_list`] — intra-node gather/bcast plus one pipelined
/// PAT+fan-out span per hierarchy level (mirrored names and reversed
/// order for reduce-scatter).
pub fn render_hier_phases(p: &Program, pl: &Placement, a: usize) -> String {
    let mut phases = hier::phase_list(pl, a);
    if matches!(p.collective, Collective::ReduceScatter) {
        phases.reverse();
    }
    // Cumulative step bounds; the final phase absorbs any unoccupied grid
    // tail (uneven pods can leave trailing slots empty).
    let mut bounds = vec![0usize];
    for ph in &phases {
        bounds.push((bounds.last().unwrap() + ph.steps).min(p.steps));
    }
    *bounds.last_mut().unwrap() = p.steps;
    let nph = phases.len();
    let mut msgs = vec![0usize; nph];
    let mut chunks = vec![0usize; nph];
    let mut cross = vec![0usize; nph];
    for m in p.messages() {
        let phase = (0..nph)
            .find(|&i| m.step < bounds[i + 1])
            .unwrap_or(nph - 1);
        msgs[phase] += 1;
        chunks[phase] += m.chunks.len();
        if pl.node_of(m.src) != pl.node_of(m.dst) {
            cross[phase] += 1;
        }
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{} / {} — {} ({} ranks): {} steps in {} phases",
        p.algorithm,
        p.collective,
        pl.describe(),
        p.nranks,
        p.steps,
        nph
    );
    for i in 0..nph {
        let _ = writeln!(
            out,
            "  phase {} {:<30} steps {:>3}..{:<3} msgs {:>5} chunk-transfers {:>6} cross-node {:>5}",
            i + 1,
            hier_phase_name(phases[i].name, p.collective),
            bounds[i],
            bounds[i + 1],
            msgs[i],
            chunks[i],
            cross[i]
        );
    }
    out
}

/// Render the pipeline structure of a composed all-reduce program: one
/// line per (segment, phase) with its step span, message count and chunk
/// traffic. Adjacent lines sharing a step range are the pipelining overlap
/// (segment i's all-gather running alongside segment i+1's
/// reduce-scatter).
pub fn render_compose_phases(p: &Program, layout: &crate::sched::compose::Layout) -> String {
    use crate::sched::compose::Phase;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{} / {} on {} ranks — {} steps, {} segment(s) (rs {} + ag {} steps each)",
        p.algorithm,
        p.collective,
        p.nranks,
        p.steps,
        layout.segments,
        layout.rs_steps,
        layout.ag_steps
    );
    let nseg = layout.segments;
    let mut msgs = vec![[0usize; 2]; nseg];
    let mut chunks = vec![[0usize; 2]; nseg];
    for m in p.messages() {
        let Some(&c0) = m.chunks.first() else { continue };
        let (seg, phase) = layout.classify(m.step, c0);
        let pi = match phase {
            Phase::ReduceScatter => 0,
            Phase::AllGather => 1,
        };
        msgs[seg][pi] += 1;
        chunks[seg][pi] += m.chunks.len();
    }
    for seg in 0..nseg {
        for (pi, phase) in [Phase::ReduceScatter, Phase::AllGather].into_iter().enumerate() {
            let (lo, hi) = layout.span(seg, phase);
            let _ = writeln!(
                out,
                "  seg {seg} {:<14} steps {:>4}..{:<4} msgs {:>6} chunk-transfers {:>7}",
                phase.as_str(),
                lo,
                hi,
                msgs[seg][pi],
                chunks[seg][pi]
            );
        }
    }
    out
}

/// Render the per-root binomial-tree decomposition (Fig. 2 / Fig. 4): for
/// each root rank, the tree its chunk follows.
pub fn render_root_trees(p: &Program) -> String {
    let mut out = String::new();
    let n = p.nranks;
    let _ = writeln!(out, "{}: per-root broadcast trees", p.algorithm);
    for root in 0..n {
        let _ = writeln!(out, "root {root}:");
        // Collect the (src, dst, step) edges carrying this root's chunk.
        let mut edges: Vec<(usize, usize, usize)> = Vec::new();
        for m in p.messages() {
            if m.chunks.contains(&root) {
                edges.push((m.src, m.dst, m.step));
            }
        }
        edges.sort_by_key(|e| e.2);
        for (src, dst, step) in edges {
            let _ = writeln!(out, "  step {step}: {src} -> {dst}");
        }
    }
    out
}

/// Distance around the ring (minimum of the two directions) — the "how far
/// does this transfer travel" metric of the paper's discussion.
pub fn ring_distance(a: usize, b: usize, n: usize) -> usize {
    let d = (b + n - a) % n;
    d.min(n - d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::{bruck, pat};

    #[test]
    fn render_steps_has_all_steps() {
        let p = pat::allgather(8, 2);
        let s = render_steps(&p);
        for step in 0..4 {
            assert!(s.contains(&format!("step {step}:")), "missing step {step}\n{s}");
        }
    }

    #[test]
    fn render_tree_mentions_phases() {
        let s = render_pat_tree(8, 2);
        assert!(s.contains("1 logarithmic + 3 linear"), "{s}");
        assert!(s.contains("offset 4"), "{s}");
    }

    #[test]
    fn root_trees_cover_all_roots() {
        let p = bruck::allgather_near_first(4);
        let s = render_root_trees(&p);
        for r in 0..4 {
            assert!(s.contains(&format!("root {r}:")));
        }
    }

    #[test]
    fn ring_distance_wraps() {
        assert_eq!(ring_distance(7, 0, 8), 1);
        assert_eq!(ring_distance(0, 4, 8), 4);
        assert_eq!(ring_distance(2, 1, 8), 1);
    }

    #[test]
    fn render_rank_lists_ops() {
        let p = pat::allgather(4, 1);
        let s = render_rank(&p, 0);
        assert!(s.contains("send ->"));
        assert!(s.contains("recv <-"));
    }

    /// Multi-channel programs render a channel column; single-channel
    /// output keeps the pre-channel (golden) format.
    #[test]
    fn render_channel_column() {
        let base = pat::allgather(8, 2);
        let single = render_steps(&base);
        assert!(!single.contains(" ch "), "{single}");
        let split = crate::sched::channel::split(&base, 2).unwrap();
        let s = render_steps(&split);
        assert!(s.contains("2 channels"), "{s}");
        assert!(s.contains(" ch  0"), "{s}");
        assert!(s.contains(" ch  1"), "{s}");
        let r = render_rank(&split, 0);
        assert!(r.contains("/c1]"), "{r}");
    }

    #[test]
    fn render_compose_lists_every_segment_phase() {
        use crate::sched::compose::{self, Layout};
        let rs = pat::reduce_scatter(8, 2);
        let ag = crate::sched::ring::allgather(8);
        let p = compose::fuse(&rs, &ag, 2).unwrap();
        let layout = Layout::of(&rs, &ag, 2);
        let s = render_compose_phases(&p, &layout);
        assert!(s.contains("2 segment(s)"), "{s}");
        assert!(s.contains("seg 0 reduce-scatter"), "{s}");
        assert!(s.contains("seg 0 all-gather"), "{s}");
        assert!(s.contains("seg 1 reduce-scatter"), "{s}");
        assert!(s.contains("seg 1 all-gather"), "{s}");
        // each phase moves n(n-1) = 56 chunks
        assert!(s.matches(" 56").count() >= 4, "{s}");
    }

    #[test]
    fn render_hier_phases_both_collectives() {
        let pl = Placement::uniform(13, 4).unwrap();
        let ag = crate::sched::hier::allgather(&pl, 2);
        let s = render_hier_phases(&ag, &pl, 2);
        assert!(s.contains("intra-node gather"), "{s}");
        assert!(s.contains("inter-node PAT + fan-out"), "{s}");
        assert!(s.contains("sizes=[4, 4, 4, 1]"), "{s}");
        let rs = crate::sched::hier::reduce_scatter(&pl, 2);
        let s = render_hier_phases(&rs, &pl, 2);
        assert!(s.contains("intra-node fan-in"), "{s}");
        assert!(s.contains("intra-node scatter"), "{s}");
        assert!(s.contains("inter-node PAT reduce"), "{s}");
        // three-level programs render four phases
        let pl = Placement::parse("4x2", 32).unwrap();
        let ag = crate::sched::hier::allgather(&pl, 2);
        let s = render_hier_phases(&ag, &pl, 2);
        assert!(s.contains("4 phases"), "{s}");
        assert!(s.contains("intra-pod PAT + fan-out"), "{s}");
        assert!(s.contains("inter-pod PAT + fan-out"), "{s}");
    }
}
