//! The transport arena: one contiguous, page-aligned allocation per
//! communicator backing the whole datapath — wire messages, staging
//! slots, and accumulators — so the steady-state hot path performs
//! **zero** heap allocations per operation.
//!
//! Layout (computed per run by the engine, see
//! [`crate::transport::engine`]): the accumulator/staging slot grid
//! comes first (one region of `slots × slot_elems` per rank), followed
//! by one single-use wire region per `Send` op. Because every wire
//! region is dedicated to exactly one message, send/recv exchange plain
//! `(offset, len)` descriptors over the mpsc channels and the receiver
//! reads the payload directly out of the arena — no owned `Vec<f32>`
//! ever crosses a wire, and no recycling protocol can starve (the
//! pitfall that sank an earlier buffer-stealing variant).
//!
//! Safety model: the engine hands out **disjoint** `(offset, len)`
//! regions — slot leases and wire regions never overlap — and the mpsc
//! `send`/`recv` pair provides the happens-before edge between the
//! writer finishing a wire region and the reader first touching it.
//! [`ArenaCache`] guards the one remaining aliasing hazard (two
//! concurrent runs on one communicator) with a busy flag: the second
//! run gets a private arena instead of a shared one.

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ptr::NonNull;
use std::sync::{Arc, Mutex};

use crate::core::{Error, Result};

/// Arena alignment in bytes — one page, so the grid starts
/// cache-line- and page-aligned regardless of allocator behavior.
pub const ARENA_ALIGN: usize = 4096;

/// A fixed-size, page-aligned `f32` arena. Regions are addressed by
/// `(offset, len)` descriptors; disjointness of live regions is the
/// engine's responsibility (see the module docs for the safety model).
#[derive(Debug)]
pub struct Arena {
    ptr: NonNull<f32>,
    elems: usize,
}

// SAFETY: the engine only hands out disjoint (offset, len) regions to
// different threads, and cross-thread handoff of a region always rides
// an mpsc send/recv pair, which provides the necessary happens-before
// edge. The arena itself is plain memory with no interior state.
unsafe impl Send for Arena {}
unsafe impl Sync for Arena {}

impl Arena {
    /// Allocate a zeroed arena of `elems` f32 slots. A zero-element
    /// arena allocates nothing (all valid descriptors are `(0, 0)`).
    pub fn new(elems: usize) -> Result<Arena> {
        if elems == 0 {
            return Ok(Arena { ptr: NonNull::dangling(), elems: 0 });
        }
        let layout = Layout::from_size_align(elems * 4, ARENA_ALIGN)
            .map_err(|e| Error::Transport(format!("arena layout: {e}")))?;
        // SAFETY: layout has non-zero size (elems > 0).
        let raw = unsafe { alloc_zeroed(layout) };
        let ptr = NonNull::new(raw as *mut f32).ok_or_else(|| {
            Error::Transport(format!("arena allocation of {} bytes failed", elems * 4))
        })?;
        Ok(Arena { ptr, elems })
    }

    /// Number of f32 slots.
    pub fn elems(&self) -> usize {
        self.elems
    }

    /// Preallocated footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.elems * 4
    }

    /// Read a region.
    ///
    /// # Safety
    ///
    /// `off + len <= elems()`, and no live `&mut` region may overlap
    /// `(off, len)`. The engine guarantees both by handing out disjoint
    /// descriptors (module docs).
    pub unsafe fn slice(&self, off: usize, len: usize) -> &[f32] {
        debug_assert!(off + len <= self.elems, "arena read {off}+{len} > {}", self.elems);
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr().add(off), len) }
    }

    /// Mutably borrow a region.
    ///
    /// # Safety
    ///
    /// `off + len <= elems()`, and `(off, len)` must not overlap any
    /// other live region (shared or mutable). The engine guarantees
    /// this by handing out disjoint descriptors (module docs).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, off: usize, len: usize) -> &mut [f32] {
        debug_assert!(off + len <= self.elems, "arena write {off}+{len} > {}", self.elems);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr().add(off), len) }
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        if self.elems > 0 {
            // SAFETY: allocated in `new` with this exact layout.
            unsafe {
                let layout = Layout::from_size_align_unchecked(self.elems * 4, ARENA_ALIGN);
                dealloc(self.ptr.as_ptr() as *mut u8, layout);
            }
        }
    }
}

#[derive(Debug, Default)]
struct CacheInner {
    arena: Option<Arc<Arena>>,
    /// A run currently holds a lease on the cached arena. While set,
    /// `checkout` builds private arenas so two concurrent runs on one
    /// communicator can never alias the shared grid.
    busy: bool,
}

/// Per-communicator arena cache: the first run allocates, steady-state
/// runs of the same (or smaller) footprint reuse the allocation with
/// zero heap traffic. Clone shares the cache.
#[derive(Clone, Debug, Default)]
pub struct ArenaCache {
    inner: Arc<Mutex<CacheInner>>,
}

impl ArenaCache {
    pub fn new() -> ArenaCache {
        ArenaCache::default()
    }

    /// Lease an arena of at least `min_elems` slots. Reuses the cached
    /// arena when it is big enough and not already leased; otherwise
    /// allocates (publishing the new arena unless the cache is busy).
    /// `ArenaLease::fresh` says whether this checkout allocated.
    pub fn checkout(&self, min_elems: usize) -> Result<ArenaLease> {
        let mut inner = self.inner.lock().unwrap();
        if inner.busy {
            // A concurrent run holds the cached arena; do not alias it.
            return ArenaLease::private(Arena::new(min_elems)?);
        }
        if let Some(a) = &inner.arena {
            if a.elems() >= min_elems {
                inner.busy = true;
                return Ok(ArenaLease {
                    arena: a.clone(),
                    fresh: false,
                    cache: Some(self.inner.clone()),
                });
            }
        }
        let arena = Arc::new(Arena::new(min_elems)?);
        inner.arena = Some(arena.clone());
        inner.busy = true;
        Ok(ArenaLease { arena, fresh: true, cache: Some(self.inner.clone()) })
    }
}

/// An exclusive lease on an arena for the duration of one transport
/// run. Dropping the lease returns the arena to its cache (if any).
#[derive(Debug)]
pub struct ArenaLease {
    arena: Arc<Arena>,
    fresh: bool,
    cache: Option<Arc<Mutex<CacheInner>>>,
}

impl ArenaLease {
    /// A lease over a one-shot private arena (no cache behind it).
    pub fn private(arena: Arena) -> Result<ArenaLease> {
        Ok(ArenaLease { arena: Arc::new(arena), fresh: true, cache: None })
    }

    pub fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }

    /// Did this checkout allocate (true), or reuse a cached arena
    /// (false)? Steady state on a warm cache is `false`.
    pub fn fresh(&self) -> bool {
        self.fresh
    }
}

impl Drop for ArenaLease {
    fn drop(&mut self) {
        if let Some(cache) = &self.cache {
            cache.lock().unwrap().busy = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_zeroed_and_addressable() {
        let a = Arena::new(1024).unwrap();
        assert_eq!(a.elems(), 1024);
        assert_eq!(a.bytes(), 4096);
        // SAFETY: disjoint regions within bounds.
        unsafe {
            assert!(a.slice(0, 1024).iter().all(|&v| v == 0.0));
            a.slice_mut(10, 4).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
            assert_eq!(a.slice(10, 4), &[1.0, 2.0, 3.0, 4.0]);
            assert_eq!(a.slice(14, 1), &[0.0]);
        }
        // page alignment
        assert_eq!(unsafe { a.slice(0, 0) }.as_ptr() as usize % ARENA_ALIGN, 0);
    }

    #[test]
    fn zero_size_arena_is_valid() {
        let a = Arena::new(0).unwrap();
        assert_eq!(a.elems(), 0);
        assert_eq!(unsafe { a.slice(0, 0) }.len(), 0);
    }

    #[test]
    fn cache_reuses_when_big_enough() {
        let cache = ArenaCache::new();
        let first = cache.checkout(100).unwrap();
        assert!(first.fresh());
        let ptr = Arc::as_ptr(first.arena());
        drop(first);
        // same footprint: reused, no allocation
        let second = cache.checkout(100).unwrap();
        assert!(!second.fresh());
        assert_eq!(Arc::as_ptr(second.arena()), ptr);
        drop(second);
        // smaller footprint: still reused
        let third = cache.checkout(10).unwrap();
        assert!(!third.fresh());
        // bigger footprint: reallocated and republished
        drop(third);
        let fourth = cache.checkout(1000).unwrap();
        assert!(fourth.fresh());
        assert!(fourth.arena().elems() >= 1000);
        drop(fourth);
        let fifth = cache.checkout(1000).unwrap();
        assert!(!fifth.fresh());
    }

    #[test]
    fn concurrent_checkout_never_aliases() {
        let cache = ArenaCache::new();
        let first = cache.checkout(64).unwrap();
        // second concurrent lease must not share the busy arena
        let second = cache.checkout(64).unwrap();
        assert!(second.fresh());
        assert_ne!(Arc::as_ptr(first.arena()), Arc::as_ptr(second.arena()));
        drop(first);
        drop(second);
        // cache recovered: the published arena is leasable again
        let third = cache.checkout(64).unwrap();
        assert!(!third.fresh());
    }
}
